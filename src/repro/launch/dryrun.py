import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, and record memory/cost/collective analysis.

The two lines above MUST stay first (before any jax-importing import): jax
locks the device count at first init, and the dry-run needs 512 placeholder
CPU devices to build the 16x16 and 2x16x16 meshes. Nothing here allocates
device memory — inputs are ShapeDtypeStruct stand-ins (launch/specs.py) and
the artifact is the AOT-compiled executable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh pod            # single cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun               # the full 40-cell sweep
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, cell_applicable, shape_adapted_config
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_mod
from repro.models.config import SHAPES
from repro.roofline.hlo import collective_bytes
from repro.sharding import rules


def run_cell(arch: str, shape: str, multi_pod: bool,
             cfg_override=None) -> dict:
    """Lower + compile one cell; return the analysis record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules.set_mesh(mesh)
    try:
        cfg = cfg_override or shape_adapted_config(arch, shape)
        mode, inputs, shardings = specs_mod.cell_inputs(cfg, shape, mesh)
        step = specs_mod.step_fn_for(cfg, mode)

        t0 = time.perf_counter()
        jitted = jax.jit(step, in_shardings=shardings)
        lowered = jitted.lower(*inputs)
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        n_chips = mesh.devices.size
        record = {
            "arch": arch, "shape": shape, "mode": mode,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": n_chips,
            "status": "ok",
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
            "memory": {
                "argument_size": getattr(mem, "argument_size_in_bytes", 0),
                "output_size": getattr(mem, "output_size_in_bytes", 0),
                "temp_size": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_size": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            "collectives": coll,
        }
        return record
    finally:
        rules.set_mesh(None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        ok, reason = cell_applicable(arch, shape)
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip-done] {tag}", flush=True)
                    continue
            if not ok:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "skipped", "reason": reason}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[skipped ] {tag}: {reason}", flush=True)
                continue
            print(f"[compile ] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp)
                print(f"[ok      ] {tag}: compile {rec['compile_s']}s, "
                      f"flops/dev {rec['flops_per_device']:.3e}, "
                      f"coll {rec['collectives']['total_bytes']:.3e} B",
                      flush=True)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(f"[ERROR   ] {tag}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
