"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs(cfg, shape)` returns (abstract inputs, sharding specs) for the
step function the cell lowers:

  train_4k      train_step(state, batch)
  prefill_32k   prefill_step(params, batch)
  decode_32k /
  long_500k     serve_step(params, cache, tokens, t)

No device memory is allocated — everything is ShapeDtypeStruct, and the
parameter/optimizer trees come from jax.eval_shape over the real init.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import lm
from repro.models.config import ModelConfig, SHAPES
from repro.sharding import rules

SDS = jax.ShapeDtypeStruct


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    b = {"tokens": SDS((batch, seq + 1), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = SDS((batch, cfg.encoder_len, cfg.d_model),
                          jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        b["patches"] = SDS((batch, cfg.n_patches, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    return b


def _dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def batch_shardings(batch_tree: Any, mesh) -> Any:
    def spec(s):
        full = rules.batch_spec(len(s.shape))
        ax = full[0]
        axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        # drop trailing axes until the global batch divides (e.g. 256 on
        # pure_dp 2x16x16: (pod,data,model) -> (pod,data))
        while axes and s.shape[0] % _axis_size(mesh, axes) != 0:
            axes = axes[:-1]
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        return NamedSharding(mesh,
                             PartitionSpec(lead, *full[1:len(s.shape)]))

    return jax.tree.map(spec, batch_tree)


def state_struct(cfg: ModelConfig) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: lm.init_train_state(key, cfg))


def params_struct(cfg: ModelConfig) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: lm.model_init(key, cfg))


def cache_struct(cfg: ModelConfig, batch: int, seq: int) -> Any:
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, seq, jnp.dtype(cfg.dtype)))


def _axis_size(mesh, ax) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def _ns(mesh, spec_tree, like_tree=None):
    """NamedShardings; if `like_tree` given, drop specs whose sharded dims
    don't divide the actual shapes (replicate those dims instead)."""
    def one(spec, leaf=None):
        if leaf is not None:
            fixed = []
            for dim, ax in enumerate(spec):
                if ax is not None and \
                        leaf.shape[dim] % _axis_size(mesh, ax) != 0:
                    fixed.append(None)
                else:
                    fixed.append(ax)
            spec = PartitionSpec(*fixed)
        return NamedSharding(mesh, spec)
    if like_tree is None:
        return jax.tree.map(one, spec_tree,
                            is_leaf=lambda x: isinstance(x, PartitionSpec))
    return jax.tree.map(lambda s, lk: one(s, lk), spec_tree, like_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def cell_inputs(cfg: ModelConfig, shape_name: str, mesh
                ) -> Tuple[str, Tuple[Any, ...], Tuple[Any, ...]]:
    """-> (mode, abstract_inputs, input_shardings) for one cell."""
    sh = SHAPES[shape_name]
    # pure-DP applies to throughput modes WHEN the global batch saturates
    # the device count (otherwise dropping TP idles the model axis: measured
    # 16x per-device work on prefill_32k, batch 32 < 256 chips). Decode
    # keeps TP (ZeRO param gathers per emitted token would dominate
    # latency).
    rules.set_pure_dp(bool(getattr(cfg, "pure_dp", False))
                      and sh.mode != "decode"
                      and sh.global_batch % mesh.devices.size == 0)
    # per-device batch must divide the data axes; global batches are as
    # assigned (256 / 32 / 128 / 1). Batch 1 long-decode replicates over data.
    if sh.mode == "train":
        state = state_struct(cfg)
        batch = batch_struct(cfg, sh.global_batch, sh.seq_len)
        sst = _ns(mesh, rules.state_specs(state, fsdp=cfg.fsdp), state)
        bst = batch_shardings(batch, mesh)
        return "train", (state, batch), (sst, bst)
    if sh.mode == "prefill":
        params = params_struct(cfg)
        batch = batch_struct(cfg, sh.global_batch, sh.seq_len)
        pst = _ns(mesh, rules.param_specs(params, fsdp=cfg.fsdp), params)
        bst = batch_shardings(batch, mesh)
        return "prefill", (params, batch), (pst, bst)
    # decode
    params = params_struct(cfg)
    cache = cache_struct(cfg, sh.global_batch, sh.seq_len)
    toks = SDS((sh.global_batch, 1), jnp.int32)
    t = SDS((), jnp.int32)
    pst = _ns(mesh, rules.param_specs(params, fsdp=cfg.fsdp), params)
    shardable = sh.global_batch % _dp_size(mesh) == 0
    cst = _ns(mesh, rules.cache_specs(cache, batch_shardable=shardable))
    tst = (NamedSharding(mesh, rules.batch_spec(2)) if shardable
           else NamedSharding(mesh, PartitionSpec()))
    sst = NamedSharding(mesh, PartitionSpec())
    return "decode", (params, cache, toks, t), (pst, cst, tst, sst)


def step_fn_for(cfg: ModelConfig, mode: str, opt_cfg=None):
    from repro.optim.adamw import AdamWConfig
    if mode == "train":
        return lm.make_train_step(cfg, opt_cfg or AdamWConfig())
    if mode == "prefill":
        return lm.make_prefill_step(cfg)
    return lm.make_serve_step(cfg)
