"""Training driver CLI.

Examples (CPU container — reduced configs):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real pod the same entry point takes --mesh pod/multipod and the full
config; the step function, sharding rules and checkpoint layout are
identical (the dry-run proves the full-scale lowering).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenStream
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.train.loop import TrainLoopConfig, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model sizing)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    d_ff=int(args.d_model * 8 // 3 // 64 * 64))
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = cfg.replace(**over)
    cfg = cfg.replace(dtype="float32")     # CPU numerics

    sched = (wsd_schedule(args.lr, args.warmup, args.steps)
             if args.arch == "minicpm-2b"
             else cosine_schedule(args.lr, args.warmup, args.steps))
    opt = AdamWConfig(lr=sched)

    key = jax.random.PRNGKey(args.seed)
    state = lm.init_train_state(key, cfg)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M")

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    step_fn = jax.jit(lm.make_train_step(
        cfg, opt, microbatches=args.microbatches,
        compress=args.compress_grads))

    def batches(step):
        b = stream.batch_at(step)
        out = {"tokens": jnp.asarray(b["tokens"])}
        if cfg.family == "encdec":
            k = jax.random.fold_in(key, step)
            out["frames"] = jax.random.normal(
                k, (args.batch, cfg.encoder_len, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            k = jax.random.fold_in(key, step)
            out["patches"] = jax.random.normal(
                k, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        return out

    def log(step, metrics):
        print(f"step {step:5d}  loss {metrics['loss']:.4f}  "
              f"acc {metrics.get('accuracy', 0):.3f}  "
              f"gnorm {metrics.get('grad_norm', 0):.2f}", flush=True)

    loop_cfg = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every, metrics_hook=log,
                               log_every=10)
    t0 = time.time()
    state, report = train_loop(step_fn, state, batches, loop_cfg)
    dt = time.time() - t0
    print(f"done: steps {report.start_step}->{report.end_step} in {dt:.1f}s "
          f"({'restored' if report.restored else 'fresh'}), "
          f"final loss {report.losses[-1]:.4f}, "
          f"stragglers {report.stragglers}")
    return report


if __name__ == "__main__":
    main()
