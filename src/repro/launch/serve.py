"""Serving driver CLI: batched generation with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.serve.loop import Request, ServeConfig, generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(dtype="float32")
    if cfg.family == "encdec":
        raise SystemExit("whisper serving: use examples/whisper_asr.py")

    key = jax.random.PRNGKey(args.seed)
    params = lm.model_init(key, cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(4, args.prompt_len + 1)
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    outs = generate(params, cfg, reqs,
                    ServeConfig(batch=args.batch,
                                max_seq=args.prompt_len + args.max_new + 8))
    dt = time.time() - t0
    tokens = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: prompt[:4]={reqs[i].prompt[:4].tolist()} "
              f"-> {o[:8].tolist()}")
    return outs


if __name__ == "__main__":
    main()
