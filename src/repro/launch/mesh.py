"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS first; smoke tests see 1 CPU).

Axis semantics (DESIGN.md §4):
  pod    inter-pod data parallelism (DCN/ICI proxy links — the paper's
         inter-chip proxy units; gradient all-reduce crosses it once/step)
  data   intra-pod data parallelism (+ FSDP shard axis for big configs)
  model  tensor/expert parallelism (the paper's PSUM fan-in expansion)
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/elastic rescale (e.g. (4, 2) on 8 devices)."""
    return jax.make_mesh(shape, axes)


def describe(mesh: jax.sharding.Mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))}"
