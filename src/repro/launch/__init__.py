"""launch — mesh construction, multi-pod dry-run, train/serve drivers.

IMPORTANT: this package must stay import-side-effect-free (no jax import at
package level): `dryrun.py` sets XLA_FLAGS before the first jax import.
"""
