"""SNN layer library + the paper's three application models (§V-B3).

Models:
  srnn_ecg   — recurrent ALIF hidden layer + LIF readout (Yin et al. 2021),
               the ECG/QTDB task. `heterogeneous=False` gives the paper's
               'TaiBai-homogeneous' ablation (plain LIF everywhere).
  dhsnn_shd  — 700 -> 64 DH-LIF (4 dendritic branches) -> 20 LI readout
               (Zheng et al. 2024), the SHD speech task. The 4x700=2800
               fan-in exceeds TaiBai's 2048 limit, so the chip splits branch
               currents across PSUM neurons in one core (fan-in expansion);
               on TPU the same decomposition is the branch axis of the
               einsum (and, distributed, a TP partial-sum).
  bci_net    — 16 sub-paths of (linear transform, channel attention,
               temporal conv), Hadamard-product fusion, concat -> LIF ->
               fused BN1d+FC readout with accumulated-spike on-chip learning.

All are built on the events.py INTEG/FIRE engine.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import events
from repro.core.events import Connection
from repro.core.neuron import ALIF, DHLIF, LI, LIF, locacc
from repro.core.plasticity import SynapseProgram, accumulated_spike_fc
from repro.kernels.lif.ops import lif_scan

Array = jax.Array


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(n_in))
    return {"w": scale * jax.random.normal(key, (n_in, n_out), jnp.float32)}


# ---------------------------------------------------------------------------
# integrate functions (INTEG stage): spikes -> currents
# ---------------------------------------------------------------------------


def ff_integrate(params, feeds):
    """sum over inbound feeds of  s @ W_feed  (LOCACC)."""
    cur = 0.0
    for name, s in feeds.items():
        key = name.split("@")[0]
        cur = cur + locacc(s, params[f"w_{key}"])
    return cur


# The `hoist` tag tells the plan compiler (core/plan.py) this INTEG is the
# per-feed `s @ w_<src>` convention, so it can be lifted out of the time
# loop as one all-T spikemm per feed. Custom integrates opt in the same way.
ff_integrate.hoist = "ff"


def branch_integrate(params, feeds):
    """DH-LIF INTEG: input split over dendritic branches.

    w_input: (n_branches, n_in, n_out); current: (batch, n_branches, n_out).
    On chip each branch is a PSUM neuron (fan-in expansion, Fig. 11).
    """
    (src, s), = feeds.items()
    return jnp.einsum("bi,kio->bko", s, params["w_input"])


# The "branch" hoist convention: single feed, weights (n_branches, n_in,
# n_out) under the fixed key `w_input`. The plan compiler lifts the einsum
# out of the time loop as one spikemm against the (n_in, K*n_out) view.
branch_integrate.hoist = "branch"


# ---------------------------------------------------------------------------
# SRNN for ECG (QTDB)
# ---------------------------------------------------------------------------


def make_srnn_ecg(key, n_in=4, n_hidden=64, n_out=6, heterogeneous=True):
    """Returns (nodes, params). Input: level-crossing-coded ECG,
    (T=1301, batch, 4). Output: per-timestep band logits (membrane)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # sigmoid surrogate: ALIF's moving threshold needs a wide grad window
    # (a rectangle window dead-zones adapted neurons; alpha=4 keeps grads
    # alive across the threshold excursion range)
    hidden_neuron = (ALIF(surrogate="sigmoid", alpha=4.0, beta=0.5)
                     if heterogeneous else LIF(surrogate="sigmoid", alpha=4.0))
    nodes = [
        events.LayerNode("hidden", hidden_neuron, ff_integrate,
                         inputs=(Connection("input"), Connection("self")),
                         out_dim=n_hidden),
        events.LayerNode("readout", LI(tau=0.95), ff_integrate,
                         inputs=(Connection("hidden"),), out_dim=n_out),
    ]
    params = {
        "hidden": {"w_input": _dense_init(k1, n_in, n_hidden)["w"],
                   "w_self": 0.1 * jax.random.normal(k2, (n_hidden, n_hidden)),
                   "neuron": (hidden_neuron.param_init(k3, (n_hidden,))
                              if heterogeneous else None)},
        "readout": {"w_hidden": _dense_init(k4, n_hidden, n_out)["w"]},
    }
    return nodes, params


def make_plastic_ff(key, n_in=64, n_hidden=32, n_out=4,
                    rule: SynapseProgram = None, tau=0.8, v_th=0.6):
    """A 2-layer LIF stack whose input connection learns on-chip.

    The hidden layer's input edge carries `rule` (default: pair STDP), so
    under `plan.run` the weight `w_input` updates over every window — the
    fused `stdp_seq` lowering when the rule's structure matches, the
    per-step fallback otherwise. Used by the plasticity bench, the
    `stdp_online` example, and the synapse-plan tests.
    """
    from repro.core.plasticity import pair_stdp
    rule = rule if rule is not None else pair_stdp()
    k1, k2 = jax.random.split(key)
    nodes = [
        events.LayerNode("hidden", LIF(tau=tau, v_th=v_th), ff_integrate,
                         inputs=(Connection("input", plastic=rule),),
                         out_dim=n_hidden),
        events.LayerNode("readout", LI(tau=0.95), ff_integrate,
                         inputs=(Connection("hidden"),), out_dim=n_out),
    ]
    params = {
        "hidden": {"w_input": _dense_init(k1, n_in, n_hidden)["w"]},
        "readout": {"w_hidden": _dense_init(k2, n_hidden, n_out)["w"]},
    }
    return nodes, params


# ---------------------------------------------------------------------------
# DHSNN for SHD speech
# ---------------------------------------------------------------------------


def make_dhsnn_shd(key, n_in=700, n_hidden=64, n_out=20, n_branches=4,
                   dendritic=True):
    """The paper's speech model. `dendritic=False` = homogeneous ablation."""
    k1, k2, k3 = jax.random.split(key, 3)
    if dendritic:
        hidden = events.LayerNode(
            "hidden", DHLIF(n_branches=n_branches), branch_integrate,
            inputs=("input",), out_dim=n_hidden)
        w_in = (1.0 / jnp.sqrt(n_in)) * jax.random.normal(
            k1, (n_branches, n_in, n_hidden))
        hparams = {"w_input": w_in,
                   "neuron": DHLIF(n_branches=n_branches).param_init(
                       k2, (n_hidden,))}
    else:
        hidden = events.LayerNode("hidden", LIF(), ff_integrate,
                                  inputs=("input",), out_dim=n_hidden)
        hparams = {"w_input": _dense_init(k1, n_in, n_hidden)["w"]}
    nodes = [hidden,
             events.LayerNode("readout", LI(tau=0.97), ff_integrate,
                              inputs=("hidden",), out_dim=n_out)]
    params = {"hidden": hparams,
              "readout": {"w_hidden": _dense_init(k3, n_hidden, n_out)["w"]}}
    return nodes, params


# ---------------------------------------------------------------------------
# BCI cross-day decoder (16 sub-paths + on-chip learning)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BCIConfig:
    n_channels: int = 128       # M1 array channels
    n_steps: int = 50           # 20 ms windows
    n_paths: int = 16
    d_path: int = 32            # per-path feature width
    kernel_t: int = 5           # temporal conv width
    n_out: int = 4              # hand-movement classes


def bci_init(key, cfg: BCIConfig):
    keys = jax.random.split(key, 6)
    C, P, D = cfg.n_channels, cfg.n_paths, cfg.d_path
    s = 1.0 / jnp.sqrt(C)
    params = {
        "lin": s * jax.random.normal(keys[0], (P, C, D)),       # linear transform
        "attn": s * jax.random.normal(keys[1], (P, C, C)),      # channel attention
        "tconv": (1.0 / jnp.sqrt(cfg.kernel_t)) *
                 jax.random.normal(keys[2], (P, cfg.kernel_t, D)),
        # fused BN1d+FC readout (Fig. 9d): trained as the fused tensors
        "fc_w": (1.0 / jnp.sqrt(P * D)) *
                jax.random.normal(keys[3], (P * D, cfg.n_out)),
        "fc_b": jnp.zeros((cfg.n_out,)),
    }
    return params


def bci_forward(params, x, cfg: BCIConfig, lif=LIF(tau=0.8)):
    """x: (batch, n_channels, n_steps) filtered/binned neural signal.

    Sub-path: linear transform (x) channel attention (x) temporal conv,
    fused by Hadamard product + addition (paper §V-B3); concat across paths
    -> LIF over time -> accumulated-spike FC readout (on-chip-learnable).
    Returns logits (batch, n_out) and the spike record (T, batch, P*D).
    """
    B, C, T = x.shape
    # linear transform module: (B, P, T, D)
    lin = jnp.einsum("bct,pcd->bptd", x, params["lin"])
    # channel attention: softmax over channels, then project
    att = jax.nn.softmax(jnp.einsum("bct,pce->bpet", x, params["attn"]), axis=2)
    att = jnp.einsum("bpet,pcd->bptd", att * x[:, None], params["lin"])
    # temporal convolution along t (same-padded, depthwise over D)
    pad = cfg.kernel_t // 2
    lp = jnp.pad(lin, ((0, 0), (0, 0), (pad, cfg.kernel_t - 1 - pad), (0, 0)))
    idx = jnp.arange(T)[:, None] + jnp.arange(cfg.kernel_t)[None, :]
    tconv = jnp.einsum("bptkd,pkd->bptd", lp[:, :, idx], params["tconv"])
    # Hadamard fusion + addition
    fused = lin * att + tconv                                   # (B, P, T, D)
    feat = fused.transpose(2, 0, 1, 3).reshape(T, B, cfg.n_paths * cfg.d_path)
    # LIF over time — the fused kernel runs the whole (T, B, P*D) current
    # block in one launch (plan-path FIRE; currents are already all-T here)
    n_feat = cfg.n_paths * cfg.d_path
    v0 = jnp.zeros((B, n_feat), feat.dtype)
    spikes, _ = lif_scan(feat, jnp.full((n_feat,), lif.tau, jnp.float32), v0,
                         lif.v_th, lif.surrogate, lif.alpha)    # (T, B, P*D)
    logits = accumulated_spike_fc(spikes, params["fc_w"], params["fc_b"])
    return logits, spikes


def bci_finetune_fc(params, x_few, y_few, cfg: BCIConfig, lr=0.05, steps=20):
    """Cross-day on-chip learning (§V-B3): update ONLY the fused FC with
    accumulated-spike backprop on 32 samples."""

    def loss_fn(fc, x, y):
        p = dict(params, fc_w=fc["fc_w"], fc_b=fc["fc_b"])
        logits, _ = bci_forward(p, x, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    fc = {"fc_w": params["fc_w"], "fc_b": params["fc_b"]}

    def step(fc, _):
        loss, g = jax.value_and_grad(loss_fn)(fc, x_few, y_few)
        fc = jax.tree.map(lambda p, gg: p - lr * gg, fc, g)
        return fc, loss

    fc, losses = jax.lax.scan(step, fc, jnp.arange(steps))
    return dict(params, **fc), losses
