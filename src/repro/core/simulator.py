"""Behavioural chip simulator (paper §IV-C, §V-B: the paper's own energy,
power, and throughput numbers come from this component, not silicon).

Given (a) a model's per-layer spike statistics — measured from the actual
JAX run, not assumed — and (b) a Mapping from `core/mapping.py`, produce:

  SOPs          synaptic operations = sum_t sum_i s_i(t) * fanout_i
  packets       spike events x multicast replication (parallel-send aware)
  energy        SOPs x E_SOP + packets x hops x E_hop + static
  throughput    bounded by NoC bandwidth (322 GSE/s intra, 363 MSE/s inter)
  power         energy / time at the 500 MHz INTEG/FIRE schedule

Constants from Table III/IV: E_SOP = 2.61 pJ, chip power 1.83 W typical,
memory fraction 70.3% (Fig. 13c). The GPU comparator models an RTX 3090
(350 W TDP, 35.6 TFLOP/s fp16 dense) running the same network densely —
the paper's §V-B2 protocol ('record the power while the model is running').
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

# TaiBai constants (Table III / IV / Fig. 13)
E_SOP_PJ = 2.61               # energy per synaptic op
E_HOP_PJ = 1.1                # router energy per packet-hop (28 nm class)
STATIC_W = 0.20               # leakage + clock tree at 0.9 V
CHIP_POWER_W = 1.83           # typical total (Table III)
MEM_FRACTION = 0.703          # Fig. 13c power breakdown
CLOCK_HZ = 500e6
INTRA_SE_S = 322e9            # intra-chip spike events / s
INTER_SE_S = 363e6            # inter-chip spike events / s
PEAK_GSOPS = 528e9            # peak synaptic ops / s

# RTX 3090 comparator (§V-B2)
GPU_TDP_W = 350.0
GPU_FP16_FLOPS = 35.6e12
GPU_IDLE_W = 25.0
GPU_UTIL = 0.35               # achieved fraction of peak on small SNN batches


@dataclasses.dataclass
class LayerStats:
    """Per-layer activity measured from a model run."""

    name: str
    n_neurons: int
    fan_out: int               # synapses per firing neuron
    spike_rate: float          # mean spikes / neuron / timestep (0..1)
    dense_flops: float         # FLOPs a dense implementation would burn per timestep


@dataclasses.dataclass
class SimReport:
    sops: float
    packets: float
    hops_per_packet: float
    time_s: float
    energy_j: float
    power_w: float
    throughput_fps: float
    gpu_energy_j: float
    gpu_power_w: float
    gpu_fps: float
    efficiency_x: float        # (TaiBai FPS/W) / (GPU FPS/W)
    power_ratio_x: float

    def asdict(self):
        return dataclasses.asdict(self)


def spike_stats_from_records(records: Dict[str, np.ndarray],
                             fan_outs: Dict[str, int],
                             dense_flops: Dict[str, float]) -> List[LayerStats]:
    """records[name]: (T, batch, n) spike tensors recorded by events.run."""
    out = []
    for name, rec in records.items():
        rate = float(np.mean(rec != 0))
        out.append(LayerStats(name, rec.shape[-1], fan_outs[name], rate,
                              dense_flops[name]))
    return out


GPU_STEP_FLOOR_S = 30e-6      # per-timestep kernel-launch latency floor


def simulate(layers: Sequence[LayerStats], timesteps: int,
             hops_per_packet: float = 3.0, parallel_send: int = 4,
             inter_chip_fraction: float = 0.0,
             parallel_speedup: float = 1.0,
             replication: float = 1.0) -> SimReport:
    """Run the behavioural cost model for one inference of `timesteps` steps.

    parallel_speedup: compute-time divisor from spreading a population over
    more cores (the throughput-objective mapping);
    replication: average number of destination REGIONS each spike multicasts
    to — spreading a layer over more cores raises it (more packets, more
    energy: the Fig. 13e efficiency cost of throughput mode).
    """
    sops = 0.0
    packets = 0.0
    dense_flops = 0.0
    for L in layers:
        events = L.n_neurons * L.spike_rate * timesteps
        sops += events * L.fan_out
        # parallel-send: one event reaches `parallel_send` NCs as ONE packet
        # per region (multicast), not N point-to-point packets
        packets += events * max(1.0, L.fan_out / 256 / parallel_send)             * replication
        dense_flops += L.dense_flops * timesteps

    # time: compute bound vs NoC bound, whichever is slower
    t_compute = sops / PEAK_GSOPS / max(parallel_speedup, 1e-9)
    noc_bw = (1 - inter_chip_fraction) * INTRA_SE_S + inter_chip_fraction * INTER_SE_S
    t_noc = packets / noc_bw
    # INTEG->FIRE phase barriers: the compiler picks cycles/timestep from
    # model complexity (§IV-A); 4096 cycles is the applications' setting
    t_sync = timesteps / (CLOCK_HZ / 4096)
    time_s = max(t_compute, t_noc) + t_sync

    # E_SOP is the ALL-IN per-op energy (Table IV's metric, memory included
    # — Fig. 13c's 70.3% memory share is a breakdown of it, not an adder)
    dyn_e = (sops * E_SOP_PJ + packets * hops_per_packet * E_HOP_PJ) * 1e-12
    energy = dyn_e + STATIC_W * time_s
    power = energy / time_s
    fps = 1.0 / time_s

    # GPU comparator: dense tensor math, spike rate irrelevant (§V-C1);
    # small SNNs are kernel-launch-bound, hence the per-step latency floor
    gpu_compute_time = dense_flops / (GPU_FP16_FLOPS * GPU_UTIL)
    gpu_time = max(gpu_compute_time, timesteps * GPU_STEP_FLOOR_S)
    # launch-bound workloads leave the GPU mostly idle: power scales with
    # the fraction of time the SMs are actually busy
    util_frac = min(1.0, gpu_compute_time / max(gpu_time, 1e-12))
    gpu_power = GPU_IDLE_W + (GPU_TDP_W - GPU_IDLE_W) * 0.8 * max(util_frac, 0.05)
    gpu_energy = gpu_power * gpu_time
    gpu_fps = 1.0 / gpu_time

    eff = (fps / power) / (gpu_fps / gpu_power)
    return SimReport(sops, packets, hops_per_packet, time_s, energy, power,
                     fps, gpu_energy, gpu_power, gpu_fps, eff,
                     gpu_power / power)


def energy_per_sop(report: SimReport) -> float:
    """pJ/SOP achieved — Table IV's comparison metric."""
    return report.energy_j * 1e12 / max(report.sops, 1.0)
