"""Event-driven INTEG/FIRE execution engine (paper §IV-A, Fig. 10).

One SNN timestep on TaiBai = an INTEG phase (spike events drive current
accumulation at their destination cores; silent cores stay in RECV) followed
by a FIRE phase (membrane update, spike emission, and — for on-chip learning
— weight update). On TPU this becomes a `lax.scan` over timesteps where each
step is integrate -> fire; sparsity is exploited at block granularity by the
`spikemm` kernel instead of at word granularity by the NoC.

The engine runs a `Program`: an ordered list of `LayerNode`s whose
connections may be feed-forward, recurrent (previous-timestep spikes), or
skip (delayed delivery, Fig. 8c — implemented as a ring buffer of spike
tensors, exactly the chip's 'delayed-fire' neuron type).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One population of neurons + its inbound connections.

    integrate: (params, inputs: dict[str, Array]) -> current  (INTEG stage)
    neuron:    NeuronSpec                                      (FIRE stage)
    inputs:    names of source nodes ("input" = external spikes); a name
               suffixed with "@d" is a skip connection delayed by d steps;
               "self" = recurrent (previous timestep of this node).
    """

    name: str
    neuron: NeuronSpec
    integrate: Callable[[Dict[str, Any], Dict[str, Array]], Array]
    inputs: Tuple[str, ...] = ("input",)
    out_dim: int = 0


def _parse_src(src: str) -> Tuple[str, int]:
    if "@" in src:
        name, d = src.split("@")
        return name, int(d)
    return src, 0


def state_dtype(dtype) -> jnp.dtype:
    """Neuron state must be float: integer spike inputs (common for encoded
    datasets) would otherwise build integer membranes that truncate every
    DIFF step. Callers pass x.dtype; ints coerce to float32."""
    dtype = jnp.dtype(dtype)
    return dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.dtype(jnp.float32)


def init_state(nodes: List[LayerNode], batch: int, dtype=jnp.float32):
    """Neuron states + skip-delay ring buffers for every node."""
    dtype = state_dtype(dtype)
    state = {}
    max_delay: Dict[str, int] = {}
    for n in nodes:
        for src in n.inputs:
            name, d = _parse_src(src)
            if d:
                max_delay[name] = max(max_delay.get(name, 0), d)
    for n in nodes:
        s = n.neuron.init_state((batch, n.out_dim), dtype)
        s["out"] = jnp.zeros((batch, n.out_dim), dtype)  # last emitted spikes
        if n.name in max_delay:
            s["ring"] = jnp.zeros((max_delay[n.name], batch, n.out_dim), dtype)
        state[n.name] = s
    return state


def step(nodes: List[LayerNode], params: Dict[str, Any], state: Dict[str, Any],
         x_t: Array, ext: Optional[Dict[str, Array]] = None
         ) -> Tuple[Dict[str, Any], Array]:
    """One INTEG+FIRE timestep through all nodes (in order).

    `ext` maps raw input specifiers (e.g. "conv1", "conv1@2") to externally
    supplied per-timestep feeds — the plan compiler (`core/plan.py`) uses it
    to run a fallback *segment* of a Program whose remaining nodes were
    fused out of the time loop (their full-time outputs, delay-shifted as
    needed, arrive here one slice per step).
    """
    new_state = dict(state)
    emitted: Dict[str, Array] = {"input": x_t}
    for n in nodes:
        feeds = {}
        for src in n.inputs:
            name, d = _parse_src(src)
            if name == "self":
                feeds[src] = state[n.name]["out"]          # recurrent: t-1
            elif ext is not None and src in ext:
                feeds[src] = ext[src]                      # plan-fused source
            elif d:
                feeds[src] = state[name]["ring"][d - 1]    # delayed-fire
            elif name in emitted:
                feeds[src] = emitted[name]                 # same-timestep FF
            else:
                feeds[src] = state[name]["out"]            # not yet run: t-1
        current = n.integrate(params.get(n.name, {}), feeds)   # INTEG
        ns, s_out = n.neuron.fire(
            {k: v for k, v in state[n.name].items() if k not in ("out", "ring")},
            current, params.get(n.name, {}).get("neuron"))      # FIRE
        ns = dict(ns)
        ns["out"] = s_out
        if "ring" in state[n.name]:
            ring = state[n.name]["ring"]
            ns["ring"] = jnp.concatenate([s_out[None], ring[:-1]], axis=0)
        new_state[n.name] = ns
        emitted[n.name] = s_out
    return new_state, emitted[nodes[-1].name]


def run(nodes: List[LayerNode], params: Dict[str, Any], x: Array,
        state: Optional[Dict[str, Any]] = None, record: Tuple[str, ...] = ()):
    """Scan the INTEG/FIRE machine over time.

    x: (T, batch, n_in) input spikes (or floats — TaiBai NCs accept both).
    Returns (final_state, outputs (T, batch, n_out), recorded dict).
    """
    if state is None:
        state = init_state(nodes, x.shape[1], x.dtype)

    def body(st, x_t):
        st, out = step(nodes, params, st, x_t)
        rec = {r: st[r]["out"] for r in record}
        return st, (out, rec)

    final, (outs, recs) = jax.lax.scan(body, state, x)
    return final, outs, recs
