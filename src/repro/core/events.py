"""Event-driven INTEG/FIRE execution engine (paper §IV-A, Fig. 10).

One SNN timestep on TaiBai = an INTEG phase (spike events drive current
accumulation at their destination cores; silent cores stay in RECV) followed
by a FIRE phase (membrane update, spike emission, and — for on-chip learning
— weight update). On TPU this becomes a `lax.scan` over timesteps where each
step is integrate -> fire; sparsity is exploited at block granularity by the
`spikemm` kernel instead of at word granularity by the NoC.

The engine runs a `Program`: an ordered list of `LayerNode`s whose inbound
edges are first-class `Connection` objects — source, delay (skip/delayed
delivery, Fig. 8c — implemented as a ring buffer of spike tensors, exactly
the chip's 'delayed-fire' neuron type), the weight-parameter key, and an
optional `SynapseProgram` (core/plasticity.py) making the edge learnable
on-chip. The legacy string micro-syntax ("name", "name@d", "self") still
works everywhere: `Connection.parse` is the thin back-compat adapter, and
`LayerNode` normalizes mixed string/Connection input tuples at
construction.

The stepper itself is forward-only; plasticity executes at run granularity
in `core/plan.py` (fused `stdp_seq` lowering or the per-step fallback over
the realized spike trains — identical trajectories), with synapse state
carried here in `state[node]["syn:<conn>"]`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core.neuron import NeuronSpec

Array = jax.Array


def _parse_src(src: str) -> Tuple[str, int]:
    if "@" in src:
        name, d = src.split("@")
        return name, int(d)
    return src, 0


@dataclasses.dataclass(frozen=True)
class Connection:
    """One inbound edge of a LayerNode, first-class.

    src:     source node name; "input" = the external spike tensor;
             "self" = this node's own output at t-1 (recurrence).
    delay:   delayed-fire depth in timesteps (ring-buffered delivery).
    weight:  params key holding this edge's weight; "" = the canonical
             convention ("w_<src>", "w_self") that `ff_integrate` /
             `branch_integrate` resolve from the feed key. Overriding it
             (weight sharing, swapping in a learned tensor) is honored
             end to end: the plan compiler and the plasticity machinery
             read `weight_key`, and the stepper aliases the canonical key
             to the override for the integrate call, so `ff_integrate`
             picks it up unchanged.
    plastic: optional `SynapseProgram` (core/plasticity.py); the edge's
             weight then learns on-chip under `plan.run` and the updated
             tensor is published in `state[node]["syn:<key>"]["w"]`.
    topology: optional compressed connectivity for this edge — an
             `EncodedTopology` instance, or a string naming one inside
             `params[node]`. The edge then executes straight from the IE
             tables (type-2 FC through the dense/sparse spikemm channels,
             sparse/conv/pool through the `spikemm_gather` channel) and no
             dense weight tensor is read. Mutually exclusive with both
             `plastic` (tables are not learnable) and a `weight` override
             (there is no dense tensor to alias).
    """

    src: str
    delay: int = 0
    weight: str = ""
    plastic: Optional["SynapseProgram"] = None  # noqa: F821
    topology: Optional[Any] = None              # EncodedTopology | params key

    def __post_init__(self):
        if not self.src:
            raise ValueError("Connection needs a source name")
        if self.delay < 0:
            raise ValueError(f"negative delay {self.delay} on connection "
                             f"from {self.src!r}")
        if self.topology is not None:
            from repro.core.topology import EncodedTopology
            if not isinstance(self.topology, (str, EncodedTopology)):
                raise TypeError(
                    f"Connection.topology must be an EncodedTopology or a "
                    f"params key, got {type(self.topology).__name__}")
            if self.plastic is not None:
                raise ValueError(
                    f"connection from {self.src!r}: topology-backed edges "
                    "cannot be plastic (IE tables are static configuration)")
            if self.weight:
                raise ValueError(
                    f"connection from {self.src!r}: topology and a weight "
                    "override are mutually exclusive")
        if self.plastic is not None:
            from repro.core.plasticity import validate_synapse_program
            validate_synapse_program(self.plastic)

    @property
    def key(self) -> str:
        """The feed-dict key — identical to the legacy string spelling, so
        integrate callables written against the old API see the same dict."""
        return f"{self.src}@{self.delay}" if self.delay else self.src

    @property
    def weight_key(self) -> str:
        if self.weight:
            return self.weight
        return "w_self" if self.src == "self" else f"w_{self.src}"

    @classmethod
    def parse(cls, spec: Union[str, "Connection"]) -> "Connection":
        """Back-compat adapter: "name" / "name@d" / "self" -> Connection."""
        if isinstance(spec, cls):
            return spec
        name, d = _parse_src(spec)
        return cls(src=name, delay=d)

    @classmethod
    def from_topology(cls, src: str, topology: Any,
                      delay: Optional[int] = None) -> "Connection":
        """Edge backed by compressed connectivity. `delay` defaults to the
        topology's own skip delay (Fig. 8c delayed-fire) when it carries
        one, else 0."""
        if delay is None:
            meta = getattr(topology, "meta", None) or {}
            delay = int(meta.get("delay", 0)) \
                if getattr(topology, "kind", "") == "skip" else 0
        return cls(src=src, delay=delay, topology=topology)


def resolve_topology(conn: Connection, node_name: str,
                     params: Dict[str, Any]):
    """The EncodedTopology a connection executes through, or None.

    A string rides as a key into `params[node]` — the topology then lives
    with the rest of the node's parameters (it is a registered pytree leaf
    with no traced children, so jit treats it as static configuration)."""
    t = conn.topology
    if t is None:
        return None
    if isinstance(t, str):
        t = params.get(node_name, {}).get(t)
        if t is None:
            raise KeyError(
                f"node {node_name!r}: connection {conn.key!r} names topology "
                f"{conn.topology!r} but params[{node_name!r}] has no such "
                "entry")
    from repro.core.topology import EncodedTopology
    if not isinstance(t, EncodedTopology):
        raise TypeError(
            f"node {node_name!r}: params[{conn.topology!r}] is "
            f"{type(t).__name__}, expected EncodedTopology")
    return t


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One population of neurons + its inbound connections.

    integrate: (params, inputs: dict[str, Array]) -> current  (INTEG stage)
    neuron:    NeuronSpec                                      (FIRE stage)
    inputs:    inbound edges — `Connection` objects or legacy strings
               ("input" = external spikes, "name@d" = skip connection
               delayed by d steps, "self" = previous timestep of this
               node); mixed tuples are fine. Normalized at construction:
               `.connections` holds the Connection view, `.inputs` the
               equivalent feed keys.
    """

    name: str
    neuron: NeuronSpec
    integrate: Callable[[Dict[str, Any], Dict[str, Array]], Array]
    inputs: Tuple[Union[str, Connection], ...] = ("input",)
    out_dim: int = 0
    connections: Tuple[Connection, ...] = dataclasses.field(init=False)

    def __post_init__(self):
        conns = tuple(Connection.parse(s) for s in self.inputs)
        keys = [c.key for c in conns]
        if len(set(keys)) != len(keys):
            raise ValueError(f"node {self.name!r}: duplicate connection "
                             f"keys {keys}")
        canon = {}
        for c in conns:
            if c.weight and canon.setdefault(
                    "w_self" if c.src == "self" else f"w_{c.src}",
                    c.weight) != c.weight:
                # the ff convention shares one weight per source, so two
                # same-source edges cannot alias it to different tensors
                raise ValueError(f"node {self.name!r}: conflicting weight "
                                 f"overrides for source {c.src!r}")
        object.__setattr__(self, "connections", conns)
        object.__setattr__(self, "inputs", tuple(keys))


def state_dtype(dtype) -> jnp.dtype:
    """Neuron state must be float: integer spike inputs (common for encoded
    datasets) would otherwise build integer membranes that truncate every
    DIFF step. Callers pass x.dtype; ints coerce to float32."""
    dtype = jnp.dtype(dtype)
    return dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.dtype(jnp.float32)


def init_state(nodes: List[LayerNode], batch: int, dtype=jnp.float32,
               params: Optional[Dict[str, Any]] = None):
    """Neuron states, skip-delay ring buffers, and synapse (plasticity)
    state for every node. Plastic connections need `params` to seed the
    learned weight (trace shapes derive from it)."""
    dtype = state_dtype(dtype)
    state = {}
    max_delay: Dict[str, int] = {}
    for n in nodes:
        for c in n.connections:
            if c.delay:
                max_delay[c.src] = max(max_delay.get(c.src, 0), c.delay)
    for n in nodes:
        s = n.neuron.init_state((batch, n.out_dim), dtype)
        s["out"] = jnp.zeros((batch, n.out_dim), dtype)  # last emitted spikes
        if n.name in max_delay:
            s["ring"] = jnp.zeros((max_delay[n.name], batch, n.out_dim), dtype)
        for c in n.connections:
            if c.plastic is None:
                continue
            if params is None:
                raise ValueError(
                    f"node {n.name!r}: connection {c.key!r} is plastic; "
                    "init_state needs params=... to seed its weight")
            from repro.core.plasticity import synapse_init
            w = params[n.name][c.weight_key]
            s[f"syn:{c.key}"] = synapse_init(c.plastic, w, batch)
        state[n.name] = s
    return state


def _node_params(n: LayerNode, params: Dict[str, Any]) -> Dict[str, Any]:
    """Node params with custom `Connection.weight` keys aliased onto the
    canonical names, so the built-in integrate conventions (`w_<src>`,
    `w_self`) transparently pick up overridden/shared weight tensors.
    Topology-backed edges alias the canonical name to the EncodedTopology
    itself — `neuron.locacc` routes it through the compressed channels."""
    p = params.get(n.name, {})
    remap = {("w_self" if c.src == "self" else f"w_{c.src}"): c.weight
             for c in n.connections if c.weight}
    topos = {("w_self" if c.src == "self" else f"w_{c.src}"):
             resolve_topology(c, n.name, params)
             for c in n.connections if c.topology is not None}
    if remap or topos:
        p = dict(p)
        for canon, key in remap.items():
            p[canon] = p[key]
        p.update(topos)
    return p


def step(nodes: List[LayerNode], params: Dict[str, Any], state: Dict[str, Any],
         x_t: Array, ext: Optional[Dict[str, Array]] = None
         ) -> Tuple[Dict[str, Any], Array]:
    """One INTEG+FIRE timestep through all nodes (in order).

    `ext` maps feed keys (e.g. "conv1", "conv1@2") to externally supplied
    per-timestep feeds — the plan compiler (`core/plan.py`) uses it to run
    a fallback *segment* of a Program whose remaining nodes were fused out
    of the time loop (their full-time outputs, delay-shifted as needed,
    arrive here one slice per step). Synapse state rides through untouched
    (plasticity is a run-granularity pass, not a stepper concern).
    """
    new_state = dict(state)
    emitted: Dict[str, Array] = {"input": x_t}
    for n in nodes:
        feeds = {}
        for c in n.connections:
            if c.src == "self":
                feeds[c.key] = state[n.name]["out"]        # recurrent: t-1
            elif ext is not None and c.key in ext:
                feeds[c.key] = ext[c.key]                  # plan-fused source
            elif c.delay:
                feeds[c.key] = state[c.src]["ring"][c.delay - 1]  # delayed
            elif c.src in emitted:
                feeds[c.key] = emitted[c.src]              # same-timestep FF
            else:
                feeds[c.key] = state[c.src]["out"]         # not yet run: t-1
        current = n.integrate(_node_params(n, params), feeds)  # INTEG
        ns, s_out = n.neuron.fire(
            {k: v for k, v in state[n.name].items()
             if k not in ("out", "ring") and not k.startswith("syn:")},
            current, params.get(n.name, {}).get("neuron"))      # FIRE
        # injected dead/stuck neuron rows (repro.core.faults): the faulty
        # rows poison everything downstream of the emission — recurrence,
        # rings, same-timestep feeds — exactly like a dead core would
        s_out = faults.perturb_output(n.name, s_out)
        ns = dict(ns)
        ns["out"] = s_out
        if "ring" in state[n.name]:
            ring = state[n.name]["ring"]
            ns["ring"] = jnp.concatenate([s_out[None], ring[:-1]], axis=0)
        for k, v in state[n.name].items():
            if k.startswith("syn:"):
                ns[k] = v
        new_state[n.name] = ns
        emitted[n.name] = s_out
    return new_state, emitted[nodes[-1].name]


def run(nodes: List[LayerNode], params: Dict[str, Any], x: Array,
        state: Optional[Dict[str, Any]] = None, record: Tuple[str, ...] = ()):
    """Scan the INTEG/FIRE machine over time.

    x: (T, batch, n_in) input spikes (or floats — TaiBai NCs accept both).
    Returns (final_state, outputs (T, batch, n_out), recorded dict).
    """
    if state is None:
        state = init_state(nodes, x.shape[1], x.dtype, params)

    def body(st, x_t):
        st, out = step(nodes, params, st, x_t)
        rec = {r: st[r]["out"] for r in record}
        return st, (out, rec)

    final, (outs, recs) = jax.lax.scan(body, state, x)
    return final, outs, recs
