"""On-chip learning rules (paper §II-A, §IV-B, Fig. 9d-e).

Two families, both 'fully programmable' on TaiBai and both implemented here:

1. STDP — local, event-driven, unsupervised. Pre/post exponential traces
   (updated with the DIFF primitive) implement the classic pair-based rule:
   causal pairs potentiate, acausal pairs depress.

2. Accumulated-spike backprop — the paper's on-chip BPTT optimization for
   the BCI task: instead of storing per-timestep spikes for the backward
   pass (huge) or bitmap-compressing them (slow to decode), TaiBai
   *accumulates* spikes over time during the forward pass and uses the
   accumulated tensor in backward. For a readout stack of the paper's form
   (FC on spikes, loss on time-summed logits) the gradient w.r.t. the FC
   weight is EXACTLY dL/dW = delta @ (sum_t s_t)^T, so the approximation is
   lossless there — we implement it as a custom-VJP layer that saves only
   sum_t s_t (T x memory saving), and use it for the BCI cross-day
   fine-tuning exactly as §V-B3 does (32 samples, FC-only update).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.neuron import diff

Array = jax.Array


# ---------------------------------------------------------------------------
# STDP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class STDPConfig:
    a_plus: float = 0.01        # potentiation amplitude (causal,  dt > 0)
    a_minus: float = 0.012      # depression amplitude  (acausal, dt < 0)
    tau_plus: float = 0.9       # pre-trace decay  per timestep
    tau_minus: float = 0.9      # post-trace decay per timestep
    w_min: float = -1.0
    w_max: float = 1.0


def stdp_init(n_pre: int, n_post: int, batch: int = 1, dtype=jnp.float32):
    return {"x_pre": jnp.zeros((batch, n_pre), dtype),
            "x_post": jnp.zeros((batch, n_post), dtype)}


def stdp_step(cfg: STDPConfig, traces: Dict[str, Array], w: Array,
              s_pre: Array, s_post: Array,
              use_kernel: bool = False) -> Tuple[Dict[str, Array], Array]:
    """One event-driven STDP update.

    s_pre: (batch, n_pre) spikes at t;  s_post: (batch, n_post) spikes at t.
    On a post spike, potentiate by the presynaptic trace (recent causal pres);
    on a pre spike, depress by the postsynaptic trace (recent acausal posts).
    All terms are outer products of events with traces — exactly what the
    chip computes in the FIRE stage, batched here. `use_kernel` routes the
    weight update through the fused Pallas kernel (kernels/stdp): one
    HBM->VMEM->HBM pass over the weight tile per step.
    """
    x_pre = diff(traces["x_pre"], cfg.tau_plus, s_pre)     # DIFF drives traces
    x_post = diff(traces["x_post"], cfg.tau_minus, s_post)
    if use_kernel:
        from repro.kernels.stdp import stdp_update
        w = stdp_update(x_pre, s_post, s_pre, x_post, w,
                        a_plus=cfg.a_plus, a_minus=cfg.a_minus,
                        w_min=cfg.w_min, w_max=cfg.w_max, force_pallas=True)
    else:
        dw_pot = cfg.a_plus * jnp.einsum("bi,bj->ij", x_pre, s_post)
        dw_dep = cfg.a_minus * jnp.einsum("bi,bj->ij", s_pre, x_post)
        w = jnp.clip(w + dw_pot - dw_dep, cfg.w_min, cfg.w_max)
    return {"x_pre": x_pre, "x_post": x_post}, w


def stdp_run(cfg: STDPConfig, w: Array, pre_spikes: Array, post_spikes: Array):
    """Run STDP over a (T, batch, n) spike train pair; returns final weights."""
    traces = stdp_init(w.shape[0], w.shape[1], pre_spikes.shape[1],
                       pre_spikes.dtype)

    def body(carry, ts):
        traces, w = carry
        s_pre, s_post = ts
        traces, w = stdp_step(cfg, traces, w, s_pre, s_post)
        return (traces, w), None

    (traces, w), _ = jax.lax.scan(body, (traces, w), (pre_spikes, post_spikes))
    return w


# ---------------------------------------------------------------------------
# Accumulated-spike backprop (the paper's on-chip BPTT memory optimization)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def accumulated_spike_fc(spikes_t: Array, w: Array, b: Array) -> Array:
    """Time-summed FC readout: logits = (sum_t s_t) @ W + T*b.

    Forward is mathematically identical to sum_t (s_t @ W + b); backward
    stores ONLY the accumulated spikes (not the (T, B, N) history), which is
    the paper's on-chip learning trick. Input: (T, B, N). Output: (B, M).
    """
    acc = jnp.sum(spikes_t, axis=0)
    return acc @ w + spikes_t.shape[0] * b


def _asfc_fwd(spikes_t, w, b):
    acc = jnp.sum(spikes_t, axis=0)            # <- the only stored activation
    out = acc @ w + spikes_t.shape[0] * b
    return out, (acc, w, spikes_t.shape[0])


def _asfc_bwd(res, ct):
    acc, w, T = res
    d_acc = ct @ w.T                           # (B, N)
    dw = acc.T @ ct                            # exact: delta (x) sum_t s_t
    db = T * jnp.sum(ct, axis=0)
    # upstream sees the gradient spread uniformly over time (the accumulated
    # approximation of §IV-B: 'accumulated spikes are used instead of
    # timestep-by-timestep spikes')
    d_spikes = jnp.broadcast_to(d_acc[None], (T,) + d_acc.shape)
    return d_spikes, dw, db


accumulated_spike_fc.defvjp(_asfc_fwd, _asfc_bwd)


def fuse_bn1d_fc(gamma, beta, mean, var, eps, w, b):
    """BN1d + FC fusion (paper Fig. 9d: 'fused weights'/'fused bias').

    y = ((x - mean)/sqrt(var+eps) * gamma + beta) @ W + b
      =  x @ W' + b'  with  W' = diag(gamma/std) W,  b' = (beta - mean*gamma/std) @ W + b
    """
    std = jnp.sqrt(var + eps)
    scale = gamma / std
    w_fused = scale[:, None] * w
    b_fused = (beta - mean * scale) @ w + b
    return w_fused, b_fused
