"""On-chip learning rules (paper §II-A, §IV-B, Fig. 9d-e).

TaiBai's second headline claim is that *synapses* are as programmable as
neurons: the same multi-granularity instruction set expresses synaptic
dynamics and on-chip learning. Mirroring `core/neuron.py::NeuronProgram`,
a learning rule here is a declarative `SynapseProgram`:

  * **traces** — `TraceVar`s, each one DIFF update
    ``trace' = decay * trace + scale * spikes(source)`` driven by the pre-
    or post-synaptic spike train (`update="after"` makes weight terms read
    the previous-step value, as triplet STDP's slow traces require);
  * **terms** — event-gated outer-product weight updates with signed
    amplitudes: ``dw += amp * prod(pre factors)^T prod(post factors)``,
    batch-summed, where a factor is ``"spikes"``, a trace name, or
    ``"mod"`` (the external modulator/reward plane — post side only);
  * **bounds** — per-step ``clip(w + dw, w_min, w_max)``.

One generic interpreter (`synapse_step` / `synapse_run`) executes any
valid program; pair STDP, triplet STDP, reward-modulated STDP, and the
paper's accumulated-spike rule are thin factories over programs, and
`register_synapse(name, factory)` opens the menu to user rules. Because
the rule is data, the execution-plan compiler (`core/plan.py`)
pattern-matches its structure and lowers matching programs to the fused
`stdp_seq` kernel family (trace DIFF hoisted through `linrec`, all T
outer-product updates applied with the weight tile VMEM-resident);
anything else runs through the parity-checked per-step fallback. Attach a
program to a `Connection(plastic=...)` (`core/events.py`) and learning
runs inside `plan.run`.

Semantics note (chunked-online): within one `run` window the forward pass
uses the entry weights; traces and weight updates integrate across the
window's realized spike trains, and the learned weight is published in
the returned state (`state[node]["syn:<conn>"]["w"]`). `apply_learned`
merges it back into params between chunks — exactly the granularity at
which the chip drains its FIRE-stage weight updates.

Also here, unchanged: the accumulated-spike *readout* implementation
(`accumulated_spike_fc`, the paper's on-chip BPTT memory optimization —
backward stores only sum_t s_t), used by the BCI cross-day fine-tuning
(§V-B3); the `accumulated_spike` SynapseProgram factory is its
connection-level, teacher-gated counterpart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.neuron import Decay, decay_array, diff

Array = jax.Array

_PSEUDO_FACTORS = ("spikes", "mod")


# ---------------------------------------------------------------------------
# the synapse-program IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceVar:
    """One DIFF synaptic trace: trace' = decay * trace + scale * spikes.

    source: "pre" (presynaptic spike train, shape (B, n_pre)) or "post"
            (this node's emitted spikes, (B, n_post)).
    update: "before" — weight terms read the freshly updated value (pair
            STDP's nearest-spike traces); "after" — terms read the
            previous-step value (triplet STDP's slow traces, which gate a
            spike *before* integrating it). The trajectory is identical;
            only what the terms observe differs.
    """

    name: str
    source: str
    decay: Decay
    scale: float = 1.0
    update: str = "before"


@dataclasses.dataclass(frozen=True)
class UpdateTerm:
    """One signed outer-product weight update, batch-summed:

        dw += amp * einsum("bi,bj->ij", prod(pre factors), prod(post factors))

    Factors multiply elementwise within a side. "spikes" is the side's
    spike train (making the term event-gated); a trace name reads that
    trace; "mod" (post side only) is the external modulator — the reward
    scalar of R-STDP or the per-neuron teaching signal of the
    accumulated-spike rule. With no modulator supplied at run time, "mod"
    factors evaluate to zero (no reward, no update).
    """

    amp: float
    pre: Tuple[str, ...] = ("spikes",)
    post: Tuple[str, ...] = ("spikes",)


@dataclasses.dataclass(frozen=True)
class SynapseProgram:
    """Declarative synaptic dynamics + learning rule for one Connection."""

    traces: Tuple[TraceVar, ...]
    terms: Tuple[UpdateTerm, ...]
    w_min: float = -1.0
    w_max: float = 1.0


def validate_synapse_program(prog: SynapseProgram) -> SynapseProgram:
    """Raise ValueError on a structurally invalid program; return it."""
    names = [t.name for t in prog.traces]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate trace names: {names}")
    by_name = {t.name: t for t in prog.traces}
    for tr in prog.traces:
        if tr.name in _PSEUDO_FACTORS + ("w",):
            raise ValueError(f"trace name {tr.name!r} is reserved")
        if tr.source not in ("pre", "post"):
            raise ValueError(f"trace {tr.name!r}: bad source {tr.source!r}")
        if tr.update not in ("before", "after"):
            raise ValueError(f"trace {tr.name!r}: bad update {tr.update!r}")
        if tr.decay.kind not in ("const", "learned"):
            raise ValueError(f"trace {tr.name!r}: bad decay kind "
                             f"{tr.decay.kind!r}")
        if tr.decay.kind == "learned" and not tr.decay.param:
            raise ValueError(f"trace {tr.name!r}: learned decay needs a "
                             "param name")
    if not prog.terms:
        raise ValueError("program needs at least one update term")
    for i, term in enumerate(prog.terms):
        if not math.isfinite(term.amp):
            raise ValueError(f"term {i}: non-finite amp {term.amp!r}")
        for side, factors in (("pre", term.pre), ("post", term.post)):
            if not factors:
                raise ValueError(f"term {i}: empty {side} factor list")
            for f in factors:
                if f == "spikes":
                    continue
                if f == "mod":
                    if side == "pre":
                        raise ValueError(f"term {i}: 'mod' is a post-side "
                                         "factor")
                    continue
                if f not in by_name:
                    raise ValueError(f"term {i}: unknown factor {f!r}")
                if by_name[f].source != side:
                    raise ValueError(f"term {i}: {side} factor {f!r} reads "
                                     f"a {by_name[f].source} trace")
    if not prog.w_min <= prog.w_max:
        raise ValueError(f"w_min {prog.w_min} > w_max {prog.w_max}")
    return prog


# ---------------------------------------------------------------------------
# the per-step reference interpreter
# ---------------------------------------------------------------------------


def synapse_init(prog: SynapseProgram, w: Array, batch: int) -> Dict[str, Array]:
    """Synapse state for one Connection: zero traces + the live weight.

    Trace shapes derive from the weight: pre traces are (batch, w.shape[0]),
    post traces (batch, w.shape[1]).
    """
    syn = {"w": w}
    for tr in prog.traces:
        n = w.shape[0] if tr.source == "pre" else w.shape[1]
        syn[tr.name] = jnp.zeros((batch, n), w.dtype)
    return syn


def mod_plane(mod: Optional[Array], batch: int, n_post: int,
              dtype) -> Array:
    """Broadcast a modulator signal to the (batch, n_post) term plane.

    Accepts None (-> zeros: no reward, no update), a scalar (global
    reward), (batch,) per-trial reward, or (batch, n_post) per-neuron
    teaching signal.
    """
    if mod is None:
        return jnp.zeros((batch, n_post), dtype)
    m = jnp.asarray(mod, dtype)
    if m.ndim == 1:
        m = m[:, None]
    return jnp.broadcast_to(m, (batch, n_post))


def synapse_step(prog: SynapseProgram, syn: Dict[str, Array],
                 s_pre: Array, s_post: Array, mod: Optional[Array] = None,
                 params: Optional[Dict[str, Array]] = None
                 ) -> Dict[str, Array]:
    """One event-driven step of a SynapseProgram — the lowering oracle.

    s_pre: (B, n_pre) delivered presynaptic spikes; s_post: (B, n_post)
    emitted spikes; syn: {"w": (n_pre, n_post), <trace>: (B, n)}. Phase
    order: traces integrate their DIFF update, then every term's outer
    product accumulates into the weight ("before" traces are read fresh,
    "after" traces at their pre-update value), then the bounds clip.
    """
    by_name = {t.name: t for t in prog.traces}
    old = {t.name: syn[t.name] for t in prog.traces}
    new: Dict[str, Array] = {}
    for tr in prog.traces:
        drive = s_pre if tr.source == "pre" else s_post
        tau = decay_array(tr.decay, params, drive.dtype)
        new[tr.name] = diff(old[tr.name], tau, tr.scale * drive)

    mod_p = mod_plane(mod, s_post.shape[0], s_post.shape[1], s_post.dtype)

    def side(factors, spikes):
        val = None
        for f in factors:
            if f == "spikes":
                v = spikes
            elif f == "mod":
                v = mod_p
            else:
                v = new[f] if by_name[f].update == "before" else old[f]
            val = v if val is None else val * v
        return val

    w = syn["w"]
    dw = jnp.zeros_like(w)
    for term in prog.terms:
        p = side(term.pre, s_pre)
        q = side(term.post, s_post)
        dw = dw + term.amp * jnp.einsum("bi,bj->ij", p, q)
    out = dict(new)
    out["w"] = jnp.clip(w + dw, prog.w_min, prog.w_max)
    return out


def synapse_run(prog: SynapseProgram, w: Array, pre_spikes: Array,
                post_spikes: Array, mod: Optional[Array] = None,
                params: Optional[Dict[str, Array]] = None,
                syn: Optional[Dict[str, Array]] = None) -> Dict[str, Array]:
    """Scan `synapse_step` over (T, B, n) spike-train pairs.

    The per-step reference the fused plan lowering is parity-checked
    against. `mod`, if given, is (T,), (T, B), or (T, B, n_post). Returns
    the final synapse state (learned weight + final traces).
    """
    if syn is None:
        syn = synapse_init(prog, w, pre_spikes.shape[1])

    def body(syn, ts):
        s_pre, s_post, m = ts
        return synapse_step(prog, syn, s_pre, s_post, m, params), None

    T = pre_spikes.shape[0]
    if mod is None:
        mod_ts = jnp.zeros((T, 1), pre_spikes.dtype)
    else:
        mod_ts = jnp.asarray(mod)
        if mod_ts.ndim == 1:
            mod_ts = mod_ts[:, None]
    syn, _ = jax.lax.scan(body, syn, (pre_spikes, post_spikes, mod_ts))
    return syn


# ---------------------------------------------------------------------------
# built-in rule factories (all thin programs; all plan-lowerable)
# ---------------------------------------------------------------------------


def pair_stdp(a_plus: float = 0.01, a_minus: float = 0.012,
              tau_plus: float = 0.9, tau_minus: float = 0.9,
              w_min: float = -1.0, w_max: float = 1.0) -> SynapseProgram:
    """Classic pair-based STDP: causal pairs potentiate, acausal depress.

    On a post spike, potentiate by the presynaptic trace (recent causal
    pres); on a pre spike, depress by the postsynaptic trace. Numerically
    identical to the legacy `stdp_step` loop.
    """
    return validate_synapse_program(SynapseProgram(
        traces=(TraceVar("x_pre", "pre", Decay("const", tau_plus)),
                TraceVar("x_post", "post", Decay("const", tau_minus))),
        terms=(UpdateTerm(a_plus, pre=("x_pre",), post=("spikes",)),
               UpdateTerm(-a_minus, pre=("spikes",), post=("x_post",)),),
        w_min=w_min, w_max=w_max))


def triplet_stdp(a2_plus: float = 0.006, a3_plus: float = 0.006,
                 a2_minus: float = 0.007, a3_minus: float = 0.002,
                 tau_plus: float = 0.9, tau_x: float = 0.95,
                 tau_minus: float = 0.9, tau_y: float = 0.97,
                 w_min: float = -1.0, w_max: float = 1.0) -> SynapseProgram:
    """Triplet STDP (Pfister & Gerstner 2006, all-to-all).

    Fast traces (r1 pre, o1 post) implement the pair terms; slow traces
    (r2 pre, o2 post) are read at their *previous-step* value
    (`update="after"`) and gate the triplet interactions — LTP grows with
    recent post activity, LTD with recent pre activity.
    """
    return validate_synapse_program(SynapseProgram(
        traces=(TraceVar("r1", "pre", Decay("const", tau_plus)),
                TraceVar("r2", "pre", Decay("const", tau_x), update="after"),
                TraceVar("o1", "post", Decay("const", tau_minus)),
                TraceVar("o2", "post", Decay("const", tau_y), update="after")),
        terms=(UpdateTerm(a2_plus, pre=("r1",), post=("spikes",)),
               UpdateTerm(a3_plus, pre=("r1",), post=("spikes", "o2")),
               UpdateTerm(-a2_minus, pre=("spikes",), post=("o1",)),
               UpdateTerm(-a3_minus, pre=("spikes", "r2"), post=("o1",)),),
        w_min=w_min, w_max=w_max))


def reward_stdp(a_plus: float = 0.01, a_minus: float = 0.012,
                tau_plus: float = 0.9, tau_minus: float = 0.9,
                w_min: float = -1.0, w_max: float = 1.0) -> SynapseProgram:
    """Reward-modulated STDP: the pair rule gated by the modulator.

    Every term carries the "mod" factor, so dw = r_t * dw_pair; with no
    reward signal supplied the weights stay frozen. Feed `mod` as a (T,)
    global reward or (T, B) per-trial reward to `plan.run(mod=...)`.
    """
    return validate_synapse_program(SynapseProgram(
        traces=(TraceVar("x_pre", "pre", Decay("const", tau_plus)),
                TraceVar("x_post", "post", Decay("const", tau_minus))),
        terms=(UpdateTerm(a_plus, pre=("x_pre",), post=("spikes", "mod")),
               UpdateTerm(-a_minus, pre=("spikes",), post=("x_post", "mod")),),
        w_min=w_min, w_max=w_max))


def accumulated_spike(lr: float = 0.05, w_min: float = -float("inf"),
                      w_max: float = float("inf")) -> SynapseProgram:
    """The paper's accumulated-spike rule as a synapse program (§IV-B).

    A decay-1 trace accumulates presynaptic spikes over the window; the
    single term applies dw = lr * acc ⊗ mod, so supplying the per-neuron
    teaching signal (e.g. -dL/dlogits) on the final step reproduces the
    accumulated-spike FC update dW = lr * (sum_t s_t) ⊗ delta exactly —
    the connection-level counterpart of `accumulated_spike_fc`.
    """
    return validate_synapse_program(SynapseProgram(
        traces=(TraceVar("acc", "pre", Decay("const", 1.0)),),
        terms=(UpdateTerm(lr, pre=("acc",), post=("mod",)),),
        w_min=w_min, w_max=w_max))


SYNAPSE_REGISTRY: Dict[str, Callable[..., SynapseProgram]] = {
    "pair_stdp": pair_stdp,
    "triplet_stdp": triplet_stdp,
    "reward_stdp": reward_stdp,
    "accumulated_spike": accumulated_spike,
}


def register_synapse(name: str, factory: Callable[..., SynapseProgram], *,
                     override: bool = False
                     ) -> Callable[..., SynapseProgram]:
    """Open the synapse menu: name a factory returning a SynapseProgram so
    configs/CLIs can `make_synapse(name)` it. Duplicate names raise unless
    `override=True` (deliberate replacement)."""
    if not override and name in SYNAPSE_REGISTRY:
        raise ValueError(f"synapse rule {name!r} already registered "
                         f"({SYNAPSE_REGISTRY[name]!r}); pass override=True "
                         "to replace it")
    SYNAPSE_REGISTRY[name] = factory
    return factory


def make_synapse(name: str, **kwargs) -> SynapseProgram:
    if name not in SYNAPSE_REGISTRY:
        raise KeyError(f"unknown synapse rule {name!r}; registered: "
                       f"{sorted(SYNAPSE_REGISTRY)}")
    return SYNAPSE_REGISTRY[name](**kwargs)


def apply_learned(nodes, params: Dict[str, Any],
                  state: Dict[str, Any]) -> Dict[str, Any]:
    """Merge learned weights out of the run state back into params.

    For every plastic Connection, `state[node]["syn:<conn>"]["w"]` replaces
    `params[node][<weight key>]` — call between chunks to make the next
    window's forward pass see the updates (chunked-online semantics).
    """
    out = dict(params)
    for n in nodes:
        for c in n.connections:
            if c.plastic is None:
                continue
            syn = state.get(n.name, {}).get(f"syn:{c.key}")
            if syn is not None:
                out[n.name] = dict(out.get(n.name, {}))
                out[n.name][c.weight_key] = syn["w"]
    return out


# ---------------------------------------------------------------------------
# legacy pair-STDP API (kept: the hand-written loop the program replaces)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class STDPConfig:
    a_plus: float = 0.01        # potentiation amplitude (causal,  dt > 0)
    a_minus: float = 0.012      # depression amplitude  (acausal, dt < 0)
    tau_plus: float = 0.9       # pre-trace decay  per timestep
    tau_minus: float = 0.9      # post-trace decay per timestep
    w_min: float = -1.0
    w_max: float = 1.0

    @property
    def program(self) -> SynapseProgram:
        """The declarative equivalent of this config's hand-coded rule."""
        return pair_stdp(self.a_plus, self.a_minus, self.tau_plus,
                         self.tau_minus, self.w_min, self.w_max)


def stdp_init(n_pre: int, n_post: int, batch: int = 1, dtype=jnp.float32):
    return {"x_pre": jnp.zeros((batch, n_pre), dtype),
            "x_post": jnp.zeros((batch, n_post), dtype)}


def stdp_step(cfg: STDPConfig, traces: Dict[str, Array], w: Array,
              s_pre: Array, s_post: Array,
              use_kernel: bool = False) -> Tuple[Dict[str, Array], Array]:
    """One event-driven STDP update.

    s_pre: (batch, n_pre) spikes at t;  s_post: (batch, n_post) spikes at t.
    On a post spike, potentiate by the presynaptic trace (recent causal pres);
    on a pre spike, depress by the postsynaptic trace (recent acausal posts).
    All terms are outer products of events with traces — exactly what the
    chip computes in the FIRE stage, batched here. `use_kernel` routes the
    weight update through the fused Pallas kernel (kernels/stdp): one
    HBM->VMEM->HBM pass over the weight tile per step.
    """
    x_pre = diff(traces["x_pre"], cfg.tau_plus, s_pre)     # DIFF drives traces
    x_post = diff(traces["x_post"], cfg.tau_minus, s_post)
    if use_kernel:
        from repro.kernels.stdp import stdp_update
        w = stdp_update(x_pre, s_post, s_pre, x_post, w,
                        a_plus=cfg.a_plus, a_minus=cfg.a_minus,
                        w_min=cfg.w_min, w_max=cfg.w_max, force_pallas=True)
    else:
        dw_pot = cfg.a_plus * jnp.einsum("bi,bj->ij", x_pre, s_post)
        dw_dep = cfg.a_minus * jnp.einsum("bi,bj->ij", s_pre, x_post)
        w = jnp.clip(w + dw_pot - dw_dep, cfg.w_min, cfg.w_max)
    return {"x_pre": x_pre, "x_post": x_post}, w


def stdp_run(cfg: STDPConfig, w: Array, pre_spikes: Array, post_spikes: Array,
             use_kernel: bool = False):
    """Run STDP over a (T, batch, n) spike train pair; returns final weights.

    `use_kernel` is threaded through to every `stdp_step` (it used to be
    silently dropped by the scan body, so the fused kernel never ran).
    """
    traces = stdp_init(w.shape[0], w.shape[1], pre_spikes.shape[1],
                       pre_spikes.dtype)

    def body(carry, ts):
        traces, w = carry
        s_pre, s_post = ts
        traces, w = stdp_step(cfg, traces, w, s_pre, s_post,
                              use_kernel=use_kernel)
        return (traces, w), None

    (traces, w), _ = jax.lax.scan(body, (traces, w), (pre_spikes, post_spikes))
    return w


# ---------------------------------------------------------------------------
# Accumulated-spike backprop (the paper's on-chip BPTT memory optimization)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def accumulated_spike_fc(spikes_t: Array, w: Array, b: Array) -> Array:
    """Time-summed FC readout: logits = (sum_t s_t) @ W + T*b.

    Forward is mathematically identical to sum_t (s_t @ W + b); backward
    stores ONLY the accumulated spikes (not the (T, B, N) history), which is
    the paper's on-chip learning trick. Input: (T, B, N). Output: (B, M).
    """
    acc = jnp.sum(spikes_t, axis=0)
    return acc @ w + spikes_t.shape[0] * b


def _asfc_fwd(spikes_t, w, b):
    acc = jnp.sum(spikes_t, axis=0)            # <- the only stored activation
    out = acc @ w + spikes_t.shape[0] * b
    return out, (acc, w, spikes_t.shape[0])


def _asfc_bwd(res, ct):
    acc, w, T = res
    d_acc = ct @ w.T                           # (B, N)
    dw = acc.T @ ct                            # exact: delta (x) sum_t s_t
    db = T * jnp.sum(ct, axis=0)
    # upstream sees the gradient spread uniformly over time (the accumulated
    # approximation of §IV-B: 'accumulated spikes are used instead of
    # timestep-by-timestep spikes')
    d_spikes = jnp.broadcast_to(d_acc[None], (T,) + d_acc.shape)
    return d_spikes, dw, db


accumulated_spike_fc.defvjp(_asfc_fwd, _asfc_bwd)


def fuse_bn1d_fc(gamma, beta, mean, var, eps, w, b):
    """BN1d + FC fusion (paper Fig. 9d: 'fused weights'/'fused bias').

    y = ((x - mean)/sqrt(var+eps) * gamma + beta) @ W + b
      =  x @ W' + b'  with  W' = diag(gamma/std) W,  b' = (beta - mean*gamma/std) @ W + b
    """
    std = jnp.sqrt(var + eps)
    scale = gamma / std
    w_fused = scale[:, None] * w
    b_fused = (beta - mean * scale) @ w + b
    return w_fused, b_fused
