"""Compiler stack: network partition + core placement (paper §IV-C, Fig. 12).

Pipeline (matching the paper's four steps):
  1. operator IR + fusion     — `fuse_ops` (conv+BN -> conv, BN1d+FC -> FC)
  2. network partition        — `partition`: neurons -> cores in channel
                                order under per-core neuron/fan-in budgets
  3. placement + resource opt — `place_zigzag` initial placement, then
                                `optimize_placement` (greedy swaps or
                                simulated annealing) driven by the packet
                                cost model; `merge_cores` folds under-utilized
                                cores of compatible operators together
  4. codegen                  — on TaiBai, binaries; here, a `Mapping` the
                                behavioural simulator and the sharding layer
                                consume (population shard -> mesh coordinate).

The identical cost model drives pod-level placement: a "core" generalizes to
"chip x population shard" and hop distance to ICI hops on the TPU torus.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

# TaiBai hardware budgets (Table III, §IV-B)
CORE_NEURONS = 256            # neurons per NC (264K / 1056 NCs)
CORE_FANIN = 2048             # max fan-ins per neuron
GRID = (11, 12)               # CC array (132 CCs x 8 NCs)
NCS_PER_CC = 8
# Per-source-neuron fanout budget for the NoC link model: one CC's worth
# of downstream synapse slots. `repro.analysis.check_mapping` (TB405)
# flags sources whose average downstream synapse count per neuron exceeds
# it — the multicast the mesh would have to carry every timestep.
LINK_FANOUT = CORE_FANIN * NCS_PER_CC


@dataclasses.dataclass
class Op:
    """One operator-IR node after parsing a model front-end."""

    name: str
    kind: str                 # conv | fc | pool | bn | act | add
    n_neurons: int
    fan_in: int               # per-neuron fan-in
    inputs: Tuple[str, ...] = ()
    fused: Tuple[str, ...] = ()


def fuse_ops(ops: List[Op]) -> List[Op]:
    """Operator fusion: BN (and pool/activation bookkeeping) folds into the
    preceding conv/fc — paper Fig. 12b. Returns the optimized IR."""
    out: List[Op] = []
    by_name = {o.name: o for o in ops}
    consumed = set()
    for o in ops:
        if o.kind in ("bn", "act") and o.inputs:
            src = by_name.get(o.inputs[0])
            if src is not None and src.kind in ("conv", "fc"):
                src.fused = src.fused + (o.name,)
                consumed.add(o.name)
                # re-route consumers of the BN to the conv
                for q in ops:
                    q.inputs = tuple(src.name if i == o.name else i
                                     for i in q.inputs)
                continue
    for o in ops:
        if o.name not in consumed:
            out.append(o)
    return out


@dataclasses.dataclass
class CoreAssignment:
    op: str
    neuron_lo: int
    neuron_hi: int
    merged_with: List[str] = dataclasses.field(default_factory=list)


def partition(ops: List[Op], core_neurons: int = CORE_NEURONS,
              core_fanin: int = CORE_FANIN) -> List[CoreAssignment]:
    """Assign neurons to cores in channel order (Fig. 12c).

    Fan-in expansion: a neuron with fan-in F > core_fanin is decomposed into
    ceil(F / core_fanin) PSUM parts + 1 spiking part (paper Fig. 11); TaiBai
    keeps them in ONE core (intra-NC data path), so the per-core neuron
    budget is charged (parts) x (neurons) — we model exactly that.
    """
    cores: List[CoreAssignment] = []
    for op in ops:
        if op.kind in ("add",):
            continue                      # fused into destination cores (Fig. 8)
        parts = max(1, math.ceil(op.fan_in / core_fanin))
        effective = core_neurons // parts  # PSUM parts share the core
        n_cores = math.ceil(op.n_neurons / max(effective, 1))
        for c in range(n_cores):
            lo = c * effective
            hi = min(op.n_neurons, lo + effective)
            cores.append(CoreAssignment(op.name, lo, hi))
    return cores


def merge_cores(cores: List[CoreAssignment], ops: List[Op],
                core_neurons: int = CORE_NEURONS) -> List[CoreAssignment]:
    """Resource optimizer (Fig. 12d): merge under-utilized cores running the
    same operator *kind* at different layers (the paper's multi-network
    fusion gave 3.4x core reduction on the BCI app)."""
    kind_of = {o.name: o.kind for o in ops}
    merged: List[CoreAssignment] = []
    open_slots: Dict[str, CoreAssignment] = {}
    open_load: Dict[str, int] = {}
    for c in sorted(cores, key=lambda c: c.neuron_hi - c.neuron_lo):
        k = kind_of.get(c.op, "fc")
        size = c.neuron_hi - c.neuron_lo
        slot = open_slots.get(k)
        if slot is not None and open_load[k] + size <= core_neurons:
            slot.merged_with.append(c.op)
            open_load[k] += size
        else:
            nc = CoreAssignment(c.op, c.neuron_lo, c.neuron_hi)
            merged.append(nc)
            open_slots[k] = nc
            open_load[k] = size
    return merged


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------


def place_zigzag(n_cores: int, grid: Tuple[int, int] = GRID) -> np.ndarray:
    """Initial placement on the CC grid along a zigzag (boustrophedon) curve
    — consecutive cores stay adjacent, so feed-forward traffic is short.

    Networks larger than one chip spill onto additional chips laid out in a
    row (the paper's proxy-unit chip expansion, §IV-B): chip c occupies
    x in [c*W, (c+1)*W), so inter-chip traffic shows up as long hops —
    exactly the cost structure the placement optimizer should punish."""
    H, W = grid
    coords = []
    for y in range(H):
        xs = range(W) if y % 2 == 0 else range(W - 1, -1, -1)
        for x in xs:
            coords.append((y, x))
    cap = len(coords) * NCS_PER_CC
    out = []
    for i in range(n_cores):
        chip, local = divmod(i, cap)
        y, x = coords[local // NCS_PER_CC]
        out.append((y, x + chip * W))
    return np.asarray(out)


def traffic_cost(traffic: np.ndarray, pos: np.ndarray) -> float:
    """Sum over core pairs of packets x Manhattan hops (XY routing)."""
    d = np.abs(pos[:, None, :] - pos[None, :, :]).sum(-1)
    return float((traffic * d).sum())


def optimize_placement(traffic: np.ndarray, grid: Tuple[int, int] = GRID,
                       iters: int = 2000, seed: int = 0,
                       method: str = "anneal") -> Tuple[np.ndarray, float]:
    """Greedy / simulated-annealing placement refinement (Fig. 12d).

    traffic[i, j] = packets from core i to core j (from the behavioural
    simulator). Swap deltas are computed incrementally (O(n) per proposal,
    not O(n^2)). Returns (positions, cost)."""
    n = traffic.shape[0]
    pos = place_zigzag(n, grid)
    rng = np.random.default_rng(seed)
    sym = (traffic + traffic.T).astype(np.float64)   # undirected hop cost
    np.fill_diagonal(sym, 0.0)
    cost = 0.5 * float((sym * np.abs(
        pos[:, None, :] - pos[None, :, :]).sum(-1)).sum()) if n <= 2048         else traffic_cost(traffic, pos)
    t0 = max(cost / max(n, 1), 1e-9)

    def delta_swap(i, j):
        """Cost change if cores i and j swap positions."""
        di = np.abs(pos - pos[i]).sum(1)             # (n,) hops to pos_i
        dj = np.abs(pos - pos[j]).sum(1)
        ti, tj = sym[i].copy(), sym[j].copy()
        ti[j] = tj[i] = 0.0                          # i<->j unchanged by swap
        ti[i] = tj[j] = 0.0
        before = ti @ di + tj @ dj
        after = ti @ dj + tj @ di
        return after - before

    for it in range(iters):
        i, j = rng.integers(0, n, 2)
        if i == j:
            continue
        d = delta_swap(i, j)
        accept = d < 0
        if method == "anneal" and not accept:
            temp = t0 * (1.0 - it / iters) + 1e-12
            accept = rng.random() < math.exp(min(-d / temp, 0.0))
        if accept:
            pos[[i, j]] = pos[[j, i]]
            cost += d
    return pos, cost


@dataclasses.dataclass
class Mapping:
    """Final artifact: cores, positions, and objective telemetry."""

    cores: List[CoreAssignment]
    positions: np.ndarray
    cost: float
    meta: Dict = dataclasses.field(default_factory=dict)


MAX_PLACE_NODES = 512


def compile_network(ops: List[Op], traffic_fn=None, objective: str = "cores",
                    grid: Tuple[int, int] = GRID, seed: int = 0,
                    anneal_iters: int = 1000) -> Mapping:
    """End-to-end: fuse -> partition -> (merge) -> place -> optimize.

    objective: 'cores' minimizes core count (merge aggressively, as the
    paper's application deployments do); 'throughput' skips merging and
    spreads populations (more parallel-send width, more cores) — the Fig.
    13e trade-off.

    Networks with more cores than MAX_PLACE_NODES are COARSENED for the
    placement search: consecutive cores (already adjacent after zigzag)
    group into placement clusters; the optimizer moves clusters, every core
    inherits its cluster's position. Standard VLSI-placer clustering — keeps
    the SA search O(clusters^2) independent of network size.
    """
    ir = fuse_ops([dataclasses.replace(o) for o in ops])
    if objective == "throughput":
        # spread: halve the effective per-core population to widen parallelism
        cores = partition(ir, core_neurons=CORE_NEURONS // 4)
    else:
        cores = merge_cores(partition(ir), ir)
    n = len(cores)
    g = max(1, -(-n // MAX_PLACE_NODES))             # cores per cluster
    groups = [cores[i:i + g] for i in range(0, n, g)]
    if traffic_fn is not None:
        traffic = traffic_fn(groups)
    else:
        traffic = _default_traffic(groups, ir)
    # clusters of g cores occupy g NC slots -> effective grid unchanged;
    # place clusters on a grid scaled so capacity still fits
    pos_g, cost = optimize_placement(traffic, grid, iters=anneal_iters,
                                     seed=seed)
    pos = np.repeat(pos_g, [len(gr) for gr in groups], axis=0)
    return Mapping(cores, pos, cost,
                   meta={"objective": objective, "n_cores": n,
                         "n_clusters": len(groups)})


def _group_index(groups: List[List[CoreAssignment]]) -> Dict[str, List[int]]:
    idx_of: Dict[str, List[int]] = {}
    for gi, group in enumerate(groups):
        for c in group:
            idx_of.setdefault(c.op, []).append(gi)
    return idx_of


def _default_traffic(groups: List[List[CoreAssignment]],
                     ops: List[Op]) -> np.ndarray:
    """Feed-forward traffic estimate at cluster granularity: packets ∝
    source population size flowing to clusters of consumer ops."""
    idx_of = _group_index(groups)
    sizes = np.array([sum(c.neuron_hi - c.neuron_lo for c in g)
                      for g in groups], np.float64)
    consumers: Dict[str, List[str]] = {}
    for o in ops:
        for src in o.inputs:
            consumers.setdefault(src, []).append(o.name)
    n = len(groups)
    t = np.zeros((n, n))
    for o in ops:
        src_idx = sorted(set(idx_of.get(o.name, ())))
        if not src_idx:
            continue
        for dst_op in consumers.get(o.name, ()):
            dst_idx = sorted(set(idx_of.get(dst_op, ())))
            if not dst_idx:
                continue
            t[np.ix_(src_idx, dst_idx)] += (sizes[src_idx, None]
                                            / len(dst_idx))
    return t
