"""Hierarchical network-topology representation (paper §III-D, Figs. 4-8).

TaiBai stores connectivity in two 2-level tables:

  fan-out:  fired-neuron ID ->  Directory Entry (DE) -> Information Entries
            (IEs) carrying routing targets + the *global axon ID*
  fan-in :  (tag, index) from the packet -> DE -> typed IEs resolving the
            *target neurons* and the *weight address*

Four fan-in IE types specialize the encoding per connection pattern:

  type 0  sparse/pool:  IE = target-neuron IDs; weight found from the global
          axon ID through a bitmap (FINDIDX) — smallest storage.
  type 1  sparse (high-throughput): IE = (neuron ID, local axon ID) pairs —
          weight address is direct, no bitmap decode latency.
  type 2  fully connected: 4 fields (coding mask, margin, n_accum, start ID)
          represent *all* destination neurons by incremental addressing;
          the coding mask implements the parallel-send mechanism.
  type 3  convolution: decoupled weight addressing
              w_addr = axon_global * k^2 + axon_local        (paper eq. 4)
          where axon_global = upstream channel ID (from the fan-out DE) and
          axon_local = position of the tap inside the k x k filter. IE count
          scales with single-channel spatial positions, NOT with channels.

  skip connections reuse the fan-out DT with a delayed-fire neuron type
  (Fig. 8c) instead of relay neurons.

Everything here is an exact, executable software model: `storage_bits()`
reproduces the Fig. 14 accounting; `propagate()` is the event-driven
reference semantics used by the behavioural simulator and the tests (it must
agree with dense matmul / conv2d on the same weights).

Field widths (parameterizable, defaults sized for the TaiBai chip):
  neuron ID 18 b (264K neurons), core ID 10 b (1056 NCs), local axon 11 b
  (2K fan-in limit), global axon 16 b, coding mask 8 b (NCs per CC),
  margin/count 12 b.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

# ---------------------------------------------------------------------------
# Field widths (bits)
# ---------------------------------------------------------------------------

BITS = dict(
    neuron_id=18,
    core_id=10,
    local_axon=11,
    global_axon=16,
    coding_mask=8,
    margin=12,
    count=12,
    route=22,      # destination region (x0,y0,x1,y1) + mode for fan-out IEs
    tag=6,
    type=2,
    delay=4,       # delayed-fire slots for skip connections
)


@dataclasses.dataclass
class FanInIE:
    """One fan-in information entry (typed)."""

    ie_type: int
    # type 0: targets; type 1: (targets, local_axons); type 2: (start, count,
    # stride/margin, coding_mask); type 3: (targets, local_axons) for ONE
    # channel + replication mask.
    targets: np.ndarray
    local_axons: Optional[np.ndarray] = None
    start: int = 0
    count: int = 0
    margin: int = 1
    coding_mask: int = 0xFF

    def storage_bits(self) -> int:
        if self.ie_type == 0:
            return len(self.targets) * BITS["neuron_id"]
        if self.ie_type == 1:
            return len(self.targets) * (BITS["neuron_id"] + BITS["local_axon"])
        if self.ie_type == 2:
            # coding, margin, number of accumulations, starting neuron ID
            return (BITS["coding_mask"] + BITS["margin"] + BITS["count"]
                    + BITS["neuron_id"])
        if self.ie_type == 3:
            # mask, numbers, neuron ID + local axon ID per single-channel tap
            return (BITS["coding_mask"] + BITS["count"]
                    + len(self.targets) * (BITS["neuron_id"] + BITS["local_axon"]))
        raise ValueError(self.ie_type)


@dataclasses.dataclass
class FanInDE:
    """Fan-in directory entry: tag + pointer into the IT."""

    tag: int
    ie_type: int
    ies: List[FanInIE]

    def storage_bits(self) -> int:
        de = BITS["tag"] + BITS["type"] + 2 * BITS["count"]  # start+len pointer
        return de + sum(ie.storage_bits() for ie in self.ies)


@dataclasses.dataclass
class FanOutEntry:
    """Fan-out DE + IEs for one (source neuron | source channel)."""

    global_axon: int
    routes: int = 1            # IEs: destination regions (multicast rectangles)
    delayed: bool = False      # skip-connection delayed-fire flag (Fig. 8c)

    def storage_bits(self) -> int:
        de = BITS["global_axon"] + BITS["type"] + 2 * BITS["count"]
        ie = self.routes * BITS["route"]
        if self.delayed:
            ie += BITS["delay"]
        return de + ie


# ---------------------------------------------------------------------------
# Encoded layer = the pair of tables + enough metadata to execute it
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class EncodedTopology:
    """Fan-in + fan-out tables for one connection (layer), executable.

    Instances compare and hash by identity (eq=False): they ride inside jit
    closures and params pytrees as *static* leaves, so they need a stable
    hash, and ndarray fields make field-wise equality ill-defined anyway.
    """

    kind: str                                  # fc | conv | sparse | pool | skip
    n_pre: int
    n_post: int
    fan_in: List[FanInDE]
    fan_out: List[FanOutEntry]
    weights: Optional[np.ndarray] = None       # packed weights (layout per kind)
    meta: Dict = dataclasses.field(default_factory=dict)

    # -- storage ------------------------------------------------------------
    def fan_in_bits(self) -> int:
        return sum(de.storage_bits() for de in self.fan_in)

    def fan_out_bits(self) -> int:
        return sum(e.storage_bits() for e in self.fan_out)

    def storage_bits(self) -> int:
        return self.fan_in_bits() + self.fan_out_bits()

    # -- baseline: fully-connected unrolled mode (Fig. 14 leftmost bars) -----
    def baseline_bits(self) -> int:
        """Every (pre, post) connection stored explicitly as
        (target neuron ID + axon ID) — the 'fully connected unfolded mode'."""
        n_conn = self.meta.get("n_connections")
        if n_conn is None:
            raise ValueError("encoder must record n_connections")
        return n_conn * (BITS["neuron_id"] + BITS["local_axon"])

    # -- execution (event-driven reference semantics) -------------------------
    def propagate(self, spikes: np.ndarray) -> np.ndarray:
        """Event-driven propagation: iterate fired neurons, resolve their
        fan-out axon, look up fan-in IEs, accumulate currents. Must equal the
        dense/conv reference on the same weights. `spikes`: (n_pre,) 0/1."""
        raise NotImplementedError  # overridden per kind by the encoders

    def dense_equivalent(self) -> np.ndarray:
        """(n_pre, n_post) dense weight matrix these tables encode."""
        raise NotImplementedError

    # -- execution (jax lowerings; the plan compiler consumes these) ---------
    @property
    def shape(self) -> Tuple[int, int]:
        """(n_pre, n_post): lets topology-backed connections stand in for a
        dense weight tensor anywhere shapes are inspected."""
        return (self.n_pre, self.n_post)

    def exec_channel(self) -> str:
        """'dense' routes through the existing spikemm channels (type-2 FC);
        'gather' routes IE tables through the block-gather spikemm family."""
        return "gather"

    def coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(pre, post, weight) triples derived from the IE tables — never by
        materializing `dense_equivalent()`. Duplicated (pre, post) entries
        accumulate, matching `propagate()`."""
        raise NotImplementedError

    def lowering(self, bk: Optional[int] = None, bn: Optional[int] = None):
        """Block-gather tables for the `spikemm_gather` kernel family, built
        once from `coo()` and cached on the instance."""
        from repro.kernels.spikemm import gather as _g
        cached = getattr(self, "_gather_tables", None)
        if cached is not None and (bk is None or cached.bk == bk) \
                and (bn is None or cached.bn == bn):
            return cached
        pre, post, w = self.coo()
        tables = _g.build_gather_tables(
            pre, post, w, self.n_pre, self.n_post,
            bk=bk or _g.DEFAULT_BK, bn=bn or _g.DEFAULT_BN)
        object.__setattr__(self, "_gather_tables", tables)
        return tables

    def apply_spikes(self, x):
        """jax-executable matmul-equivalent: (M, n_pre) -> (M, n_post).

        FC (type-2 IEs) routes to the dense/sparse `spikemm` channels on its
        incremental-addressed weight matrix; sparse/conv/pool IE tables route
        to the `spikemm_gather` channel without a dense materialization.
        """
        if self.exec_channel() == "dense":
            from repro.kernels.spikemm.ops import spikemm
            import jax.numpy as jnp
            return spikemm(x, jnp.asarray(self.weights))
        from repro.kernels.spikemm.gather import spikemm_gather
        return spikemm_gather(x, self.lowering())


# ---------------------------------------------------------------------------
# Encoders
# ---------------------------------------------------------------------------


class _FC(EncodedTopology):
    def propagate(self, spikes):
        w = self.weights                              # (n_pre, n_post)
        out = np.zeros(self.n_post, w.dtype)
        for pre in np.flatnonzero(spikes):
            de = self.fan_in[0]
            for ie in de.ies:
                # incremental addressing: start + i*margin, i in [0, count)
                idx = ie.start + ie.margin * np.arange(ie.count)
                out[idx] += w[pre, idx]
        return out

    def dense_equivalent(self):
        return self.weights

    def exec_channel(self):
        return "dense"


def _build_fc(weights: np.ndarray, n_cores: int = 1) -> EncodedTopology:
    """Type-2 IE: the whole fully-connected layer costs 4 fields per core
    partition (parallel-send distributes destination neurons over `n_cores`
    NCs — without the mechanism the fan-in table would replicate N times)."""
    n_pre, n_post = weights.shape
    per_core = math.ceil(n_post / n_cores)
    ies = []
    for c in range(n_cores):
        start = c * per_core
        cnt = min(per_core, n_post - start)
        if cnt <= 0:
            break
        ies.append(FanInIE(ie_type=2, targets=np.empty(0, np.int64),
                           start=start, count=cnt, margin=1,
                           coding_mask=(1 << c) & 0xFF))
    # Parallel-send: ONE DE whose IEs fan to all cores in parallel.
    fan_in = [FanInDE(tag=0, ie_type=2, ies=ies)]
    fan_out = [FanOutEntry(global_axon=i) for i in range(n_pre)]
    return _FC("fc", n_pre, n_post, fan_in, fan_out, weights,
               meta={"n_connections": n_pre * n_post, "n_cores": n_cores})


class _Conv(EncodedTopology):
    def propagate(self, spikes):
        m = self.meta
        h, w_, cin, cout, k, s, p = (m["h"], m["w"], m["c_in"], m["c_out"],
                                     m["k"], m["stride"], m["pad"])
        ho, wo = m["h_out"], m["w_out"]
        filt = self.weights                            # (cout, cin, k, k)
        out = np.zeros(cout * ho * wo, filt.dtype)
        fired = np.flatnonzero(spikes)
        for pre in fired:
            ch = pre // (h * w_)                       # fan-out DE: global axon = channel
            pos = pre % (h * w_)
            de = self.fan_in[pos]                      # IE count ∝ single-channel positions
            for ie in de.ies:
                for t, ax_local in zip(ie.targets, ie.local_axons):
                    # eq. (4): w_addr = axon_global * k^2 + axon_local
                    w_addr = ch * k * k + ax_local
                    ky, kx = divmod(int(ax_local), k)
                    # same IE serves every output channel (replication mask)
                    for co in range(cout):
                        out[co * ho * wo + t] += filt[co, ch, ky, kx]
        return out

    def dense_equivalent(self):
        m = self.meta
        h, w_, cin, cout, k = m["h"], m["w"], m["c_in"], m["c_out"], m["k"]
        ho, wo = m["h_out"], m["w_out"]
        dense = np.zeros((cin * h * w_, cout * ho * wo), self.weights.dtype)
        eye = np.eye(cin * h * w_, dtype=self.weights.dtype)
        for i in range(cin * h * w_):
            dense[i] = self.propagate(eye[i])
        return dense

    def coo(self):
        m = self.meta
        h, w_, cin, cout, k = m["h"], m["w"], m["c_in"], m["c_out"], m["k"]
        ho, wo = m["h_out"], m["w_out"]
        pos_rep, t_all, ax_all = [], [], []
        for pos, de in enumerate(self.fan_in):
            for ie in de.ies:
                pos_rep.append(np.full(len(ie.targets), pos, np.int64))
                t_all.append(ie.targets)
                ax_all.append(ie.local_axons)
        pos_rep = np.concatenate(pos_rep) if pos_rep else np.empty(0, np.int64)
        t_all = np.concatenate(t_all) if t_all else np.empty(0, np.int64)
        ax_all = np.concatenate(ax_all) if ax_all else np.empty(0, np.int64)
        ky, kx = np.divmod(ax_all, k)
        # one single-channel IE serves every (c_in, c_out) pair (eq. 4):
        # replicate by axon arithmetic, weights straight from the filter bank.
        ci = np.arange(cin, dtype=np.int64)
        co = np.arange(cout, dtype=np.int64)
        full = (cout, cin, len(pos_rep))
        pre = np.broadcast_to(
            ci[None, :, None] * (h * w_) + pos_rep[None, None, :], full)
        post = np.broadcast_to(
            co[:, None, None] * (ho * wo) + t_all[None, None, :], full)
        w = self.weights[:, :, ky, kx]                  # (cout, cin, P)
        return (np.ascontiguousarray(pre).ravel(),
                np.ascontiguousarray(post).ravel().astype(np.int64),
                np.ascontiguousarray(w).ravel().astype(np.float32))


def _build_conv(filters: np.ndarray, h: int, w: int, stride: int = 1,
                pad: int = 0) -> EncodedTopology:
    """Type-3 IE with decoupled weight addressing (paper eq. 4).

    `filters`: (c_out, c_in, k, k). Fan-in IEs are built per *single-channel*
    spatial position; channels are resolved by global/local axon arithmetic,
    so storage is independent of (c_in x c_out) — this is the mechanism
    behind the paper's 286-947x reduction on conv nets.
    """
    c_out, c_in, k, _ = filters.shape
    h_out = (h + 2 * pad - k) // stride + 1
    w_out = (w + 2 * pad - k) // stride + 1
    fan_in: List[FanInDE] = []
    for pos in range(h * w):
        y, x = divmod(pos, w)
        targets, axons = [], []
        for ky in range(k):
            for kx in range(k):
                oy, ox = y + pad - ky, x + pad - kx
                if oy % stride or ox % stride:
                    continue
                oy, ox = oy // stride, ox // stride
                if 0 <= oy < h_out and 0 <= ox < w_out:
                    targets.append(oy * w_out + ox)     # single-channel target
                    axons.append(ky * k + kx)           # local axon = filter tap
        ie = FanInIE(ie_type=3, targets=np.asarray(targets, np.int64),
                     local_axons=np.asarray(axons, np.int64))
        fan_in.append(FanInDE(tag=0, ie_type=3, ies=[ie]))
    # fan-out: DE per presynaptic neuron; global axon = channel ID
    fan_out = [FanOutEntry(global_axon=i // (h * w)) for i in range(c_in * h * w)]
    n_conn = c_in * c_out * h_out * w_out * k * k
    return _Conv("conv", c_in * h * w, c_out * h_out * w_out, fan_in, fan_out,
                 filters, meta=dict(h=h, w=w, c_in=c_in, c_out=c_out, k=k,
                                    stride=stride, pad=pad, h_out=h_out,
                                    w_out=w_out, n_connections=n_conn))


class _Sparse(EncodedTopology):
    def propagate(self, spikes):
        out = np.zeros(self.n_post, self.weights.dtype)
        bitmap = self.meta["bitmap"]
        row_ptr = self.meta["row_ptr"]
        for pre in np.flatnonzero(spikes):
            de = self.fan_in[pre]
            for ie in de.ies:
                if ie.ie_type == 1:
                    out[ie.targets] += self.weights[ie.local_axons]
                else:  # type 0: FINDIDX — bitmap prefix decode
                    row = bitmap[pre]
                    packed = self.weights[row_ptr[pre]:row_ptr[pre + 1]]
                    out[np.flatnonzero(row)] += packed
        return out

    def dense_equivalent(self):
        dense = np.zeros((self.n_pre, self.n_post), self.weights.dtype)
        bitmap, row_ptr = self.meta["bitmap"], self.meta["row_ptr"]
        for pre in range(self.n_pre):
            cols = np.flatnonzero(bitmap[pre])
            dense[pre, cols] = self.weights[row_ptr[pre]:row_ptr[pre + 1]]
        return dense

    def coo(self):
        # bitmap rows in row-major order match the packed-weight order the
        # encoder wrote, for both IE types (type 1 local axons index it, type
        # 0 FINDIDX prefix-decodes it).
        rows, cols = np.nonzero(self.meta["bitmap"])
        return (rows.astype(np.int64), cols.astype(np.int64),
                np.asarray(self.weights, np.float32))


def _build_sparse(dense: np.ndarray, ie_type: int = 1) -> EncodedTopology:
    """Sparse connection. ie_type 0 = bitmap/FINDIDX (min storage);
    ie_type 1 = explicit (neuron, axon) pairs (min decode latency)."""
    assert ie_type in (0, 1)
    n_pre, n_post = dense.shape
    bitmap = (dense != 0).astype(np.int8)
    packed, row_ptr = [], [0]
    fan_in = []
    for pre in range(n_pre):
        cols = np.flatnonzero(bitmap[pre])
        base = row_ptr[-1]
        packed.extend(dense[pre, cols].tolist())
        row_ptr.append(base + len(cols))
        if ie_type == 1:
            ie = FanInIE(ie_type=1, targets=cols,
                         local_axons=np.arange(base, base + len(cols)))
        else:
            ie = FanInIE(ie_type=0, targets=cols)
        fan_in.append(FanInDE(tag=0, ie_type=ie_type, ies=[ie]))
    fan_out = [FanOutEntry(global_axon=i) for i in range(n_pre)]
    topo = _Sparse("sparse", n_pre, n_post, fan_in, fan_out,
                   np.asarray(packed, dense.dtype),
                   meta={"bitmap": bitmap, "row_ptr": np.asarray(row_ptr),
                         "n_connections": int(bitmap.sum())})
    if ie_type == 0:
        # bitmap itself is a storage cost for FINDIDX decode
        topo.meta["extra_bits"] = int(bitmap.size)
    return topo


class _Pool(EncodedTopology):
    def propagate(self, spikes):
        m = self.meta
        h, w_, c, k = m["h"], m["w"], m["c"], m["k"]
        ho, wo = h // k, w_ // k
        out = np.zeros(c * ho * wo, np.float32)
        for pre in np.flatnonzero(spikes):
            ch, pos = pre // (h * w_), pre % (h * w_)
            de = self.fan_in[pos]
            for ie in de.ies:
                out[ch * ho * wo + ie.targets] += 1.0 / (k * k)
        return out

    def dense_equivalent(self):
        eye = np.eye(self.n_pre, dtype=np.float32)
        return np.stack([self.propagate(eye[i]) for i in range(self.n_pre)])

    def coo(self):
        m = self.meta
        h, w_, c, k = m["h"], m["w"], m["c"], m["k"]
        ho, wo = h // k, w_ // k
        pos_l, t_l = [], []
        for pos, de in enumerate(self.fan_in):
            for ie in de.ies:
                pos_l.append(np.full(len(ie.targets), pos, np.int64))
                t_l.append(ie.targets)
        pos_a = np.concatenate(pos_l) if pos_l else np.empty(0, np.int64)
        t_a = np.concatenate(t_l) if t_l else np.empty(0, np.int64)
        ch = np.arange(c, dtype=np.int64)
        full = (c, len(pos_a))
        pre = np.broadcast_to(ch[:, None] * (h * w_) + pos_a[None, :], full)
        post = np.broadcast_to(ch[:, None] * (ho * wo) + t_a[None, :], full)
        w = np.full(pre.size, 1.0 / (k * k), np.float32)
        return (np.ascontiguousarray(pre).ravel(),
                np.ascontiguousarray(post).ravel(), w)


def _build_pool(h: int, w: int, c: int, k: int) -> EncodedTopology:
    """Average pooling as type-0 IEs (paper Fig. 5a): target IDs only,
    weight implicit (1/k^2); storage ∝ single-channel positions. Positions in
    a partial window at a non-divisible edge have no pooled target and get an
    empty IE."""
    ho, wo = h // k, w // k
    fan_in = []
    n_valid = 0
    for pos in range(h * w):
        y, x = divmod(pos, w)
        if y // k < ho and x // k < wo:
            t = np.asarray([(y // k) * wo + (x // k)])
            n_valid += 1
        else:
            t = np.empty(0, np.int64)
        fan_in.append(FanInDE(tag=0, ie_type=0,
                              ies=[FanInIE(ie_type=0, targets=t)]))
    fan_out = [FanOutEntry(global_axon=i // (h * w)) for i in range(c * h * w)]
    return _Pool("pool", c * h * w, c * ho * wo, fan_in, fan_out, None,
                 meta=dict(h=h, w=w, c=c, k=k, n_connections=c * n_valid))


class _SparseCOO(EncodedTopology):
    """Sparse connectivity built straight from (pre, post, weight) triples —
    the brain-scale path: nothing O(n_pre * n_post) is ever allocated, unlike
    `encode(dense, kind='sparse')` whose FINDIDX bitmap is dense-sized."""

    def propagate(self, spikes):
        pre, post, w = self.meta["coo"]
        out = np.zeros(self.n_post, np.float32)
        mask = spikes[pre] != 0
        np.add.at(out, post[mask], w[mask] * spikes[pre][mask])
        return out

    def dense_equivalent(self):
        pre, post, w = self.meta["coo"]
        dense = np.zeros((self.n_pre, self.n_post), np.float32)
        np.add.at(dense, (pre, post), w)
        return dense

    def coo(self):
        return self.meta["coo"]


def _build_sparse_coo(triples, n_pre: int, n_post: int) -> EncodedTopology:
    """Type-1 sparse encoding from explicit (pre, post, weight) arrays.

    Fan-in IEs carry (neuron ID, local axon) pairs exactly as `encode_sparse`
    builds them, but grouped with numpy so million-edge tables stay cheap;
    the FanInDE list is per *occupied* presynaptic row only."""
    pre, post, w = (np.asarray(triples[0], np.int64),
                    np.asarray(triples[1], np.int64),
                    np.asarray(triples[2], np.float32))
    if not (len(pre) == len(post) == len(w)):
        raise ValueError("pre/post/weight lengths differ")
    order = np.lexsort((post, pre))
    pre, post, w = pre[order], post[order], w[order]
    rows, starts = np.unique(pre, return_index=True)
    ends = np.append(starts[1:], len(pre))
    fan_in = [FanInDE(tag=0, ie_type=1,
                      ies=[FanInIE(ie_type=1, targets=post[s:e],
                                   local_axons=np.arange(s, e))])
              for s, e in zip(starts, ends)]
    fan_out = [FanOutEntry(global_axon=int(r)) for r in rows]
    return _SparseCOO("sparse", n_pre, n_post, fan_in, fan_out, w,
                      meta={"coo": (pre, post, w), "row_ids": rows,
                            "n_connections": int(len(pre))})


def _build_skip(source: EncodedTopology, delay: int) -> EncodedTopology:
    """Skip connection (Fig. 8c): reuse the source fan-out DT; the only new
    state is the delayed-fire type bit + delay slots — NO relay neurons, NO
    duplicated DEs. Returns a shallow copy with the delayed flag set."""
    fan_out = [dataclasses.replace(e, delayed=True) for e in source.fan_out]
    return dataclasses.replace(source, kind="skip", fan_out=fan_out,
                               meta={**source.meta, "delay": delay,
                                     "base_kind": source.kind})


def relay_baseline_bits(source: EncodedTopology, delay: int) -> int:
    """The traditional alternative (Fig. 8a/b): `delay` generations of relay
    neurons, each with its own fan-out DE + IE, plus the relay neurons'
    state. Used by the Fig. 14 / ResNet comparison."""
    per_relay = (BITS["neuron_id"] + BITS["global_axon"] + BITS["route"]
                 + 2 * BITS["count"])
    return source.n_pre * delay * per_relay


# ---------------------------------------------------------------------------
# Encoding registry: one polymorphic entry point over the per-kind builders,
# mirroring register_neuron / register_synapse.
# ---------------------------------------------------------------------------

ENCODING_REGISTRY: Dict[str, Callable[..., EncodedTopology]] = {}


def register_encoding(name: str, factory: Callable[..., EncodedTopology], *,
                      override: bool = False) -> None:
    """Register an encoding factory `factory(obj, **opts) -> EncodedTopology`.

    Duplicate names raise unless override=True, same contract as
    `register_neuron` / `register_synapse`.
    """
    if name in ENCODING_REGISTRY and not override:
        raise ValueError(
            f"encoding {name!r} already registered; pass override=True "
            "to replace it")
    ENCODING_REGISTRY[name] = factory


def _infer_kind(obj) -> str:
    if isinstance(obj, EncodedTopology):
        return "skip"
    arr = np.asarray(obj) if obj is not None else None
    if arr is not None and arr.ndim == 4:
        return "conv"
    if arr is not None and arr.ndim == 2:
        # mostly-zero matrices encode smaller as sparse tables; otherwise the
        # type-2 incremental addressing of FC is the natural fit
        return "sparse" if np.mean(arr == 0) >= 0.5 else "fc"
    raise TypeError(
        f"cannot infer encoding kind from {type(obj).__name__}; pass "
        f"kind=... (registered: {sorted(ENCODING_REGISTRY)})")


def encode(obj=None, kind: Optional[str] = None, **opts) -> EncodedTopology:
    """Polymorphic constructor: `encode(weights, kind='fc', n_cores=4)`,
    `encode(filters, kind='conv', h=.., w=..)`, `encode(None, kind='pool',
    h=.., w=.., c=.., k=..)`, `encode(source, kind='skip', delay=2)`, ...

    With kind=None the kind is inferred: EncodedTopology -> skip, 4-D array
    -> conv, 2-D array -> fc or sparse by zero fraction.
    """
    if kind is None:
        kind = _infer_kind(obj)
    try:
        factory = ENCODING_REGISTRY[kind]
    except KeyError:
        raise KeyError(f"unknown encoding kind {kind!r}; registered: "
                       f"{sorted(ENCODING_REGISTRY)}") from None
    return factory(obj, **opts)


def _fc_factory(obj, n_cores: int = 1):
    return _build_fc(np.asarray(obj), n_cores=n_cores)


def _conv_factory(obj, h: int, w: int, stride: int = 1, pad: int = 0):
    return _build_conv(np.asarray(obj), h, w, stride=stride, pad=pad)


def _sparse_factory(obj, ie_type: int = 1):
    return _build_sparse(np.asarray(obj), ie_type=ie_type)


def _pool_factory(obj, h: int, w: int, c: int, k: int):
    if obj is not None:
        raise TypeError("pool encoding takes no tensor; pass h/w/c/k")
    return _build_pool(h, w, c, k)


def _skip_factory(obj, delay: int):
    if not isinstance(obj, EncodedTopology):
        raise TypeError("skip encoding wraps an existing EncodedTopology")
    return _build_skip(obj, delay)


def _sparse_coo_factory(obj, n_pre: int, n_post: int):
    return _build_sparse_coo(obj, n_pre, n_post)


register_encoding("fc", _fc_factory)
register_encoding("conv", _conv_factory)
register_encoding("sparse", _sparse_factory)
register_encoding("pool", _pool_factory)
register_encoding("skip", _skip_factory)
register_encoding("sparse_coo", _sparse_coo_factory)


# -- legacy names: thin wrappers over the registry --------------------------


def encode_fc(weights: np.ndarray, n_cores: int = 1) -> EncodedTopology:
    return encode(weights, kind="fc", n_cores=n_cores)


def encode_conv(filters: np.ndarray, h: int, w: int, stride: int = 1,
                pad: int = 0) -> EncodedTopology:
    return encode(filters, kind="conv", h=h, w=w, stride=stride, pad=pad)


def encode_sparse(dense: np.ndarray, ie_type: int = 1) -> EncodedTopology:
    return encode(dense, kind="sparse", ie_type=ie_type)


def encode_pool(h: int, w: int, c: int, k: int) -> EncodedTopology:
    return encode(None, kind="pool", h=h, w=w, c=c, k=k)


def encode_skip(source: EncodedTopology, delay: int) -> EncodedTopology:
    return encode(source, kind="skip", delay=delay)


def encode_sparse_coo(pre, post, w, n_pre: int, n_post: int) -> EncodedTopology:
    return encode((pre, post, w), kind="sparse_coo", n_pre=n_pre,
                  n_post=n_post)


# ---------------------------------------------------------------------------
# Pytree registration: a topology in a params dict is a *static* leaf — no
# traced children, identity-hashed aux — so jit embeds its tables as
# constants and tree_map never touches it.
# ---------------------------------------------------------------------------


def _topo_flatten(t):
    return (), t


def _topo_unflatten(aux, children):
    del children
    return aux


for _cls in (EncodedTopology, _FC, _Conv, _Sparse, _SparseCOO, _Pool):
    jax.tree_util.register_pytree_node(_cls, _topo_flatten, _topo_unflatten)


__all__ = [
    "BITS", "FanInIE", "FanInDE", "FanOutEntry", "EncodedTopology",
    "ENCODING_REGISTRY", "register_encoding", "encode",
    "encode_fc", "encode_conv", "encode_sparse", "encode_pool",
    "encode_skip", "encode_sparse_coo", "relay_baseline_bits",
]
