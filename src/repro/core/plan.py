"""Program compiler: lower an event-driven Program to a fused execution plan.

The generic stepper (`events.run`) interprets a Program one timestep at a
time: every node pays T kernel launches and round-trips its membrane state
through HBM every step, and the INTEG matmuls run at (B, fan_in) — far too
skinny to feed the MXU. But most Program structure is static: which node
feeds which, with what delay, through which neuron dynamics, learning with
what rule. This module analyzes that structure once and emits a plan of
*segments*, each executed over the whole time axis at once.

Since the neuron API became declarative (`core/neuron.py::NeuronProgram`),
classification is *structural pattern matching on the IR* — there is no
per-class dispatch, so user-registered programs fuse whenever their shape
matches a kernel pattern:

  pattern (on the program)                          FIRE lowering
  ------------------------------------------------  -------------------
  1 state, current-driven, no threshold, membrane    `linrec` (associative
  output                                             all-T scan)
  1 state, current-driven, constant threshold, zero   `lif` (+ `lifrec`
  or subtract reset, spike output                     when self-recurrent;
                                                      subtract reset is
                                                      feed-forward only)
  2 states {membrane + spike-driven adaptation},      `alif` (+ `alifrec`
  affine threshold in the adaptation, hard reset      when self-recurrent)
  2 states {branch dendrites + sum-driven soma},      branch-integrate
  constant threshold, hard reset                      prologue (`linrec`
                                                      over the branch axis)
                                                      feeding `lif`

Synapse programs (`core/plasticity.py::SynapseProgram`, attached to a
`Connection(plastic=...)`) are matched the same way: any rule whose trace
decays are constants lowers to the generalized `stdp_seq` kernel family —
trace DIFFs hoisted through all-T `linrec`, then every outer-product
update term applied over the window with the weight tile VMEM-resident —
while unmatched rules (learned decays, oversized programs) run through the
parity-checked per-step fallback (`plasticity.synapse_step` scanned over
the realized spike trains). Either way, on-chip learning runs *inside*
`plan.run` (and, forced to `REPRO_SNN_ENGINE=stepper`, as the same pass
after the interpreted forward): within one run window the forward uses the
entry weights, and the learned weight + final traces are published in
`state[node]["syn:<conn>"]` (chunked-online semantics; merge with
`plasticity.apply_learned` between windows).

INTEG is hoisted out of the time loop for every fused segment: one
registry-dispatched `spikemm` over the (T*B, fan_in) spike matrix per feed
(block-occupancy flags = the FINDIDX bitmap at MXU granularity); the
branch convention (`snn_layers.branch_integrate`) hoists as one spikemm
against the branch-flattened weight tensor. Because that goes through the
registry, the block-sparse spikemm channel engages with no plan changes:
when the plan runs eagerly and the hoisted raster's measured occupancy is
below the tuned threshold, dispatch skips silent blocks outright
(`REPRO_SPIKEMM_SPARSE=never|auto|always` pins the choice). Everything that matches no
pattern (extra states, untagged integrates, recurrent branch programs)
runs through the stepper — per segment, with the fused neighbours'
full-time outputs (delay-shifted as needed) fed in externally.

Delayed (`Connection(delay=d)` / "src@d") reads of a *fused* source are
exact: the ring buffer the stepper would maintain is just a time-shift of
the source's full output tensor, seeded from the initial ring state.

Capability checks keep the compiler conservative: a Program where any node
reads a *later* node (previous-timestep semantics) compiles to a single
whole-program fallback segment, i.e. exactly `events.run`. Every Program
runs; fusable ones run fast.

Every fallback decision carries a stable TB2xx diagnostic code next to
its prose reason (`Segment.codes` / `PlasticLower.code`), so
`Plan.describe()` is machine-checkable and `repro.analysis` can explain
fusion without re-deriving the classifier.

Env knobs: REPRO_SNN_ENGINE = plan | stepper | auto (auto = plan; set
`stepper` to force the interpreted engine, e.g. when bisecting a numerics
difference). REPRO_SNN_EXPLAIN=1 prints every compiled segment schedule
(`Plan.describe()`) as Programs are lowered. REPRO_CHECK = off | warn |
raise runs the full `repro.analysis` checker over each compiled Program:
`warn` routes warning+ findings onto the kernel incident log
(kind="check"), `raise` turns error-severity findings into
`analysis.DiagnosticError`. REPRO_FAULTS injects
deterministic faults at the run boundary and node outputs
(`core/faults.py`); REPRO_GUARD (or `run(guard=...)`) arms the numerical
guardrails (`core/guards.py`).
"""

from __future__ import annotations

import dataclasses
import os
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Tuple,
                    Union)

import jax
import jax.numpy as jnp

from repro.core import events, faults, guards, plasticity
from repro.core.neuron import Decay, NeuronProgram, decay_array
# note: `repro.kernels` re-exports an `incidents()` *function*, which
# shadows the submodule on the package namespace — import names directly
from repro.kernels.incidents import FallbackEvent, record as _record_incident
from repro.kernels.alifrec.ops import alif_scan, alifrec_scan
from repro.kernels.lif.ops import lif_scan
from repro.kernels.lifrec.ops import lifrec_scan
from repro.kernels.linrec.ops import linrec
from repro.kernels.spikemm.ops import spikemm
from repro.kernels.stdp.ops import stdp_seq

Array = jax.Array

FUSED_FF = "fused_ff"
FUSED_REC = "fused_rec"
FALLBACK = "fallback"

# FIRE lowering families the pattern matcher can emit
LOWER_LI = "li"
LOWER_LIF = "lif"
LOWER_ALIF = "alif"
LOWER_DHLIF = "dhlif"

# synapse-program lowerings
SYN_SEQ = "stdp_seq"
SYN_STEP = "step"

# Cross-engine agreement tolerance (fused plan vs stepper, jit vs eager).
#
# Root cause of the ~1e-6 DH-LIF membrane drift (CHANGES.md PR 7 note):
# the fused path evaluates the membrane DIFF through
# `jax.lax.associative_scan` (linrec), a fp32 *tree* reduction, while the
# stepper folds the same recurrence *sequentially*; fp32 addition is not
# associative, so the two orders accumulate different roundoff. Measured
# at T=1301 (the ECG window): 9.5e-7 max drift with a constant decay,
# 1.4e-6 with heterogeneous per-neuron decays (0.88..0.997). Reordering
# either side would cost the scan its O(log T) depth, so the bound is
# encoded here instead: ~7x margin over the worst observed drift. Use
# this constant — not ad-hoc atol literals — whenever comparing engines.
CROSS_ENGINE_ATOL = 1e-5


def engine_mode() -> str:
    mode = os.environ.get("REPRO_SNN_ENGINE", "auto")
    if mode not in ("auto", "plan", "stepper"):
        raise ValueError(f"REPRO_SNN_ENGINE={mode!r}: "
                         "expected 'plan', 'stepper', or 'auto'")
    return mode


def check_mode() -> str:
    mode = os.environ.get("REPRO_CHECK", "off")
    if mode not in ("off", "warn", "raise"):
        raise ValueError(f"REPRO_CHECK={mode!r}: "
                         "expected 'off', 'warn', or 'raise'")
    return mode


@dataclasses.dataclass(frozen=True)
class Segment:
    """One unit of the lowered schedule, executed over the full time axis."""

    kind: str                  # fused_ff | fused_rec | fallback
    names: Tuple[str, ...]     # node names (fused segments hold exactly one)
    reason: str = ""           # why the planner fell back (diagnostics)
    lower: str = ""            # FIRE kernel family for fused segments
    codes: Tuple[str, ...] = ()  # TB2xx codes, one per merged fallback node


@dataclasses.dataclass(frozen=True)
class PlasticLower:
    """Lowering decision for one plastic Connection (run-granularity pass)."""

    node: str                  # destination node name
    conn: str                  # Connection.key
    lower: str                 # stdp_seq | step
    reason: str = ""           # why the fused family was refused
    code: str = ""             # TB2xx code for a refused fused lowering


@dataclasses.dataclass(frozen=True)
class Plan:
    segments: Tuple[Segment, ...]
    plastic: Tuple[PlasticLower, ...] = ()

    @property
    def fully_fallback(self) -> bool:
        return all(s.kind == FALLBACK for s in self.segments)

    def describe(self) -> str:
        """Segment schedule, with every fallback's TB-code inline — the
        machine-readable why behind each stepper segment."""
        parts = []
        for s in self.segments:
            tag = f"{s.kind}[{','.join(s.names)}]"
            if s.lower:
                tag += f":{s.lower}"
            if s.reason:
                tag += f"({s.reason})"
            parts.append(tag)
        out = " -> ".join(parts)
        if self.plastic:
            learns = []
            for p in self.plastic:
                tag = f"{p.node}.{p.conn}:{p.lower}"
                if p.reason:
                    tag += f"({p.code}: {p.reason})" if p.code \
                        else f"({p.reason})"
                learns.append(tag)
            out += " | learn " + ",".join(learns)
        return out


def _hoist_tag(node: events.LayerNode) -> Optional[str]:
    """INTEG hoist convention: "ff" = per-feed `s @ w` matmuls against each
    connection's weight key (`snn_layers.ff_integrate` = the canonical
    "w_<src>" naming), "branch" = the single-feed dendritic einsum
    (`snn_layers.branch_integrate`, fixed key "w_input"). Custom integrates
    opt in by setting `.hoist`; untagged integrates keep the stepper."""
    return getattr(node.integrate, "hoist", None)


def _match_fire_pattern(prog: NeuronProgram
                        ) -> Tuple[Optional[str], str, str]:
    """Structurally match a NeuronProgram against the fused FIRE kernels.

    Returns (lowering family, "", "") on a match, else
    (None, TB-code, reason). Driven ONLY by program structure — any user
    program with a matching shape (<= 2 coupled linear states + threshold
    + zero/subtract reset, or a pure leaky integrator) fuses, whatever
    Python class built it.
    """
    th = prog.threshold
    if not prog.states:
        return None, "TB206", "empty program"
    if th is None:
        sv = prog.states[0]
        if (len(prog.states) == 1 and not sv.branch
                and sv.drive == "current" and prog.output == sv.name):
            return LOWER_LI, "", ""
        return None, "TB206", "unfusable non-spiking program"
    if prog.output != "spikes":
        return None, "TB206", "state readout on a spiking program"
    if prog.reset not in ("zero", "subtract"):
        return None, "TB206", f"reset={prog.reset}"
    mem = next((s for s in prog.states if s.name == th.on), None)
    if mem is None or mem.branch:
        return None, "TB206", "threshold not on a plain membrane state"
    others = [s for s in prog.states if s.name != th.on]
    if mem.drive == "current" and not others and not th.adapt:
        return LOWER_LIF, "", ""
    if prog.reset != "zero":
        # the alif/dhlif kernels implement the hard reset only
        return None, "TB206", "subtract reset on a multi-state program"
    if (mem.drive == "current" and len(others) == 1
            and others[0].drive == "spikes" and not others[0].branch
            and th.adapt == others[0].name):
        return LOWER_ALIF, "", ""
    if (len(others) == 1 and others[0].branch
            and others[0].drive == "current"
            and mem.drive == f"sum:{others[0].name}" and not th.adapt):
        # the prologue feeds the soma the branches' NEW values, which is the
        # interpreter's semantics only when the branch state updates first
        names = [s.name for s in prog.states]
        if names.index(others[0].name) < names.index(mem.name):
            return LOWER_DHLIF, "", ""
        return None, "TB206", "soma declared before its branches"
    return None, "TB206", "program shape matches no fused FIRE kernel"


def _match_synapse_pattern(prog: "plasticity.SynapseProgram"
                           ) -> Tuple[str, str, str]:
    """Structurally match a SynapseProgram against the `stdp_seq` family.

    -> (SYN_SEQ, "", "") when the program is small enough for the fused
    plane stack; else (SYN_STEP, TB-code, reason) — the per-step
    interpreter over the realized spike trains, always correct. Learned
    per-synapse trace decays are fine: `linrec` takes a full decay plane,
    so a sigmoid-resolved learned decay hoists exactly like a constant.
    """
    if len(prog.traces) > 4:
        return SYN_STEP, "TB210", f"{len(prog.traces)} traces"
    if len(prog.terms) > 4:
        return SYN_STEP, "TB210", f"{len(prog.terms)} update terms"
    return SYN_SEQ, "", ""


def _classify(node: events.LayerNode, order: Dict[str, int]
              ) -> Tuple[str, str, str, str]:
    """-> (segment kind, TB-code, fallback reason, lowering family)."""
    hoist = _hoist_tag(node)
    if hoist not in ("ff", "branch"):
        return FALLBACK, "TB202", "integrate not hoistable", ""
    n_self = 0
    for c in node.connections:
        if c.src == "self":
            if c.delay:
                return FALLBACK, "TB203", "delayed self", ""
            n_self += 1
        elif c.src != "input" and order[c.src] >= order[node.name]:
            # previous-timestep read of a later node: handled by caller
            # (whole-program fallback); unreachable here, kept for safety
            return FALLBACK, "TB201", "back reference", ""
    if n_self > 1:
        return FALLBACK, "TB204", "multiple self feeds", ""
    try:
        prog = node.neuron.program
    except NotImplementedError:
        return FALLBACK, "TB205", "neuron declares no program", ""
    family, code, why = _match_fire_pattern(prog)
    if family is None:
        return FALLBACK, code, why, ""
    needs_branch = family == LOWER_DHLIF
    if needs_branch != (hoist == "branch"):
        return FALLBACK, "TB207", (
            f"{family} program needs "
            f"{'branch' if needs_branch else 'ff'} integrate, "
            f"got {hoist}"), ""
    if hoist == "branch":
        n_feeds = sum(1 for c in node.connections if c.src != "self")
        if n_feeds != 1:
            # the branch convention hoists exactly one feed through w_input;
            # extra feeds would be silently dropped
            return FALLBACK, "TB207", \
                f"branch integrate with {n_feeds} feeds", ""
    if n_self:
        if family == LOWER_LIF and prog.reset != "zero":
            return FALLBACK, "TB208", "recurrent subtract reset", ""
        if family in (LOWER_LIF, LOWER_ALIF):
            return FUSED_REC, "", "", family
        return FALLBACK, "TB208", f"recurrent {family}", ""
    return FUSED_FF, "", "", family


def compile_program(nodes: List[events.LayerNode]) -> Plan:
    """Analyze the node DAG and emit the segment + plastic-lowering plan."""
    order = {n.name: i for i, n in enumerate(nodes)}
    plastic: List[PlasticLower] = []
    for n in nodes:
        for c in n.connections:
            if c.plastic is None:
                continue
            lower, code, why = _match_synapse_pattern(c.plastic)
            plastic.append(PlasticLower(n.name, c.key, lower, why, code))

    # Any previous-timestep read of a later node couples the whole Program
    # per-timestep: compile to one stepper segment (exactly events.run).
    plan = None
    for n in nodes:
        for c in n.connections:
            if c.src not in ("input", "self") and order[c.src] >= order[n.name]:
                plan = Plan((Segment(
                    FALLBACK, tuple(x.name for x in nodes),
                    f"{n.name}: TB201 reads later node {c.src}",
                    codes=("TB201",)),), tuple(plastic))
                break
        if plan:
            break

    if plan is None:
        segments: List[Segment] = []
        pending_fallback: List[str] = []
        pending_reason = ""
        pending_codes: List[str] = []

        def flush():
            nonlocal pending_fallback, pending_reason, pending_codes
            if pending_fallback:
                segments.append(Segment(FALLBACK, tuple(pending_fallback),
                                        pending_reason,
                                        codes=tuple(pending_codes)))
                pending_fallback, pending_reason = [], ""
                pending_codes = []

        for n in nodes:
            kind, code, reason, family = _classify(n, order)
            if kind == FALLBACK:
                pending_fallback.append(n.name)
                pending_codes.append(code)
                pending_reason = (pending_reason + "; " if pending_reason
                                  else "") + f"{n.name}: {code} {reason}"
            else:
                flush()
                segments.append(Segment(kind, (n.name,), lower=family))
        flush()
        plan = Plan(tuple(segments), tuple(plastic))

    if os.environ.get("REPRO_SNN_EXPLAIN") == "1":
        print(f"[repro.plan] {plan.describe()}")
    _run_check_hook(nodes, plan)
    return plan


# Re-entrancy latch for the REPRO_CHECK hook: `analysis.check_nodes` may
# itself call `compile_program` (it reuses the planner for TB2xx), which
# must not re-trigger the hook.
_IN_CHECK = False


def _run_check_hook(nodes: List[events.LayerNode], plan: "Plan") -> None:
    """Opt-in static checking at compile time (REPRO_CHECK=warn|raise).

    warn: warning+ findings land on the kernel incident log (kind="check")
    — observable, never fatal, and deliberately record()ed rather than
    degrade()d so REPRO_STRICT CI stays green. raise: error-severity
    findings abort compilation with `analysis.DiagnosticError`.
    """
    global _IN_CHECK
    mode = check_mode()
    if mode == "off" or _IN_CHECK:
        return
    from repro import analysis  # deferred: analysis imports this module
    _IN_CHECK = True
    try:
        diags = analysis.check_nodes(nodes, plan=plan)
    finally:
        _IN_CHECK = False
    if mode == "raise":
        analysis.raise_if(diags, "error")
    for d in analysis.at_least(diags, "warning"):
        _record_incident(FallbackEvent(
            kind="check", family="plan", stage=d.code,
            error=f"{d.site}: {d.message}"))


# ---------------------------------------------------------------------------
# plan execution
# ---------------------------------------------------------------------------


def _feed_full(outs: Dict[str, Array], state: Dict[str, Any], name: str,
               d: int, T: int) -> Array:
    """Full-time feed of source `name` delayed by `d` steps.

    feed_t = out_{t-d}; times < 0 come from the source's initial ring
    (zeros when the Program starts cold), exactly the stepper's delayed-fire
    semantics.
    """
    s_full = outs[name]
    if d == 0:
        return s_full
    ring = state.get(name, {}).get("ring")
    if ring is not None:
        prefix = ring[d - 1::-1]                     # s_{-d} ... s_{-1}
    else:
        prefix = jnp.zeros((d,) + s_full.shape[1:], s_full.dtype)
    return jnp.concatenate([prefix, s_full], axis=0)[:T]


def _advance_ring(ring: Array, out_full: Array) -> Array:
    """Ring state after the whole run: ring[k] = out_{T-1-k}, seeded from
    the initial ring for T < k."""
    stacked = jnp.concatenate([ring[::-1], out_full.astype(ring.dtype)], axis=0)
    return stacked[-ring.shape[0]:][::-1]


def _hoisted_current(node: events.LayerNode, params: Dict[str, Any],
                     outs: Dict[str, Array], state: Dict[str, Any],
                     T: int, B: int) -> Array:
    """All-T INTEG: one event-gated spikemm per inbound connection.

    The "branch" convention hoists the dendritic einsum as a single
    spikemm against the branch-flattened (n_in, K*n_out) weight view,
    yielding a (T, B, K, n_out) per-branch current block.
    """
    if _hoist_tag(node) == "branch":
        conn = next(c for c in node.connections if c.src != "self")
        s = _feed_full(outs, state, conn.src, conn.delay, T)
        w = params[node.name]["w_input"]             # (K, n_in, n_out)
        K, n_in, n_out = w.shape
        if not jnp.issubdtype(s.dtype, jnp.floating):
            s = s.astype(w.dtype)
        w2 = jnp.transpose(w, (1, 0, 2)).reshape(n_in, K * n_out)
        c = spikemm(s.reshape(T * B, -1), w2)
        return c.reshape(T, B, K, n_out)
    cur = None
    for conn in node.connections:
        if conn.src == "self":
            continue
        s = _feed_full(outs, state, conn.src, conn.delay, T)
        topo = events.resolve_topology(conn, node.name, params)
        if topo is not None:
            # compressed connectivity: hoist straight through the topology's
            # execution channel (spikemm for type-2 FC, spikemm_gather for
            # sparse/conv/pool IE tables) — dense_equivalent() never runs
            if not jnp.issubdtype(s.dtype, jnp.floating):
                s = s.astype(events.state_dtype(s.dtype))
            c = topo.apply_spikes(s.reshape(T * B, -1)).reshape(T, B, -1)
        else:
            w = params[node.name][conn.weight_key]
            if not jnp.issubdtype(s.dtype, jnp.floating):
                s = s.astype(w.dtype)                # int spikes: match locacc
            c = spikemm(s.reshape(T * B, -1), w).reshape(T, B, -1)
        cur = c if cur is None else cur + c
    if cur is None:
        cur = jnp.zeros((T, B, node.out_dim),
                        events.state_dtype(outs["input"].dtype))
    return cur


def _decay_vec(decay: Decay, nparams: Optional[Dict[str, Array]], n: int,
               n_branches: int = 0) -> Array:
    """Resolve a program Decay to the kernel-facing fp32 decay tensor:
    (N,) for per-neuron states, (K, N) for branch states."""
    shape = (n_branches, n) if n_branches else (n,)
    p = (nparams or {}).get(decay.param) if decay.kind != "const" else None
    if p is not None:
        return jnp.broadcast_to(jax.nn.sigmoid(p.astype(jnp.float32)), shape)
    return jnp.full(shape, decay.value, jnp.float32)


def _self_weight(node: events.LayerNode, params: Dict[str, Any]) -> Array:
    conn = next(c for c in node.connections if c.src == "self")
    return params[node.name][conn.weight_key]


def _is_spiking(node: events.LayerNode) -> bool:
    """Whether the node emits a spike train (rate monitors make sense) as
    opposed to a membrane/state readout. Unknown programs count as spiking
    only if they declare a threshold."""
    try:
        return node.neuron.program.threshold is not None
    except NotImplementedError:
        return False


def _run_fused(node: events.LayerNode, kind: str, lower: str,
               params: Dict[str, Any], outs: Dict[str, Array],
               state: Dict[str, Any], new_state: Dict[str, Any],
               T: int, B: int,
               gcfg: guards.GuardConfig = guards.GuardConfig()) -> None:
    cur = _hoisted_current(node, params, outs, state, T, B)
    prog = node.neuron.program
    nparams = params.get(node.name, {}).get("neuron")
    sur, alpha = node.neuron.surrogate, node.neuron.alpha
    th = prog.threshold
    N = node.out_dim

    if lower == LOWER_LI:
        sv = prog.states[0]
        tau = _decay_vec(sv.decay, nparams, N)
        a = jnp.broadcast_to(tau.astype(cur.dtype), cur.shape)
        out, vT = linrec(a, cur, state[node.name][sv.name])
        ns = {sv.name: vT}
    elif lower == LOWER_LIF:
        tau = _decay_vec(prog.states[0].decay, nparams, N)
        v0 = state[node.name][th.on]
        if kind == FUSED_REC:
            out, vT = lifrec_scan(cur, _self_weight(node, params), tau, v0,
                                  state[node.name]["out"], th.base, sur,
                                  alpha)
        else:
            out, vT = lif_scan(cur, tau, v0, th.base, sur, alpha, False,
                               prog.reset)
        ns = {th.on: vT}
    elif lower == LOWER_ALIF:
        mem = next(s for s in prog.states if s.name == th.on)
        ad = next(s for s in prog.states if s.name == th.adapt)
        tau = _decay_vec(mem.decay, nparams, N)
        rho = _decay_vec(ad.decay, nparams, N)
        v0, a0 = state[node.name][mem.name], state[node.name][ad.name]
        if kind == FUSED_REC:
            out, vT, aT = alifrec_scan(cur, _self_weight(node, params), tau,
                                       rho, v0, a0, state[node.name]["out"],
                                       th.base, th.scale, sur, alpha)
        else:
            out, vT, aT = alif_scan(cur, tau, rho, v0, a0, th.base, th.scale,
                                    sur, alpha)
        ns = {mem.name: vT, ad.name: aT}
    elif lower == LOWER_DHLIF:
        # branch-integrate prologue: the dendrites never reset, so they are
        # a pure linear recurrence -> associative all-T linrec over the
        # branch-flattened axis, summed into the soma's LIF kernel.
        mem = next(s for s in prog.states if s.name == th.on)
        br = next(s for s in prog.states if s.branch)
        d0 = state[node.name][br.name]               # (B, K, N)
        K = d0.shape[-2]
        tau_d = _decay_vec(br.decay, nparams, N, n_branches=K)
        a = jnp.broadcast_to(tau_d.astype(cur.dtype)[None],
                             (B, K, N)).reshape(B * K, N)
        a = jnp.broadcast_to(a[None], (T, B * K, N))
        d_full, dT = linrec(a, cur.reshape(T, B * K, N),
                            d0.reshape(B * K, N))
        soma_cur = jnp.sum(d_full.reshape(T, B, K, N), axis=2)
        tau_s = _decay_vec(mem.decay, nparams, N)
        out, vT = lif_scan(soma_cur, tau_s, state[node.name][mem.name],
                           th.base, sur, alpha)
        ns = {mem.name: vT, br.name: dT.reshape(B, K, N)}
    else:  # pragma: no cover - compile_program only emits known families
        raise ValueError(f"unknown FIRE lowering {lower!r}")

    # dead/stuck-row faults: the mask is time-independent, so masking the
    # full (T, B, N) train here equals the stepper's per-step masking for
    # everything downstream (feeds, rings, "out"). A fused *recurrent*
    # kernel's in-loop feedback runs pre-mask, unlike the stepper — use
    # feed-forward topologies (or the stepper engine) when exact
    # cross-engine equivalence under dead_rows matters.
    out = faults.perturb_output(node.name, out)
    out = guards.check_tensor(f"{node.name}.out", out, gcfg)
    if lower != LOWER_LI:
        guards.check_spikes(node.name, out, gcfg)
    ns = {k: guards.check_tensor(f"{node.name}.{k}", v, gcfg)
          for k, v in ns.items()}
    outs[node.name] = out
    ns["out"] = out[-1]
    if "ring" in state[node.name]:
        ns["ring"] = _advance_ring(state[node.name]["ring"], out)
    for k, v in state[node.name].items():
        if k.startswith("syn:"):
            ns[k] = v
    new_state[node.name] = ns


def _run_fallback(seg: Segment, nodes_by_name: Dict[str, events.LayerNode],
                  params: Dict[str, Any], x: Array, outs: Dict[str, Array],
                  state: Dict[str, Any], new_state: Dict[str, Any],
                  T: int,
                  gcfg: guards.GuardConfig = guards.GuardConfig()) -> None:
    seg_nodes = [nodes_by_name[name] for name in seg.names]
    seg_names = set(seg.names)
    sub_state = {name: state[name] for name in seg.names}
    ext: Dict[str, Array] = {}
    for n in seg_nodes:
        for c in n.connections:
            if c.src == "self" or c.src in seg_names or c.key in ext:
                continue
            if c.src == "input" and c.delay == 0:
                continue                 # events.step already emits x_t
            ext[c.key] = _feed_full(outs, state, c.src, c.delay, T)

    def body(st, ts):
        x_t, ext_t = ts
        st, _ = events.step(seg_nodes, params, st, x_t, ext=ext_t)
        return st, {name: st[name]["out"] for name in seg.names}

    final_sub, rec = jax.lax.scan(body, sub_state, (x, ext))
    if gcfg.active:
        for name in seg.names:
            rec[name] = guards.check_tensor(f"{name}.out", rec[name], gcfg)
            if _is_spiking(nodes_by_name[name]):
                guards.check_spikes(name, rec[name], gcfg)
        final_sub = {
            name: {k: (guards.check_tensor(f"{name}.{k}", v, gcfg)
                       if not k.startswith("syn:") else v)
                   for k, v in ns.items()}
            for name, ns in final_sub.items()}
    outs.update(rec)
    new_state.update(final_sub)


# ---------------------------------------------------------------------------
# session-state pack/unpack (the serve engine's gather/scatter primitives)
# ---------------------------------------------------------------------------
#
# A `plan.run` state tree is {node: {key: array}} where every per-neuron
# leaf carries the batch on axis 0 — except the delay ring, whose layout is
# (depth, batch, n). The serve engine (repro.serve) multiplexes many
# batch-1 streaming sessions through ONE resident jitted window step by
# concatenating their states into cohort slots along the batch axis and
# slicing them back out on window boundaries; these helpers are the
# batch-axis-aware primitives it builds on. Synapse ("syn:") entries are
# deliberately rejected: their weight plane has NO batch axis (one tile
# per connection, batch-summed updates), so packing sessions that learn
# would alias their weights — the engine keeps those per-session and runs
# the learning path vmapped instead.


def _state_batch_axis(key: str) -> int:
    return 1 if key == "ring" else 0


def state_nbytes(state: Dict[str, Any]) -> int:
    """Total bytes of one state tree — the per-session footprint the serve
    cache budgets against (syn entries included: they are carried per
    session even though they never enter a packed cohort)."""
    return sum(int(v.size) * v.dtype.itemsize if hasattr(v, "dtype") else 0
               for v in jax.tree_util.tree_leaves(state))


def pack_states(states: List[Dict[str, Any]], pad_to: Optional[int] = None
                ) -> Dict[str, Any]:
    """Concatenate per-session state trees into one cohort state.

    Every leaf joins along its batch axis (axis 0; axis 1 for delay
    rings); `pad_to` right-pads the cohort with zero slots up to a fixed
    capacity so the resident jitted step never retraces. Raises on
    "syn:" entries — see the module note above.
    """
    if not states:
        raise ValueError("pack_states needs at least one state")
    total = sum(next(iter(s.values()))["out"].shape[0] for s in states)
    pad = 0 if pad_to is None else pad_to - total
    if pad < 0:
        raise ValueError(f"pack_states: {total} batch rows exceed "
                         f"pad_to={pad_to}")
    out: Dict[str, Any] = {}
    for node in states[0]:
        nd: Dict[str, Any] = {}
        for k in states[0][node]:
            if k.startswith("syn:"):
                raise ValueError(
                    f"pack_states: node {node!r} carries synapse state "
                    f"{k!r}, which has no batch axis; keep syn entries "
                    "per-session (see repro.serve)")
            ax = _state_batch_axis(k)
            parts = [s[node][k] for s in states]
            if pad:
                shape = list(parts[0].shape)
                shape[ax] = pad
                parts.append(jnp.zeros(tuple(shape), parts[0].dtype))
            nd[k] = jnp.concatenate(parts, axis=ax)
        out[node] = nd
    return out


def unpack_state(state: Dict[str, Any], index: int,
                 width: int = 1) -> Dict[str, Any]:
    """Slice one session (batch rows [index, index+width)) back out of a
    packed cohort state — the exact inverse of its `pack_states` slot, so
    gather -> run -> scatter round-trips are bit-identical."""
    out: Dict[str, Any] = {}
    for node, nd in state.items():
        out[node] = {
            k: (v[:, index:index + width] if _state_batch_axis(k) == 1
                else v[index:index + width])
            for k, v in nd.items()}
    return out


# ---------------------------------------------------------------------------
# the plasticity pass (run-granularity on-chip learning)
# ---------------------------------------------------------------------------


def _mod_full(mod: Optional[Array], T: int, B: int, N: int, dtype) -> Array:
    """Broadcast the run-level modulator to the (T, B, N) term plane.

    Accepts None (zeros: no reward, no update), (T,) global reward per
    step, (T, B) per-trial reward, or (T, B, N) per-neuron teaching
    signal."""
    if mod is None:
        return jnp.zeros((T, B, N), dtype)
    m = jnp.asarray(mod, dtype)
    if m.ndim == 1:
        m = m[:, None, None]
    elif m.ndim == 2:
        m = m[..., None]
    return jnp.broadcast_to(m, (T, B, N))


def _learn_fused(prog: "plasticity.SynapseProgram", syn0: Dict[str, Array],
                 pre_full: Array, post_full: Array,
                 mod_full: Optional[Array],
                 sparams: Optional[Dict[str, Array]] = None
                 ) -> Dict[str, Array]:
    """Fused `stdp_seq` lowering of one SynapseProgram window.

    Trace DIFFs are pure linear recurrences -> hoisted through all-T
    `linrec`; each term's pre/post factor products become (T*B, n) planes
    ("after" traces read the one-step-shifted trajectory); the stacked
    planes drive the serial-in-time `stdp_seq` kernel with the weight tile
    VMEM-resident across the whole window. Learned per-synapse decays
    (`sparams`, the `params[node]["syn:<conn>"]` dict) resolve through
    `decay_array` exactly like the per-step interpreter and broadcast into
    the decay plane.
    """
    T, B = pre_full.shape[:2]
    by_name = {t.name: t for t in prog.traces}
    traj: Dict[str, Array] = {}
    shifted: Dict[str, Array] = {}
    finals: Dict[str, Array] = {}
    for tr in prog.traces:
        s = pre_full if tr.source == "pre" else post_full
        h0 = syn0[tr.name].astype(s.dtype)
        a = jnp.broadcast_to(decay_array(tr.decay, sparams, s.dtype), s.shape)
        y, hT = linrec(a, tr.scale * s, h0)
        traj[tr.name] = y
        finals[tr.name] = hT.astype(syn0[tr.name].dtype)
        shifted[tr.name] = jnp.concatenate([h0[None], y[:-1]], axis=0)

    def plane(factors, spikes):
        v = None
        for f in factors:
            if f == "spikes":
                x = spikes
            elif f == "mod":
                x = mod_full
            else:
                x = traj[f] if by_name[f].update == "before" else shifted[f]
            v = x if v is None else v * x
        return v

    P = jnp.stack([plane(t.pre, pre_full).reshape(T * B, -1)
                   for t in prog.terms])
    Q = jnp.stack([plane(t.post, post_full).reshape(T * B, -1)
                   for t in prog.terms])
    w1 = stdp_seq(P, Q, syn0["w"], amps=tuple(t.amp for t in prog.terms),
                  w_min=prog.w_min, w_max=prog.w_max, batch=B)
    out = {"w": w1}
    out.update(finals)
    return out


def _learn_conn(node: events.LayerNode, conn: events.Connection, lower: str,
                params: Dict[str, Any], outs: Dict[str, Array],
                state: Dict[str, Any], new_state: Dict[str, Any],
                T: int, B: int, mod: Optional[Array],
                order: Dict[str, int],
                gcfg: guards.GuardConfig = guards.GuardConfig()) -> None:
    """Apply one plastic Connection's learning rule over the run window.

    The pre train is exactly the feed the stepper delivered: delay-shifted
    for "src@d" reads, and the *previous-step* output for "self" and for
    undelayed back-references (a source ordered at-or-after the node is
    read before it runs, i.e. at t-1, seeded from its initial "out"). The
    post train is the node's emitted output. The whole update is a weight
    write: stop_gradient keeps it out of STBP autodiff, like an optimizer
    step.
    """
    prog = conn.plastic
    key = f"syn:{conn.key}"
    syn0 = state[node.name][key]
    prev_step = conn.src == "self" or (
        conn.src != "input" and conn.delay == 0
        and order[conn.src] >= order[node.name])
    if prev_step:
        src_name = node.name if conn.src == "self" else conn.src
        s_full = outs[src_name]
        pre = jnp.concatenate([state[src_name]["out"][None], s_full[:-1]], 0)
    else:
        pre = _feed_full(outs, state, conn.src, conn.delay, T)
    post = outs[node.name]
    fdt = events.state_dtype(post.dtype)
    pre, post = pre.astype(fdt), post.astype(fdt)
    uses_mod = any("mod" in t.post for t in prog.terms)
    mod_f = _mod_full(mod, T, B, post.shape[-1], fdt) if uses_mod else None
    pre, post, syn0, mod_f = jax.lax.stop_gradient((pre, post, syn0, mod_f))
    sparams = params.get(node.name, {}).get(key)
    if lower == SYN_SEQ:
        syn1 = _learn_fused(prog, syn0, pre, post, mod_f, sparams)
    else:
        syn1 = plasticity.synapse_run(prog, syn0["w"], pre, post, mod_f,
                                      sparams, syn=syn0)
    if gcfg.active:
        # chunked-online divergence guard: a window whose learned weights
        # go nonfinite or explode is flagged (warn/raise) or rolled back
        # to the entry tensor (sanitize) before it is published
        syn1 = dict(syn1)
        syn1["w"] = guards.guard_learned(f"{node.name}.{conn.key}",
                                         syn0["w"], syn1["w"], gcfg)
    ns = dict(new_state[node.name])
    ns[key] = syn1
    new_state[node.name] = ns


def _learn_pass(plan: Plan, nodes: List[events.LayerNode],
                params: Dict[str, Any], outs: Dict[str, Array],
                state: Dict[str, Any], new_state: Dict[str, Any],
                T: int, B: int, mod: Optional[Array],
                gcfg: guards.GuardConfig = guards.GuardConfig()) -> None:
    nodes_by_name = {n.name: n for n in nodes}
    order = {n.name: i for i, n in enumerate(nodes)}
    for p in plan.plastic:
        node = nodes_by_name[p.node]
        conn = next(c for c in node.connections if c.key == p.conn)
        _learn_conn(node, conn, p.lower, params, outs, state, new_state,
                    T, B, mod, order, gcfg)


def run(nodes: List[events.LayerNode], params: Dict[str, Any], x: Array,
        state: Optional[Dict[str, Any]] = None, record: Tuple[str, ...] = (),
        plan: Optional[Plan] = None, mod: Optional[Array] = None,
        learn: bool = True,
        guard: Union[None, str, guards.GuardConfig] = None):
    """Drop-in replacement for `events.run` through the compiled plan.

    x: (T, batch, n_in). Returns (final_state, outputs (T, batch, n_out),
    recorded dict) — numerically equivalent to the stepper. Plastic
    Connections learn over the window (disable with `learn=False`); `mod`
    is the optional modulator/reward signal ((T,), (T, B), or (T, B,
    n_post)) feeding the rules' "mod" factors. Learned weights + final
    traces come back in `state[node]["syn:<conn>"]`
    (`plasticity.apply_learned` merges them into params).

    Resilience hooks: active faults (`REPRO_FAULTS` / `faults.inject`)
    perturb the input raster and weight planes once at entry and node
    outputs inside both engines, identically. `guard` enables numerical
    guardrails (`core/guards.py`) — a policy string off|warn|raise|sanitize
    or a full `GuardConfig`; None defers to `REPRO_GUARD` (default off).
    """
    mode = engine_mode()
    if plan is None:
        plan = compile_program(nodes)
    gcfg = guards.config(guard)
    do_learn = learn and bool(plan.plastic)
    nodes_by_name = {n.name: n for n in nodes}
    T, B = x.shape[0], x.shape[1]

    # injected faults hit the run boundary once, before either engine (and
    # before init_state seeds plastic synapses), so both see the same world
    x = faults.perturb_input(x)
    params = faults.perturb_params(params)
    x = guards.check_tensor("input", x, gcfg)

    if mode == "stepper" or plan.fully_fallback:
        if not do_learn:
            final, out, recs = events.run(nodes, params, x, state, record)
            out = guards.check_tensor(f"{nodes[-1].name}.out", out, gcfg)
            if gcfg.active and _is_spiking(nodes[-1]):
                guards.check_spikes(nodes[-1].name, out, gcfg)
            return final, out, recs
        # interpreted forward, then the same learning pass over the
        # realized spike trains (record what the plastic conns need)
        if state is None:
            state = events.init_state(nodes, B, x.dtype, params)
        needed = set(record)
        for p in plan.plastic:
            needed.add(p.node)
            conn = next(c for c in nodes_by_name[p.node].connections
                        if c.key == p.conn)
            if conn.src not in ("input", "self"):
                needed.add(conn.src)
        final, out, recs = events.run(nodes, params, x, state, tuple(needed))
        out = guards.check_tensor(f"{nodes[-1].name}.out", out, gcfg)
        if gcfg.active and _is_spiking(nodes[-1]):
            guards.check_spikes(nodes[-1].name, out, gcfg)
        outs = dict(recs)
        outs["input"] = x
        outs[nodes[-1].name] = out
        new_state = dict(final)
        _learn_pass(plan, nodes, params, outs, state, new_state,
                    T, B, mod, gcfg)
        return new_state, out, {r: outs[r] for r in record}

    if state is None:
        state = events.init_state(nodes, B, x.dtype, params)
    outs: Dict[str, Array] = {"input": x}
    new_state = dict(state)
    for seg in plan.segments:
        if seg.kind == FALLBACK:
            _run_fallback(seg, nodes_by_name, params, x, outs, state,
                          new_state, T, gcfg)
        else:
            _run_fused(nodes_by_name[seg.names[0]], seg.kind, seg.lower,
                       params, outs, state, new_state, T, B, gcfg)
    if do_learn:
        _learn_pass(plan, nodes, params, outs, state, new_state,
                    T, B, mod, gcfg)
    recs = {r: outs[r] for r in record}
    return new_state, outs[nodes[-1].name], recs


def run_stream(nodes: List[events.LayerNode], params: Dict[str, Any],
               chunks: Iterable[Array],
               state: Optional[Dict[str, Any]] = None,
               plan: Optional[Plan] = None, mod: Optional[Array] = None,
               learn: bool = True,
               guard: Union[None, str, guards.GuardConfig] = None
               ) -> Iterator[Tuple[Dict[str, Any], Array]]:
    """Chunked/streaming execution: constant peak memory in stream length.

    Consumes an iterable of (T_chunk, batch, n_in) spike chunks and yields
    `(state, outputs)` after each one, carrying neuron state, skip-delay
    ring buffers, and synapse state across chunk boundaries. Ring-buffered
    delay lines make this exact: a delayed edge reads its prefix from the
    carried ring (`_feed_full`), never from a delay-shifted full-time
    tensor, so concatenating the yielded outputs reproduces the one-shot
    `run` on the concatenated stream bit-for-bit while peak host+device
    memory scales with the chunk length only — the paper's
    infinite-time-window streaming mode.

    The plan is compiled once up front; `mod`, when given, must be an
    iterable aligned with `chunks` (one modulator window per chunk).
    """
    if plan is None:
        plan = compile_program(nodes)
    mods = iter(mod) if mod is not None else None
    for x in chunks:
        m = next(mods) if mods is not None else None
        state, out, _ = run(nodes, params, x, state=state, plan=plan,
                            mod=m, learn=learn, guard=guard)
        yield state, out


__all__ = ["Plan", "PlasticLower", "Segment", "compile_program",
           "engine_mode", "check_mode", "run", "run_stream",
           "CROSS_ENGINE_ATOL",
           "state_nbytes", "pack_states", "unpack_state",
           "FUSED_FF", "FUSED_REC", "FALLBACK",
           "LOWER_LI", "LOWER_LIF", "LOWER_ALIF", "LOWER_DHLIF",
           "SYN_SEQ", "SYN_STEP"]
