"""Program compiler: lower an event-driven Program to a fused execution plan.

The generic stepper (`events.run`) interprets a Program one timestep at a
time: every node pays T kernel launches and round-trips its membrane state
through HBM every step, and the INTEG matmuls run at (B, fan_in) — far too
skinny to feed the MXU. But most Program structure is static: which node
feeds which, with what delay, through which neuron dynamics. This module
analyzes that structure once and emits a plan of *segments*, each executed
over the whole time axis at once:

  fused_ff    A node whose inputs are all same-timestep feeds from earlier
              segments (or the external input). INTEG is hoisted out of the
              time loop entirely — one registry-dispatched `spikemm` over
              the (T*B, fan_in) spike matrix (block-occupancy flags = the
              FINDIDX bitmap at MXU granularity) — and FIRE becomes one
              time-fused kernel over the (T, B, N) current block:
              `lif` for LIF/PLIF, `linrec` for LI readouts.
  fused_rec   Same hoisted INTEG for the feed-forward part, plus the
              `lifrec` kernel for the self-connection: recurrent weights
              stay resident in VMEM and time runs serially inside the
              kernel (LIF/PLIF + "self").
  fallback    Everything the planner can't fuse yet (ALIF moving threshold,
              DHLIF branch integrate, non-tagged integrate functions) runs
              through the stepper — per segment, with the fused neighbours'
              full-time outputs (delay-shifted as needed) fed in externally.

Delayed ("src@d") reads of a *fused* source are exact: the ring buffer the
stepper would maintain is just a time-shift of the source's full output
tensor, seeded from the initial ring state.

Capability checks keep the compiler conservative: a Program where any node
reads a *later* node (previous-timestep semantics) compiles to a single
whole-program fallback segment, i.e. exactly `events.run`. Every Program
runs; fusable ones run fast.

Env knob: REPRO_SNN_ENGINE = plan | stepper | auto (auto = plan). Set
`stepper` to force the interpreted engine, e.g. when bisecting a numerics
difference.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import events
from repro.core.neuron import LI, LIF, PLIF
from repro.kernels.lif.ops import lif_scan
from repro.kernels.lifrec.ops import lifrec_scan
from repro.kernels.linrec.ops import linrec
from repro.kernels.spikemm.ops import spikemm

Array = jax.Array

FUSED_FF = "fused_ff"
FUSED_REC = "fused_rec"
FALLBACK = "fallback"


def engine_mode() -> str:
    mode = os.environ.get("REPRO_SNN_ENGINE", "auto")
    if mode not in ("auto", "plan", "stepper"):
        raise ValueError(f"REPRO_SNN_ENGINE={mode!r}: "
                         "expected 'plan', 'stepper', or 'auto'")
    return mode


@dataclasses.dataclass(frozen=True)
class Segment:
    """One unit of the lowered schedule, executed over the full time axis."""

    kind: str                  # fused_ff | fused_rec | fallback
    names: Tuple[str, ...]     # node names (fused segments hold exactly one)
    reason: str = ""           # why the planner fell back (diagnostics)


@dataclasses.dataclass(frozen=True)
class Plan:
    segments: Tuple[Segment, ...]

    @property
    def fully_fallback(self) -> bool:
        return all(s.kind == FALLBACK for s in self.segments)

    def describe(self) -> str:
        parts = []
        for s in self.segments:
            tag = f"{s.kind}[{','.join(s.names)}]"
            if s.reason:
                tag += f"({s.reason})"
            parts.append(tag)
        return " -> ".join(parts)


def _hoistable(node: events.LayerNode) -> bool:
    """INTEG can be hoisted iff the integrate fn declares the `w_<src>`
    matmul convention (see `snn_layers.ff_integrate`)."""
    return getattr(node.integrate, "hoist", None) == "ff"


def _classify(node: events.LayerNode, order: Dict[str, int]
              ) -> Tuple[str, str]:
    """-> (segment kind, fallback reason)."""
    if not _hoistable(node):
        return FALLBACK, "integrate not hoistable"
    n_self = 0
    for src in node.inputs:
        name, d = events._parse_src(src)
        if name == "self":
            if d:
                return FALLBACK, "delayed self"
            n_self += 1
        elif name != "input" and order[name] >= order[node.name]:
            # previous-timestep read of a later node: handled by caller
            # (whole-program fallback); unreachable here, kept for safety
            return FALLBACK, "back reference"
    if n_self > 1:
        return FALLBACK, "multiple self feeds"
    neuron = node.neuron
    if n_self:
        if type(neuron) in (LIF, PLIF):
            return FUSED_REC, ""
        return FALLBACK, f"recurrent {type(neuron).__name__}"
    if type(neuron) in (LIF, PLIF):
        return FUSED_FF, ""
    if type(neuron) is LI:
        return FUSED_FF, ""
    return FALLBACK, type(neuron).__name__


def compile_program(nodes: List[events.LayerNode]) -> Plan:
    """Analyze the node DAG and emit the segment schedule."""
    order = {n.name: i for i, n in enumerate(nodes)}
    # Any previous-timestep read of a later node couples the whole Program
    # per-timestep: compile to one stepper segment (exactly events.run).
    for n in nodes:
        for src in n.inputs:
            name, _ = events._parse_src(src)
            if name not in ("input", "self") and order[name] >= order[n.name]:
                return Plan((Segment(FALLBACK, tuple(x.name for x in nodes),
                                     f"{n.name} reads later node {name}"),))

    segments: List[Segment] = []
    pending_fallback: List[str] = []
    pending_reason = ""

    def flush():
        nonlocal pending_fallback, pending_reason
        if pending_fallback:
            segments.append(Segment(FALLBACK, tuple(pending_fallback),
                                    pending_reason))
            pending_fallback, pending_reason = [], ""

    for n in nodes:
        kind, reason = _classify(n, order)
        if kind == FALLBACK:
            pending_fallback.append(n.name)
            pending_reason = (pending_reason + "; " if pending_reason
                              else "") + f"{n.name}: {reason}"
        else:
            flush()
            segments.append(Segment(kind, (n.name,)))
    flush()
    return Plan(tuple(segments))


# ---------------------------------------------------------------------------
# plan execution
# ---------------------------------------------------------------------------


def _feed_full(outs: Dict[str, Array], state: Dict[str, Any], name: str,
               d: int, T: int) -> Array:
    """Full-time feed of source `name` delayed by `d` steps.

    feed_t = out_{t-d}; times < 0 come from the source's initial ring
    (zeros when the Program starts cold), exactly the stepper's delayed-fire
    semantics.
    """
    s_full = outs[name]
    if d == 0:
        return s_full
    ring = state.get(name, {}).get("ring")
    if ring is not None:
        prefix = ring[d - 1::-1]                     # s_{-d} ... s_{-1}
    else:
        prefix = jnp.zeros((d,) + s_full.shape[1:], s_full.dtype)
    return jnp.concatenate([prefix, s_full], axis=0)[:T]


def _advance_ring(ring: Array, out_full: Array) -> Array:
    """Ring state after the whole run: ring[k] = out_{T-1-k}, seeded from
    the initial ring for T < k."""
    stacked = jnp.concatenate([ring[::-1], out_full], axis=0)
    return stacked[-ring.shape[0]:][::-1]


def _hoisted_current(node: events.LayerNode, params: Dict[str, Any],
                     outs: Dict[str, Array], state: Dict[str, Any],
                     T: int, B: int) -> Array:
    """All-T INTEG: one event-gated spikemm per inbound feed."""
    cur = None
    for src in node.inputs:
        name, d = events._parse_src(src)
        if name == "self":
            continue
        s = _feed_full(outs, state, name, d, T)
        w = params[node.name][f"w_{name}"]
        c = spikemm(s.reshape(T * B, -1), w).reshape(T, B, -1)
        cur = c if cur is None else cur + c
    if cur is None:
        cur = jnp.zeros((T, B, node.out_dim), outs["input"].dtype)
    return cur


def _tau_vector(node: events.LayerNode, params: Dict[str, Any]) -> Array:
    neuron = node.neuron
    if type(neuron) is PLIF:
        return jax.nn.sigmoid(
            params[node.name]["neuron"]["w_tau"].astype(jnp.float32))
    return jnp.full((node.out_dim,), neuron.tau, jnp.float32)


def _run_fused(node: events.LayerNode, kind: str, params: Dict[str, Any],
               outs: Dict[str, Array], state: Dict[str, Any],
               new_state: Dict[str, Any], T: int, B: int) -> None:
    cur = _hoisted_current(node, params, outs, state, T, B)
    neuron = node.neuron
    v0 = state[node.name]["v"]
    if type(neuron) is LI:
        a = jnp.broadcast_to(jnp.asarray(neuron.tau, cur.dtype), cur.shape)
        out, vT = linrec(a, cur, v0)
    elif kind == FUSED_REC:
        out, vT = lifrec_scan(cur, params[node.name]["w_self"],
                              _tau_vector(node, params), v0,
                              state[node.name]["out"], neuron.v_th,
                              neuron.surrogate, neuron.alpha)
    else:
        out, vT = lif_scan(cur, _tau_vector(node, params), v0, neuron.v_th,
                           neuron.surrogate, neuron.alpha)
    outs[node.name] = out
    ns = {"v": vT, "out": out[-1]}
    if "ring" in state[node.name]:
        ns["ring"] = _advance_ring(state[node.name]["ring"], out)
    new_state[node.name] = ns


def _run_fallback(seg: Segment, nodes_by_name: Dict[str, events.LayerNode],
                  params: Dict[str, Any], x: Array, outs: Dict[str, Array],
                  state: Dict[str, Any], new_state: Dict[str, Any],
                  T: int) -> None:
    seg_nodes = [nodes_by_name[name] for name in seg.names]
    seg_names = set(seg.names)
    sub_state = {name: state[name] for name in seg.names}
    ext: Dict[str, Array] = {}
    for n in seg_nodes:
        for src in n.inputs:
            name, d = events._parse_src(src)
            if name == "self" or name in seg_names or src in ext:
                continue
            if name == "input" and d == 0:
                continue                 # events.step already emits x_t
            ext[src] = _feed_full(outs, state, name, d, T)

    def body(st, ts):
        x_t, ext_t = ts
        st, _ = events.step(seg_nodes, params, st, x_t, ext=ext_t)
        return st, {name: st[name]["out"] for name in seg.names}

    final_sub, rec = jax.lax.scan(body, sub_state, (x, ext))
    outs.update(rec)
    new_state.update(final_sub)


def run(nodes: List[events.LayerNode], params: Dict[str, Any], x: Array,
        state: Optional[Dict[str, Any]] = None, record: Tuple[str, ...] = (),
        plan: Optional[Plan] = None):
    """Drop-in replacement for `events.run` through the compiled plan.

    x: (T, batch, n_in). Returns (final_state, outputs (T, batch, n_out),
    recorded dict) — numerically equivalent to the stepper.
    """
    if engine_mode() == "stepper":
        return events.run(nodes, params, x, state, record)
    if plan is None:
        plan = compile_program(nodes)
    if plan.fully_fallback:
        return events.run(nodes, params, x, state, record)

    T, B = x.shape[0], x.shape[1]
    if state is None:
        state = events.init_state(nodes, B, x.dtype)
    nodes_by_name = {n.name: n for n in nodes}
    outs: Dict[str, Array] = {"input": x}
    new_state = dict(state)
    for seg in plan.segments:
        if seg.kind == FALLBACK:
            _run_fallback(seg, nodes_by_name, params, x, outs, state,
                          new_state, T)
        else:
            _run_fused(nodes_by_name[seg.names[0]], seg.kind, params, outs,
                       state, new_state, T, B)
    recs = {r: outs[r] for r in record}
    return new_state, outs[nodes[-1].name], recs


__all__ = ["Plan", "Segment", "compile_program", "engine_mode", "run",
           "FUSED_FF", "FUSED_REC", "FALLBACK"]
