"""Deterministic, seedable fault injection for the execution runtime.

Neuromorphic deployments are fault-prone by design: spike packets drop on
the NoC, cores die and leave their neuron rows silent (or stuck firing),
weight SRAM takes bit-flips. A runtime that claims to serve always-on
streaming workloads has to stay correct-enough — and above all *defined* —
under those faults, so this module makes them injectable on demand:

  data faults (applied inside the engines, jit-safe, fully deterministic)
    drop_blocks   packet loss: whole (bt x bn) tiles of the input raster
                  zeroed.            p=<frac>, bt=8, bn=128, seed=<int>
    dead_rows     dead/stuck neuron rows at node outputs.
                  frac=<frac>, mode=dead|stuck, node=<name or *>, seed
    bitflip       weight-plane sign flips on "w_*" params.
                  frac=<frac>, seed
    nan_weights   weight-plane NaN poisoning on "w_*" params.
                  frac=<frac>, seed

  infrastructure faults (applied at dispatch / tuning time)
    compile_fail  forces the Pallas stage of kernel dispatch to raise
                  `FaultInjectedError`, exercising the registry fallback
                  chain.  kernels=<name|name2|...| * >, p=<frac>, seed,
                  autotune=1 to also fail autotuner candidate probes
    vmem_limit    simulated VMEM pressure: the effective budget becomes
                  min(REPRO_VMEM_LIMIT_MB, mb).     mb=<float>

Faults are specified as `kind:key=val,key=val` clauses joined with ";",
either in the `REPRO_FAULTS` env var or pushed with the `inject()` context
manager (which *replaces* the env spec while active, so tests are
deterministic under a chaos-CI environment). All randomness derives from
`jax.random.PRNGKey(seed)` folded with a crc32 site label: the same spec
produces bit-identical masks eagerly and under jit, across processes, and
the masks for node outputs depend only on the neuron axis — so the fused
plan engine and the per-step stepper see *exactly* the same fault.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

_ENV = "REPRO_FAULTS"

KINDS = ("drop_blocks", "dead_rows", "bitflip", "nan_weights",
         "compile_fail", "vmem_limit")


class FaultInjectedError(RuntimeError):
    """The exception injected infrastructure faults raise."""


@dataclasses.dataclass(frozen=True)
class Fault:
    kind: str
    params: Tuple[Tuple[str, str], ...] = ()

    def get(self, key: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == key:
                return v
        return default

    def getf(self, key: str, default: float) -> float:
        return float(self.get(key, default))

    def geti(self, key: str, default: int) -> int:
        return int(float(self.get(key, default)))


def parse(spec: str) -> Tuple[Fault, ...]:
    """Parse a REPRO_FAULTS spec string into Fault clauses."""
    out: List[Fault] = []
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        kind, _, rest = clause.partition(":")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in {_ENV} "
                             f"(known: {', '.join(KINDS)})")
        params = []
        for kv in rest.split(","):
            kv = kv.strip()
            if not kv:
                continue
            k, sep, v = kv.partition("=")
            if not sep:
                raise ValueError(f"fault param {kv!r} is not key=value "
                                 f"(clause {clause!r})")
            params.append((k.strip(), v.strip()))
        out.append(Fault(kind, tuple(params)))
    return tuple(out)


# ---------------------------------------------------------------------------
# active-fault resolution: context stack overrides env
# ---------------------------------------------------------------------------

_STACK: List[Tuple[Fault, ...]] = []
_ENV_CACHE: Tuple[str, Tuple[Fault, ...]] = ("", ())


def active() -> Tuple[Fault, ...]:
    """The faults in effect: innermost `inject()` context, else REPRO_FAULTS."""
    if _STACK:
        return _STACK[-1]
    global _ENV_CACHE
    spec = os.environ.get(_ENV, "")
    if spec != _ENV_CACHE[0]:
        _ENV_CACHE = (spec, parse(spec) if spec else ())
    return _ENV_CACHE[1]


@contextlib.contextmanager
def inject(spec: str = ""):
    """Install a fault spec for the dynamic extent of the with-block.

    The spec *replaces* whatever REPRO_FAULTS / outer contexts carry
    (inject("") therefore disables all faults), keeping tests
    deterministic under a chaos-CI environment.
    """
    _STACK.append(parse(spec) if spec else ())
    try:
        yield
    finally:
        _STACK.pop()


def _select(kind: str) -> Tuple[Fault, ...]:
    return tuple(f for f in active() if f.kind == kind)


def _site_key(seed: int, site: str) -> jax.Array:
    """Deterministic PRNG key for a (seed, site) pair; crc32 keeps the site
    hash stable across processes (Python's hash() is salted)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed),
                              zlib.crc32(site.encode()) & 0x7FFFFFFF)


def _hits(name: str, patterns: str) -> bool:
    pats = [p for p in patterns.split("|") if p]
    return "*" in pats or name in pats


# ---------------------------------------------------------------------------
# data faults
# ---------------------------------------------------------------------------


def perturb_input(x: jax.Array) -> jax.Array:
    """Apply `drop_blocks` packet loss to the (T, B, N) input raster.

    Whole (bt x bn) time-by-neuron tiles are zeroed across the batch —
    the software image of spike packets lost in transit. Identity when no
    drop_blocks fault is active.
    """
    for f in _select("drop_blocks"):
        p = f.getf("p", 0.05)
        bt, bn = f.geti("bt", 8), f.geti("bn", 128)
        seed = f.geti("seed", 0)
        T, N = x.shape[0], x.shape[-1]
        gt, gn = -(-T // bt), -(-N // bn)
        key = _site_key(seed, f"drop_blocks:{T}x{N}")
        keep = (jax.random.uniform(key, (gt, gn)) >= p)
        mask = jnp.repeat(jnp.repeat(keep, bt, 0)[:T], bn, 1)[:, :N]
        shape = (T,) + (1,) * (x.ndim - 2) + (N,)
        x = x * mask.reshape(shape).astype(x.dtype)
    return x


def perturb_output(node: str, out: jax.Array) -> jax.Array:
    """Apply `dead_rows` (dead / stuck-at-1 neuron rows) to a node output.

    The mask depends only on (seed, node, N) — never on time — so
    applying it per-step in the stepper and once on the full (T, B, N)
    tensor in the fused engine yields bit-identical results.
    """
    for f in _select("dead_rows"):
        if not _hits(node, str(f.get("node", "*"))):
            continue
        frac = f.getf("frac", 0.05)
        mode = str(f.get("mode", "dead"))
        seed = f.geti("seed", 0)
        N = out.shape[-1]
        key = _site_key(seed, f"dead_rows:{node}:{N}")
        hit = jax.random.uniform(key, (N,)) < frac
        if mode == "stuck":
            out = jnp.where(hit, jnp.ones((), out.dtype), out)
        else:
            out = out * (~hit).astype(out.dtype)
    return out


def _poison_plane(w: jax.Array, site: str, frac: float, seed: int,
                  nan: bool) -> jax.Array:
    if not jnp.issubdtype(w.dtype, jnp.floating):
        return w
    key = _site_key(seed, site)
    hit = jax.random.uniform(key, w.shape) < frac
    if nan:
        return jnp.where(hit, jnp.asarray(jnp.nan, w.dtype), w)
    return jnp.where(hit, -w, w)          # sign bit-flip


def perturb_params(params: Dict[str, Any]) -> Dict[str, Any]:
    """Apply `bitflip` / `nan_weights` poisoning to every "w_*" weight
    plane in a two-level SNN params dict. Identity when inactive."""
    flips = _select("bitflip")
    nans = _select("nan_weights")
    if not flips and not nans:
        return params
    out = dict(params)
    for node, sub in params.items():
        if not isinstance(sub, dict):
            continue
        new = dict(sub)
        for k, v in sub.items():
            if not k.startswith("w_") or not hasattr(v, "dtype"):
                continue
            for f in flips:
                new[k] = _poison_plane(new[k], f"bitflip:{node}/{k}",
                                       f.getf("frac", 1e-3),
                                       f.geti("seed", 0), nan=False)
            for f in nans:
                new[k] = _poison_plane(new[k], f"nan:{node}/{k}",
                                       f.getf("frac", 1e-3),
                                       f.geti("seed", 0), nan=True)
        out[node] = new
    return out


# ---------------------------------------------------------------------------
# infrastructure faults
# ---------------------------------------------------------------------------


def _fails(f: Fault, kernel: str) -> bool:
    if not _hits(kernel, str(f.get("kernels", "*"))):
        return False
    p = f.getf("p", 1.0)
    if p >= 1.0:
        return True
    seed = f.geti("seed", 0)
    # deterministic per (kernel, seed): the same kernels fail all run long
    return (zlib.crc32(f"{kernel}:{seed}".encode()) % 10000) < p * 10000


def maybe_fail_compile(kernel: str, autotune: bool = False) -> None:
    """Raise `FaultInjectedError` when a compile_fail fault targets
    `kernel`. Dispatch calls this at the top of its Pallas stage(s);
    the autotuner opts in per-candidate only for specs with autotune=1."""
    for f in _select("compile_fail"):
        if autotune and str(f.get("autotune", "0")) != "1":
            continue
        if _fails(f, kernel):
            raise FaultInjectedError(
                f"injected kernel compile failure for {kernel!r}")


def vmem_limit_override_bytes() -> Optional[int]:
    """Simulated VMEM pressure: the smallest injected `vmem_limit` budget
    in bytes, or None when the fault is inactive. The effective budget is
    min(env limit, this) — pressure only ever shrinks the budget."""
    faults = _select("vmem_limit")
    if not faults:
        return None
    return int(min(f.getf("mb", 1.0) for f in faults) * 2 ** 20)


def describe(faults: Optional[Sequence[Fault]] = None) -> str:
    fs = active() if faults is None else tuple(faults)
    return "; ".join(
        f.kind + (":" + ",".join(f"{k}={v}" for k, v in f.params)
                  if f.params else "")
        for f in fs) or "(none)"


__all__ = ["Fault", "FaultInjectedError", "KINDS", "active", "describe",
           "inject", "maybe_fail_compile", "parse", "perturb_input",
           "perturb_output", "perturb_params", "vmem_limit_override_bytes"]
