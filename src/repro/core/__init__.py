"""core — TaiBai's primary contribution as composable JAX modules.

The paper's "brain-inspired instruction set" (Table I) becomes a neuron-
dynamics DSL built on two primitives:

  diff(v, tau, c)   — the DIFF instruction: first-order ODE step v' = tau*v + c
  locacc(spikes, w) — the LOCACC/FINDIDX pair: event-driven current accumulation

The 2-level fan-in/fan-out topology tables (Fig. 4-8) are `topology.py`;
the INTEG/FIRE phase machine (Fig. 10) is `events.py`; on-chip learning
(STDP + accumulated-spike backprop, Fig. 9d-e) is `plasticity.py`; the
compiler stack (Fig. 12) is `mapping.py`; the behavioural chip simulator
(§V-B) is `simulator.py`.
"""
