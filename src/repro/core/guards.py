"""Numerical guardrails for `plan.run`: finite checks, spike-rate
monitors, and chunked-online divergence detection.

An always-on streaming SNN fails in characteristic ways: a NaN sneaks into
a weight plane and silently poisons every window after it; a mis-tuned
threshold drives a population silent (rate 0) or saturated (rate ~1); an
unstable plasticity rule blows the learned weights up over a few windows.
Guards make those states *observable and survivable* instead of silent:

  policy (REPRO_GUARD env or `plan.run(guard=...)`):
    off       no checks, zero inserted ops (the default)
    warn      violations emit a warning and a "guard" incident on the
              per-process log (`repro.kernels.incidents()`)
    raise     violations raise `GuardViolation` when the value is
              concrete; under jit tracing this degrades to `warn` via a
              host callback (a traced value cannot abort the computation
              — run eagerly or use checkify semantics for hard aborts)
    sanitize  violations are repaired in-graph (jit-safe, deterministic):
              nonfinite activations become 0, a diverged learned-weight
              window rolls back to its entry tensor

  checks:
    check_tensor   nonfinite values in activations / carried state
    check_spikes   population silence (mean rate <= rate_silence) and
                   saturation (mean rate >= rate_saturation)
    guard_learned  chunked-online divergence: nonfinite learned entries
                   fall back elementwise, and a weight-norm explosion
                   (||w1|| > w_ratio_max * (||w0|| + 1)) rolls the whole
                   window's learned tensor back to the entry weights
"""

from __future__ import annotations

import dataclasses
import functools
import os
import warnings
from typing import Union

import jax
import jax.numpy as jnp

# import the submodule directly: the `repro.kernels` package re-exports an
# `incidents()` *function* that shadows the module attribute of the same name
from repro.kernels.incidents import FallbackEvent, record as _record_incident

_ENV = "REPRO_GUARD"
POLICIES = ("off", "warn", "raise", "sanitize")


class GuardViolation(RuntimeError):
    """Raised by policy="raise" on a concrete guard violation."""


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    policy: str = "off"
    finite: bool = True              # nonfinite activation/state check
    rate_silence: float = 0.0        # mean spike rate <= this => silent
    rate_saturation: float = 0.98    # mean spike rate >= this => saturated
    w_ratio_max: float = 16.0        # learned-vs-entry weight norm blowup

    @property
    def active(self) -> bool:
        return self.policy != "off"


def config(policy: Union[None, str, GuardConfig] = None) -> GuardConfig:
    """Resolve a guard policy: explicit arg > REPRO_GUARD env > off."""
    if isinstance(policy, GuardConfig):
        return policy
    if policy is None:
        policy = os.environ.get(_ENV, "off")
    if policy not in POLICIES:
        raise ValueError(f"{_ENV}={policy!r}: expected one of "
                         f"{', '.join(POLICIES)}")
    return GuardConfig(policy=policy)


def _notify(tag: str, msg: str, policy: str) -> None:
    """Host-side violation handler (concrete values and jit callbacks)."""
    _record_incident(FallbackEvent(
        kind="guard", family=tag, stage=policy, error=msg))
    if policy == "raise":
        raise GuardViolation(f"[REPRO_GUARD] {tag}: {msg}")
    warnings.warn(f"[REPRO_GUARD] {tag}: {msg}", RuntimeWarning,
                  stacklevel=3)


def _host_flag(bad, *, tag: str, msg: str, policy: str) -> None:
    if bool(bad):
        # inside jit a raise cannot abort the traced computation; degrade
        # to warn so the violation is still observable on the incident log
        _notify(tag, msg, "warn" if policy == "raise" else policy)


def _flag(tag: str, bad: jax.Array, msg: str, cfg: GuardConfig) -> None:
    """Act on a scalar bool violation flag, traced or concrete."""
    if isinstance(bad, jax.core.Tracer):
        jax.debug.callback(functools.partial(_host_flag, tag=tag, msg=msg,
                                             policy=cfg.policy), bad)
    elif bool(bad):
        _notify(tag, msg, cfg.policy)


def check_tensor(tag: str, x: jax.Array, cfg: GuardConfig) -> jax.Array:
    """Finite check on one activation/state tensor. Returns x, sanitized
    (nonfinite -> 0) under policy="sanitize"."""
    if not cfg.active or not cfg.finite:
        return x
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x                       # integer spikes cannot be nonfinite
    finite = jnp.isfinite(x)
    if cfg.policy == "sanitize":
        return jnp.where(finite, x, jnp.zeros((), x.dtype))
    _flag(tag, ~finite.all(), "nonfinite values detected", cfg)
    return x


def check_spikes(tag: str, spikes: jax.Array, cfg: GuardConfig) -> None:
    """Silence / saturation monitor on an emitted spike train."""
    if not cfg.active or cfg.policy == "sanitize":
        return                         # rates are a symptom, not repairable
    rate = jnp.mean(spikes.astype(jnp.float32))
    _flag(tag, rate <= cfg.rate_silence,
          f"population silent (mean rate <= {cfg.rate_silence})", cfg)
    _flag(tag, rate >= cfg.rate_saturation,
          f"population saturated (mean rate >= {cfg.rate_saturation})", cfg)


def guard_learned(tag: str, w0: jax.Array, w1: jax.Array,
                  cfg: GuardConfig) -> jax.Array:
    """Chunked-online divergence guard on one window's learned weights.

    w0 is the window's entry tensor, w1 the learned result. Under
    "sanitize", nonfinite entries fall back elementwise — to the entry
    value, or to 0 where the entry itself is already poisoned — and a
    norm explosion rolls the whole window back (jit-safe selects);
    otherwise violations warn/raise and w1 passes through.
    """
    if not cfg.active:
        return w1
    finite = jnp.isfinite(w1)
    n0 = jnp.linalg.norm(w0.astype(jnp.float32))
    n1 = jnp.linalg.norm(jnp.where(finite, w1, 0).astype(jnp.float32))
    exploded = n1 > cfg.w_ratio_max * (n0 + 1.0)
    if cfg.policy == "sanitize":
        safe0 = jnp.where(jnp.isfinite(w0), w0, jnp.zeros((), w0.dtype))
        w1 = jnp.where(finite, w1, safe0)
        return jnp.where(exploded, safe0, w1)
    _flag(tag, ~finite.all(), "nonfinite learned weights", cfg)
    _flag(tag, exploded,
          f"learned-weight norm explosion (> {cfg.w_ratio_max}x entry)", cfg)
    return w1


__all__ = ["GuardConfig", "GuardViolation", "POLICIES", "config",
           "check_tensor", "check_spikes", "guard_learned"]
