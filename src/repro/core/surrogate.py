"""Surrogate gradients for the non-differentiable fire operation (STBP, §II-A).

The forward pass is an exact Heaviside step (spikes are binary, as on chip);
the backward pass substitutes a smooth proxy so BPTT can train through the
fire stage. The paper cites Wu et al. 2018 (STBP) which uses a rectangular
window; we also provide sigmoid' and arctan' proxies, selectable per neuron —
"fully programmable" applies to the learning rule too.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_SURROGATES = {}


def register(name):
    def deco(fn):
        _SURROGATES[name] = fn
        return fn
    return deco


@register("rectangle")
def _rectangle_grad(x, alpha):
    # STBP h1: 1/alpha inside a window of width alpha around the threshold.
    return (jnp.abs(x) < (alpha / 2.0)).astype(x.dtype) / alpha


@register("sigmoid")
def _sigmoid_grad(x, alpha):
    s = jax.nn.sigmoid(alpha * x)
    return alpha * s * (1.0 - s)


@register("arctan")
def _arctan_grad(x, alpha):
    return alpha / (2.0 * (1.0 + (jnp.pi / 2.0 * alpha * x) ** 2))


@register("triangle")
def _triangle_grad(x, alpha):
    return jnp.maximum(0.0, 1.0 - jnp.abs(alpha * x)) * alpha


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def spike(v_minus_th, surrogate: str = "rectangle", alpha: float = 1.0):
    """Heaviside(v - v_th) with a surrogate gradient.

    Args:
      v_minus_th: membrane potential minus threshold.
      surrogate: one of {rectangle, sigmoid, arctan, triangle}.
      alpha: surrogate sharpness.
    Returns:
      binary spikes with the dtype of the input.
    """
    return (v_minus_th >= 0.0).astype(v_minus_th.dtype)


def _spike_fwd(v_minus_th, surrogate, alpha):
    return spike(v_minus_th, surrogate, alpha), v_minus_th


def _spike_bwd(surrogate, alpha, res, ct):
    v_minus_th = res
    g = _SURROGATES[surrogate](v_minus_th, jnp.asarray(alpha, v_minus_th.dtype))
    return (ct * g,)


spike.defvjp(_spike_fwd, _spike_bwd)


def surrogate_names():
    return sorted(_SURROGATES)
