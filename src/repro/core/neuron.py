"""Programmable neuron dynamics — the TaiBai instruction set as a JAX DSL.

TaiBai's Table I defines five special instructions; here they are the
primitives every neuron model is written in:

  diff(v, tau, c)    DIFF    first-order ODE step  v' = tau * v + c
  locacc(s, w)       LOCACC  current accumulation  I = s @ w   (event-driven)
  findidx(...)       FINDIDX bitmap-compressed sparse weight lookup
  spike(...)         SEND    threshold + emit (surrogate gradient in training)
  (RECV is implicit: a neuron's step function runs when events arrive — on
   TPU, when its timestep slice is scanned.)

A neuron model is a `NeuronSpec`: `init_state(shape)` plus a `step(state,
current) -> (state, spikes)` written only in terms of the primitives. The
INTEG/FIRE split of the chip (§IV-A) maps onto `integrate` (current
accumulation happens outside, in the layer) and `fire` (this module).

Models provided (all used by the paper's applications, §V-B3):
  LIF     eqs. (1)-(3)
  PLIF    LIF with learnable decay (parameterized via sigmoid)
  ALIF    adaptive threshold (Yin et al. 2021) — ECG SRNN hidden layer
  DHLIF   multi-branch dendritic LIF (Zheng et al. 2024) — SHD speech task
  LI      non-spiking leaky-integrator readout (DHSNN/SRNN output layers)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.surrogate import spike

Array = jax.Array
State = Dict[str, Array]


def diff(v: Array, tau, c) -> Array:
    """The DIFF instruction: one Euler step of dv/dt = -(1-tau) v + input.

    TaiBai accelerates exactly this form (`v = tau*v + c`) in hardware; the
    Pallas `linrec` kernel is the TPU analogue for time-batched execution.
    """
    return tau * v + c


def locacc(spikes: Array, weights: Array) -> Array:
    """The LOCACC instruction: accumulate presynaptic events into currents.

    Dense reference form. The event-gated Pallas kernel (`kernels/spikemm`)
    is the TPU analogue exploiting spatio-temporal spike sparsity.
    """
    return spikes @ weights


def findidx(bitmap: Array, packed_weights: Array, axon_id) -> Array:
    """The FINDIDX instruction: bitmap-based sparse weight lookup.

    `bitmap` is a (n_axons, n_neurons) 0/1 connectivity mask; weights for
    axon `a` are packed contiguously (CSR-style). FINDIDX computes, for a
    given axon, the dense weight row by scattering the packed run back to
    neuron positions — the chip does this with a popcount prefix; we do it
    with a cumulative-sum prefix (identical semantics).
    """
    row = bitmap[axon_id]                       # (n_neurons,) 0/1
    # position of each neuron's weight inside the packed run for this axon
    prefix = jnp.cumsum(row) - 1                # index into packed row
    row_start = jnp.sum(jnp.cumsum(jnp.sum(bitmap, axis=1))[axon_id]) - jnp.sum(bitmap[axon_id])
    gathered = packed_weights[row_start + prefix]
    return jnp.where(row > 0, gathered, 0.0)


# ---------------------------------------------------------------------------
# Neuron specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NeuronSpec:
    """Base class: a programmable neuron is (init_state, fire)."""

    surrogate: str = "rectangle"
    alpha: float = 1.0

    def init_state(self, shape, dtype=jnp.float32) -> State:
        raise NotImplementedError

    def fire(self, state: State, current: Array, params: Dict[str, Any] | None = None
             ) -> Tuple[State, Array]:
        """One FIRE-stage update given the INTEG-stage current."""
        raise NotImplementedError

    def param_init(self, key, shape) -> Dict[str, Array]:
        """Learnable per-neuron parameters (empty for fixed models)."""
        return {}


@dataclasses.dataclass(frozen=True)
class LIF(NeuronSpec):
    """Leaky integrate-and-fire, paper eqs. (1)-(3). Hard reset to zero."""

    tau: float = 0.9
    v_th: float = 1.0

    def init_state(self, shape, dtype=jnp.float32):
        return {"v": jnp.zeros(shape, dtype)}

    def fire(self, state, current, params=None):
        v = diff(state["v"], jnp.asarray(self.tau, current.dtype), current)
        s = spike(v - self.v_th, self.surrogate, self.alpha)
        v = v * (1.0 - s)                       # reset-to-zero (eq. 3)
        return {"v": v}, s


@dataclasses.dataclass(frozen=True)
class PLIF(NeuronSpec):
    """Parametric LIF: decay is a learnable per-neuron parameter.

    tau = sigmoid(w_tau) keeps the decay in (0, 1); used by PLIF-Net
    (Table II benchmark).
    """

    v_th: float = 1.0
    tau_init: float = 2.0     # sigmoid(2.0) ~= 0.88

    def init_state(self, shape, dtype=jnp.float32):
        return {"v": jnp.zeros(shape, dtype)}

    def param_init(self, key, shape):
        return {"w_tau": jnp.full(shape[-1:], self.tau_init, jnp.float32)}

    def fire(self, state, current, params=None):
        tau = jax.nn.sigmoid(params["w_tau"]).astype(current.dtype)
        v = diff(state["v"], tau, current)
        s = spike(v - self.v_th, self.surrogate, self.alpha)
        v = v * (1.0 - s)
        return {"v": v}, s


@dataclasses.dataclass(frozen=True)
class ALIF(NeuronSpec):
    """Adaptive-threshold LIF (Yin/Corradi/Bohte 2021), the paper's ECG model.

    Threshold: th(t) = v_th + beta * a(t); a' = rho * a + s. The adaptation
    variable `a` rises after every emitted spike and decays exponentially —
    neuronal heterogeneity comes from per-neuron (tau, rho) if trained.
    """

    tau: float = 0.9
    rho: float = 0.97        # adaptation decay
    beta: float = 1.8        # adaptation strength
    v_th: float = 1.0

    def init_state(self, shape, dtype=jnp.float32):
        return {"v": jnp.zeros(shape, dtype), "a": jnp.zeros(shape, dtype)}

    def param_init(self, key, shape):
        # heterogeneous time constants: learnable logits around the defaults
        n = shape[-1]
        k1, k2 = jax.random.split(key)
        return {
            "w_tau": jnp.log(self.tau / (1 - self.tau)) + 0.5 * jax.random.normal(k1, (n,)),
            "w_rho": jnp.log(self.rho / (1 - self.rho)) + 0.5 * jax.random.normal(k2, (n,)),
        }

    def fire(self, state, current, params=None):
        if params:
            tau = jax.nn.sigmoid(params["w_tau"]).astype(current.dtype)
            rho = jax.nn.sigmoid(params["w_rho"]).astype(current.dtype)
        else:
            tau = jnp.asarray(self.tau, current.dtype)
            rho = jnp.asarray(self.rho, current.dtype)
        v = diff(state["v"], tau, current)
        th = self.v_th + self.beta * state["a"]
        s = spike(v - th, self.surrogate, self.alpha)
        v = v * (1.0 - s)
        a = diff(state["a"], rho, s)            # DIFF drives adaptation too
        return {"v": v, "a": a}, s


@dataclasses.dataclass(frozen=True)
class DHLIF(NeuronSpec):
    """Dendritic-heterogeneity LIF (Zheng et al. 2024), the paper's SHD model.

    Each neuron has `n_branches` dendritic compartments with their own decay
    tau_d; branch currents are integrated separately (this is what forces the
    fan-in expansion on chip: 4 branches x 700 inputs = 2800 > 2048 fan-in
    limit, §V-B3) and summed into the soma.

    `fire` expects `current` of shape (..., n_branches, n) — one current per
    branch — mirroring the chip's PSUM-neuron decomposition.
    """

    n_branches: int = 4
    tau: float = 0.9
    v_th: float = 1.0

    def init_state(self, shape, dtype=jnp.float32):
        # shape is the soma shape (..., n); branch states add an axis.
        branch_shape = shape[:-1] + (self.n_branches,) + shape[-1:]
        return {"v": jnp.zeros(shape, dtype), "d": jnp.zeros(branch_shape, dtype)}

    def param_init(self, key, shape):
        n = shape[-1]
        # heterogeneous branch time constants — log-spaced around tau
        base = jnp.linspace(1.0, 6.0, self.n_branches)[:, None]
        return {"w_tau_d": jnp.broadcast_to(base, (self.n_branches, n)),
                "w_tau_s": jnp.full((n,), 2.0)}

    def fire(self, state, current, params=None):
        tau_d = jax.nn.sigmoid(params["w_tau_d"]).astype(current.dtype)
        tau_s = jax.nn.sigmoid(params["w_tau_s"]).astype(current.dtype)
        d = diff(state["d"], tau_d, current)    # per-branch DIFF
        soma_in = jnp.sum(d, axis=-2)           # dendrites -> soma
        v = diff(state["v"], tau_s, soma_in)
        s = spike(v - self.v_th, self.surrogate, self.alpha)
        v = v * (1.0 - s)
        return {"v": v, "d": d}, s


@dataclasses.dataclass(frozen=True)
class LI(NeuronSpec):
    """Non-spiking leaky integrator readout (no fire, no reset).

    The paper's speech output layer is 'a variant of the LIF neuron which
    does not exhibit spike firing and membrane potential resetting' — the
    classification is read from the membrane potential.
    """

    tau: float = 0.95

    def init_state(self, shape, dtype=jnp.float32):
        return {"v": jnp.zeros(shape, dtype)}

    def fire(self, state, current, params=None):
        v = diff(state["v"], jnp.asarray(self.tau, current.dtype), current)
        return {"v": v}, v                       # "spikes" = membrane readout


NEURON_REGISTRY = {
    "lif": LIF,
    "plif": PLIF,
    "alif": ALIF,
    "dhlif": DHLIF,
    "li": LI,
}


def make_neuron(name: str, **kwargs) -> NeuronSpec:
    return NEURON_REGISTRY[name](**kwargs)
