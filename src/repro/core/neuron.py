"""Programmable neuron dynamics — the TaiBai instruction set as a JAX DSL.

TaiBai's Table I defines five special instructions; here they are the
primitives every neuron model is written in:

  diff(v, tau, c)    DIFF    first-order ODE step  v' = tau * v + c
  locacc(s, w)       LOCACC  current accumulation  I = s @ w   (event-driven)
  findidx(...)       FINDIDX bitmap-compressed sparse weight lookup
  spike(...)         SEND    threshold + emit (surrogate gradient in training)
  (RECV is implicit: a neuron's step function runs when events arrive — on
   TPU, when its timestep slice is scanned.)

The FIRE stage itself is *declarative*: a neuron model is a
`NeuronProgram` — a list of DIFF state updates (each `StateVar` declares
its decay source and its drive), a threshold expression, a reset rule, and
an output selector — interpreted by one generic `NeuronSpec.fire`. Because
the dynamics are data rather than opaque Python, the execution-plan
compiler (`core/plan.py`) pattern-matches the program structure and lowers
matching programs to fused whole-time-axis kernels; anything else runs on
the always-correct stepper. This mirrors the chip's multi-granularity ISA
(§IV, Table I): user-defined dynamics compile onto the same substrate as
the built-ins instead of hitting a closed neuron menu.

Models provided (all used by the paper's applications, §V-B3), each a thin
dataclass factory producing its program:
  LIF     eqs. (1)-(3)
  PLIF    LIF with learnable decay (parameterized via sigmoid)
  ALIF    adaptive threshold (Yin et al. 2021) — ECG SRNN hidden layer
  DHLIF   multi-branch dendritic LIF (Zheng et al. 2024) — SHD speech task
  LI      non-spiking leaky-integrator readout (DHSNN/SRNN output layers)

Custom models: build a `NeuronProgram`, wrap it in `ProgramNeuron`, and
(optionally) `register_neuron("myneuron", factory)` so configs and CLIs can
name it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.surrogate import spike

Array = jax.Array
State = Dict[str, Array]


def diff(v: Array, tau, c) -> Array:
    """The DIFF instruction: one Euler step of dv/dt = -(1-tau) v + input.

    TaiBai accelerates exactly this form (`v = tau*v + c`) in hardware; the
    Pallas `linrec` kernel is the TPU analogue for time-batched execution.
    """
    return tau * v + c


def locacc(spikes: Array, weights: Array) -> Array:
    """The LOCACC instruction: accumulate presynaptic events into currents.

    Dense reference form. The event-gated Pallas kernel (`kernels/spikemm`)
    is the TPU analogue exploiting spatio-temporal spike sparsity. An
    `EncodedTopology` in weight position executes through its compressed IE
    tables (`apply_spikes`) — same currents, no dense matrix.
    """
    if hasattr(weights, "apply_spikes"):
        lead = spikes.shape[:-1]
        flat = spikes.reshape((-1, spikes.shape[-1]))
        return weights.apply_spikes(flat).reshape(lead + (weights.shape[1],))
    return spikes @ weights


def findidx(bitmap: Array, packed_weights: Array, axon_id) -> Array:
    """The FINDIDX instruction: bitmap-based sparse weight lookup.

    `bitmap` is a (n_axons, n_neurons) 0/1 connectivity mask; weights for
    axon `a` are packed contiguously (CSR-style). FINDIDX computes, for a
    given axon, the dense weight row by scattering the packed run back to
    neuron positions — the chip does this with a popcount prefix; we do it
    with a cumulative-sum prefix (identical semantics).
    """
    row = bitmap[axon_id]                       # (n_neurons,) 0/1
    # position of each neuron's weight inside the packed run for this axon
    prefix = jnp.cumsum(row) - 1                # index into packed row
    row_start = jnp.sum(jnp.cumsum(jnp.sum(bitmap, axis=1))[axon_id]) - jnp.sum(bitmap[axon_id])
    gathered = packed_weights[row_start + prefix]
    return jnp.where(row > 0, gathered, 0.0)


# ---------------------------------------------------------------------------
# the neuron-program IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Decay:
    """Where a state's DIFF decay comes from.

    kind:   "const"      — fixed `value` for every neuron
            "learned"    — sigmoid(params[param]), per-neuron logits;
                           `value` is the fallback when params are absent
            "per_branch" — like "learned" but the logits carry a leading
                           branch axis (shape (n_branches, n))
    """

    kind: str = "const"
    value: float = 0.9
    param: str = ""


@dataclasses.dataclass(frozen=True)
class StateVar:
    """One DIFF state update: state' = decay * state + drive.

    drive:  "current"      — the INTEG-stage input current
            "spikes"       — this step's emitted spikes (updates AFTER the
                             threshold fires, e.g. ALIF's adaptation trace)
            "sum:<state>"  — branch-sum of another (branch) state, e.g. the
                             DH-LIF soma integrating its dendrites
    branch: the state carries a leading dendritic-branch axis
            (shape (..., n_branches, n)); its drive arrives per branch.
    """

    name: str
    decay: Decay
    drive: str = "current"
    branch: bool = False


@dataclasses.dataclass(frozen=True)
class Threshold:
    """Spike condition: fire where  state[on] >= base + scale * state[adapt].

    `adapt=""` is the constant threshold; ALIF's moving threshold is
    `Threshold(base=v_th, adapt="a", scale=beta)`. The adaptation state is
    read at its pre-update (previous-step) value when it is spike-driven.
    """

    base: float = 1.0
    on: str = "v"
    adapt: str = ""
    scale: float = 0.0


@dataclasses.dataclass(frozen=True)
class NeuronProgram:
    """Declarative FIRE-stage dynamics.

    threshold=None describes a non-spiking integrator (no reset either);
    reset "zero" is the hard reset of eq. (3), "none" skips it; output is
    "spikes" or the name of a state to read out (LI reads its membrane).
    """

    states: Tuple[StateVar, ...]
    threshold: Optional[Threshold] = None
    reset: str = "zero"
    output: str = "spikes"
    n_branches: int = 1


def validate_program(prog: NeuronProgram) -> NeuronProgram:
    """Raise ValueError on a structurally invalid program; return it."""
    names = [sv.name for sv in prog.states]
    if not names:
        raise ValueError("program needs at least one state")
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate state names: {names}")
    for sv in prog.states:
        if sv.decay.kind not in ("const", "learned", "per_branch"):
            raise ValueError(f"state {sv.name!r}: bad decay kind "
                             f"{sv.decay.kind!r}")
        if sv.decay.kind != "const" and not sv.decay.param:
            raise ValueError(f"state {sv.name!r}: {sv.decay.kind} decay "
                             "needs a param name")
        if sv.decay.kind == "per_branch" and not sv.branch:
            raise ValueError(f"state {sv.name!r}: per_branch decay on a "
                             "non-branch state")
        if sv.drive.startswith("sum:"):
            src = sv.drive[4:]
            if src not in names:
                raise ValueError(f"state {sv.name!r} sums unknown state "
                                 f"{src!r}")
            if not next(s for s in prog.states if s.name == src).branch:
                raise ValueError(f"state {sv.name!r} sums non-branch state "
                                 f"{src!r}")
            if sv.branch:
                raise ValueError(f"branch state {sv.name!r} cannot be "
                                 "sum-driven")
        elif sv.drive == "spikes":
            if prog.threshold is None:
                raise ValueError(f"state {sv.name!r} is spike-driven but "
                                 "the program never spikes")
        elif sv.drive != "current":
            raise ValueError(f"state {sv.name!r}: bad drive {sv.drive!r}")
    if prog.threshold is not None:
        th = prog.threshold
        if th.on not in names:
            raise ValueError(f"threshold on unknown state {th.on!r}")
        if next(s for s in prog.states if s.name == th.on).branch:
            raise ValueError("threshold cannot fire on a branch state")
        if th.adapt:
            if th.adapt not in names:
                raise ValueError(f"threshold adapts on unknown state "
                                 f"{th.adapt!r}")
            if next(s for s in prog.states if s.name == th.adapt).branch:
                raise ValueError("threshold cannot adapt on a branch state")
    if prog.reset not in ("zero", "subtract", "none"):
        raise ValueError(f"bad reset {prog.reset!r}")
    if prog.output != "spikes":
        if prog.output not in names:
            raise ValueError(f"output selects unknown state {prog.output!r}")
        if next(s for s in prog.states if s.name == prog.output).branch:
            raise ValueError("output cannot select a branch state")
    if prog.output == "spikes" and prog.threshold is None:
        raise ValueError("spike output needs a threshold")
    if prog.n_branches < 1:
        raise ValueError(f"n_branches must be >= 1, got {prog.n_branches}")
    return prog


def decay_array(decay: Decay, params: Optional[Dict[str, Array]],
                dtype) -> Array:
    """Resolve a Decay to a concrete decay factor in (0, 1)."""
    if decay.kind != "const" and params and decay.param in params:
        return jax.nn.sigmoid(params[decay.param]).astype(dtype)
    return jnp.asarray(decay.value, dtype)


def program_fire(prog: NeuronProgram, state: State, current: Array,
                 params: Optional[Dict[str, Any]], surrogate: str,
                 alpha: float) -> Tuple[State, Array]:
    """Interpret one FIRE-stage step of a NeuronProgram.

    Phase order: current-/sum-driven states update first (in declaration
    order, so a sum-driven soma sees its branches' NEW values), then the
    threshold fires and resets, then spike-driven states integrate the
    fresh spikes — exactly the per-model closed forms the programs replace.
    """
    dtype = current.dtype
    vals = {sv.name: state[sv.name] for sv in prog.states}
    for sv in prog.states:
        if sv.drive == "spikes":
            continue
        c = (current if sv.drive == "current"
             else jnp.sum(vals[sv.drive[4:]], axis=-2))
        vals[sv.name] = diff(vals[sv.name], decay_array(sv.decay, params,
                                                        dtype), c)
    if prog.threshold is None:
        return vals, vals[prog.output]
    th = prog.threshold
    level = th.base + (th.scale * vals[th.adapt] if th.adapt else 0.0)
    s = spike(vals[th.on] - level, surrogate, alpha)
    if prog.reset == "zero":
        vals[th.on] = vals[th.on] * (1.0 - s)
    elif prog.reset == "subtract":
        vals[th.on] = vals[th.on] - level * s
    for sv in prog.states:
        if sv.drive == "spikes":
            vals[sv.name] = diff(vals[sv.name], decay_array(sv.decay, params,
                                                            dtype), s)
    return vals, (s if prog.output == "spikes" else vals[prog.output])


# ---------------------------------------------------------------------------
# Neuron specs (thin factories over programs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NeuronSpec:
    """Base class: a programmable neuron is a NeuronProgram plus the
    surrogate-gradient choice; `init_state` and `fire` are generic
    interpreters over `self.program`."""

    surrogate: str = "rectangle"
    alpha: float = 1.0

    @property
    def program(self) -> NeuronProgram:
        raise NotImplementedError

    def init_state(self, shape, dtype=jnp.float32) -> State:
        prog = self.program
        state = {}
        for sv in prog.states:
            s = (shape[:-1] + (prog.n_branches,) + shape[-1:] if sv.branch
                 else tuple(shape))
            state[sv.name] = jnp.zeros(s, dtype)
        return state

    def fire(self, state: State, current: Array,
             params: Dict[str, Any] | None = None) -> Tuple[State, Array]:
        """One FIRE-stage update given the INTEG-stage current."""
        return program_fire(self.program, state, current, params,
                            self.surrogate, self.alpha)

    def param_init(self, key, shape) -> Dict[str, Array]:
        """Learnable per-neuron parameters (empty for fixed models)."""
        return {}


@dataclasses.dataclass(frozen=True)
class ProgramNeuron(NeuronSpec):
    """A NeuronSpec defined directly by its program — the user-space entry
    point for custom dynamics. Validates at construction; fusable patterns
    (see `plan._match_fire_pattern`) get kernel lowering for free."""

    prog: NeuronProgram = NeuronProgram(
        states=(StateVar("v", Decay("const", 0.9)),), threshold=Threshold())

    def __post_init__(self):
        validate_program(self.prog)

    @property
    def program(self) -> NeuronProgram:
        return self.prog


@dataclasses.dataclass(frozen=True)
class LIF(NeuronSpec):
    """Leaky integrate-and-fire, paper eqs. (1)-(3). Hard reset to zero by
    default; `reset="subtract"` keeps the suprathreshold residue
    (v <- v - v_th on spike), the convention rate-coded converters use."""

    tau: float = 0.9
    v_th: float = 1.0
    reset: str = "zero"

    @property
    def program(self) -> NeuronProgram:
        return NeuronProgram(
            states=(StateVar("v", Decay("const", self.tau)),),
            threshold=Threshold(base=self.v_th), reset=self.reset)


@dataclasses.dataclass(frozen=True)
class PLIF(NeuronSpec):
    """Parametric LIF: decay is a learnable per-neuron parameter.

    tau = sigmoid(w_tau) keeps the decay in (0, 1); used by PLIF-Net
    (Table II benchmark).
    """

    v_th: float = 1.0
    tau_init: float = 2.0     # sigmoid(2.0) ~= 0.88

    @property
    def program(self) -> NeuronProgram:
        fallback = 1.0 / (1.0 + math.exp(-self.tau_init))
        return NeuronProgram(
            states=(StateVar("v", Decay("learned", fallback, "w_tau")),),
            threshold=Threshold(base=self.v_th))

    def param_init(self, key, shape):
        return {"w_tau": jnp.full(shape[-1:], self.tau_init, jnp.float32)}


@dataclasses.dataclass(frozen=True)
class ALIF(NeuronSpec):
    """Adaptive-threshold LIF (Yin/Corradi/Bohte 2021), the paper's ECG model.

    Threshold: th(t) = v_th + beta * a(t); a' = rho * a + s. The adaptation
    variable `a` rises after every emitted spike and decays exponentially —
    neuronal heterogeneity comes from per-neuron (tau, rho) if trained.
    """

    tau: float = 0.9
    rho: float = 0.97        # adaptation decay
    beta: float = 1.8        # adaptation strength
    v_th: float = 1.0

    @property
    def program(self) -> NeuronProgram:
        return NeuronProgram(
            states=(StateVar("v", Decay("learned", self.tau, "w_tau")),
                    StateVar("a", Decay("learned", self.rho, "w_rho"),
                             drive="spikes")),
            threshold=Threshold(base=self.v_th, adapt="a", scale=self.beta))

    def param_init(self, key, shape):
        # heterogeneous time constants: learnable logits around the defaults
        n = shape[-1]
        k1, k2 = jax.random.split(key)
        return {
            "w_tau": jnp.log(self.tau / (1 - self.tau)) + 0.5 * jax.random.normal(k1, (n,)),
            "w_rho": jnp.log(self.rho / (1 - self.rho)) + 0.5 * jax.random.normal(k2, (n,)),
        }


@dataclasses.dataclass(frozen=True)
class DHLIF(NeuronSpec):
    """Dendritic-heterogeneity LIF (Zheng et al. 2024), the paper's SHD model.

    Each neuron has `n_branches` dendritic compartments with their own decay
    tau_d; branch currents are integrated separately (this is what forces the
    fan-in expansion on chip: 4 branches x 700 inputs = 2800 > 2048 fan-in
    limit, §V-B3) and summed into the soma.

    `fire` expects `current` of shape (..., n_branches, n) — one current per
    branch — mirroring the chip's PSUM-neuron decomposition.
    """

    n_branches: int = 4
    tau: float = 0.9
    v_th: float = 1.0
    tau_s_init: float = 2.0   # soma-decay logit; sigmoid(2.0) ~= 0.88

    @property
    def program(self) -> NeuronProgram:
        soma_fallback = 1.0 / (1.0 + math.exp(-self.tau_s_init))
        return NeuronProgram(
            states=(StateVar("d", Decay("per_branch", self.tau, "w_tau_d"),
                             branch=True),
                    StateVar("v", Decay("learned", soma_fallback, "w_tau_s"),
                             drive="sum:d")),
            threshold=Threshold(base=self.v_th),
            n_branches=self.n_branches)

    def param_init(self, key, shape):
        n = shape[-1]
        # heterogeneous branch time constants — log-spaced around tau
        base = jnp.linspace(1.0, 6.0, self.n_branches)[:, None]
        return {"w_tau_d": jnp.broadcast_to(base, (self.n_branches, n)),
                "w_tau_s": jnp.full((n,), self.tau_s_init)}


@dataclasses.dataclass(frozen=True)
class LI(NeuronSpec):
    """Non-spiking leaky integrator readout (no fire, no reset).

    The paper's speech output layer is 'a variant of the LIF neuron which
    does not exhibit spike firing and membrane potential resetting' — the
    classification is read from the membrane potential.
    """

    tau: float = 0.95

    @property
    def program(self) -> NeuronProgram:
        return NeuronProgram(
            states=(StateVar("v", Decay("const", self.tau)),),
            threshold=None, reset="none", output="v")


NEURON_REGISTRY: Dict[str, Callable[..., NeuronSpec]] = {
    "lif": LIF,
    "plif": PLIF,
    "alif": ALIF,
    "dhlif": DHLIF,
    "li": LI,
}


def register_neuron(name: str, factory: Callable[..., NeuronSpec], *,
                    override: bool = False) -> Callable[..., NeuronSpec]:
    """Open the neuron menu: name a factory (class or function returning a
    NeuronSpec) so configs/CLIs can `make_neuron(name)` it. Duplicate names
    raise unless `override=True` (deliberate replacement)."""
    if not override and name in NEURON_REGISTRY:
        raise ValueError(f"neuron {name!r} already registered "
                         f"({NEURON_REGISTRY[name]!r}); pass override=True "
                         "to replace it")
    NEURON_REGISTRY[name] = factory
    return factory


def make_neuron(name: str, **kwargs) -> NeuronSpec:
    if name not in NEURON_REGISTRY:
        raise KeyError(f"unknown neuron {name!r}; registered: "
                       f"{sorted(NEURON_REGISTRY)}")
    return NEURON_REGISTRY[name](**kwargs)
