"""Structured diagnostics for the static-analysis subsystem.

Every checker in `repro.analysis` reports findings as `Diagnostic`
records: a stable TB-code, a severity, the source site (node, kernel,
core, ...), a human message, and a fix hint. Codes are grouped by layer —
the same layering the compiler stack has:

  TB1xx  program checks   (events.Program DAG + Neuron/SynapseProgram IR)
  TB2xx  plan checks      (fusion explainability, VMEM prediction,
                           chunked-online learning hazards)
  TB3xx  kernel-spec checks (grid coverage, block contracts, VMEM model
                           sanity, sparse-channel block tables)
  TB4xx  mapping checks   (core capacity, unmapped ops, placement, links)
  TB5xx  serve checks     (state-cache budget vs session footprint,
                           cohort shape vs plan, admission bounds)

The default severity of each code lives in `CODES`; `make()` applies it
so checkers and tests agree on one source of truth. `raise_if` turns a
finding list into a `DiagnosticError` — the `REPRO_CHECK=raise` hook in
`core/plan.py` and the CLI's `--fail-on` both go through it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SEVERITIES: Tuple[str, ...] = ("info", "warning", "error")

# code -> (default severity, title)
CODES: Dict[str, Tuple[str, str]] = {
    # -- TB1xx: program checks ------------------------------------------------
    "TB100": ("error", "invalid program structure"),
    "TB101": ("error", "connection reads unknown source"),
    "TB102": ("error", "learned-parameter key collision"),
    "TB103": ("warning", "zero-delay cross-node cycle"),
    "TB104": ("warning", "unreachable or dead node"),
    "TB105": ("warning", "unread state variable"),
    "TB106": ("warning", "unread synaptic trace"),
    "TB107": ("error", "plastic edge missing its weight tensor"),
    "TB108": ("warning", "decay outside (0, 1]"),
    "TB109": ("warning", "degenerate threshold"),
    "TB110": ("error", "weight shape mismatch"),
    "TB111": ("error", "non-positive layer width"),
    # -- TB2xx: plan checks ---------------------------------------------------
    "TB201": ("info", "whole-program fallback"),
    "TB202": ("info", "integrate not hoistable"),
    "TB203": ("info", "delayed self-connection"),
    "TB204": ("info", "multiple self feeds"),
    "TB205": ("info", "neuron declares no program"),
    "TB206": ("info", "no fused FIRE pattern match"),
    "TB207": ("info", "hoist convention mismatch"),
    "TB208": ("info", "recurrent variant unsupported"),
    "TB210": ("info", "synapse program runs per-step"),
    "TB230": ("warning", "predicted segment VMEM over budget"),
    "TB231": ("error", "plastic connections collide on a weight key"),
    "TB232": ("warning", "plastic weight key aliased by another edge"),
    # -- TB3xx: kernel-spec checks --------------------------------------------
    "TB301": ("error", "index map leaves output gaps"),
    "TB302": ("error", "index map overlaps output blocks"),
    "TB303": ("warning", "block axis violates its contract"),
    "TB304": ("error", "vmem model underestimates operand tiles"),
    "TB305": ("warning", "vmem model far above operand tiles"),
    "TB306": ("warning", "default blocks exceed the VMEM budget"),
    "TB307": ("error", "sparse block-table defect"),
    "TB308": ("warning", "unknown block-axis key"),
    "TB309": ("info", "kernel declares no tile model"),
    # -- TB4xx: mapping checks ------------------------------------------------
    "TB401": ("error", "core over neuron capacity"),
    "TB402": ("error", "op missing from the core map"),
    "TB403": ("error", "core placed off-grid"),
    "TB404": ("error", "fan-in unsatisfiable"),
    "TB405": ("warning", "fanout exceeds link budget"),
    # -- TB5xx: serve checks ----------------------------------------------------
    "TB501": ("error", "state-cache budget below one session footprint"),
    "TB502": ("warning", "state-cache budget thrashes at capacity"),
    "TB503": ("warning", "serving a plan with fallback segments"),
    "TB504": ("warning", "admission queue smaller than cohort capacity"),
    "TB505": ("error", "window/capacity configuration invalid"),
    # -- TB6xx: topology checks -------------------------------------------------
    "TB601": ("error", "IE entry targets a neuron outside out_dim"),
    "TB602": ("warning", "duplicate (pre, post) IE entries accumulate"),
    "TB603": ("warning", "IE coverage misses output neurons"),
    "TB604": ("error", "storage-bits accounting disagrees with tables"),
    "TB605": ("error", "delay exceeds the delay-field capacity"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: code + severity + where + what + how to fix."""

    code: str
    severity: str
    site: str
    message: str
    hint: str = ""

    def __str__(self) -> str:
        s = f"{self.code} {self.severity}: {self.site}: {self.message}"
        if self.hint:
            s += f"  (hint: {self.hint})"
        return s


class DiagnosticError(ValueError):
    """Raised when findings at/above the requested severity exist."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics: Tuple[Diagnostic, ...] = tuple(diagnostics)
        lines = "\n".join(f"  {d}" for d in self.diagnostics)
        super().__init__(
            f"{len(self.diagnostics)} static-analysis finding(s):\n{lines}")


def make(code: str, site: str, message: str, hint: str = "",
         severity: Optional[str] = None) -> Diagnostic:
    """Build a Diagnostic with the code's default severity applied."""
    if code not in CODES:
        raise KeyError(f"unknown diagnostic code {code!r}")
    sev = severity if severity is not None else CODES[code][0]
    if sev not in SEVERITIES:
        raise ValueError(f"bad severity {sev!r}; expected one of {SEVERITIES}")
    return Diagnostic(code=code, severity=sev, site=site, message=message,
                      hint=hint)


def severity_rank(severity: str) -> int:
    return SEVERITIES.index(severity)


def at_least(diags: Iterable[Diagnostic],
             severity: str = "warning") -> List[Diagnostic]:
    """Findings at or above `severity`, most severe first."""
    floor = severity_rank(severity)
    out = [d for d in diags if severity_rank(d.severity) >= floor]
    out.sort(key=lambda d: (-severity_rank(d.severity), d.code, d.site))
    return out


def worst(diags: Iterable[Diagnostic]) -> Optional[str]:
    """The highest severity present, or None when there are no findings."""
    ranks = [severity_rank(d.severity) for d in diags]
    return SEVERITIES[max(ranks)] if ranks else None


def render(diags: Sequence[Diagnostic]) -> str:
    """Human-readable report, most severe first."""
    if not diags:
        return "no findings"
    ordered = at_least(diags, "info")
    return "\n".join(str(d) for d in ordered)


def raise_if(diags: Sequence[Diagnostic], severity: str = "error") -> None:
    """Raise `DiagnosticError` when findings at/above `severity` exist."""
    bad = at_least(diags, severity)
    if bad:
        raise DiagnosticError(bad)


__all__ = ["CODES", "SEVERITIES", "Diagnostic", "DiagnosticError", "make",
           "severity_rank", "at_least", "worst", "render", "raise_if"]
