"""TB1xx: static checks over the events Program DAG and its IRs.

Checks the `LayerNode` graph, each node's `NeuronProgram`, and each
plastic edge's `SynapseProgram` without running anything: width/shape
inference over the DAG, zero-delay cycles, dead or unreachable nodes,
unread state/trace variables, learned-parameter key collisions, plastic
edges bound to missing weight tensors, and degenerate decay/threshold
configurations that `validate_program` / `validate_synapse_program`
deliberately accept (they gate structure, not fitness).

Shape checks are params-gated: pass the params pytree to `check_nodes`
and every weight tensor is checked against the widths the DAG implies.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.events import LayerNode
from repro.core.neuron import NeuronProgram, validate_program
from repro.core.plasticity import SynapseProgram, validate_synapse_program

from repro.analysis.diagnostics import Diagnostic, make

DEFAULT_EXTERNAL: Tuple[str, ...] = ("input",)


def _node_program(node: LayerNode) -> Optional[NeuronProgram]:
    try:
        return node.neuron.program
    except NotImplementedError:
        return None


# ---------------------------------------------------------------------------
# NeuronProgram checks
# ---------------------------------------------------------------------------


def check_program(prog: NeuronProgram, site: str = "program") -> List[Diagnostic]:
    """TB100/102/105/108/109 over one neuron program."""
    out: List[Diagnostic] = []
    try:
        validate_program(prog)
    except ValueError as e:
        out.append(make("TB100", site, str(e)))
        return out  # downstream checks assume structural validity

    # TB102: two learned decays bound to one params key
    seen: Dict[str, str] = {}
    for sv in prog.states:
        if sv.decay.kind != "const" and sv.decay.param:
            if sv.decay.param in seen:
                out.append(make(
                    "TB102", f"{site}.{sv.name}",
                    f"decay param {sv.decay.param!r} already bound by state "
                    f"{seen[sv.decay.param]!r}",
                    hint="give each learned decay its own params key"))
            else:
                seen[sv.decay.param] = sv.name

    # TB105: states nothing ever reads
    read: Set[str] = set()
    if prog.output != "spikes":
        read.add(prog.output)
    if prog.threshold is not None:
        read.add(prog.threshold.on)
        if prog.threshold.adapt:
            read.add(prog.threshold.adapt)
    for sv in prog.states:
        if sv.drive.startswith("sum:"):
            read.add(sv.drive[4:])
    for sv in prog.states:
        if sv.name not in read:
            out.append(make(
                "TB105", f"{site}.{sv.name}",
                "state is never read (not the output, not thresholded, "
                "not a branch-sum source)",
                hint="drop the state or wire it into the output/threshold"))

    # TB108: constant decay outside (0, 1]
    for sv in prog.states:
        if sv.decay.kind == "const" and not (0.0 < sv.decay.value <= 1.0):
            out.append(make(
                "TB108", f"{site}.{sv.name}",
                f"constant decay {sv.decay.value} outside (0, 1]",
                hint="decays in (0, 1] keep the membrane bounded"))

    # TB109: threshold that can never gate meaningfully
    th = prog.threshold
    if th is not None:
        if th.base <= 0.0 and not th.adapt:
            out.append(make(
                "TB109", site,
                f"threshold base {th.base} <= 0 with no adaptation: every "
                "positive membrane fires",
                hint="set base > 0 or add an adaptation state"))
        if th.adapt and th.scale == 0.0:
            out.append(make(
                "TB109", site,
                f"threshold adapts on {th.adapt!r} with scale=0: the "
                "adaptation state has no effect",
                hint="set scale != 0 or drop adapt"))
    return out


# ---------------------------------------------------------------------------
# SynapseProgram checks
# ---------------------------------------------------------------------------


def check_synapse(sp: SynapseProgram, site: str = "synapse") -> List[Diagnostic]:
    """TB100/102/106/108 over one synapse program."""
    out: List[Diagnostic] = []
    try:
        validate_synapse_program(sp)
    except ValueError as e:
        out.append(make("TB100", site, str(e)))
        return out

    seen: Dict[str, str] = {}
    for tr in sp.traces:
        if tr.decay.kind != "const" and tr.decay.param:
            if tr.decay.param in seen:
                out.append(make(
                    "TB102", f"{site}.{tr.name}",
                    f"trace decay param {tr.decay.param!r} already bound by "
                    f"trace {seen[tr.decay.param]!r}",
                    hint="give each learned trace decay its own params key"))
            else:
                seen[tr.decay.param] = tr.name

    used: Set[str] = set()
    for term in sp.terms:
        used.update(term.pre)
        used.update(term.post)
    for tr in sp.traces:
        if tr.name not in used:
            out.append(make(
                "TB106", f"{site}.{tr.name}",
                "trace appears in no update term",
                hint="drop the trace or reference it from an UpdateTerm"))

    for tr in sp.traces:
        if tr.decay.kind == "const" and not (0.0 < tr.decay.value <= 1.0):
            out.append(make(
                "TB108", f"{site}.{tr.name}",
                f"constant trace decay {tr.decay.value} outside (0, 1]",
                hint="1.0 accumulates, (0, 1) decays; <= 0 or > 1 diverges"))
    return out


# ---------------------------------------------------------------------------
# Node-graph checks
# ---------------------------------------------------------------------------


def _shape_of(w: Any) -> Optional[Tuple[int, ...]]:
    shape = getattr(w, "shape", None)
    if shape is None:
        return None
    try:
        return tuple(int(d) for d in shape)
    except TypeError:
        return None


def _check_weight_shapes(n: LayerNode, prog: Optional[NeuronProgram],
                         node_params: Mapping[str, Any],
                         widths: Mapping[str, int]) -> List[Diagnostic]:
    """TB110 under the built-in hoist conventions (ff / branch)."""
    out: List[Diagnostic] = []
    hoist = getattr(n.integrate, "hoist", None)
    if hoist not in ("ff", "branch"):
        return out  # custom integrate: weight layout is its own contract
    for c in n.connections:
        site = f"{n.name}.{c.key}"
        if getattr(c, "topology", None) is not None:
            # topology-backed edge: shape lives on the encoding, not a
            # dense weight tensor — check (n_pre, n_post) instead
            topo = c.topology
            if isinstance(topo, str):
                topo = node_params.get(topo)
            shape = _shape_of(topo)
            src_dim = (widths.get(n.name) if c.src == "self"
                       else widths.get(c.src))
            if shape is not None and (
                    shape[1] != n.out_dim
                    or (src_dim is not None and shape[0] != src_dim)):
                out.append(make(
                    "TB110", site,
                    f"topology has shape {shape}, expected "
                    f"({src_dim if src_dim is not None else '?'}, "
                    f"{n.out_dim})"))
            continue
        w = node_params.get(c.weight_key)
        if w is None:
            out.append(make(
                "TB110", site,
                f"integrate convention {hoist!r} reads weight "
                f"{c.weight_key!r} but params[{n.name!r}] has no such key",
                hint="add the tensor or set Connection.weight to the "
                     "key that holds it"))
            continue
        shape = _shape_of(w)
        if shape is None:
            continue
        src_dim = widths.get(n.name) if c.src == "self" else widths.get(c.src)
        if hoist == "ff":
            want = (src_dim, n.out_dim)
            ok = (len(shape) == 2 and shape[1] == n.out_dim
                  and (src_dim is None or shape[0] == src_dim))
            if not ok:
                out.append(make(
                    "TB110", site,
                    f"weight {c.weight_key!r} has shape {shape}, expected "
                    f"({want[0] if want[0] is not None else '?'}, {want[1]})"))
        else:  # branch
            kb = prog.n_branches if prog is not None else None
            ok = (len(shape) == 3 and shape[2] == n.out_dim
                  and (kb is None or shape[0] == kb)
                  and (src_dim is None or shape[1] == src_dim))
            if not ok:
                out.append(make(
                    "TB110", site,
                    f"weight {c.weight_key!r} has shape {shape}, expected "
                    f"(n_branches={kb if kb is not None else '?'}, "
                    f"{src_dim if src_dim is not None else '?'}, "
                    f"{n.out_dim})"))
    return out


def _zero_delay_cycles(nodes: Sequence[LayerNode]) -> List[List[str]]:
    """Cycles in the zero-delay cross-node feed graph (self edges excluded)."""
    names = {n.name for n in nodes}
    edges: Dict[str, List[str]] = {n.name: [] for n in nodes}
    for n in nodes:
        for c in n.connections:
            if c.delay == 0 and c.src != "self" and c.src in names:
                edges[c.src].append(n.name)
    cycles: List[List[str]] = []
    color: Dict[str, int] = {}
    stack: List[str] = []

    def visit(v: str) -> None:
        color[v] = 1
        stack.append(v)
        for w in edges[v]:
            if color.get(w, 0) == 0:
                visit(w)
            elif color.get(w) == 1:
                cycles.append(stack[stack.index(w):] + [w])
        stack.pop()
        color[v] = 2

    for n in nodes:
        if color.get(n.name, 0) == 0:
            visit(n.name)
    return cycles


def check_nodes_graph(nodes: Sequence[LayerNode],
                      params: Optional[Dict[str, Any]] = None,
                      external: Sequence[str] = DEFAULT_EXTERNAL
                      ) -> List[Diagnostic]:
    """TB1xx + TB231/232 over a node graph (no plan compilation)."""
    out: List[Diagnostic] = []
    names = [n.name for n in nodes]
    name_set = set(names)
    ext = set(external)

    dupes = {x for x in names if names.count(x) > 1}
    for d in sorted(dupes):
        out.append(make("TB100", d, "duplicate node name"))
    if dupes:
        return out

    widths = {n.name: n.out_dim for n in nodes}

    # TB101 / TB111 / per-node programs
    for n in nodes:
        if n.out_dim <= 0:
            out.append(make(
                "TB111", n.name, f"out_dim={n.out_dim} is not positive",
                hint="LayerNode needs its width for shape inference and "
                     "kernel lowering"))
        for c in n.connections:
            if c.src != "self" and c.src not in name_set and c.src not in ext:
                out.append(make(
                    "TB101", f"{n.name}.{c.key}",
                    f"source {c.src!r} is neither a node nor a declared "
                    f"external input {sorted(ext)}",
                    hint="fix the name or pass external=(...) to the check"))
        prog = _node_program(n)
        if prog is not None:
            out.extend(check_program(prog, site=n.name))

        node_params = (params or {}).get(n.name, {})

        # TB107: plastic edges need their weight seeded
        if params is not None:
            for c in n.connections:
                if c.plastic is not None and c.weight_key not in node_params:
                    out.append(make(
                        "TB107", f"{n.name}.{c.key}",
                        f"plastic edge learns {c.weight_key!r} but "
                        f"params[{n.name!r}] does not define it",
                        hint="seed the weight in params; init_state will "
                             "fail without it"))

        # TB231/232: weight-key aliasing hazards under chunked-online learning
        plastic_keys: Dict[str, str] = {}
        static_keys: Dict[str, str] = {}
        for c in n.connections:
            (plastic_keys if c.plastic is not None else static_keys)\
                .setdefault(c.weight_key, c.key)
        for c in n.connections:
            if c.plastic is None:
                continue
            first = plastic_keys.get(c.weight_key)
            if first is not None and first != c.key:
                out.append(make(
                    "TB231", f"{n.name}.{c.key}",
                    f"plastic edges {first!r} and {c.key!r} both learn "
                    f"weight {c.weight_key!r}: their updates overwrite each "
                    "other (last writer wins per chunk)",
                    hint="give each plastic edge its own weight key"))
            if c.weight_key in static_keys:
                out.append(make(
                    "TB232", f"{n.name}.{c.key}",
                    f"weight {c.weight_key!r} is learned here but also read "
                    f"by non-plastic edge {static_keys[c.weight_key]!r}; "
                    "the alias sees updated values mid-window",
                    hint="alias deliberately (weight sharing) or split keys"))
            out.extend(check_synapse(c.plastic, site=f"{n.name}.{c.key}"))

        if params is not None:
            out.extend(_check_weight_shapes(n, prog, node_params, widths))

    # TB103: zero-delay cross-node cycles
    for cyc in _zero_delay_cycles(nodes):
        out.append(make(
            "TB103", cyc[0],
            "zero-delay cycle " + " -> ".join(cyc) + ": later edges read "
            "stale t-1 outputs, silently, in declaration order",
            hint="add delay=1 on one edge to make the loop explicit"))

    # TB104: unreachable from any external input; dead outputs
    fed_by_ext = {n.name for n in nodes
                  for c in n.connections if c.src in ext}
    reach = set(fed_by_ext)
    frontier = list(fed_by_ext)
    consumers: Dict[str, List[str]] = {n.name: [] for n in nodes}
    for n in nodes:
        for c in n.connections:
            if c.src in name_set and c.src != n.name:
                consumers[c.src].append(n.name)
    while frontier:
        v = frontier.pop()
        for w in consumers[v]:
            if w not in reach:
                reach.add(w)
                frontier.append(w)
    for n in nodes:
        if n.name not in reach:
            out.append(make(
                "TB104", n.name,
                "no path from any external input reaches this node",
                hint="wire it to an input (directly or transitively) or "
                     "drop it"))
        elif not consumers[n.name] and nodes and n.name != nodes[-1].name:
            out.append(make(
                "TB104", n.name,
                "output feeds nothing and the node is not the terminal "
                "(last-declared) readout",
                hint="consume its output or move it last if it is a readout"))
    return out


__all__ = ["check_program", "check_synapse", "check_nodes_graph",
           "DEFAULT_EXTERNAL"]
