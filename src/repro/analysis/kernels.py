"""TB3xx: static checks over registered KernelSpecs.

For every registered kernel family, at its default block shapes AND every
tuning candidate:

  * grid x index-map coverage: the output tiling implied by the spec's
    `TileModel` writes every output element exactly once — no gaps
    (TB301), no overlaps (TB302);
  * block contracts: preferred/align consistency and exact-axis division
    (TB303) — a violated contract means padding corrupts chained state;
  * `vmem_bytes` honesty: the model must bound the operand tiles the
    `TileModel` declares (TB304 when it underestimates — dispatch would
    green-light a block shape that blows VMEM — and TB305 when it is so
    loose the autotuner prunes everything);
  * the default blocks must fit `REPRO_VMEM_LIMIT_MB` at the spec's
    canonical dims (TB306);
  * candidate/tuning-cache block keys must name real axes (TB308);
  * the block-sparse spikemm channel's compacted table must be a faithful
    permutation of the occupancy bitmap, sentinels included (TB307).

Everything here is pure Python/numpy over spec metadata — no tracing, no
Pallas, no TPU.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import registry, tuning

from repro.analysis.diagnostics import Diagnostic, make

# tuning-cache kernel keys that are policies, not registered kernels
_PSEUDO_KERNEL_PREFIXES = ("spikemm.sparse_th",)


# ---------------------------------------------------------------------------
# coverage painting
# ---------------------------------------------------------------------------


def _default_cells(tm: "registry.TileModel", dims: Mapping[str, int],
                   blocks: Mapping[str, int]
                   ) -> Iterable[Tuple[Tuple[int, int], ...]]:
    """The dense row-major tiling implied by `TileModel.out`."""
    per_axis: List[List[Tuple[int, int]]] = []
    for dim, axis in tm.out:
        size = int(dims[dim])
        if axis is None:
            per_axis.append([(0, size)])
            continue
        b = int(blocks[axis])
        per_axis.append([(i * b, min((i + 1) * b, size))
                         for i in range(max(1, -(-size // b)))])
    idx = [0] * len(per_axis)
    while True:
        yield tuple(per_axis[a][idx[a]] for a in range(len(per_axis)))
        for a in reversed(range(len(per_axis))):
            idx[a] += 1
            if idx[a] < len(per_axis[a]):
                break
            idx[a] = 0
        else:
            return


def coverage_problems(tm: "registry.TileModel", dims: Mapping[str, int],
                      blocks: Mapping[str, int]) -> List[str]:
    """Paint every grid cell onto the output; report gaps and overlaps."""
    sizes = tuple(int(dims[dim]) for dim, _ in tm.out)
    paint = np.zeros(sizes, dtype=np.int16)
    cells = (tm.coverage(dims, blocks) if tm.coverage is not None
             else _default_cells(tm, dims, blocks))
    for cell in cells:
        paint[tuple(slice(lo, hi) for lo, hi in cell)] += 1
    problems: List[str] = []
    gaps = int((paint == 0).sum())
    overlaps = int((paint > 1).sum())
    if gaps:
        first = np.argwhere(paint == 0)[0]
        problems.append(
            f"gap: {gaps} output element(s) never written "
            f"(first at {tuple(int(i) for i in first)})")
    if overlaps:
        first = np.argwhere(paint > 1)[0]
        problems.append(
            f"overlap: {overlaps} output element(s) written more than once "
            f"(first at {tuple(int(i) for i in first)})")
    return problems


# ---------------------------------------------------------------------------
# sparse block-table verification
# ---------------------------------------------------------------------------


def check_block_table(flags: Any, ii: Any, kk: Any, active: Any) -> List[str]:
    """Verify a `compact_blocks` table against its occupancy bitmap.

    Contract: active entries enumerate each occupied block exactly once,
    row-major; every row block appears (silent rows via an inactive
    sentinel) so the kernel's output-revisit accounting initializes every
    output block; inactive padding may only trail, pointing at the last
    row. Returns a list of violations (empty = faithful).
    """
    flags = np.asarray(flags)
    ii = np.asarray(ii)
    kk = np.asarray(kk)
    active = np.asarray(active)
    Mb, Kb = flags.shape
    problems: List[str] = []
    if not (ii.shape == kk.shape == active.shape) or ii.ndim != 1:
        return [f"table arrays disagree on shape: ii{ii.shape} kk{kk.shape} "
                f"active{active.shape}"]
    if np.any((ii < 0) | (ii >= Mb)):
        problems.append(f"row index out of range [0, {Mb})")
    act = active != 0
    if np.any(act & ((kk < 0) | (kk >= Kb))):
        problems.append(f"active column index out of range [0, {Kb})")
    if np.any(np.diff(ii) < 0):
        problems.append("row indices not non-decreasing (breaks the "
                        "same-row output accumulation)")
    occ = flags != 0
    seen = np.zeros((Mb, Kb), dtype=np.int64)
    for i, k, a in zip(ii, kk, act):
        if a and 0 <= i < Mb and 0 <= k < Kb:
            seen[i, k] += 1
    dup = np.argwhere(seen > 1)
    if dup.size:
        problems.append(f"occupied block visited twice (first at "
                        f"{tuple(int(x) for x in dup[0])})")
    missed = np.argwhere(occ & (seen == 0))
    if missed.size:
        problems.append(f"occupied block never visited (first at "
                        f"{tuple(int(x) for x in missed[0])})")
    ghost = np.argwhere((~occ) & (seen > 0))
    if ghost.size:
        problems.append(f"active entry at a silent block (first at "
                        f"{tuple(int(x) for x in ghost[0])})")
    rows = set(int(i) for i in ii[(ii >= 0) & (ii < Mb)])
    missing_rows = sorted(set(range(Mb)) - rows)
    if missing_rows:
        problems.append(f"row block(s) {missing_rows} absent from the table "
                        "(their output tiles are never initialized)")
    return problems


def _block_flags(raster: np.ndarray, bm: int, bk: int) -> np.ndarray:
    M, K = raster.shape
    return (raster.reshape(M // bm, bm, K // bk, bk)
            .any(axis=(1, 3)).astype(np.int32))


def _check_sparse_channel(site: str) -> List[Diagnostic]:
    """TB307 over representative occupancy patterns (concrete path)."""
    import jax.numpy as jnp
    from repro.kernels.spikemm import sparse

    out: List[Diagnostic] = []
    bm, bk = 128, 512
    M, K = 4 * bm, 2 * bk
    rng = np.random.default_rng(0)
    rasters = {
        "all-silent": np.zeros((M, K), np.float32),
        "all-dense": np.ones((M, K), np.float32),
        "random-p0.1": (rng.random((M, K)) < 0.1).astype(np.float32),
        "silent-middle-row": np.ones((M, K), np.float32),
    }
    rasters["silent-middle-row"][bm:2 * bm, :] = 0.0
    for label, raster in rasters.items():
        flags = _block_flags(raster, bm, bk)
        ii, kk, active = sparse.compact_blocks(jnp.asarray(flags))
        for problem in check_block_table(flags, ii, kk, active):
            out.append(make(
                "TB307", f"{site}.sparse[{label}]", problem,
                hint="compact_blocks must enumerate occupied blocks "
                     "row-major with per-row sentinels"))
    return out


# ---------------------------------------------------------------------------
# per-spec checks
# ---------------------------------------------------------------------------


def _tile_bytes(tiles: Mapping[str, Tuple[int, ...]]) -> int:
    return 4 * sum(int(math.prod(shape)) for shape in tiles.values())


def check_kernel(name: str) -> List[Diagnostic]:
    """TB301-309 for one registered kernel family."""
    import jax

    spec = registry.get(name)
    out: List[Diagnostic] = []
    axis_names = {ax.name for ax in spec.block_axes}

    # static contracts on the axes themselves
    for ax in spec.block_axes:
        if ax.preferred % ax.align:
            out.append(make(
                "TB303", f"{name}.{ax.name}",
                f"preferred={ax.preferred} is not a multiple of "
                f"align={ax.align}"))

    # candidate / tuning-cache keys must name real axes
    for i, cand in enumerate(spec.candidates):
        unknown = sorted(set(cand) - axis_names)
        if unknown:
            out.append(make(
                "TB308", f"{name}.candidates[{i}]",
                f"override keys {unknown} match no block axis "
                f"{sorted(axis_names)}"))
    for cache, origin in ((tuning.default_cache(), "local"),
                          (tuning.bundled_cache(), "bundled")):
        try:
            entries = list(cache.entries())
        except Exception:
            continue  # a corrupt cache is dispatch's problem, not ours
        for kernel, backend, bucket, blocks in entries:
            if kernel != name:
                continue
            unknown = sorted(set(blocks) - axis_names)
            if unknown:
                out.append(make(
                    "TB308", f"{name}@{origin}:{backend}|{bucket}",
                    f"cached block keys {unknown} match no block axis "
                    f"{sorted(axis_names)}",
                    hint="stale cache entry from a renamed axis; retune"))

    if spec.make_inputs is None:
        return out
    args = spec.make_inputs(jax.random.PRNGKey(0))
    dims = spec.dims_of(*args)
    tm = spec.tile_model
    if tm is None:
        out.append(make(
            "TB309", name, "spec declares no TileModel: coverage and "
            "vmem-honesty checks are skipped",
            hint="add tile_model= to the KernelSpec registration"))

    limit = tuning.vmem_limit_bytes()
    shapes: List[Tuple[str, Dict[str, int]]] = [
        ("default", spec.resolve_blocks(dims, use_cache=False))]
    shapes += [(f"candidates[{i}]",
                spec.resolve_blocks(dims, overrides=c, use_cache=False))
               for i, c in enumerate(spec.candidates)]
    for label, blocks in shapes:
        site = f"{name}.{label}"
        for ax in spec.block_axes:
            b = blocks[ax.name]
            if ax.exact and dims[ax.dim] % b:
                out.append(make(
                    "TB303", site,
                    f"exact axis {ax.name}: block {b} does not divide "
                    f"{ax.dim}={dims[ax.dim]} (padding would corrupt the "
                    "chained state)"))
            elif not ax.exact and b % ax.align:
                out.append(make(
                    "TB303", site,
                    f"axis {ax.name}: block {b} is not a multiple of "
                    f"align={ax.align}"))
        if tm is None:
            continue
        for problem in coverage_problems(tm, dims, blocks):
            code = "TB302" if problem.startswith("overlap") else "TB301"
            out.append(make(code, site, problem))
        tiles = tm.tiles(dims, blocks)
        need = _tile_bytes(tiles)
        if spec.vmem_bytes is not None:
            model = int(spec.vmem_bytes(dims, blocks))
            if model < need:
                out.append(make(
                    "TB304", site,
                    f"vmem model claims {model} B but the declared operand "
                    f"tiles need {need} B: dispatch would green-light an "
                    "over-budget block shape"))
            elif need and model > 8 * need:
                out.append(make(
                    "TB305", site,
                    f"vmem model claims {model} B vs {need} B of declared "
                    "tiles (>8x): the autotuner will prune viable shapes"))
            if label == "default" and model > limit:
                out.append(make(
                    "TB306", site,
                    f"default blocks {blocks} model {model / 2**20:.1f} MiB "
                    f"> budget {limit / 2**20:.1f} MiB at the canonical "
                    f"dims {dims}: dispatch degrades before tuning ever "
                    "runs"))

    if "sparse" in spec.channels:
        out.extend(_check_sparse_channel(name))
    return out


def check_kernels(names: Optional[Sequence[str]] = None) -> List[Diagnostic]:
    """TB3xx across the registry (default: every registered family)."""
    registry.ensure_registered()
    out: List[Diagnostic] = []
    known = set(registry.names())
    for name in (names if names is not None else sorted(known)):
        out.extend(check_kernel(name))
    if names is None:
        # cache entries pointing at kernels nobody registers anymore
        for cache, origin in ((tuning.default_cache(), "local"),
                              (tuning.bundled_cache(), "bundled")):
            try:
                entries = list(cache.entries())
            except Exception:
                continue
            for kernel, backend, bucket, _ in entries:
                if kernel in known or kernel.startswith(
                        _PSEUDO_KERNEL_PREFIXES):
                    continue
                out.append(make(
                    "TB308", f"{origin}:{kernel}|{backend}|{bucket}",
                    "tuning-cache entry references an unregistered kernel",
                    hint="renamed family? drop or retune the entry"))
    return out


__all__ = ["check_kernel", "check_kernels", "check_block_table",
           "coverage_problems"]
