"""TB2xx: fusion explainability + predicted-VMEM checks over a Plan.

`core/plan.py` already decides *and records* why every stepper segment
fell back (`Segment.codes` / `PlasticLower.code`); this module lifts
those decisions into `Diagnostic` records (severity info — a fallback is
legal, just slow) and adds the one check only the analyzer can do
statically: predict each fused segment's kernel VMEM working set at the
tuned block shapes and compare it against `REPRO_VMEM_LIMIT_MB` (TB230)
before anything is traced.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core import plan as plan_mod
from repro.core.events import LayerNode
from repro.kernels import registry, tuning

from repro.analysis.diagnostics import Diagnostic, make


def compile_quiet(nodes: Sequence[LayerNode]) -> "plan_mod.Plan":
    """compile_program with the REPRO_CHECK hook latched off (the analyzer
    calls the planner; the planner must not call the analyzer back)."""
    prev = plan_mod._IN_CHECK
    plan_mod._IN_CHECK = True
    try:
        return plan_mod.compile_program(list(nodes))
    finally:
        plan_mod._IN_CHECK = prev


def _fallback_diags(plan: "plan_mod.Plan") -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for seg in plan.segments:
        if seg.kind != plan_mod.FALLBACK:
            continue
        entries = [e.strip() for e in seg.reason.split(";")] if seg.reason \
            else []
        if len(seg.codes) == len(seg.names) == len(entries):
            for name, code, entry in zip(seg.names, seg.codes, entries):
                msg = entry.split(":", 1)[1].strip() if ":" in entry else entry
                if msg.startswith(code):
                    msg = msg[len(code):].strip()
                out.append(make(
                    code, name, msg,
                    hint="runs through the per-step stepper segment"))
        else:
            # whole-program fallback: one code covers every node
            code = seg.codes[0] if seg.codes else "TB201"
            out.append(make(
                code, seg.names[0] if seg.names else "program",
                seg.reason or "program compiles to a single stepper segment",
                hint="runs through the per-step stepper segment"))
    for p in plan.plastic:
        if p.code:
            out.append(make(
                p.code, f"{p.node}.{p.conn}", p.reason,
                hint="the rule runs through plasticity.synapse_step"))
    return out


# fused lowering family -> kernel spec name(s), keyed by recurrence
_FAMILY_KERNELS = {
    (plan_mod.LOWER_LI, False): ("linrec",),
    (plan_mod.LOWER_LIF, False): ("lif",),
    (plan_mod.LOWER_LIF, True): ("lifrec",),
    (plan_mod.LOWER_ALIF, False): ("alif",),
    (plan_mod.LOWER_ALIF, True): ("alifrec",),
    (plan_mod.LOWER_DHLIF, False): ("linrec", "lif"),
}


def _fire_dims(kernel: str, family: str, T: int, B: int, n: int,
               n_branches: int) -> Dict[str, int]:
    if kernel == "linrec":
        # the dhlif prologue scans the branch-flattened (T, B*K, N) tensor
        b = B * n_branches if family == plan_mod.LOWER_DHLIF else B
        return {"T": T, "B": b, "D": n}
    return {"T": T, "B": B, "N": n}


def _predict_vmem(kernel: str, dims: Mapping[str, int]
                  ) -> Optional[Dict[str, Any]]:
    try:
        spec = registry.get(kernel)
    except KeyError:
        return None
    if spec.vmem_bytes is None:
        return None
    blocks = spec.resolve_blocks(dims)
    return {"kernel": kernel, "blocks": blocks,
            "bytes": int(spec.vmem_bytes(dims, blocks))}


def _vmem_diags(nodes: Sequence[LayerNode], plan: "plan_mod.Plan",
                T: int, B: int,
                params: Optional[Dict[str, Any]] = None) -> List[Diagnostic]:
    limit = tuning.vmem_limit_bytes()
    by_name = {n.name: n for n in nodes}
    widths = {n.name: n.out_dim for n in nodes}
    out: List[Diagnostic] = []

    def check(site: str, pred: Optional[Dict[str, Any]]) -> None:
        if pred is not None and pred["bytes"] > limit:
            out.append(make(
                "TB230", site,
                f"{pred['kernel']} predicts "
                f"{pred['bytes'] / 2**20:.1f} MiB at blocks "
                f"{pred['blocks']} > budget {limit / 2**20:.1f} MiB",
                hint="raise REPRO_VMEM_LIMIT_MB or retune; dispatch will "
                     "reject the compiled channel and degrade"))

    for seg in plan.segments:
        if seg.kind == plan_mod.FALLBACK:
            continue
        node = by_name[seg.names[0]]
        prog = node.neuron.program
        kb = prog.n_branches
        for kernel in _FAMILY_KERNELS.get(
                (seg.lower, seg.kind == plan_mod.FUSED_REC), ()):
            check(node.name, _predict_vmem(
                kernel, _fire_dims(kernel, seg.lower, T, B, node.out_dim, kb)))
        # the hoisted INTEG spikemm per feed, when the source width is known
        for c in node.connections:
            if c.src == "self":
                continue
            src_dim = widths.get(c.src)
            if src_dim is None and params is not None:
                w = params.get(node.name, {}).get(c.weight_key)
                shape = getattr(w, "shape", None)
                if shape is not None and len(shape) >= 2:
                    src_dim = int(shape[-2])
            if src_dim is None:
                continue
            n_out = node.out_dim * (kb if seg.lower == plan_mod.LOWER_DHLIF
                                    else 1)
            check(f"{node.name}.{c.key}", _predict_vmem(
                "spikemm", {"M": T * B, "K": src_dim, "N": n_out}))
    return out


def check_plan(nodes: Sequence[LayerNode],
               plan: Optional["plan_mod.Plan"] = None,
               T: Optional[int] = None, B: Optional[int] = None,
               params: Optional[Dict[str, Any]] = None) -> List[Diagnostic]:
    """TB201-210 fusion explainability (+ TB230 when T and B are given)."""
    if plan is None:
        plan = compile_quiet(nodes)
    out = _fallback_diags(plan)
    if T is not None and B is not None and nodes:
        out.extend(_vmem_diags(nodes, plan, int(T), int(B), params))
    return out


__all__ = ["check_plan", "compile_quiet"]
