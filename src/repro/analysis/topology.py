"""TB6xx: compile-time checks over compressed-topology encodings.

An `EncodedTopology` is static configuration that the gather channel
executes directly — if its IE tables are malformed, the failure surfaces
as silent numerical corruption inside a Pallas kernel, not a Python
exception. These checks prove table integrity before anything is lowered:

  TB601  ghost entries: IE targets outside [0, n_post) or sources outside
         [0, n_pre) — the gather lowering would scatter out of bounds
  TB602  duplicate (pre, post) entries: the COO accumulation sums them,
         which is almost never what an encoder intended
  TB603  coverage: structured kinds (fc / conv / pool) should reach every
         output neuron; a hole means a mis-sized encode
  TB604  storage honesty: `meta["n_connections"]` (the denominator of the
         Fig. 14 compression claims) must equal what the tables hold
  TB605  delay capacity: a skip connection's delay must fit the
         `BITS["delay"]` field the fan-out IE actually stores
"""

from __future__ import annotations

from typing import Any, List, Optional

import numpy as np

from repro.analysis.diagnostics import Diagnostic, make

# kinds whose encoders promise full output coverage (TB603); sparse/skip
# connectivity is allowed to leave outputs unreached
_COVERED_KINDS = ("fc", "conv", "pool")

_DECODE_ERROR = object()


def _coo_of(topo: Any) -> Optional[tuple]:
    try:
        return topo.coo()
    except NotImplementedError:
        return None
    except Exception as e:  # a crashing decode is itself a ghost-table sign
        return (_DECODE_ERROR, e)


def check_topology(topo: Any) -> List[Diagnostic]:
    """TB6xx over one `EncodedTopology` (any kind, including skip)."""
    from repro.core.topology import BITS

    out: List[Diagnostic] = []
    site = f"topology:{topo.kind}"
    n_pre, n_post = int(topo.n_pre), int(topo.n_post)

    # -- TB605: delay field capacity -----------------------------------------
    delay = topo.meta.get("delay")
    delayed = any(getattr(e, "delayed", False) for e in topo.fan_out)
    if delayed or topo.kind == "skip":
        cap = (1 << BITS["delay"]) - 1
        if delay is None:
            out.append(make(
                "TB605", site,
                "delayed fan-out entries but meta records no 'delay'",
                hint="encode skips via encode(source, kind='skip', "
                     "delay=d)"))
        elif not 0 <= int(delay) <= cap:
            out.append(make(
                "TB605", site,
                f"delay {delay} does not fit the {BITS['delay']}-bit "
                f"delay field (max {cap})",
                hint="split the skip across relay stages or widen "
                     "BITS['delay']"))

    # -- fc: type-2 incremental addressing is checked symbolically -----------
    if topo.kind == "fc" or (topo.kind == "skip"
                             and _coo_of(topo) is None):
        covered = np.zeros(n_post, bool)
        for de in topo.fan_in:
            for ie in de.ies:
                if ie.ie_type != 2:
                    continue
                last = ie.start + ie.margin * (ie.count - 1)
                if ie.start < 0 or last >= n_post:
                    out.append(make(
                        "TB601", site,
                        f"type-2 IE spans [{ie.start}, {last}] but "
                        f"out_dim is {n_post}"))
                    continue
                idx = ie.start + ie.margin * np.arange(ie.count)
                if covered[idx].any():
                    out.append(make(
                        "TB602", site,
                        "type-2 IE ranges overlap: the same output "
                        "neuron accumulates twice per spike"))
                covered[idx] = True
        if not covered.all():
            out.append(make(
                "TB603", site,
                f"type-2 IEs cover {int(covered.sum())}/{n_post} "
                f"output neurons",
                hint="check n_cores partitioning in encode(..., "
                     "kind='fc')"))
        n_conn = topo.meta.get("n_connections")
        if n_conn is None or int(n_conn) != n_pre * n_post:
            out.append(make(
                "TB604", site,
                f"meta n_connections={n_conn} but an fc layer of shape "
                f"({n_pre}, {n_post}) holds {n_pre * n_post}"))
        return out

    # -- everything else: check the executable COO view ----------------------
    coo = _coo_of(topo)
    if coo is None:
        return out
    if coo[0] is _DECODE_ERROR:
        out.append(make("TB601", site,
                        f"IE decode crashed: {coo[1]!r}",
                        hint="the tables do not round-trip; re-encode"))
        return out
    pre, post, w = (np.asarray(coo[0]), np.asarray(coo[1]),
                    np.asarray(coo[2]))
    if pre.size:
        if pre.min() < 0 or pre.max() >= n_pre:
            out.append(make(
                "TB601", site,
                f"IE source ids span [{pre.min()}, {pre.max()}] outside "
                f"[0, {n_pre})"))
        if post.min() < 0 or post.max() >= n_post:
            out.append(make(
                "TB601", site,
                f"IE target ids span [{post.min()}, {post.max()}] "
                f"outside [0, {n_post})"))
        pairs = pre.astype(np.int64) * n_post + post.astype(np.int64)
        n_dup = pairs.size - np.unique(pairs).size
        if n_dup:
            out.append(make(
                "TB602", site,
                f"{n_dup} duplicate (pre, post) entries — their weights "
                f"accumulate on every spike"))
    base_kind = topo.meta.get("base_kind", topo.kind)
    if base_kind in _COVERED_KINDS and post.size:
        reached = np.unique(post[(post >= 0) & (post < n_post)])
        if reached.size < n_post:
            out.append(make(
                "TB603", site,
                f"IEs reach {reached.size}/{n_post} output neurons",
                hint="for pool/conv check the input geometry divides "
                     "into the declared output shape"))
    n_conn = topo.meta.get("n_connections")
    if base_kind == "conv":
        # conv counts every (output, tap) pair incl. zero-padding taps,
        # so the honest value comes from the recorded geometry
        m = topo.meta
        expect = (m["c_in"] * m["c_out"] * m["h_out"] * m["w_out"]
                  * m["k"] * m["k"]) if all(
                      k in m for k in
                      ("c_in", "c_out", "h_out", "w_out", "k")) else None
    else:
        expect = int(pre.size)
    if n_conn is None:
        out.append(make(
            "TB604", site,
            "meta records no n_connections; baseline_bits() and the "
            "Fig. 14 storage comparison cannot be computed"))
    elif expect is not None and int(n_conn) != expect:
        out.append(make(
            "TB604", site,
            f"meta n_connections={int(n_conn)} but the IE tables hold "
            f"{expect} connections — storage_bits() vs "
            f"baseline_bits() comparisons would lie"))
    return out


__all__ = ["check_topology"]
