"""Static analysis for the whole stack: programs, plans, kernels, mappings.

Compile-time verification in the TaiBai co-design spirit — the toolchain
proves properties of what will execute before anything is traced:

  check_nodes(nodes, params=, T=, B=)   TB1xx + TB2xx over a Program DAG
  check_program(prog) / check_synapse(sp)   one IR object
  check_plan(nodes, plan=, T=, B=)      fusion explainability + VMEM
  check_kernel(name) / check_kernels()  TB3xx over the registry
  check_cores(cores, ops) / check_mapping(mapping, ops)   TB4xx
  check_serve(nodes, params, cfg)       TB5xx over a serve deployment
  check_topology(topo)                  TB6xx over a compressed encoding
  check(target, **kw)                   polymorphic dispatch over the above

All of them return `List[Diagnostic]` (stable code, severity, site,
message, fix hint); `at_least`/`raise_if`/`render` post-process. The CLI
(`python -m repro.analysis --all --fail-on warning`) lints the shipped
registry + application models; `REPRO_CHECK=warn|raise` wires the same
checks into `core.plan.compile_program`.
"""

from __future__ import annotations

from typing import Any, List

from repro.analysis.diagnostics import (CODES, SEVERITIES, Diagnostic,
                                        DiagnosticError, at_least, make,
                                        raise_if, render, severity_rank,
                                        worst)
from repro.analysis.kernels import (check_block_table, check_kernel,
                                    check_kernels, coverage_problems)
from repro.analysis.mapping import check_cores, check_mapping
from repro.analysis.plans import check_plan, compile_quiet
from repro.analysis.program import (DEFAULT_EXTERNAL, check_nodes_graph,
                                    check_program, check_synapse)
from repro.analysis.serve import check_serve, session_footprint
from repro.analysis.topology import check_topology


def check_nodes(nodes: Any, params: Any = None, T: Any = None, B: Any = None,
                plan: Any = None,
                external: Any = DEFAULT_EXTERNAL) -> List[Diagnostic]:
    """TB1xx graph/IR checks + TB2xx plan checks over a node list.

    Plan checks are skipped when the graph has error-severity findings
    (the planner assumes a structurally valid DAG).
    """
    out = check_nodes_graph(nodes, params=params, external=external)
    if not any(d.severity == "error" for d in out):
        try:
            out.extend(check_plan(nodes, plan=plan, T=T, B=B, params=params))
        except Exception as e:  # a planner crash is itself a finding
            out.append(make("TB100", "plan",
                            f"plan compilation failed: {e!r}"))
    return out


def check(target: Any, **kw: Any) -> List[Diagnostic]:
    """Polymorphic entry point: dispatch on what `target` is.

    list/tuple of LayerNode -> check_nodes; NeuronProgram ->
    check_program; SynapseProgram -> check_synapse; kernel name (str) ->
    check_kernel; mapping.Mapping -> check_mapping(target, ops=...);
    EncodedTopology -> check_topology.
    """
    from repro.core import mapping as mp
    from repro.core.neuron import NeuronProgram
    from repro.core.plasticity import SynapseProgram
    from repro.core.topology import EncodedTopology

    if isinstance(target, str):
        return check_kernel(target, **kw)
    if isinstance(target, EncodedTopology):
        return check_topology(target, **kw)
    if isinstance(target, NeuronProgram):
        return check_program(target, **kw)
    if isinstance(target, SynapseProgram):
        return check_synapse(target, **kw)
    if isinstance(target, mp.Mapping):
        return check_mapping(target, **kw)
    if isinstance(target, (list, tuple)):
        return check_nodes(list(target), **kw)
    raise TypeError(f"don't know how to check {type(target).__name__}")


__all__ = [
    "CODES", "SEVERITIES", "Diagnostic", "DiagnosticError",
    "at_least", "make", "raise_if", "render", "severity_rank", "worst",
    "check", "check_block_table", "check_cores", "check_kernel",
    "check_kernels", "check_mapping", "check_nodes", "check_nodes_graph",
    "check_plan", "check_program", "check_serve", "check_synapse",
    "check_topology",
    "compile_quiet", "coverage_problems", "session_footprint",
    "DEFAULT_EXTERNAL",
]
