"""TB4xx: placement/mapping validation (core/mapping.py artifacts).

Validates what the mapping compiler emits against the chip model it
claims to target: per-core neuron budgets under fan-in expansion
(TB401), complete op coverage (TB402), on-grid placement (TB403),
physically satisfiable fan-in (TB404), and the NoC link budget (TB405).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import mapping as mp

from repro.analysis.diagnostics import Diagnostic, make


def check_cores(cores: Sequence[mp.CoreAssignment], ops: Sequence[mp.Op],
                core_neurons: int = mp.CORE_NEURONS,
                core_fanin: int = mp.CORE_FANIN) -> List[Diagnostic]:
    """TB401/402/404 over a core assignment (pre-placement)."""
    out: List[Diagnostic] = []
    by_name = {o.name: o for o in ops}

    for o in ops:
        # TB404: fan-in so large even a whole core of PSUM parts can't host
        # one neuron (parts + 1 spiking slot must fit core_neurons)
        parts = max(1, math.ceil(o.fan_in / core_fanin))
        if o.kind not in ("add",) and parts > core_neurons:
            out.append(make(
                "TB404", o.name,
                f"fan_in={o.fan_in} needs {parts} PSUM parts per neuron "
                f"> {core_neurons} slots per core",
                hint="split the operator (channel groups) before mapping"))

    # TB401: charged load over budget (merge_cores loses ranges for merged
    # ops, so the charged check applies to each core's primary op + the
    # open-slot invariant merge_cores maintains is re-checked via sizes)
    for idx, c in enumerate(cores):
        o = by_name.get(c.op)
        if o is None:
            continue
        parts = max(1, math.ceil(o.fan_in / core_fanin))
        load = (c.neuron_hi - c.neuron_lo) * parts
        if load > core_neurons:
            out.append(make(
                "TB401", f"core[{idx}]:{c.op}",
                f"neurons [{c.neuron_lo}, {c.neuron_hi}) x {parts} PSUM "
                f"part(s) = {load} slots > {core_neurons} per core"))
        if c.neuron_hi < c.neuron_lo or c.neuron_lo < 0:
            out.append(make(
                "TB401", f"core[{idx}]:{c.op}",
                f"degenerate neuron range [{c.neuron_lo}, {c.neuron_hi})"))

    # TB402: every real op appears somewhere; primary-only ops must cover
    # their full neuron range (merged placements lose ranges by design)
    primary: Dict[str, List[Tuple[int, int]]] = {}
    mentioned = set()
    for c in cores:
        primary.setdefault(c.op, []).append((c.neuron_lo, c.neuron_hi))
        mentioned.add(c.op)
        mentioned.update(c.merged_with)
    for o in ops:
        if o.kind in ("add",) or o.n_neurons <= 0:
            continue  # adds fuse into their destination cores
        if o.name not in mentioned:
            out.append(make(
                "TB402", o.name,
                f"{o.n_neurons} neuron(s) assigned to no core"))
            continue
        ranges = sorted(primary.get(o.name, []))
        if ranges and o.name not in {
                m for c in cores for m in c.merged_with}:
            covered = 0
            cursor = 0
            for lo, hi in ranges:
                if lo > cursor:
                    break
                covered = max(covered, hi)
                cursor = max(cursor, hi)
            if covered < o.n_neurons:
                out.append(make(
                    "TB402", o.name,
                    f"cores cover neurons [0, {covered}) of "
                    f"{o.n_neurons}: range has holes or is truncated"))
    return out


def _fanout_per_neuron(ops: Sequence[mp.Op]) -> Dict[str, float]:
    """Average downstream synapse slots each source neuron must drive."""
    demand: Dict[str, float] = {o.name: 0.0 for o in ops}
    for q in ops:
        if not q.inputs:
            continue
        share = (q.n_neurons * q.fan_in) / len(q.inputs)
        for src in q.inputs:
            if src in demand:
                demand[src] += share
    return {name: demand[name] / o.n_neurons
            for name, o in ((o.name, o) for o in ops) if o.n_neurons > 0}


def check_mapping(mapping: mp.Mapping, ops: Sequence[mp.Op],
                  grid: Tuple[int, int] = mp.GRID,
                  core_neurons: int = mp.CORE_NEURONS,
                  core_fanin: int = mp.CORE_FANIN,
                  link_fanout: Optional[int] = None) -> List[Diagnostic]:
    """TB401-405 over a compiled Mapping (cores + positions)."""
    out = check_cores(mapping.cores, ops, core_neurons, core_fanin)
    H, W = grid
    pos = mapping.positions
    n_cores = len(mapping.cores)
    if pos is None or len(pos) != n_cores:
        out.append(make(
            "TB403", "positions",
            f"{0 if pos is None else len(pos)} position(s) for "
            f"{n_cores} core(s)"))
    else:
        cap = H * W * mp.NCS_PER_CC
        n_chips = max(1, math.ceil(n_cores / cap))
        for idx, (y, x) in enumerate(pos):
            if not (0 <= y < H and 0 <= x < W * n_chips):
                out.append(make(
                    "TB403", f"core[{idx}]:{mapping.cores[idx].op}",
                    f"placed at (y={int(y)}, x={int(x)}) outside the "
                    f"{H}x{W} grid across {n_chips} chip(s)"))

    budget = mp.LINK_FANOUT if link_fanout is None else link_fanout
    for name, fanout in sorted(_fanout_per_neuron(ops).items()):
        if fanout > budget:
            out.append(make(
                "TB405", name,
                f"each source neuron drives ~{fanout:.0f} downstream "
                f"synapses > link budget {budget}",
                hint="multicast trees or axon replication needed; expect "
                     "NoC congestion at this fanout"))
    return out


__all__ = ["check_cores", "check_mapping"]
