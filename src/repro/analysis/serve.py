"""TB5xx: static checks over a serve-engine deployment.

A streaming deployment is a (model, EngineConfig) pair; most operational
pathologies are decidable before the first session opens, from exactly
the numbers the engine itself uses:

  TB501 error    cache_bytes below ONE session's state footprint — every
                 cohort gather spills every other tenant to host and
                 restores it next window; the cache degenerates into a
                 per-window host round-trip for the entire fleet.
  TB502 warning  cache_bytes below capacity x footprint — a full cohort
                 cannot stay hot simultaneously, so steady-state serving
                 thrashes the spill path even with zero queue.
  TB503 warning  the compiled plan has fallback (stepper) segments — the
                 resident window step multiplies that per-step cost by
                 every slot of every window; fix the program or accept
                 the throughput.
  TB504 warning  queue_limit (in buffered windows) below cohort capacity
                 — admission can never hold enough work to fill a cohort,
                 capping occupancy below 1 by construction.
  TB505 error    non-positive window / capacity / queue_limit /
                 cache_bytes — the configuration cannot run at all.

`check_serve(nodes, params, cfg)` returns `List[Diagnostic]` like every
other checker; the CLI (`python -m repro.analysis --serve` / `--all`)
lints the shipped models under a representative config.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax.numpy as jnp

from repro.analysis.diagnostics import Diagnostic, make


def session_footprint(nodes: Any, params: Any, dtype=jnp.float32) -> int:
    """Bytes of one session's full state tree (syn entries included)."""
    from repro.core import events
    from repro.core.plan import state_nbytes
    return state_nbytes(events.init_state(nodes, 1, dtype, params))


def check_serve(nodes: Any, params: Any, cfg: Any = None,
                plan: Any = None, dtype=jnp.float32) -> List[Diagnostic]:
    """TB5xx checks for serving `nodes` under EngineConfig `cfg`.

    `cfg` defaults to `serve.EngineConfig()`; `plan` is compiled from the
    nodes when not supplied. Duck-typed: any object with window/capacity/
    queue_limit/cache_bytes attributes works (tests pass SimpleNamespace
    to reach configurations EngineConfig's own validation refuses).
    """
    from repro.core import plan as plan_mod
    from repro.serve.engine import EngineConfig

    if cfg is None:
        cfg = EngineConfig()
    out: List[Diagnostic] = []

    window = int(getattr(cfg, "window", 0))
    capacity = int(getattr(cfg, "capacity", 0))
    queue_limit: Optional[int] = getattr(cfg, "queue_limit", None)
    cache_bytes: Optional[int] = getattr(cfg, "cache_bytes", None)

    for name, val, floor in (("window", window, 1), ("capacity", capacity, 1)):
        if val < floor:
            out.append(make(
                "TB505", f"cfg.{name}",
                f"{name}={val} must be >= {floor}",
                hint="the engine needs at least one timestep per window "
                     "and one cohort slot"))
    for name, val in (("queue_limit", queue_limit),
                      ("cache_bytes", cache_bytes)):
        if val is not None and val < 1:
            out.append(make(
                "TB505", f"cfg.{name}",
                f"{name}={val} must be positive (or None for unbounded)"))
    if any(d.severity == "error" for d in out):
        return out  # footprint math below assumes a sane config

    fp = session_footprint(nodes, params, dtype)
    if cache_bytes is not None:
        if cache_bytes < fp:
            out.append(make(
                "TB501", "cfg.cache_bytes",
                f"budget {cache_bytes} B < one session footprint {fp} B: "
                "every cohort gather spills the rest of the fleet to host "
                "and restores it next window",
                hint=f"raise cache_bytes to >= {capacity * fp} B "
                     f"(capacity x footprint) or shrink the model state"))
        elif cache_bytes < capacity * fp:
            hot = max(1, cache_bytes // fp)
            out.append(make(
                "TB502", "cfg.cache_bytes",
                f"budget {cache_bytes} B holds ~{hot} hot session(s) but "
                f"cohorts serve {capacity}: steady state thrashes the "
                "spill/restore path every window",
                hint=f"raise cache_bytes to >= {capacity * fp} B or lower "
                     "capacity"))

    if queue_limit is not None and queue_limit < capacity:
        out.append(make(
            "TB504", "cfg.queue_limit",
            f"queue_limit={queue_limit} buffered windows < "
            f"capacity={capacity} slots: admission can never hold enough "
            "work to fill a cohort, capping occupancy at "
            f"{queue_limit}/{capacity}",
            hint="set queue_limit >= capacity (several multiples for "
                 "smooth arrivals)"))

    if plan is None:
        plan = plan_mod.compile_program(list(nodes))
    fb = [s for s in plan.segments if s.kind == plan_mod.FALLBACK]
    if fb:
        names = ",".join(n for s in fb for n in s.names)
        out.append(make(
            "TB503", f"plan:{names}",
            f"{len(fb)} fallback segment(s) inside the resident window "
            "step: per-step stepper cost is paid by every slot of every "
            "window",
            hint="see plan.describe() / the TB2xx codes on each segment "
                 "for why fusion was refused"))
    return out


__all__ = ["check_serve", "session_footprint"]
