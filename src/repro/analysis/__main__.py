"""CLI: lint the kernel registry, the shipped models, and their mappings.

Usage:
  python -m repro.analysis --all [--fail-on warning] [--json]
  python -m repro.analysis --kernels
  python -m repro.analysis --models [-T 128] [-B 8]
  python -m repro.analysis --mapping
  python -m repro.analysis --serve
  python -m repro.analysis --topologies

Exit status 1 when findings at/above --fail-on exist (default: error;
"never" always exits 0). CI runs `--all --fail-on warning` as a fast-tier
gate: the shipped registry and application models must check clean.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Callable, Dict, List, Tuple

from repro.analysis.diagnostics import Diagnostic, at_least, render


def _model_factories() -> Dict[str, Callable[..., Tuple[list, dict]]]:
    from repro.core import snn_layers as L
    return {
        "srnn_ecg": L.make_srnn_ecg,
        "srnn_ecg_homogeneous":
            lambda key: L.make_srnn_ecg(key, heterogeneous=False),
        "dhsnn_shd": L.make_dhsnn_shd,
        "plastic_ff": L.make_plastic_ff,
    }


def _check_models(T: int, B: int) -> List[Diagnostic]:
    import jax

    from repro import analysis
    out: List[Diagnostic] = []
    for name, factory in _model_factories().items():
        nodes, params = factory(jax.random.PRNGKey(0))
        for d in analysis.check_nodes(nodes, params=params, T=T, B=B):
            out.append(Diagnostic(d.code, d.severity, f"{name}:{d.site}",
                                  d.message, d.hint))
    return out


def _check_serving() -> List[Diagnostic]:
    """TB5xx over the shipped models under a representative deployment:
    an 8-slot cohort with a cache budget sized for the full cohort (the
    configuration the README quickstart ships), so the gate proves the
    defaults do not thrash."""
    import jax

    from repro import analysis
    from repro.serve import EngineConfig

    out: List[Diagnostic] = []
    for name, factory in _model_factories().items():
        nodes, params = factory(jax.random.PRNGKey(0))
        fp = analysis.session_footprint(nodes, params)
        cfg = EngineConfig(window=32, capacity=8, queue_limit=64,
                           cache_bytes=8 * fp)
        for d in analysis.check_serve(nodes, params, cfg):
            out.append(Diagnostic(d.code, d.severity, f"{name}:{d.site}",
                                  d.message, d.hint))
    return out


def _check_mappings() -> List[Diagnostic]:
    from repro import analysis
    from repro.configs import snn_models
    from repro.core import mapping as mp

    out: List[Diagnostic] = []
    for name, factory in sorted(snn_models.MODELS.items()):
        specs, _ = factory()
        ops = snn_models.to_ops(specs)
        ir = mp.fuse_ops([dataclasses.replace(o) for o in ops])
        for label, cores in (
                ("partition", mp.partition(ir)),
                ("merged", mp.merge_cores(mp.partition(ir), ir))):
            for d in analysis.check_cores(cores, ir):
                out.append(Diagnostic(d.code, d.severity,
                                      f"{name}:{label}:{d.site}",
                                      d.message, d.hint))
    # one end-to-end placement (cheap anneal) through the full validator
    specs, _ = snn_models.MODELS["plif_net"]()
    ops = snn_models.to_ops(specs)
    mapped = mp.compile_network(ops, anneal_iters=50)
    ir = mp.fuse_ops([dataclasses.replace(o) for o in ops])
    for d in analysis.check_mapping(mapped, ir):
        out.append(Diagnostic(d.code, d.severity, f"plif_net:placed:{d.site}",
                              d.message, d.hint))
    return out


def _check_topologies() -> List[Diagnostic]:
    """TB6xx over a representative set of shipped encodings: every IE type
    (0/1/2/3), pooling, and a delayed skip — the same shapes the compressed
    execution path runs through the gather channel."""
    import numpy as np

    from repro import analysis
    from repro.core import topology as tp

    rng = np.random.default_rng(0)
    dense = rng.normal(size=(40, 30)).astype(np.float32)
    sparse = dense * (rng.random((40, 30)) < 0.1)
    filt = rng.normal(size=(4, 3, 3, 3)).astype(np.float32)
    cases = {
        "fc": tp.encode(dense, kind="fc", n_cores=4),
        "sparse_t0": tp.encode(sparse, kind="sparse", ie_type=0),
        "sparse_t1": tp.encode(sparse, kind="sparse", ie_type=1),
        "conv": tp.encode(filt, kind="conv", h=8, w=8),
        "pool": tp.encode(None, kind="pool", h=8, w=8, c=3, k=2),
        "skip": tp.encode(tp.encode(sparse, kind="sparse"), kind="skip",
                          delay=3),
    }
    out: List[Diagnostic] = []
    for name, enc in cases.items():
        for d in analysis.check_topology(enc):
            out.append(Diagnostic(d.code, d.severity, f"{name}:{d.site}",
                                  d.message, d.hint))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static checks over programs, plans, kernel specs, "
                    "mappings, serve deployments, and compressed "
                    "topologies (TB1xx-TB6xx).")
    ap.add_argument("--all", action="store_true",
                    help="kernels + models + mappings + serve + "
                         "topologies (the CI gate)")
    ap.add_argument("--kernels", action="store_true",
                    help="TB3xx over every registered kernel family")
    ap.add_argument("--models", action="store_true",
                    help="TB1xx/TB2xx over the shipped application models")
    ap.add_argument("--mapping", action="store_true",
                    help="TB4xx over configs/snn_models.py mappings")
    ap.add_argument("--serve", action="store_true",
                    help="TB5xx over the shipped models under the "
                         "default serve deployment")
    ap.add_argument("--topologies", action="store_true",
                    help="TB6xx over representative compressed "
                         "encodings (all four IE types + pool + skip)")
    ap.add_argument("--fail-on", choices=["error", "warning", "never"],
                    default="error",
                    help="exit 1 when findings at/above this severity "
                         "exist (default: error)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("-T", type=int, default=128,
                    help="time steps assumed for VMEM prediction (TB230)")
    ap.add_argument("-B", type=int, default=8,
                    help="batch assumed for VMEM prediction (TB230)")
    args = ap.parse_args(argv)

    if not (args.all or args.kernels or args.models or args.mapping
            or args.serve or args.topologies):
        args.all = True

    from repro import analysis

    diags: List[Diagnostic] = []
    if args.all or args.kernels:
        diags.extend(analysis.check_kernels())
    if args.all or args.models:
        diags.extend(_check_models(args.T, args.B))
    if args.all or args.mapping:
        diags.extend(_check_mappings())
    if args.all or args.serve:
        diags.extend(_check_serving())
    if args.all or args.topologies:
        diags.extend(_check_topologies())

    if args.json:
        print(json.dumps([d.__dict__ for d in at_least(diags, "info")],
                         indent=1))
    else:
        print(render(diags))
        counts = {s: sum(1 for d in diags if d.severity == s)
                  for s in ("error", "warning", "info")}
        print(f"-- {counts['error']} error(s), {counts['warning']} "
              f"warning(s), {counts['info']} info")

    if args.fail_on == "never":
        return 0
    return 1 if at_least(diags, args.fail_on) else 0


if __name__ == "__main__":
    sys.exit(main())
