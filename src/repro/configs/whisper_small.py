"""whisper-small — encoder-decoder ASR backbone
(arXiv:2212.04356; unverified). 12L(+12L enc) d_model=768 12H(kv=12)
d_ff=3072 vocab=51865. Conv/log-mel frontend is a STUB: input_specs()
provides precomputed (B, 1500, d) frame embeddings per the assignment."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        encoder_layers=12, encoder_len=1500,
        act="gelu", learned_pos=True, tie_embeddings=True,
        # whisper's native decoder ctx is 448; the assignment's decode_32k
        # cell dictates 32k cache slots, so positions extend to 32k.
        max_position=32768,
    )
