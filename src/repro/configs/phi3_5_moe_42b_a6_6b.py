"""phi3.5-moe-42b (6.6b active) — 16 experts top-2
(hf:microsoft/Phi-3.5-MoE-instruct). 32L d_model=4096 32H(kv=8) d_ff=6400
vocab=32064. FSDP on: 42B params exceed TP-16's per-chip HBM."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab_size=32064,
        n_experts=16, top_k=2, capacity_factor=1.25,
        fsdp=True, remat="dots_saveable", moe_group=256,
    )
