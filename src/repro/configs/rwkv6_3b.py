"""rwkv6-3b "Finch" — attention-free, data-dependent decay
(arXiv:2404.05892; hf). 32L d_model=2560 d_ff=8960 vocab=65536.

The wkv6 recurrence is the paper's DIFF primitive with per-token per-channel
decay — runs on the linrec kernel (DESIGN.md §2)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        rwkv_head_dim=64, decay_lora=64, tshift_lora=32, ssm_chunk=256,
        # Perf iters rwkv-4..6 (EXPERIMENTS.md §Perf): rwkv6's five distinct
        # ddlerp projection inputs make TP all-gather-heavy, so train/prefill
        # run PURE data-parallel with ZeRO-3 params (X: 13.5s -> 0.69s);
        # decode keeps TP automatically. dots_saveable remat: M -14%.
        # (rwkv_pad_heads=48 was the TP-alignment fix, superseded by pure_dp;
        # the feature remains available/tested for TP deployments.)
        pure_dp=True, remat="dots_saveable",
    )
