"""configs — one module per assigned architecture (+ the paper's SNNs).

Every architecture is selectable by id (``--arch <id>``); `get_config`
returns the exact published configuration, `get_smoke_config` the reduced
same-family variant used by CPU smoke tests. `cell_applicable` encodes the
assignment's skip rules (long_500k needs sub-quadratic attention; encoder-
only models have no decode step).
"""

from __future__ import annotations

import importlib
from typing import Optional, Tuple

from repro.models.config import ModelConfig, SHAPES, ShapeConfig, smoke_config

ARCH_IDS = [
    "zamba2-1.2b", "rwkv6-3b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b",
    "whisper-small", "deepseek-7b", "minicpm-2b", "qwen2-1.5b",
    "llama3.2-3b", "pixtral-12b",
]

_MODULE_OF = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
              for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULE_OF[arch]).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_config(get_config(arch))


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch x shape) cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.name == "long_500k":
        if cfg.family in ("ssm", "hybrid", "rwkv"):
            return True, ""
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{arch} is full-attention ({cfg.family})")
    if sh.mode == "decode" and cfg.family == "encdec" and cfg.n_layers == 0:
        return False, "encoder-only: no decode step"      # none assigned
    return True, ""


def shape_adapted_config(arch: str, shape: str) -> ModelConfig:
    """Per-cell config adaptation (recorded in DESIGN.md §6): zamba2's shared
    attention blocks switch to sliding-window at 500k context."""
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.family == "hybrid":
        cfg = cfg.replace(sliding_window=4096)
    return cfg
