"""zamba2-1.2b — hybrid Mamba2 backbone + ONE shared attention block
applied every 6 layers (arXiv:2411.15242; hf). 38L d_model=2048 32H(kv=32)
d_ff=8192 vocab=32000 ssm_state=64.

The shared block consumes concat(hidden, original embedding) (2d -> d
projection) — weight sharing across depth is zamba2's signature and maps to
TaiBai's type-3 weight multiplexing (DESIGN.md §6)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, d_conv=4,
        attn_every=6, ssm_chunk=256,
        # Perf iters zamba-4/5 (EXPERIMENTS.md §Perf): activation collectives
        # under TP outweigh ZeRO-3 param gathers for this width -> pure DP
        # for train/prefill (decode keeps TP); dots_saveable remat.
        pure_dp=True, remat="dots_saveable",
    )
