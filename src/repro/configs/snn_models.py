"""The paper's SNN benchmark networks (Table II) + Fig. 14 topology models.

Three evaluation SNNs, exactly as Table II specifies:

  PLIF-Net    Input-256c3p1x3-mp2-256c3p1x3-mp2-fc4096-fc10   in 32x32x3
  5Blocks-Net Input-mp2-16c3-[16c3p1x2]-mp2-...x5-fc11        in 128x128x2
  ResNet19    Input-64c3-[128c3p1x2]x3-[256c3p1x2]x3-
              [512c3p1x2]x2-fc256-fc10                        in 32x32x3

Each builder returns (ops, meta): `ops` feed the mapping compiler
(core/mapping.py) and the behavioural simulator; `topology_layers()`
materializes the 2-level fan-in/fan-out tables for the Fig. 14 storage
accounting (conv layers use type-3 decoupled addressing, pools type-0,
FCs type-2, residual skips the delayed-fire scheme).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.mapping import Op
from repro.core import topology as topo


@dataclasses.dataclass
class ConvSpec:
    kind: str                  # conv | pool | fc | skip
    c_in: int = 0
    c_out: int = 0
    k: int = 3
    stride: int = 1
    pad: int = 1
    h: int = 0                 # input spatial (set during build)
    w: int = 0
    n_in: int = 0              # fc
    n_out: int = 0
    skip_from: int = -1        # index of the layer this skip bypasses to


def _net(input_hw: Tuple[int, int, int], layers: List[ConvSpec]):
    """Fill in spatial dims; returns specs with shapes resolved."""
    h, w, c = input_hw
    out = []
    for L in layers:
        L = dataclasses.replace(L)
        if L.kind == "conv":
            L.h, L.w, L.c_in = h, w, c
            h = (h + 2 * L.pad - L.k) // L.stride + 1
            w = (w + 2 * L.pad - L.k) // L.stride + 1
            c = L.c_out
        elif L.kind == "pool":
            L.h, L.w, L.c_in = h, w, c
            h, w = h // L.k, w // L.k
        elif L.kind == "fc":
            if L.n_in == 0:
                L.n_in = h * w * c
            h, w, c = 1, 1, L.n_out
        out.append(L)
    return out


def plif_net() -> Tuple[List[ConvSpec], str]:
    layers = ([ConvSpec("conv", c_out=256)] * 3 + [ConvSpec("pool", k=2)]
              + [ConvSpec("conv", c_out=256)] * 3 + [ConvSpec("pool", k=2)]
              + [ConvSpec("fc", n_out=4096), ConvSpec("fc", n_out=10)])
    return _net((32, 32, 3), layers), "PLIF-Net"


def blocks5_net() -> Tuple[List[ConvSpec], str]:
    layers: List[ConvSpec] = [ConvSpec("pool", k=2), ConvSpec("conv", c_out=16, pad=0)]
    for _ in range(5):
        layers += [ConvSpec("conv", c_out=16)] * 2 + [ConvSpec("pool", k=2)]
    layers += [ConvSpec("fc", n_out=11)]
    return _net((128, 128, 2), layers), "5Blocks-Net"


def resnet19() -> Tuple[List[ConvSpec], str]:
    layers: List[ConvSpec] = [ConvSpec("conv", c_out=64)]
    blocks = [(128, 3), (256, 3), (512, 2)]
    li = 0
    for c_out, reps in blocks:
        for r in range(reps):
            start = len(layers)
            stride = 2 if r == 0 else 1
            layers.append(ConvSpec("conv", c_out=c_out, stride=stride))
            layers.append(ConvSpec("conv", c_out=c_out))
            layers.append(ConvSpec("skip", skip_from=start - 1))
    layers += [ConvSpec("fc", n_out=256), ConvSpec("fc", n_out=10)]
    return _net((32, 32, 3), layers), "ResNet19"


def vgg16_cifar() -> Tuple[List[ConvSpec], str]:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    layers: List[ConvSpec] = []
    for v in cfg:
        if v == "M":
            layers.append(ConvSpec("pool", k=2))
        else:
            layers.append(ConvSpec("conv", c_out=v))
    layers += [ConvSpec("fc", n_out=512), ConvSpec("fc", n_out=10)]
    return _net((32, 32, 3), layers), "VGG16"


def resnet18_cifar() -> Tuple[List[ConvSpec], str]:
    layers: List[ConvSpec] = [ConvSpec("conv", c_out=64)]
    for c_out, reps in [(64, 2), (128, 2), (256, 2), (512, 2)]:
        for r in range(reps):
            start = len(layers)
            stride = 2 if (r == 0 and c_out > 64) else 1
            layers.append(ConvSpec("conv", c_out=c_out, stride=stride))
            layers.append(ConvSpec("conv", c_out=c_out))
            layers.append(ConvSpec("skip", skip_from=start - 1))
    layers += [ConvSpec("fc", n_out=10)]
    return _net((32, 32, 3), layers), "ResNet18"


MODELS = {"plif_net": plif_net, "5blocks_net": blocks5_net,
          "resnet19": resnet19, "vgg16": vgg16_cifar,
          "resnet18": resnet18_cifar}


# ---------------------------------------------------------------------------
# bridges to the mapping compiler and the topology tables
# ---------------------------------------------------------------------------


def to_ops(specs: List[ConvSpec]) -> List[Op]:
    ops: List[Op] = []
    prev = "input"
    for i, L in enumerate(specs):
        name = f"L{i}_{L.kind}"
        if L.kind == "conv":
            ho = (L.h + 2 * L.pad - L.k) // L.stride + 1
            wo = (L.w + 2 * L.pad - L.k) // L.stride + 1
            ops.append(Op(name, "conv", L.c_out * ho * wo,
                          L.c_in * L.k * L.k, (prev,)))
        elif L.kind == "pool":
            ops.append(Op(name, "pool", L.c_in * (L.h // L.k) * (L.w // L.k),
                          L.k * L.k, (prev,)))
        elif L.kind == "fc":
            ops.append(Op(name, "fc", L.n_out, L.n_in, (prev,)))
        elif L.kind == "skip":
            ops.append(Op(name, "add", 0, 0, (prev, f"L{L.skip_from}_conv")))
        prev = ops[-1].name if ops else prev
    return ops


def topology_layers(specs: List[ConvSpec], seed: int = 0,
                    max_fc_core: int = 8) -> List[topo.EncodedTopology]:
    """Materialize the encoded tables for every connection (Fig. 14)."""
    rng = np.random.default_rng(seed)
    out: List[topo.EncodedTopology] = []
    for i, L in enumerate(specs):
        if L.kind == "conv":
            filt = rng.standard_normal((L.c_out, L.c_in, L.k, L.k)
                                       ).astype(np.float32)
            out.append(topo.encode_conv(filt, L.h, L.w, L.stride, L.pad))
        elif L.kind == "pool":
            out.append(topo.encode_pool(L.h, L.w, L.c_in, L.k))
        elif L.kind == "fc":
            w = rng.standard_normal((L.n_in, L.n_out)).astype(np.float32)
            out.append(topo.encode_fc(w, n_cores=max_fc_core))
        elif L.kind == "skip" and out:
            # delayed-fire reuse of the bypassed layer's fan-out table
            src = out[L.skip_from] if 0 <= L.skip_from < len(out) else out[-1]
            out.append(topo.encode_skip(src, delay=2))
    return out
