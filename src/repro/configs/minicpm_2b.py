"""minicpm-2b — llama-like dense with WSD schedule + depth-scaled
residuals (arXiv:2404.06395; hf). 40L d_model=2304 36H(kv=36) d_ff=5760
vocab=122753. residual_scale = scale_depth/sqrt(L) = 1.4/sqrt(40)."""

import math

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        residual_scale=1.4 / math.sqrt(40), tie_embeddings=True,
    )
