"""pixtral-12b — pixtral-ViT + mistral-nemo decoder backbone
(hf:mistralai/Pixtral-12B-2409; unverified). 40L d_model=5120 32H(kv=8)
head_dim=128 d_ff=14336 vocab=131072. The ViT patch frontend is a STUB:
input_specs() provides precomputed (B, n_patches, d) patch embeddings."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=131072, head_dim=128,
        n_patches=1024, rope_theta=1e9, fsdp=True,
    )
