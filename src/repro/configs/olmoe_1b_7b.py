"""olmoe-1b-7b — 64 experts, top-8 (arXiv:2409.02060; hf).
16L d_model=2048 16H(kv=16) d_ff=1024/expert vocab=50304."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8, capacity_factor=1.25,
        remat="dots_saveable",   # perf iter olmoe-3: -11% memory term
        moe_group=256,           # perf iter olmoe-5: -7% compute term
    )
