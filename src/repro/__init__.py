"""repro — TaiBai (topology-aware, fully-programmable brain-inspired processor)
reproduced as a production-grade JAX training/serving framework for TPU pods.

Layers:
  core/      the paper's contribution: programmable neuron DSL, 2-level
             topology tables, event-driven INTEG/FIRE engine, plasticity,
             mapping compiler, behavioural cost simulator.
  models/    LM substrate for the 10 assigned architectures.
  kernels/   Pallas TPU kernels (linrec/lif/spikemm/attention).
  sharding/  DP/TP/EP/SP/FSDP rules over the production mesh.
  launch/    mesh construction, multi-pod dry-run, train/serve drivers.
  roofline/  compiled-artifact roofline analysis.
"""

__version__ = "1.0.0"
