"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The paper's model-pipeline analogue: TaiBai runs network layers as a
pipeline across CC cores, with spike packets flowing stage-to-stage while
every stage works on a different timestep's data (§III-A "model pipeline
parallel computation mechanism"). Here the stages are mesh devices along a
`stage` axis, the packets are microbatch activations moved by
`lax.ppermute`, and the schedule is the classic GPipe fill-drain:

  tick t (0 <= t < M + S - 1): stage s computes microbatch (t - s) if valid,
  then shifts its output one stage rightward.

Stage parameters live sharded over the stage axis (leading dim = S); each
device sees only its own stage's weights, so a model S times larger than
one device's HBM fits. Differentiable (jax.grad through the shard_map),
composable with the DP/TP axes of the same mesh.

Bubble fraction: (S-1)/(M+S-1) — the usual GPipe trade; pick M >= 4*S.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def pipeline_apply(stage_fn: Callable[[Any, Array], Array],
                   stage_params: Any, x: Array, mesh: Mesh,
                   axis: str = "stage") -> Array:
    """Run `stage_fn` S times as a pipeline over `axis`.

    stage_params: pytree whose leaves have leading dim S (one slice per
      stage), sharded over `axis`.
    x: (M, mb, ...) microbatched input (M microbatches), replicated.
    Returns (M, mb, ...) output of the last stage, replicated.
    """
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params, x):
        # params: this stage's slice (leading dim 1); x: full (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        s = jax.lax.axis_index(axis)
        n_ticks = M + S - 1
        buf = jnp.zeros_like(x[0])                  # current inbound act
        outs = jnp.zeros_like(x)                    # last stage collects

        def tick(carry, t):
            buf, outs = carry
            mb_idx = t - s                           # microbatch this stage works on
            valid = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 reads from the input stream; others from the buffer
            x_in = jnp.where(s == 0,
                             x[jnp.clip(t, 0, M - 1)], buf)
            y = stage_fn(params, x_in)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # collect at the last stage
            outs = jnp.where(
                (s == S - 1) & valid,
                outs.at[jnp.clip(mb_idx, 0, M - 1)].set(y), outs)
            # shift rightward: stage s -> s+1 (ring; the wraparound value
            # lands in stage 0's buffer and is never read)
            buf = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # replicate the last stage's collected outputs to all stages
        outs = jax.lax.psum(
            jnp.where(s == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(per_stage, mesh=mesh,
                     in_specs=(pspec, P()), out_specs=P(),
                     check_rep=False)(stage_params, x)


def microbatch(x: Array, n_micro: int) -> Array:
    """(B, ...) -> (M, B/M, ...)."""
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    return x.reshape((n_micro, B // n_micro) + x.shape[1:])


def pipeline_loss_fn(stage_fn: Callable, loss_head: Callable,
                     mesh: Mesh, axis: str = "stage",
                     n_micro: int = 8):
    """Build a differentiable pipelined loss:
    loss = mean over microbatches of loss_head(pipeline(x), y)."""

    def loss(stage_params, batch_x, batch_y):
        xm = microbatch(batch_x, n_micro)
        ym = microbatch(batch_y, n_micro)
        out = pipeline_apply(stage_fn, stage_params, xm, mesh, axis)
        return jnp.mean(jax.vmap(loss_head)(out, ym))

    return loss
