"""Parameter/activation sharding rules (DP / TP / EP / FSDP / SP).

The paper's fan-in expansion (PSUM neurons, Fig. 11) is tensor parallelism:
a neuron whose fan-in exceeds one core's budget is split into partial-sum
shards that reduce into the firing neuron. Here that is the `model` axis:
every weight matrix whose contraction dimension is sharded produces partial
sums that XLA reduces — the PSUM neuron's 'accumulated current transmission'
is the all-reduce. The mapping is:

  TaiBai                         TPU mesh
  ------                         --------
  parallel-send over NCs     ->  TP over `model` (16-way within a pod row)
  multi-core population      ->  DP over (`pod`,) `data`
  PSUM partial currents      ->  contraction-dim sharding + psum
  proxy-unit chip expansion  ->  the `pod` axis (inter-pod DCN/ICI)

Rules are keyed on parameter path substrings; `param_specs` walks any params
pytree and returns a matching PartitionSpec tree. `fsdp=True` additionally
shards a replicated-after-TP dimension over `data` (ZeRO-3 via GSPMD: XLA
inserts the use-site all-gathers).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# ---------------------------------------------------------------------------
# process-wide mesh registry (set by launchers; None => no-op constraints)
# ---------------------------------------------------------------------------

_MESH: Optional[Mesh] = None
_PURE_DP: bool = False


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def set_pure_dp(flag: bool) -> None:
    """Pure data-parallel mode (perf iter rwkv-4): the `model` axis joins
    the data axes; parameters ZeRO-3-shard over the combined axis. Chosen
    for architectures whose activation-collective volume under TP exceeds
    the FSDP parameter-gather volume (rwkv6's five distinct ddlerp
    projection inputs make TP all-gather-heavy)."""
    global _PURE_DP
    _PURE_DP = flag


def pure_dp() -> bool:
    return _PURE_DP


def dp_axes() -> Tuple[str, ...]:
    """Mesh axes that jointly carry data parallelism."""
    if _MESH is None:
        return ("data",)
    names = _MESH.axis_names
    axes = tuple(a for a in ("pod", "data") if a in names)
    if _PURE_DP and "model" in names:
        axes = axes + ("model",)
    return axes


def _resolve(logical: Sequence) -> PartitionSpec:
    """Map logical axis names -> mesh axes ('data' expands to (pod, data);
    under pure_dp it absorbs 'model' too, and explicit 'model' axes vanish)."""
    out = []
    for ax in logical:
        if ax == "data":
            d = dp_axes()
            out.append(d if len(d) > 1 else (d[0] if d else None))
        elif ax == "model" and _PURE_DP:
            out.append(None)
        else:
            out.append(ax)
    return PartitionSpec(*out)


def _axis_size(ax) -> int:
    sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint when a mesh is registered; no-op otherwise.

    Divisibility-aware: a dim that doesn't divide its axis product drops
    trailing axes from the tuple until it does (e.g. global batch 256 on
    the 2x16x16 mesh under pure_dp: (pod,data,model)=512 -> (pod,data)=32)."""
    if _MESH is None:
        return x
    spec = _resolve(logical)
    fixed = []
    for dim, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        while axes and x.shape[dim] % _axis_size(axes) != 0:
            axes = axes[:-1]
        fixed.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, PartitionSpec(*fixed)))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (path regex, spec WITHOUT the stacked-layer leading dim). The first match
# wins. Specs use logical axes; "data" resolves to (pod, data) on multi-pod.
_RULES = [
    # embeddings / head: vocab over model (the big dim)
    (r"embed/tok$", ("model", None)),
    (r"embed/head$", (None, "model")),
    (r"embed/pos$", (None, None)),
    (r"patch_proj$", (None, None)),
    # attention: heads over model
    (r"attn/w[qkv]$", (None, "model")),
    (r"attn/wo$", ("model", None)),
    (r"attn/b[qkv]$", ("model",)),
    # dense MLP: hidden over model
    (r"(mlp|ffn)/w_(gate|up)$", (None, "model")),
    (r"(mlp|ffn)/w_down$", ("model", None)),
    (r"(mlp|ffn)/b_up$", ("model",)),
    (r"(mlp|ffn)/b_down$", (None,)),
    # MoE: experts over model (EP)
    (r"moe/router$", (None, None)),
    (r"moe/w_(gate|up|down)$", ("model", None, None)),
    # Mamba2: d_inner (heads) over model
    (r"mixer/w_[zx]$", (None, "model")),
    (r"mixer/w_dt$", (None, "model")),
    (r"mixer/w_(B|C)$", (None, None)),
    (r"mixer/conv_w$", (None, "model")),
    (r"mixer/conv_b$", ("model",)),
    (r"mixer/(A_log|dt_bias|D)$", ("model",)),
    (r"mixer/norm_w$", ("model",)),
    (r"mixer/w_out$", ("model", None)),
    # RWKV6: heads (= channels) over model
    (r"mix/w[rkvg]$", (None, "model")),
    (r"mix/wo$", ("model", None)),
    (r"mix/u_bonus$", (None, None)),   # (H=40, hd) — H % 16 != 0
    (r"mix/(A_dec|A_tsh)$", (None, None)),
    (r"mix/B_dec$", (None, "model")),
    (r"mix/B_tsh$", (None, None, "model")),
    (r"mix/(w_base|ln_x_w|ln_x_b)$", ("model",)),
    (r"mix/mu_(x|ffn)$", (None, None)),
    (r"mix/wk_ffn$", (None, "model")),
    (r"mix/wv_ffn$", ("model", None)),
    (r"mix/wr_ffn$", (None, "model")),
    # norms and everything scalar-ish: replicated
    (r".*", None),
]

# FSDP: for these paths, additionally shard this dim (after TP) over `data`.
_FSDP_DIM = [
    (r"embed/tok$", 1), (r"embed/head$", 0),
    (r"attn/w[qkv]$", 0), (r"attn/wo$", 1),
    (r"(mlp|ffn)/w_(gate|up)$", 0), (r"(mlp|ffn)/w_down$", 1),
    (r"moe/w_(gate|up|down)$", 2),
    (r"mixer/w_[zx]$", 0), (r"mixer/w_out$", 1),
    (r"mix/w[rkvgo]$", 0), (r"mix/wk_ffn$", 0), (r"mix/wv_ffn$", 1),
    (r"mix/wr_ffn$", 0),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for(path_str: str, ndim: int, fsdp: bool = False,
             stacked: bool = False) -> PartitionSpec:
    """Sharding spec for one parameter. `stacked`: leading layer dim."""
    body_ndim = ndim - (1 if stacked else 0)
    if _PURE_DP:
        # ZeRO-3 over the combined (pod, data, model) axis: shard the dim
        # the FSDP table nominates (falls back to replicated for small /
        # oddly-shaped leaves — divisibility enforced by the caller).
        axes = [None] * body_ndim
        for pat, dim in _FSDP_DIM:
            if re.search(pat, path_str) and dim < body_ndim:
                axes[dim] = "data"        # resolves to the combined axes
                break
        if stacked:
            axes = [None] + axes
        return _resolve(axes)
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            axes = list(spec) if spec is not None else [None] * body_ndim
            break
    if len(axes) != body_ndim:          # rank mismatch (e.g. scalars): replicate
        axes = [None] * body_ndim
    if fsdp:
        for pat, dim in _FSDP_DIM:
            if re.search(pat, path_str) and dim < body_ndim and axes[dim] is None:
                axes[dim] = "data"
                break
    if stacked:
        axes = [None] + axes
    return _resolve(axes)


def param_specs(params: Any, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching `params` (layer-stacked aware)."""
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("layers/") or "/layers/" in ps
        return spec_for(ps, jnp.ndim(leaf), fsdp=fsdp, stacked=stacked)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_spec(ndim: int = 2) -> PartitionSpec:
    """Token batches: batch dim over (pod, data); rest replicated."""
    return _resolve(["data"] + [None] * (ndim - 1))


def state_specs(state: Any, fsdp: bool = False) -> Any:
    """Specs for a TrainState-like pytree: params + optimizer moments share
    the parameter rules (moments have identical shapes); scalars replicate."""
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        # strip the state prefix (params/opt.mu/opt.nu) to reuse param rules
        ps = re.sub(r"^(params|mu|nu|opt_state/\d+)/", "", ps)
        ps = re.sub(r"^(step|rng|metrics).*", "", ps)
        if not ps or jnp.ndim(leaf) == 0:
            return PartitionSpec()
        stacked = ps.startswith("layers/") or "/layers/" in ps
        return spec_for(ps, jnp.ndim(leaf), fsdp=fsdp, stacked=stacked)
    return jax.tree_util.tree_map_with_path(leaf_spec, state)


def cache_specs(cache: Any, batch_shardable: bool = True) -> Any:
    """KV/state caches: batch over data, heads/channels over model.

    Cache layouts (leading L = layers dim):
      attn k/v     (L, B, S, Hk, hd)   -> (None, data, None, model, None)
      ssm state    (L, B, H, P, N)     -> (None, data, model, None, None)
      conv state   (L, B, K-1, C)      -> (None, data, None, model)
      rwkv S       (L, B, H, hd, hd)   -> (None, data, model, None, None)
      rwkv x_*     (L, B, d)           -> (None, data, model)
      shared attn  (A, B, S, Hk, hd)   -> (None, data, None, model, None)

    `batch_shardable=False` (long_500k: global_batch=1) switches to
    SEQUENCE parallelism: the KV time axis shards over `data` (XLA reduces
    the decode softmax across the sharded axis); per-head state tensors keep
    only the `model` split.
    """
    model_size = 1
    if _MESH is not None:
        sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))
        model_size = sizes.get("model", 1)

    def ok(shape, axes):
        for dim, ax in enumerate(axes):
            if ax == "model" and shape[dim] % model_size != 0:
                return False
        return True

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        nd = jnp.ndim(leaf)
        b = "data" if batch_shardable else None
        if nd == 5:
            if ps.endswith("S") or ps == "ssm" or ps.endswith("/ssm"):
                cands = [[None, b, "model", None, None]]
            else:
                # attention KV (L, B, S, Hk, hd). Preference order: heads over
                # `model` (GQA kv>=16); else TIME over `model` (sequence-
                # parallel KV — XLA reduces the decode softmax across
                # shards); else replicate the non-batch dims.
                sseq = None if batch_shardable else "data"
                cands = [[None, b, sseq, "model", None],
                         [None, b, "model", None, None]]
        elif nd == 4:
            cands = [[None, b, None, "model"]]
        elif nd == 3:
            cands = [[None, b, "model"]]
        else:
            return PartitionSpec()
        for c in cands:
            if ok(leaf.shape, c):
                return _resolve(c)
        return _resolve([c_ if c_ != "model" else None for c_ in cands[-1]])
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
