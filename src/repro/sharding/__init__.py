"""sharding — logical-axis partitioning rules over the production mesh.

DP (+pod), TP, EP, FSDP and sequence sharding are expressed as PartitionSpec
rules keyed on parameter path names; activations are pinned at block
boundaries with `constrain`. The mapping layer (core/mapping.py) decides
*which* population goes where; this package says *how* a tensor splits.
"""

from repro.sharding.rules import (constrain, batch_spec, param_specs,
                                  set_mesh, get_mesh, state_specs, dp_axes)
