"""Shared kernel utilities: backend detection and padding.

Dispatch policy and block sizing used to live here too; they moved into
`repro.kernels.registry` (`use_pallas` / `interpret_mode` / `fit_block`)
so that every family resolves them through one code path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.cache
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_axis(x: jax.Array, axis: int, mult: int, value=0.0):
    """Pad `axis` of x up to a multiple of `mult`. Returns (padded, orig_len)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n
