"""Shared kernel utilities: dispatch policy, padding, block sizing."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp


@functools.cache
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Pallas kernels execute in interpret mode off-TPU (CPU container)."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced == "1"
    return not on_tpu()


def pad_axis(x: jax.Array, axis: int, mult: int, value=0.0):
    """Pad `axis` of x up to a multiple of `mult`. Returns (padded, orig_len)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def pick_block(n: int, preferred: int, align: int) -> int:
    """Largest block <= preferred that is a multiple of `align` and covers n
    evenly after padding; falls back to n rounded up to `align` when small."""
    if n <= preferred:
        return max(align, -(-n // align) * align)
    return preferred
