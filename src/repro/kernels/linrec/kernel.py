"""Chunked diagonal linear-recurrence Pallas kernel (the DIFF instruction).

Computes  y_t = a_t * y_{t-1} + x_t  over the leading (time) axis for a
(T, B, D) tensor, carrying hidden state across time chunks.

TPU mapping
-----------
grid = (B/bb, D/bd, T/ct) with the TIME dimension innermost: TPU grids
execute sequentially, so a VMEM scratch tile h:(bb, bd) carries the state
from one time chunk to the next without HBM round-trips. Within a chunk the
scan is computed in log2(ct) Hillis-Steele doubling steps over the VMEM
block — all (ct, bb, bd) elementwise VPU work, no serial per-timestep loop.

VMEM working set per grid step (fp32 compute):
    a, x, y blocks: 3 * ct*bb*bd * 4 B   (+ scratch bb*bd)
Default tile (ct, bb, bd) = (256, 8, 512) -> 12.6 MiB of ~16 MiB VMEM.
bd is a multiple of 128 (lane width); bb a multiple of 8 (sublanes, fp32).

FLOPs: 3 * T*B*D * log2(ct) fp32 VPU flops vs 2*T*B*D for the serial form —
the kernel trades ~3.5x arithmetic for chunk-parallel VPU execution; the op
is HBM-bandwidth-bound (arithmetic intensity < 2 flops/byte), so the extra
flops are free and the roofline term is the 3 tensor streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _linrec_kernel(a_ref, x_ref, h0_ref, y_ref, hT_ref, h_scratch, *, ct: int):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    # First time-chunk: seed the carried state from h0.
    @pl.when(t_idx == 0)
    def _():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)          # (ct, bb, bd)
    x = x_ref[...].astype(jnp.float32)

    # Hillis-Steele inclusive scan of the monoid (a, x) along time.
    off = 1
    while off < ct:                             # static python loop
        a_prev = jnp.pad(a[:-off], ((off, 0), (0, 0), (0, 0)),
                         constant_values=1.0)
        x_prev = jnp.pad(x[:-off], ((off, 0), (0, 0), (0, 0)))
        x = x + a * x_prev
        a = a * a_prev
        off *= 2

    h = h_scratch[...]
    y = x + a * h[None]                         # inject carry
    y_ref[...] = y.astype(y_ref.dtype)
    h_scratch[...] = y[-1]

    @pl.when(t_idx == nt - 1)
    def _():
        hT_ref[...] = y[-1].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "bb", "bd", "interpret"))
def linrec_pallas(a: jax.Array, x: jax.Array, h0: jax.Array, *,
                  ct: int = 256, bb: int = 8, bd: int = 512,
                  interpret: bool = False):
    """a, x: (T, B, D); h0: (B, D). T % ct == 0, B % bb == 0, D % bd == 0.

    Returns (y: (T, B, D), h_final: (B, D)).
    """
    T, B, D = x.shape
    assert T % ct == 0 and B % bb == 0 and D % bd == 0, (T, B, D, ct, bb, bd)
    grid = (B // bb, D // bd, T // ct)

    return pl.pallas_call(
        functools.partial(_linrec_kernel, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ct, bb, bd), lambda i, j, t: (t, i, j)),   # a
            pl.BlockSpec((ct, bb, bd), lambda i, j, t: (t, i, j)),   # x
            pl.BlockSpec((bb, bd), lambda i, j, t: (i, j)),          # h0
        ],
        out_specs=[
            pl.BlockSpec((ct, bb, bd), lambda i, j, t: (t, i, j)),   # y
            pl.BlockSpec((bb, bd), lambda i, j, t: (i, j)),          # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
