"""Public entry point for the DIFF recurrence with automatic dispatch.

`linrec(a, x, h0)` pads to kernel tiles and runs the Pallas kernel on TPU
(interpret mode off-TPU when `force_pallas`), or the associative-scan
reference otherwise. A custom VJP makes the kernel differentiable with the
well-known linear-recurrence adjoint:

    forward : y_t = a_t y_{t-1} + x_t
    backward: dL/dx_t = g_t + a_{t+1} dL/dx_{t+1}   (reverse linrec!)
              dL/da_t = dL/dx_t * y_{t-1}
              dL/dh0  = a_1 * dL/dx_1-chain == dL/dx_0 carry

so the backward pass reuses the same kernel on time-reversed inputs — the
paper's "one primitive, many dynamics" thesis extends to the gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_mode, pad_axis, pick_block
from repro.kernels.linrec.kernel import linrec_pallas
from repro.kernels.linrec.ref import linrec_ref


def _linrec_fwd_impl(a, x, h0, force_pallas: bool):
    if not force_pallas:
        return linrec_ref(a, x, h0)
    T, B, D = x.shape
    ct = pick_block(T, 256, 8)
    bb = pick_block(B, 8, 8)
    bd = pick_block(D, 512, 128)
    a_p, _ = pad_axis(a, 0, ct, value=1.0)
    x_p, _ = pad_axis(x, 0, ct)
    a_p, _ = pad_axis(a_p, 1, bb, value=1.0)
    x_p, _ = pad_axis(x_p, 1, bb)
    h0_p, _ = pad_axis(h0, 0, bb)
    a_p, _ = pad_axis(a_p, 2, bd, value=1.0)
    x_p, _ = pad_axis(x_p, 2, bd)
    h0_p, _ = pad_axis(h0_p, 1, bd)
    y, hT = linrec_pallas(a_p, x_p, h0_p, ct=ct, bb=bb, bd=bd,
                          interpret=interpret_mode())
    return y[:T, :B, :D], hT[:B, :D]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linrec(a: jax.Array, x: jax.Array, h0: jax.Array,
           force_pallas: bool = False):
    """y_t = a_t * y_{t-1} + x_t over axis 0. a,x: (T,B,D); h0: (B,D)."""
    return _linrec_fwd_impl(a, x, h0, force_pallas)


def _fwd(a, x, h0, force_pallas):
    y, hT = _linrec_fwd_impl(a, x, h0, force_pallas)
    return (y, hT), (a, y, h0)


def _bwd(force_pallas, res, cts):
    a, y, h0 = res
    gy, ghT = cts
    # fold the hT cotangent into the last timestep's y cotangent
    gy = gy.at[-1].add(ghT)
    # dx_t = gy_t + a_{t+1} dx_{t+1}  -> reverse-time linrec with decay
    # a shifted by one (a_{T} beyond the end contributes nothing).
    a_rev = jnp.concatenate([a[1:], jnp.zeros_like(a[:1])], 0)[::-1]
    gx_rev, _ = _linrec_fwd_impl(a_rev, gy[::-1],
                                 jnp.zeros_like(h0), force_pallas)
    gx = gx_rev[::-1]
    y_prev = jnp.concatenate([h0[None].astype(y.dtype), y[:-1]], 0)
    ga = (gx.astype(jnp.float32) * y_prev.astype(jnp.float32)).astype(a.dtype)
    gh0 = (gx[0].astype(jnp.float32) * a[0].astype(jnp.float32)).astype(h0.dtype)
    return ga, gx, gh0


linrec.defvjp(_fwd, _bwd)
