"""Public entry point for the DIFF recurrence, dispatched via the registry.

`linrec(a, x, h0)` routes through `repro.kernels.registry`: the reference
associative scan by default, the Pallas kernel when forced (interpret mode
off-TPU), with block shapes resolved from the tuning cache. A custom VJP
makes the kernel differentiable with the well-known linear-recurrence
adjoint:

    forward : y_t = a_t y_{t-1} + x_t
    backward: dL/dx_t = g_t + a_{t+1} dL/dx_{t+1}   (reverse linrec!)
              dL/da_t = dL/dx_t * y_{t-1}
              dL/dh0  = a_1 * dL/dx_1-chain == dL/dx_0 carry

so the backward pass reuses the same kernel on time-reversed inputs — the
paper's "one primitive, many dynamics" thesis extends to the gradients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.linrec.kernel import linrec_pallas
from repro.kernels.linrec.ref import linrec_ref


def _pallas_impl(a, x, h0, *, blocks, interpret):
    T, B, D = x.shape
    ct, bb, bd = blocks["ct"], blocks["bb"], blocks["bd"]
    a_p, _ = pad_axis(a, 0, ct, value=1.0)
    x_p, _ = pad_axis(x, 0, ct)
    a_p, _ = pad_axis(a_p, 1, bb, value=1.0)
    x_p, _ = pad_axis(x_p, 1, bb)
    h0_p, _ = pad_axis(h0, 0, bb)
    a_p, _ = pad_axis(a_p, 2, bd, value=1.0)
    x_p, _ = pad_axis(x_p, 2, bd)
    h0_p, _ = pad_axis(h0_p, 1, bd)
    y, hT = linrec_pallas(a_p, x_p, h0_p, ct=ct, bb=bb, bd=bd,
                          interpret=interpret)
    return y[:T, :B, :D], hT[:B, :D]


def _linrec_fwd_impl(a, x, h0, force_pallas: bool):
    return registry.dispatch("linrec", (a, x, h0), force_pallas=force_pallas)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linrec(a: jax.Array, x: jax.Array, h0: jax.Array,
           force_pallas: bool = False):
    """y_t = a_t * y_{t-1} + x_t over axis 0. a,x: (T,B,D); h0: (B,D)."""
    return _linrec_fwd_impl(a, x, h0, force_pallas)


def _fwd(a, x, h0, force_pallas):
    y, hT = _linrec_fwd_impl(a, x, h0, force_pallas)
    return (y, hT), (a, y, h0)


def _bwd(force_pallas, res, cts):
    a, y, h0 = res
    gy, ghT = cts
    # fold the hT cotangent into the last timestep's y cotangent
    gy = gy.at[-1].add(ghT)
    # dx_t = gy_t + a_{t+1} dx_{t+1}  -> reverse-time linrec with decay
    # a shifted by one (a_{T} beyond the end contributes nothing).
    a_rev = jnp.concatenate([a[1:], jnp.zeros_like(a[:1])], 0)[::-1]
    gx_rev, _ = _linrec_fwd_impl(a_rev, gy[::-1],
                                 jnp.zeros_like(h0), force_pallas)
    gx = gx_rev[::-1]
    y_prev = jnp.concatenate([h0[None].astype(y.dtype), y[:-1]], 0)
    ga = (gx.astype(jnp.float32) * y_prev.astype(jnp.float32)).astype(a.dtype)
    gh0 = (gx[0].astype(jnp.float32) * a[0].astype(jnp.float32)).astype(h0.dtype)
    return ga, gx, gh0


linrec.defvjp(_fwd, _bwd)


def _make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    T, B, D = 24, 3, 136                      # non-multiples exercise padding
    a = jax.random.uniform(k1, (T, B, D), jnp.float32, 0.5, 0.99)
    x = jax.random.normal(k2, (T, B, D), jnp.float32)
    h0 = jax.random.normal(k3, (B, D), jnp.float32)
    return a, x, h0


registry.register(registry.KernelSpec(
    name="linrec",
    ref=linrec_ref,
    pallas=_pallas_impl,
    apply=lambda args, force=False: linrec(*args, force),
    block_axes=(registry.BlockAxis("ct", "T", preferred=256, align=8),
                registry.BlockAxis("bb", "B", preferred=8, align=8),
                registry.BlockAxis("bd", "D", preferred=512, align=128)),
    dims_of=lambda a, x, h0: {"T": x.shape[0], "B": x.shape[1],
                              "D": x.shape[2]},
    candidates=({"ct": 128, "bd": 256}, {"ct": 128, "bd": 512},
                {"ct": 256, "bd": 256}, {"ct": 512, "bd": 512},
                {"ct": 256, "bb": 16}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1, 2),
    tol=1e-4,
    # a + x in, y out, plus the h carry/h0/hT tiles
    vmem_bytes=lambda dims, b: 4 * (3 * b["ct"] * b["bb"] * b["bd"]
                                    + 3 * b["bb"] * b["bd"]),
    tile_model=registry.TileModel(
        out=(("T", "ct"), ("B", "bb"), ("D", "bd")),
        tiles=lambda dims, b: {
            "a": (b["ct"], b["bb"], b["bd"]),
            "x": (b["ct"], b["bb"], b["bd"]),
            "y": (b["ct"], b["bb"], b["bd"]),
            "h": (b["bb"], b["bd"]), "h0": (b["bb"], b["bd"]),
            "hT": (b["bb"], b["bd"])}),
))
