from repro.kernels.linrec.ops import linrec
from repro.kernels.linrec.ref import linrec_ref, linrec_naive

__all__ = ["linrec", "linrec_ref", "linrec_naive"]
