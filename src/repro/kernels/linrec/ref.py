"""Pure-jnp oracle for the DIFF recurrence  y_t = a_t * y_{t-1} + x_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linrec_naive(a: jax.Array, x: jax.Array, h0: jax.Array):
    """lax.scan reference. a, x: (T, ...); h0: (...).

    Returns (y: (T, ...), h_final: (...)). Computation in fp32.
    """
    dt = x.dtype

    def body(h, ax):
        a_t, x_t = ax
        h = a_t.astype(jnp.float32) * h + x_t.astype(jnp.float32)
        return h, h

    hT, ys = jax.lax.scan(body, h0.astype(jnp.float32), (a, x))
    return ys.astype(dt), hT.astype(dt)


def linrec_ref(a: jax.Array, x: jax.Array, h0: jax.Array):
    """associative_scan reference (parallel form, same math).

    Element monoid: (a2, x2) o (a1, x1) = (a1*a2, a2*x1 + x2)  [e1 applied
    first]. Inclusive scan gives (A_t, X_t) with y_t = X_t + A_t * h0.
    """
    dt = x.dtype
    a32, x32 = a.astype(jnp.float32), x.astype(jnp.float32)

    def combine(e1, e2):
        a1, x1 = e1
        a2, x2 = e2
        return a1 * a2, a2 * x1 + x2

    A, X = jax.lax.associative_scan(combine, (a32, x32), axis=0)
    y = X + A * h0.astype(jnp.float32)
    return y.astype(dt), y[-1].astype(dt)
