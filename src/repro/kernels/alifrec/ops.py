"""Public fused adaptive-threshold LIF entry points with STBP VJPs.

Forward dispatches through the kernel registry (`alif` feed-forward family,
`alifrec` self-recurrent family). Backward is STBP through every coupling
of the adaptive recurrence:

    u_t  = tau v_{t-1} + c_t [+ s_{t-1} @ W]    (pre-reset potential)
    th_t = v_th + beta a_{t-1}
    s_t  = H(u_t - th_t)
    v_t  = u_t (1 - s_t)
    a_t  = rho a_{t-1} + s_t

With Gu_t = dL/du_t, Gv_t/Ga_t the accumulated membrane/adaptation
cotangents, gs_t the external spike cotangent, and g() the surrogate
window, the adaptation trace adds two terms relative to `lif`/`lifrec`:
a_t collects its spike directly (Gs~ gains Ga_t) and the moving threshold
back-propagates -beta through the Heaviside argument:

    Gs~_t = gs_t + Ga_t [+ Gu_{t+1} @ W^T]
    Sig_t = (Gs~_t - Gv_t u_t) g(u_t - th_t)        (through the spike)
    Gu_t  = Gv_t (1 - s_t) + Sig_t
    Gv_{t-1} = tau Gu_t
    Ga_{t-1} = rho Ga_t - beta Sig_t
    dL/dc_t = Gu_t          dL/dtau = sum Gu_t v_{t-1}
    dL/drho = sum Ga_t a_{t-1}
    dL/dW   = sum s_{t-1}^T Gu_t     dL/ds0 = Gu_0 @ W^T
    dL/dv0  = tau Gu_0               dL/da0 = rho Ga_0 - beta Sig_0

u and the state sequences are recomputed forward from (c, s) instead of
being stored — the same storage/recompute trade `lif/ops.py` makes.
v_th and beta are static hyperparameters (non-learnable floats in every
program threshold), so no cotangent is produced for them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.surrogate import _SURROGATES
from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.alifrec.kernel import alif_pallas, alifrec_pallas
from repro.kernels.alifrec.ref import alif_scan_ref, alifrec_scan_ref


def _alif_pallas_impl(current, tau, rho, v0, a0, *, blocks, interpret,
                      v_th=1.0, beta=1.8):
    T, B, N = current.shape
    ct, bb, bn = blocks["ct"], blocks["bb"], blocks["bn"]
    # 'ct' is an exact-policy axis (see lif/ops.py): zero-padded time steps
    # would keep decaying v and a past T, so non-divisors must fail loudly.
    assert T % ct == 0, (T, ct)
    c_p, _ = pad_axis(current, 1, bb)
    c_p, _ = pad_axis(c_p, 2, bn)
    tau_p, _ = pad_axis(tau, 0, bn, value=1.0)
    rho_p, _ = pad_axis(rho, 0, bn, value=1.0)
    v0_p, _ = pad_axis(v0, 0, bb)
    v0_p, _ = pad_axis(v0_p, 1, bn)
    a0_p, _ = pad_axis(a0, 0, bb)
    a0_p, _ = pad_axis(a0_p, 1, bn)
    s, vT, aT = alif_pallas(c_p, tau_p, rho_p, v0_p, a0_p, v_th=v_th,
                            beta=beta, ct=ct, bb=bb, bn=bn,
                            interpret=interpret)
    return s[:T, :B, :N], vT[:B, :N], aT[:B, :N]


def _alifrec_pallas_impl(current, w_rec, tau, rho, v0, a0, s0, *, blocks,
                         interpret, v_th=1.0, beta=1.8):
    T, B, N = current.shape
    ct, bb = blocks["ct"], blocks["bb"]
    assert T % ct == 0, (T, ct)
    c_p, _ = pad_axis(current, 1, bb)
    c_p, _ = pad_axis(c_p, 2, 128)
    w_p, _ = pad_axis(w_rec.astype(current.dtype), 0, 128)
    w_p, _ = pad_axis(w_p, 1, 128)
    tau_p, _ = pad_axis(tau, 0, 128, value=1.0)
    rho_p, _ = pad_axis(rho, 0, 128, value=1.0)
    args = []
    for x in (v0, a0, s0):
        x_p, _ = pad_axis(x, 0, bb)
        x_p, _ = pad_axis(x_p, 1, 128)
        args.append(x_p)
    s, vT, aT = alifrec_pallas(c_p, w_p, tau_p, rho_p, *args, v_th=v_th,
                               beta=beta, ct=ct, bb=bb, interpret=interpret)
    return s[:T, :B, :N], vT[:B, :N], aT[:B, :N]


# ---------------------------------------------------------------------------
# shared STBP backward core (w_rec=None selects the feed-forward adjoint)
# ---------------------------------------------------------------------------


def _bwd_core(current, w_rec, tau, rho, v0, a0, s0, s, cts, v_th, beta,
              surrogate, alpha):
    gs, gvT, gaT = cts
    g_fn = _SURROGATES[surrogate]
    tau32 = tau.astype(jnp.float32)
    rho32 = rho.astype(jnp.float32)
    w32 = None if w_rec is None else w_rec.astype(jnp.float32)
    c32 = current.astype(jnp.float32)
    s32 = s.astype(jnp.float32)
    s0_32 = (jnp.zeros_like(v0, jnp.float32) if s0 is None
             else s0.astype(jnp.float32))

    def fwd_body(carry, ts):
        v, a, s_prev = carry
        c_t, s_t = ts
        u = tau32 * v + c_t
        if w32 is not None:
            u = u + s_prev @ w32
        return ((u * (1.0 - s_t), rho32 * a + s_t, s_t),
                (u, v, a, s_prev))           # v, a are the t-1 values

    _, (u, v_prev, a_prev, s_prev) = jax.lax.scan(
        fwd_body, (v0.astype(jnp.float32), a0.astype(jnp.float32), s0_32),
        (c32, s32))
    surr = g_fn(u - (v_th + beta * a_prev), jnp.asarray(alpha, jnp.float32))

    def bwd_body(carry, ts):
        gv, ga, gu_next = carry
        gs_t, u_t, s_t, surr_t = ts
        gs_tot = gs_t + ga
        if w32 is not None:
            gs_tot = gs_tot + gu_next @ w32.T
        sig = (gs_tot - gv * u_t) * surr_t
        gu = gv * (1.0 - s_t) + sig
        return (tau32 * gu, rho32 * ga - beta * sig, gu), (gu, ga)

    zero_gu = jnp.zeros(gs.shape[1:], jnp.float32)
    (gv_end, ga_end, _), (gu, ga_seq) = jax.lax.scan(
        bwd_body, (gvT.astype(jnp.float32), gaT.astype(jnp.float32), zero_gu),
        (gs.astype(jnp.float32), u, s32, surr), reverse=True)

    g_current = gu.astype(current.dtype)
    g_tau = jnp.sum(gu * v_prev, axis=(0, 1)).astype(tau.dtype)
    g_rho = jnp.sum(ga_seq * a_prev, axis=(0, 1)).astype(rho.dtype)
    g_v0 = gv_end.astype(v0.dtype)
    g_a0 = ga_end.astype(a0.dtype)
    if w32 is None:
        return g_current, g_tau, g_rho, g_v0, g_a0
    g_w = jnp.einsum("tbi,tbj->ij", s_prev, gu).astype(w_rec.dtype)
    g_s0 = (gu[0] @ w32.T).astype(s0.dtype)
    return g_current, g_w, g_tau, g_rho, g_v0, g_a0, g_s0


# ---------------------------------------------------------------------------
# feed-forward family: alif
# ---------------------------------------------------------------------------


def _alif_fwd_impl(current, tau, rho, v0, a0, v_th, beta, force_pallas):
    return registry.dispatch("alif", (current, tau, rho, v0, a0),
                             force_pallas=force_pallas, v_th=v_th, beta=beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def alif_scan(current: jax.Array, tau: jax.Array, rho: jax.Array,
              v0: jax.Array, a0: jax.Array, v_th: float = 1.0,
              beta: float = 1.8, surrogate: str = "rectangle",
              alpha: float = 1.0, force_pallas: bool = False):
    """Fused adaptive-threshold LIF over time. current: (T,B,N);
    tau/rho: (N,); v0/a0: (B,N).

    Returns (spikes (T,B,N), v_final (B,N), a_final (B,N)). STBP-diff'able.
    """
    return _alif_fwd_impl(current, tau, rho, v0, a0, v_th, beta, force_pallas)


def _alif_fwd(current, tau, rho, v0, a0, v_th, beta, surrogate, alpha,
              force_pallas):
    s, vT, aT = _alif_fwd_impl(current, tau, rho, v0, a0, v_th, beta,
                               force_pallas)
    return (s, vT, aT), (current, tau, rho, v0, a0, s)


def _alif_bwd(v_th, beta, surrogate, alpha, force_pallas, res, cts):
    current, tau, rho, v0, a0, s = res
    return _bwd_core(current, None, tau, rho, v0, a0, None, s, cts, v_th,
                     beta, surrogate, alpha)


alif_scan.defvjp(_alif_fwd, _alif_bwd)


# ---------------------------------------------------------------------------
# self-recurrent family: alifrec
# ---------------------------------------------------------------------------


def _alifrec_fwd_impl(current, w_rec, tau, rho, v0, a0, s0, v_th, beta,
                      force_pallas):
    return registry.dispatch("alifrec", (current, w_rec, tau, rho, v0, a0,
                                         s0),
                             force_pallas=force_pallas, v_th=v_th, beta=beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def alifrec_scan(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                 rho: jax.Array, v0: jax.Array, a0: jax.Array, s0: jax.Array,
                 v_th: float = 1.0, beta: float = 1.8,
                 surrogate: str = "rectangle", alpha: float = 1.0,
                 force_pallas: bool = False):
    """Fused self-recurrent adaptive-threshold LIF. current: (T,B,N);
    w_rec: (N,N); tau/rho: (N,); v0/a0/s0: (B,N).

    Returns (spikes (T,B,N), v_final (B,N), a_final (B,N)). STBP/BPTT.
    """
    return _alifrec_fwd_impl(current, w_rec, tau, rho, v0, a0, s0, v_th,
                             beta, force_pallas)


def _alifrec_fwd(current, w_rec, tau, rho, v0, a0, s0, v_th, beta, surrogate,
                 alpha, force_pallas):
    s, vT, aT = _alifrec_fwd_impl(current, w_rec, tau, rho, v0, a0, s0, v_th,
                                  beta, force_pallas)
    return (s, vT, aT), (current, w_rec, tau, rho, v0, a0, s0, s)


def _alifrec_bwd(v_th, beta, surrogate, alpha, force_pallas, res, cts):
    current, w_rec, tau, rho, v0, a0, s0, s = res
    return _bwd_core(current, w_rec, tau, rho, v0, a0, s0, s, cts, v_th,
                     beta, surrogate, alpha)


alifrec_scan.defvjp(_alifrec_fwd, _alifrec_bwd)


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------


def _make_alif_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    T, B, N = 20, 3, 130                      # non-multiples exercise padding
    current = 0.8 * jax.random.normal(k1, (T, B, N), jnp.float32)
    tau = jax.random.uniform(k2, (N,), jnp.float32, 0.7, 0.98)
    rho = jax.random.uniform(k3, (N,), jnp.float32, 0.85, 0.99)
    v0 = jnp.zeros((B, N), jnp.float32)
    a0 = jnp.zeros((B, N), jnp.float32)
    return current, tau, rho, v0, a0


def _make_alifrec_inputs(key):
    k1, k2 = jax.random.split(key)
    current, tau, rho, v0, a0 = _make_alif_inputs(k1)
    N = current.shape[2]
    w_rec = (0.4 / jnp.sqrt(N)) * jax.random.normal(k2, (N, N), jnp.float32)
    return current, w_rec, tau, rho, v0, a0, jnp.zeros_like(v0)


registry.register(registry.KernelSpec(
    name="alif",
    ref=alif_scan_ref,
    pallas=_alif_pallas_impl,
    apply=lambda args, force=False: alif_scan(*args, 1.0, 1.8, "rectangle",
                                              1.0, force),
    block_axes=(registry.BlockAxis("ct", "T", preferred=256, align=8,
                                   exact=True),
                registry.BlockAxis("bb", "B", preferred=8, align=8),
                registry.BlockAxis("bn", "N", preferred=512, align=128)),
    dims_of=lambda current, tau, rho, v0, a0: {"T": current.shape[0],
                                               "B": current.shape[1],
                                               "N": current.shape[2]},
    candidates=({"ct": 128, "bn": 256}, {"ct": 128, "bn": 512},
                {"ct": 256, "bn": 256}, {"ct": 512, "bn": 512}),
    make_inputs=_make_alif_inputs,
    diff_argnums=(0, 1, 2, 3, 4),
    tol=1e-4,
    # current + spikes blocks dominate; v/a scratch + init/final + tau/rho
    vmem_bytes=lambda dims, b: 4 * (2 * b["ct"] * b["bb"] * b["bn"]
                                    + 6 * b["bb"] * b["bn"] + 2 * b["bn"]),
    tile_model=registry.TileModel(
        out=(("T", "ct"), ("B", "bb"), ("N", "bn")),
        tiles=lambda dims, b: {
            "current": (b["ct"], b["bb"], b["bn"]),
            "spikes_out": (b["ct"], b["bb"], b["bn"]),
            "v": (b["bb"], b["bn"]), "a": (b["bb"], b["bn"]),
            "v0": (b["bb"], b["bn"]), "a0": (b["bb"], b["bn"]),
            "vT": (b["bb"], b["bn"]), "aT": (b["bb"], b["bn"]),
            "tau": (b["bn"],), "rho": (b["bn"],)}),
))


def _alifrec_vmem_bytes(dims, blocks):
    n = -(-dims["N"] // 128) * 128
    ct, bb = blocks["ct"], blocks["bb"]
    # current + spikes blocks, resident W, and the v/a/s state + init/final
    return 4 * (2 * ct * bb * n + n * n + 9 * bb * n + 2 * n)


registry.register(registry.KernelSpec(
    name="alifrec",
    ref=alifrec_scan_ref,
    pallas=_alifrec_pallas_impl,
    apply=lambda args, force=False: alifrec_scan(*args, 1.0, 1.8,
                                                 "rectangle", 1.0, force),
    block_axes=(registry.BlockAxis("ct", "T", preferred=128, align=8,
                                   exact=True),
                registry.BlockAxis("bb", "B", preferred=8, align=8)),
    dims_of=lambda current, w_rec, tau, rho, v0, a0, s0: {
        "T": current.shape[0], "B": current.shape[1], "N": current.shape[2]},
    candidates=({"ct": 64}, {"ct": 128}, {"ct": 256}, {"ct": 128, "bb": 16}),
    make_inputs=_make_alifrec_inputs,
    diff_argnums=(0, 1, 2, 3, 4, 5, 6),
    tol=1e-4,
    vmem_bytes=_alifrec_vmem_bytes,
    # resident (padded) N axis; only T and B are grid-tiled
    tile_model=registry.TileModel(
        out=(("T", "ct"), ("B", "bb"), ("N", None)),
        tiles=lambda dims, b: (lambda n: {
            "current": (b["ct"], b["bb"], n),
            "spikes_out": (b["ct"], b["bb"], n),
            "w_rec": (n, n),
            "v": (b["bb"], n), "a": (b["bb"], n), "s": (b["bb"], n),
            "v0": (b["bb"], n), "a0": (b["bb"], n), "s0": (b["bb"], n),
            "vT": (b["bb"], n), "aT": (b["bb"], n), "sT": (b["bb"], n),
            "tau": (n,), "rho": (n,)})(-(-dims["N"] // 128) * 128)),
))
