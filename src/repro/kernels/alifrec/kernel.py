"""Fused adaptive-threshold LIF Pallas kernels (DIFF + moving th + SEND).

Two variants of the `lif`/`lifrec` serial-in-time scheme, each carrying one
extra VMEM-resident state plane — the adaptation trace `a` — and comparing
against the moving threshold `v_th + beta * a` instead of a scalar:

  * `alif_pallas`    feed-forward: like `lif/kernel.py`, the neuron axis is
    blocked (adaptation is elementwise), grid (B/bb, N/bn, T/ct), scratch
    v and a carry state across time chunks.
  * `alifrec_pallas` self-recurrent: like `lifrec/kernel.py`, the (N, N)
    recurrent weights stay VMEM-resident and every step applies them to
    the previous spikes, so the neuron axis is NOT blocked (wrapper pads
    N to the 128-lane boundary); grid (B/bb, T/ct), scratch v, a, s.

On chip the adaptation trace is just another NC-local DIFF register —
TaiBai's point that "new neuron model" means "new program", not new
silicon; here it means one extra scratch plane, not a new engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _alif_kernel(cur_ref, tau_ref, rho_ref, v0_ref, a0_ref, s_ref, vT_ref,
                 aT_ref, v_scr, a_scr, *, ct: int, v_th: float, beta: float):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _():
        v_scr[...] = v0_ref[...].astype(jnp.float32)
        a_scr[...] = a0_ref[...].astype(jnp.float32)

    cur = cur_ref[...].astype(jnp.float32)           # (ct, bb, bn)
    tau = tau_ref[...].astype(jnp.float32)           # (1, bn)
    rho = rho_ref[...].astype(jnp.float32)           # (1, bn)

    def step(t, carry):
        v, a, s_acc = carry
        v = tau * v + cur[t]
        s = (v >= v_th + beta * a).astype(jnp.float32)
        v = v * (1.0 - s)
        a = rho * a + s
        s_acc = jax.lax.dynamic_update_index_in_dim(s_acc, s, t, 0)
        return v, a, s_acc

    v, a, spikes = jax.lax.fori_loop(
        0, ct, step, (v_scr[...], a_scr[...],
                      jnp.zeros(cur.shape, jnp.float32)))
    s_ref[...] = spikes.astype(s_ref.dtype)
    v_scr[...] = v
    a_scr[...] = a

    @pl.when(t_idx == nt - 1)
    def _():
        vT_ref[...] = v.astype(vT_ref.dtype)
        aT_ref[...] = a.astype(aT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "bb", "bn", "v_th",
                                             "beta", "interpret"))
def alif_pallas(current: jax.Array, tau: jax.Array, rho: jax.Array,
                v0: jax.Array, a0: jax.Array, *, v_th: float = 1.0,
                beta: float = 1.8, ct: int = 256, bb: int = 8, bn: int = 512,
                interpret: bool = False):
    """current: (T, B, N); tau/rho: (N,); v0/a0: (B, N). Dims tile exactly."""
    T, B, N = current.shape
    assert T % ct == 0 and B % bb == 0 and N % bn == 0
    grid = (B // bb, N // bn, T // ct)

    return pl.pallas_call(
        functools.partial(_alif_kernel, ct=ct, v_th=v_th, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ct, bb, bn), lambda i, j, t: (t, i, j)),  # current
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),          # tau
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),          # rho
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # v0
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # a0
        ],
        out_specs=[
            pl.BlockSpec((ct, bb, bn), lambda i, j, t: (t, i, j)),  # spikes
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # vT
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # aT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32),
                        pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(current, tau.reshape(1, N), rho.reshape(1, N), v0, a0)


def _alifrec_kernel(cur_ref, w_ref, tau_ref, rho_ref, v0_ref, a0_ref, s0_ref,
                    s_out_ref, vT_ref, aT_ref, v_scr, a_scr, s_scr, *,
                    ct: int, v_th: float, beta: float):
    t_idx = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _():
        v_scr[...] = v0_ref[...].astype(jnp.float32)
        a_scr[...] = a0_ref[...].astype(jnp.float32)
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    cur = cur_ref[...].astype(jnp.float32)           # (ct, bb, N)
    w = w_ref[...].astype(jnp.float32)               # (N, N)
    tau = tau_ref[...].astype(jnp.float32)           # (1, N)
    rho = rho_ref[...].astype(jnp.float32)           # (1, N)

    def step(t, carry):
        v, a, s, acc = carry
        rec = jax.lax.dot_general(s, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        v = tau * v + cur[t] + rec
        spk = (v >= v_th + beta * a).astype(jnp.float32)
        v = v * (1.0 - spk)
        a = rho * a + spk
        acc = jax.lax.dynamic_update_index_in_dim(acc, spk, t, 0)
        return v, a, spk, acc

    v, a, s, spikes = jax.lax.fori_loop(
        0, ct, step, (v_scr[...], a_scr[...], s_scr[...],
                      jnp.zeros(cur.shape, jnp.float32)))
    s_out_ref[...] = spikes.astype(s_out_ref.dtype)
    v_scr[...] = v
    a_scr[...] = a
    s_scr[...] = s

    @pl.when(t_idx == nt - 1)
    def _():
        vT_ref[...] = v.astype(vT_ref.dtype)
        aT_ref[...] = a.astype(aT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "bb", "v_th", "beta",
                                             "interpret"))
def alifrec_pallas(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                   rho: jax.Array, v0: jax.Array, a0: jax.Array,
                   s0: jax.Array, *, v_th: float = 1.0, beta: float = 1.8,
                   ct: int = 128, bb: int = 8, interpret: bool = False):
    """current: (T, B, N); w_rec: (N, N); tau/rho: (N,); v0/a0/s0: (B, N).

    T % ct == 0, B % bb == 0, N a multiple of 128 (wrapper pads).
    """
    T, B, N = current.shape
    assert T % ct == 0 and B % bb == 0
    grid = (B // bb, T // ct)

    return pl.pallas_call(
        functools.partial(_alifrec_kernel, ct=ct, v_th=v_th, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ct, bb, N), lambda i, t: (t, i, 0)),   # current
            pl.BlockSpec((N, N), lambda i, t: (0, 0)),           # w_rec
            pl.BlockSpec((1, N), lambda i, t: (0, 0)),           # tau
            pl.BlockSpec((1, N), lambda i, t: (0, 0)),           # rho
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # v0
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # a0
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # s0
        ],
        out_specs=[
            pl.BlockSpec((ct, bb, N), lambda i, t: (t, i, 0)),   # spikes
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # vT
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # aT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, N), jnp.float32),
                        pltpu.VMEM((bb, N), jnp.float32),
                        pltpu.VMEM((bb, N), jnp.float32)],
        interpret=interpret,
    )(current, w_rec, tau.reshape(1, N), rho.reshape(1, N), v0, a0, s0)
