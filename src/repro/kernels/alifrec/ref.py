"""Pure-jnp oracles for the fused adaptive-threshold LIF time scans.

ALIF (Yin et al. 2021, the paper's ECG SRNN hidden layer) extends LIF with
a spike-driven adaptation trace that raises the effective threshold:

    u_t  = tau * v_{t-1} + c_t  [+ s_{t-1} @ W_rec]     (DIFF + LOCACC)
    th_t = v_th + beta * a_{t-1}                        (moving threshold)
    s_t  = H(u_t - th_t)                                (SEND)
    v_t  = u_t * (1 - s_t)                              (hard reset)
    a_t  = rho * a_{t-1} + s_t                          (DIFF on spikes)

Two entry points: `alif_scan_ref` (feed-forward, the `alif` family) and
`alifrec_scan_ref` (self-recurrent, the `alifrec` family). The plan
compiler reaches these through the structural pattern matcher — any
NeuronProgram shaped {membrane + spike-driven adaptation + affine
threshold + hard reset} lowers here, not just the built-in ALIF.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _scan(current: jax.Array, w_rec: Optional[jax.Array], tau: jax.Array,
          rho: jax.Array, v0: jax.Array, a0: jax.Array,
          s0: Optional[jax.Array], v_th: float, beta: float):
    dt = current.dtype
    tau32 = tau.astype(jnp.float32)
    rho32 = rho.astype(jnp.float32)
    w32 = None if w_rec is None else w_rec.astype(jnp.float32)

    def body(carry, c_t):
        v, a, s = carry
        u = tau32 * v + c_t.astype(jnp.float32)
        if w32 is not None:
            u = u + s @ w32
        spk = (u >= v_th + beta * a).astype(jnp.float32)
        v = u * (1.0 - spk)
        a = rho32 * a + spk
        return (v, a, spk), spk.astype(dt)

    s_init = (jnp.zeros_like(v0, jnp.float32) if s0 is None
              else s0.astype(jnp.float32))
    (vT, aT, _), spikes = jax.lax.scan(
        body, (v0.astype(jnp.float32), a0.astype(jnp.float32), s_init),
        current)
    return spikes, vT.astype(dt), aT.astype(dt)


def alif_scan_ref(current: jax.Array, tau: jax.Array, rho: jax.Array,
                  v0: jax.Array, a0: jax.Array, v_th: float = 1.0,
                  beta: float = 1.8):
    """current: (T, B, N); tau, rho: (N,); v0, a0: (B, N).

    Returns (spikes (T, B, N), v_final (B, N), a_final (B, N)). fp32 state.
    """
    return _scan(current, None, tau, rho, v0, a0, None, v_th, beta)


def alifrec_scan_ref(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                     rho: jax.Array, v0: jax.Array, a0: jax.Array,
                     s0: jax.Array, v_th: float = 1.0, beta: float = 1.8):
    """current: (T, B, N); w_rec: (N, N); tau, rho: (N,); v0/a0/s0: (B, N).

    Returns (spikes (T, B, N), v_final (B, N), a_final (B, N)). fp32 state.
    """
    return _scan(current, w_rec, tau, rho, v0, a0, s0, v_th, beta)
