"""Per-process incident log for the resilient execution runtime.

Every degradation the runtime absorbs — a Pallas kernel falling back to
its reference implementation, a VMEM-model rejection, a numerical
guardrail firing, a serve-loop retry — is recorded here as a structured
`FallbackEvent` instead of (or in addition to) being printed. The log is
the operational story of a run: `repro.kernels.incidents()` answers "did
anything silently degrade?", which is exactly the question an always-on
streaming deployment has to be able to ask.

Policy lives here too: `REPRO_STRICT=1` (see `strict_mode`) turns every
silent degradation into a raised `FallbackError`, which is how CI's fast
tier guarantees the fast paths actually ran. The log is bounded (old
events fall off) and thread-safe (the serve loop and an async checkpoint
writer may both record).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

_MAX_EVENTS = 4096


class FallbackError(RuntimeError):
    """Raised (under REPRO_STRICT=1) instead of silently degrading."""


@dataclasses.dataclass(frozen=True)
class FallbackEvent:
    """One recorded degradation.

    kind:    "dispatch" (kernel fell back a stage), "vmem" (VMEM-model
             rejection), "channel" (implementation-channel router failed),
             "guard" (numerical guardrail fired), "autotune" (candidate or
             kernel skipped in a sweep), "serve" (request retry/degrade).
    family:  kernel family / subsystem the event belongs to.
    stage:   the stage that failed ("pallas", "interpret", ...).
    channel: implementation channel in use, if any (e.g. "sparse").
    dims:    logical dims of the call (shape fingerprint).
    error:   repr() of the underlying exception, or a description.
    """

    kind: str
    family: str
    stage: str
    error: str
    channel: Optional[str] = None
    dims: Dict[str, int] = dataclasses.field(default_factory=dict)
    blocks: Dict[str, int] = dataclasses.field(default_factory=dict)
    time_s: float = dataclasses.field(default_factory=time.time)


_LOCK = threading.Lock()
_LOG: list = []


def record(event: FallbackEvent) -> FallbackEvent:
    with _LOCK:
        _LOG.append(event)
        if len(_LOG) > _MAX_EVENTS:
            del _LOG[: len(_LOG) - _MAX_EVENTS]
    return event


def incidents(family: Optional[str] = None,
              kind: Optional[str] = None) -> Tuple[FallbackEvent, ...]:
    """Query the per-process incident log (newest last)."""
    with _LOCK:
        evs = tuple(_LOG)
    if family is not None:
        evs = tuple(e for e in evs if e.family == family)
    if kind is not None:
        evs = tuple(e for e in evs if e.kind == kind)
    return evs


def clear() -> None:
    with _LOCK:
        _LOG.clear()


# back-compat-friendly alias (docs refer to both spellings)
clear_incidents = clear


def strict_mode() -> bool:
    """REPRO_STRICT=1: degradations raise instead of silently falling back."""
    return os.environ.get("REPRO_STRICT") == "1"


def degrade(kind: str, family: str, stage: str, error: Any, *,
            channel: Optional[str] = None,
            dims: Optional[Dict[str, int]] = None,
            blocks: Optional[Dict[str, int]] = None) -> FallbackEvent:
    """Record a degradation; raise `FallbackError` under REPRO_STRICT=1.

    `error` may be an exception (chained into the strict raise) or a
    description string. Returns the recorded event when not strict.
    """
    ev = record(FallbackEvent(
        kind=kind, family=family, stage=stage,
        error=error if isinstance(error, str) else repr(error),
        channel=channel, dims=dict(dims or {}),
        blocks={k: int(v) for k, v in (blocks or {}).items()}))
    if strict_mode():
        exc = error if isinstance(error, BaseException) else None
        raise FallbackError(
            f"[REPRO_STRICT] {family}: {kind} degradation at stage "
            f"{stage!r}: {ev.error}") from exc
    return ev


__all__ = ["FallbackError", "FallbackEvent", "record", "incidents",
           "clear", "clear_incidents", "strict_mode", "degrade"]
