"""Pallas TPU kernels for the compute hot-spots TaiBai optimizes in hardware.

Each kernel is a package with three modules:

  kernel.py — the `pl.pallas_call` body with explicit BlockSpec VMEM tiling
              (TPU is the target; `interpret=True` executes the same body in
              Python on CPU for validation)
  ops.py    — the jit'd public wrapper; registers a `KernelSpec` with the
              unified registry and dispatches through it (ref vs Pallas
              policy, block resolution, tuning-cache lookup all live in
              `registry.py`, not per family)
  ref.py    — the pure-jnp oracle the tests assert against

Cross-cutting machinery (mirroring the paper's single multi-granularity
instruction set over heterogeneous dynamics):

  registry.py — KernelSpec registration + the one dispatch/policy layer,
                including the pallas -> interpret -> ref fallback chain
  incidents.py— per-process incident log of recorded degradations
                (query with `repro.kernels.incidents()`); REPRO_STRICT=1
                turns every degradation into a raised FallbackError
  tuning.py   — autotuner sweeping per-spec block candidates, persisted to
                a JSON cache keyed by (kernel, backend, shape bucket)
  parity.py   — ref<->Pallas forward + VJP agreement harness (fast CI tier)
  common.py   — padding + backend helpers shared by the wrappers

Kernels (paper instruction -> TPU adaptation):

  linrec    DIFF     chunked diagonal first-order recurrence y=a*y+x
                     (serves LIF/ALIF membranes, Mamba2 scans, RWKV6 decay)
  lif       DIFF+SEND fused integrate-fire over time (threshold/reset is not
                     associative, so this is its own serial-in-T kernel)
  spikemm   FINDIDX+LOCACC event-gated block-sparse spike x weight matmul:
                     silent (all-zero) spike blocks skip the MXU entirely
  attention —        flash attention (online softmax) for the LM substrate's
                     prefill path
  stdp      (FIRE-stage learning) fused trace-outer-product weight update:
                     one HBM->VMEM->HBM pass over the weight tile per step
"""

from repro.kernels.incidents import (FallbackError, FallbackEvent,  # noqa: E402
                                     clear_incidents, incidents,
                                     strict_mode)

__all__ = ["FallbackError", "FallbackEvent", "clear_incidents", "incidents",
           "strict_mode"]
