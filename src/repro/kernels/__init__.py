"""Pallas TPU kernels for the compute hot-spots TaiBai optimizes in hardware.

Each kernel is a package with three modules:

  kernel.py — the `pl.pallas_call` body with explicit BlockSpec VMEM tiling
              (TPU is the target; `interpret=True` executes the same body in
              Python on CPU for validation)
  ops.py    — the jit'd public wrapper: padding, block-shape selection,
              dispatch between the Pallas path (TPU / interpret) and the
              pure-XLA reference (used by the roofline path)
  ref.py    — the pure-jnp oracle the tests assert against

Kernels (paper instruction -> TPU adaptation):

  linrec    DIFF     chunked diagonal first-order recurrence y=a*y+x
                     (serves LIF/ALIF membranes, Mamba2 scans, RWKV6 decay)
  lif       DIFF+SEND fused integrate-fire over time (threshold/reset is not
                     associative, so this is its own serial-in-T kernel)
  spikemm   FINDIDX+LOCACC event-gated block-sparse spike x weight matmul:
                     silent (all-zero) spike blocks skip the MXU entirely
  attention —        flash attention (online softmax) for the LM substrate's
                     prefill path
  stdp      (FIRE-stage learning) fused trace-outer-product weight update:
                     one HBM->VMEM->HBM pass over the weight tile per step
"""
