"""Unified kernel registry: one registration + dispatch point for every
Pallas kernel family.

TaiBai's headline property is *programmability* — a multi-granularity
instruction set where LIF dynamics, plasticity, and dense attention run on
one substrate. The TPU-side analogue is this registry: each kernel family
registers its pure-jnp reference, its Pallas implementation, and a tunable
block specification ONCE, and every cross-cutting concern lives here
instead of being copy-pasted per family:

  * ref-vs-pallas dispatch policy (`force_pallas` arg, `REPRO_KERNEL_IMPL`
    env, interpret-mode fallback off-TPU),
  * block-shape resolution (per-axis alignment fitting, tuned-cache lookup
    via `repro.kernels.tuning`, explicit per-call overrides),
  * enumeration for the parity harness (`repro.kernels.parity`) and the
    autotuner / benchmarks.

Registering a new kernel means building one `KernelSpec` and calling
`register()` at the bottom of its `ops.py` — see any existing family for
the pattern. The spec carries everything the generic machinery needs:

    register(KernelSpec(
        name="mykern",
        ref=mykern_ref,                  # pure-jnp oracle
        pallas=_pallas_impl,             # (*args, blocks=, interpret=, **static)
        apply=lambda args, force=False: mykern(*args, force),
        block_axes=(BlockAxis("bt", "T", preferred=256, align=8), ...),
        dims_of=lambda *args: {"T": args[0].shape[0], ...},
        candidates=({"bt": 128}, {"bt": 256}),   # autotune sweep
        make_inputs=_make_inputs,        # key -> args (parity + tuning)
        diff_argnums=(0, 1),             # () => forward-only parity
        tol=1e-4,
    ))

Dispatch is also where the runtime's *failure story* lives: every call
runs through a fallback chain (see `dispatch`) that degrades
pallas -> interpret -> ref on a Pallas failure or VMEM-model rejection,
records a structured `FallbackEvent` on the per-process incident log
(`repro.kernels.incidents()`), and — under `REPRO_STRICT=1` — raises a
`FallbackError` instead of degrading, so CI can prove the fast paths ran.

Environment knobs:
  REPRO_KERNEL_IMPL     = ref | pallas | auto   (auto: pallas on TPU,
                                                 ref elsewhere)
  REPRO_PALLAS_INTERPRET= 1 | 0                 (force interpret on/off)
  REPRO_TUNING_CACHE    = path to the JSON tuning cache
  REPRO_STRICT          = 1: degradations raise instead of falling back
  REPRO_FAULTS          = fault-injection spec (see repro.core.faults)
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.kernels.common import on_tpu
from repro.kernels.incidents import FallbackError, degrade  # noqa: F401


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------


def interpret_mode() -> bool:
    """Pallas kernels execute in interpret mode off-TPU (CPU container)."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced == "1"
    return not on_tpu()


def use_pallas(force_pallas: bool = False) -> bool:
    """Resolve the ref-vs-pallas choice for one call.

    `force_pallas=True` (the per-call/config escape hatch) always wins;
    otherwise `REPRO_KERNEL_IMPL` picks globally. `auto` (the default)
    prefers the Mosaic kernels on a real TPU — every family is gated by the
    ref<->Pallas parity harness, so the fast path is the default where it
    actually is fast — and keeps the XLA reference elsewhere (interpret-mode
    Pallas on CPU is a debugging tool, not an execution engine).
    """
    if force_pallas:
        return True
    mode = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if mode not in ("ref", "pallas", "auto"):
        raise ValueError(f"REPRO_KERNEL_IMPL={mode!r}: "
                         "expected 'ref', 'pallas', or 'auto'")
    if mode == "pallas":
        return True
    if mode == "ref":
        return False
    return on_tpu()


# ---------------------------------------------------------------------------
# block-shape resolution
# ---------------------------------------------------------------------------


def fit_block(n: int, preferred: int, align: int) -> int:
    """Largest block <= preferred that is a multiple of `align` and covers n
    evenly after padding; falls back to n rounded up to `align` when small."""
    if n <= preferred:
        return max(align, -(-n // align) * align)
    return preferred


def exact_block(n: int, preferred: int) -> int:
    """Largest block <= preferred that divides n exactly (no padding).

    Required for axes that chain state across grid steps (e.g. the LIF time
    axis): zero-padding such an axis would run extra dynamics steps and
    corrupt the carried state, so the block must tile the axis exactly.
    Worst case (prime n > preferred) degrades to 1 — correct, just serial.
    """
    b = min(max(1, n), max(1, preferred))
    while n % b:
        b -= 1
    return b


@dataclasses.dataclass(frozen=True)
class BlockAxis:
    """One tunable block dimension of a kernel's grid.

    `name` is the key in the blocks dict handed to the Pallas wrapper;
    `dim` names the logical tensor dimension (as produced by
    `KernelSpec.dims_of`) this block tiles; `preferred`/`align` reproduce
    the family's hand-picked defaults and TPU layout constraints.
    """

    name: str
    dim: str
    preferred: int
    align: int
    exact: bool = False  # block must divide the dim (state-chained axes)


@dataclasses.dataclass(frozen=True)
class Channel:
    """An alternative implementation pair for a kernel family.

    A channel is a *semantically identical* ref/Pallas pair that wins only
    on some inputs (e.g. the block-sparse `spikemm` gather path, which
    beats the dense kernel only below a block-occupancy threshold). Both
    callables receive the resolved `blocks=` dict (the ref too — a channel
    may restructure work at block granularity even off-TPU), and the Pallas
    side additionally gets `interpret=`.
    """

    ref: Callable[..., Any]
    pallas: Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class TileModel:
    """Static description of a kernel's grid coverage and operand tiles.

    Purely declarative — `repro.analysis.check_kernel` uses it to prove,
    without tracing, that (a) the grid x index-map writes every output
    element exactly once (TB301/302) and (b) the `vmem_bytes` estimate is
    an honest bound on the per-grid-step operand tiles (TB304/305).

    out:   the output tensor's dims in order, each paired with the block
           axis that tiles it (None = the dim rides whole in every block,
           e.g. the resident N axis of the recurrent kernels).
    tiles: (dims, blocks) -> {operand name: per-grid-step tile shape in
           elements}; fp32 is assumed when converting to bytes.
    coverage: optional override returning, per grid cell, the per-output-
           axis (start, stop) half-open ranges. Defaults to the dense
           row-major tiling implied by `out`; exists so tests can inject
           gap/overlap defects without a real kernel.
    """

    out: Tuple[Tuple[str, Optional[str]], ...]
    tiles: Callable[[Mapping[str, int], Mapping[str, int]],
                    Mapping[str, Tuple[int, ...]]]
    coverage: Optional[Callable[[Mapping[str, int], Mapping[str, int]],
                                Any]] = None


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Everything the registry needs to dispatch, tune, and verify a kernel."""

    name: str
    ref: Callable[..., Any]
    pallas: Callable[..., Any]
    apply: Callable[..., Any]
    block_axes: Tuple[BlockAxis, ...]
    dims_of: Callable[..., Dict[str, int]]
    candidates: Tuple[Mapping[str, int], ...] = ()
    make_inputs: Optional[Callable[..., tuple]] = None
    # static kwargs matching make_inputs' canonical args: machinery that
    # calls spec.ref/spec.pallas directly (the autotuner) forwards these,
    # since required statics otherwise only ride along dispatch() calls
    tune_static: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    diff_argnums: Tuple[int, ...] = ()
    tol: float = 1e-4
    # (dims, blocks) -> estimated per-grid-step VMEM working set in bytes;
    # the autotuner prunes candidates that exceed the budget before timing.
    vmem_bytes: Optional[Callable[[Mapping[str, int], Mapping[str, int]],
                                  int]] = None
    # named alternative implementation channels + the dispatch-time router:
    # select_channel(*args, blocks=..., **static) returns a key into
    # `channels` or None for the default (spec.ref / spec.pallas) pair. The
    # router runs at trace/dispatch time, so it may inspect concrete values
    # (e.g. measure occupancy) but must route conservatively on tracers.
    channels: Mapping[str, Channel] = dataclasses.field(default_factory=dict)
    select_channel: Optional[Callable[..., Optional[str]]] = None
    # static grid/tile description for the analyzer (see TileModel)
    tile_model: Optional[TileModel] = None

    def resolve_blocks(self, dims: Mapping[str, int],
                       overrides: Optional[Mapping[str, int]] = None,
                       use_cache: bool = True) -> Dict[str, int]:
        """Overrides > tuned cache > spec preferred, each fitted to `dims`."""
        tuned: Mapping[str, int] = {}
        if use_cache:
            from repro.kernels import tuning  # local: avoid import cycle
            tuned = tuning.lookup_tuned(self.name, dims) or {}
        overrides = overrides or {}
        blocks = {}
        for ax in self.block_axes:
            pref = int(overrides.get(ax.name, tuned.get(ax.name,
                                                        ax.preferred)))
            if ax.exact:
                blocks[ax.name] = exact_block(dims[ax.dim], pref)
            else:
                blocks[ax.name] = fit_block(dims[ax.dim], pref, ax.align)
        return blocks


# ---------------------------------------------------------------------------
# the registry proper
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, KernelSpec] = {}

_KERNEL_MODULES = (
    "repro.kernels.linrec.ops",
    "repro.kernels.lif.ops",
    "repro.kernels.lifrec.ops",
    "repro.kernels.alifrec.ops",
    "repro.kernels.spikemm.ops",
    "repro.kernels.spikemm.gather",
    "repro.kernels.attention.ops",
    "repro.kernels.stdp.ops",
)


def register(spec: KernelSpec) -> KernelSpec:
    """Idempotent by name: re-importing an ops module re-registers itself."""
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def names() -> Tuple[str, ...]:
    ensure_registered()
    return tuple(sorted(_REGISTRY))


def ensure_registered() -> None:
    """Import every kernel family so its module-level register() has run."""
    import importlib

    for mod in _KERNEL_MODULES:
        importlib.import_module(mod)


def dispatch(name: str, args: Sequence[Any], force_pallas: bool = False,
             overrides: Optional[Mapping[str, int]] = None, **static) -> Any:
    """Run kernel `name` on `args` through the unified policy.

    `static` kwargs (thresholds, causal flags, learning rates, ...) are
    forwarded verbatim to whichever implementation wins. `overrides` pins
    individual block sizes, bypassing the tuning cache for those axes.

    Families that registered `channels` + `select_channel` get a second
    routing layer: the router picks an implementation channel per call
    (e.g. block-sparse vs dense `spikemm` by measured occupancy), then the
    usual ref-vs-Pallas policy applies within the chosen channel.

    **Fallback chain.** When the Pallas stage is selected, failures do not
    kill the run: a raising Pallas call (genuine, or injected via a
    `compile_fail` fault — see `repro.core.faults`) degrades
    compiled -> interpret -> ref, and a call whose modeled VMEM working
    set (`KernelSpec.vmem_bytes`) busts the budget is rejected up front
    (real-Mosaic calls always; interpret-mode calls only under simulated
    `vmem_limit` fault pressure, since interpret mode has no VMEM to
    blow). Each degradation records a `FallbackEvent` on the incident log
    and, under `REPRO_STRICT=1`, raises `FallbackError` instead. A failing
    channel router likewise degrades to the default (dense) channel. Note
    the chain catches what raises *through this call*: eager/interpret
    execution and trace-time errors, which is where Pallas failures
    surface off-TPU; a Mosaic compile error deferred to an outer jit's
    AOT-compile happens outside dispatch and stays fatal.
    """
    from repro.core import faults  # local: keep core<->kernels import acyclic

    spec = get(name)
    dims = spec.dims_of(*args)
    blocks: Optional[Dict[str, int]] = None

    def resolved_blocks() -> Dict[str, int]:
        nonlocal blocks
        if blocks is None:
            blocks = spec.resolve_blocks(dims, overrides)
        return blocks

    chan = None
    choice: Optional[str] = None
    if spec.select_channel is not None:
        try:
            choice = spec.select_channel(*args, blocks=resolved_blocks(),
                                         **static)
        except Exception as e:
            degrade("channel", name, "router", e, dims=dims, blocks=blocks)
            choice = None
        if choice is not None:
            chan = spec.channels[choice]

    def run_ref():
        if chan is not None:
            return chan.ref(*args, blocks=resolved_blocks(), **static)
        return spec.ref(*args, **static)

    if not use_pallas(force_pallas):
        return run_ref()

    pallas_fn = chan.pallas if chan is not None else spec.pallas
    interp = interpret_mode()

    if spec.vmem_bytes is not None:
        from repro.kernels import tuning  # local: avoid import cycle
        limit = tuning.vmem_limit_bytes()
        pressured = faults.vmem_limit_override_bytes() is not None
        if not interp or pressured:
            est = spec.vmem_bytes(dims, resolved_blocks())
            if est > limit:
                degrade("vmem", name, "vmem-model",
                        f"modeled working set {int(est)} B exceeds budget "
                        f"{limit} B", channel=choice, dims=dims,
                        blocks=blocks)
                return run_ref()

    blk = resolved_blocks()   # resolve up front so incidents carry context
    try:
        faults.maybe_fail_compile(name)
        return pallas_fn(*args, blocks=blk, interpret=interp, **static)
    except Exception as e:
        degrade("dispatch", name, "pallas", e, channel=choice, dims=dims,
                blocks=blk)
    if not interp:
        # the compiled path failed on real hardware: interpret mode runs the
        # same kernel body in Python — slow, but it preserves the kernel's
        # exact numerics while we limp along
        try:
            faults.maybe_fail_compile(name)
            return pallas_fn(*args, blocks=blk, interpret=True, **static)
        except Exception as e:
            degrade("dispatch", name, "interpret", e, channel=choice,
                    dims=dims, blocks=blk)
    return run_ref()


__all__ = ["BlockAxis", "Channel", "FallbackError", "KernelSpec", "TileModel",
           "register", "get", "names", "ensure_registered", "dispatch",
           "fit_block", "exact_block", "use_pallas", "interpret_mode"]
