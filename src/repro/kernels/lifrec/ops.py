"""Public fused recurrent-LIF entry point with surrogate-gradient VJP.

Forward dispatches through the kernel registry (Pallas when forced or on
TPU under `auto`, scan reference otherwise). Backward is STBP through both
couplings of the recurrence:

    u_t = tau * v_{t-1} + c_t + s_{t-1} @ W      (pre-reset potential)
    s_t = H(u_t - v_th)
    v_t = u_t (1 - s_t)

With Gu_t = dL/du_t, Gs_t the external spike cotangent, and g() the
surrogate window, the spike cotangent gains a recurrent term relative to
the pure-FF LIF adjoint (`lif/ops.py`) — spikes at t feed u_{t+1} through W:

    Gs~_t = Gs_t + Gu_{t+1} @ W^T
    Gu_t  = Gv_t (1 - s_t) + (Gs~_t - Gv_t u_t) g(u_t - v_th)
    Gv_{t-1} = tau * Gu_t
    dL/dc_t = Gu_t          dL/dW  = sum_t s_{t-1}^T Gu_t
    dL/dtau = sum Gu_t v_{t-1}     dL/dv0 = tau Gu_0
    dL/ds0  = Gu_0 @ W^T

u is recomputed forward from (c, s) instead of being stored — one extra
scan, the same storage/recompute trade `lif/ops.py` makes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.surrogate import _SURROGATES
from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.lifrec.kernel import lifrec_pallas
from repro.kernels.lifrec.ref import lifrec_scan_ref


def _pallas_impl(current, w_rec, tau, v0, s0, *, blocks, interpret,
                 v_th=1.0):
    T, B, N = current.shape
    ct, bb = blocks["ct"], blocks["bb"]
    # 'ct' is an exact-policy axis: resolve_blocks only hands out divisors
    # of T. Zero-padding time instead would run extra decay steps past T
    # and silently corrupt v_final, so a non-divisor must fail loudly.
    assert T % ct == 0, (T, ct)
    c_p, _ = pad_axis(current, 1, bb)
    c_p, _ = pad_axis(c_p, 2, 128)
    w_p, _ = pad_axis(w_rec.astype(current.dtype), 0, 128)
    w_p, _ = pad_axis(w_p, 1, 128)
    tau_p, _ = pad_axis(tau, 0, 128, value=1.0)
    v0_p, _ = pad_axis(v0, 0, bb)
    v0_p, _ = pad_axis(v0_p, 1, 128)
    s0_p, _ = pad_axis(s0, 0, bb)
    s0_p, _ = pad_axis(s0_p, 1, 128)
    s, vT = lifrec_pallas(c_p, w_p, tau_p, v0_p, s0_p, v_th=v_th,
                          ct=ct, bb=bb, interpret=interpret)
    return s[:T, :B, :N], vT[:B, :N]


def _fwd_impl(current, w_rec, tau, v0, s0, v_th, force_pallas):
    return registry.dispatch("lifrec", (current, w_rec, tau, v0, s0),
                             force_pallas=force_pallas, v_th=v_th)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def lifrec_scan(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                v0: jax.Array, s0: jax.Array, v_th: float = 1.0,
                surrogate: str = "rectangle", alpha: float = 1.0,
                force_pallas: bool = False):
    """Fused recurrent LIF over time. current: (T,B,N); w_rec: (N,N);
    tau: (N,); v0/s0: (B,N).

    Returns (spikes (T,B,N), v_final (B,N)). Differentiable via STBP/BPTT.
    """
    return _fwd_impl(current, w_rec, tau, v0, s0, v_th, force_pallas)


def _lifrec_fwd(current, w_rec, tau, v0, s0, v_th, surrogate, alpha,
                force_pallas):
    s, vT = _fwd_impl(current, w_rec, tau, v0, s0, v_th, force_pallas)
    return (s, vT), (current, w_rec, tau, v0, s0, s)


def _lifrec_bwd(v_th, surrogate, alpha, force_pallas, res, cts):
    current, w_rec, tau, v0, s0, s = res
    gs, gvT = cts
    g_fn = _SURROGATES[surrogate]
    tau32 = tau.astype(jnp.float32)
    w32 = w_rec.astype(jnp.float32)
    c32 = current.astype(jnp.float32)
    s32 = s.astype(jnp.float32)

    def fwd_body(carry, ts):
        v, s_prev = carry
        c_t, s_t = ts
        u = tau32 * v + c_t + s_prev @ w32
        v = u * (1.0 - s_t)
        return (v, s_t), (u, v, s_prev)

    (_, _), (u, v_seq, s_prev) = jax.lax.scan(
        fwd_body, (v0.astype(jnp.float32), s0.astype(jnp.float32)),
        (c32, s32))
    v_prev = jnp.concatenate([v0[None].astype(jnp.float32), v_seq[:-1]], 0)
    surr = g_fn(u - v_th, jnp.asarray(alpha, jnp.float32))

    def bwd_body(carry, ts):
        gv, gu_next = carry
        gs_t, u_t, s_t, surr_t = ts
        gs_tot = gs_t + gu_next @ w32.T
        gu = gv * (1.0 - s_t) + (gs_tot - gv * u_t) * surr_t
        return (tau32 * gu, gu), gu

    zero_gu = jnp.zeros(gs.shape[1:], jnp.float32)
    (_, _), gu = jax.lax.scan(
        bwd_body, (gvT.astype(jnp.float32), zero_gu),
        (gs.astype(jnp.float32), u, s32, surr), reverse=True)
    g_current = gu.astype(current.dtype)
    g_w = jnp.einsum("tbi,tbj->ij", s_prev, gu).astype(w_rec.dtype)
    g_tau = jnp.sum(gu * v_prev, axis=(0, 1)).astype(tau.dtype)
    g_v0 = (tau32 * gu[0]).astype(v0.dtype)
    g_s0 = (gu[0] @ w32.T).astype(s0.dtype)
    return g_current, g_w, g_tau, g_v0, g_s0


lifrec_scan.defvjp(_lifrec_fwd, _lifrec_bwd)


def _make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    T, B, N = 20, 3, 70                       # non-multiples exercise padding
    current = 0.8 * jax.random.normal(k1, (T, B, N), jnp.float32)
    w_rec = (0.4 / jnp.sqrt(N)) * jax.random.normal(k2, (N, N), jnp.float32)
    tau = jax.random.uniform(k3, (N,), jnp.float32, 0.7, 0.98)
    v0 = jnp.zeros((B, N), jnp.float32)
    s0 = jnp.zeros((B, N), jnp.float32)
    return current, w_rec, tau, v0, s0


def _vmem_bytes(dims, blocks):
    n = -(-dims["N"] // 128) * 128
    ct, bb = blocks["ct"], blocks["bb"]
    # current + spikes blocks, resident W, and the v/s/tau/v0/s0/vT tiles
    return 4 * (2 * ct * bb * n + n * n + 6 * bb * n + n)


registry.register(registry.KernelSpec(
    name="lifrec",
    ref=lifrec_scan_ref,
    pallas=_pallas_impl,
    apply=lambda args, force=False: lifrec_scan(*args, 1.0, "rectangle", 1.0,
                                                force),
    block_axes=(registry.BlockAxis("ct", "T", preferred=128, align=8,
                                   exact=True),
                registry.BlockAxis("bb", "B", preferred=8, align=8)),
    dims_of=lambda current, w_rec, tau, v0, s0: {"T": current.shape[0],
                                                 "B": current.shape[1],
                                                 "N": current.shape[2]},
    candidates=({"ct": 64}, {"ct": 128}, {"ct": 256}, {"ct": 128, "bb": 16}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1, 2, 3, 4),
    tol=1e-4,
    vmem_bytes=_vmem_bytes,
    # the N axis is VMEM-resident (whole, padded to the 128 lane) — only
    # T and B are tiled by the grid
    tile_model=registry.TileModel(
        out=(("T", "ct"), ("B", "bb"), ("N", None)),
        tiles=lambda dims, b: (lambda n: {
            "current": (b["ct"], b["bb"], n),
            "spikes_out": (b["ct"], b["bb"], n),
            "w_rec": (n, n),
            "v": (b["bb"], n), "s": (b["bb"], n),
            "v0": (b["bb"], n), "s0": (b["bb"], n),
            "vT": (b["bb"], n), "sT": (b["bb"], n),
            "tau": (n,)})(-(-dims["N"] // 128) * 128)),
))
