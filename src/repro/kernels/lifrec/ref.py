"""Pure-jnp oracle for the fused recurrent-LIF time scan.

Recurrent LIF (the SRNN hidden layer, paper §V-B3) couples the FIRE stage
back into the next INTEG stage through the self-connection:

    u_t = tau * v_{t-1} + c_t + s_{t-1} @ W_rec
    s_t = H(u_t - v_th)
    v_t = u_t * (1 - s_t)

`c` is the feed-forward current, already hoisted out of the time loop by
the plan compiler (one all-T spikemm); only the self-term is serial.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lifrec_scan_ref(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                    v0: jax.Array, s0: jax.Array, v_th: float = 1.0):
    """current: (T, B, N); w_rec: (N, N); tau: (N,); v0, s0: (B, N).

    Returns (spikes (T, B, N), v_final (B, N)). fp32 state.
    """
    dt = current.dtype
    tau32 = tau.astype(jnp.float32)
    w32 = w_rec.astype(jnp.float32)

    def body(carry, c_t):
        v, s = carry
        v = tau32 * v + c_t.astype(jnp.float32) + s @ w32
        spk = (v >= v_th).astype(jnp.float32)
        v = v * (1.0 - spk)
        return (v, spk), spk.astype(dt)

    (vT, _), spikes = jax.lax.scan(
        body, (v0.astype(jnp.float32), s0.astype(jnp.float32)), current)
    return spikes, vT.astype(dt)
