"""Fused recurrent-LIF Pallas kernel (DIFF + LOCACC(self) + threshold + SEND).

Like `lif/kernel.py`, the reset makes the scan non-associative, so time runs
serially inside the kernel — but here every step also applies the recurrent
weights to the previous step's spikes. The win is residency: W_rec stays in
VMEM for the whole time chunk (on chip this is the NC-local weight SRAM),
the per-step (bb, N) x (N, N) matmul feeds the MXU from VMEM, and neither
membrane state nor spikes round-trip to HBM between steps.

The neuron axis is NOT blocked: the recurrence couples all N outputs to all
N previous spikes, so the whole (N, N) weight block must be resident. SNN
populations are small (64-2048 neurons); the wrapper pads N to the 128-lane
boundary. grid = (B/bb, T/ct), time innermost; scratch v and s: (bb, N)
carry the state across time chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lifrec_kernel(cur_ref, w_ref, tau_ref, v0_ref, s0_ref, s_out_ref,
                   vT_ref, v_scr, s_scr, *, ct: int, v_th: float):
    t_idx = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _():
        v_scr[...] = v0_ref[...].astype(jnp.float32)
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    cur = cur_ref[...].astype(jnp.float32)           # (ct, bb, N)
    w = w_ref[...].astype(jnp.float32)               # (N, N)
    tau = tau_ref[...].astype(jnp.float32)           # (1, N)

    def step(t, carry):
        v, s, acc = carry
        rec = jax.lax.dot_general(s, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        v = tau * v + cur[t] + rec
        spk = (v >= v_th).astype(jnp.float32)
        v = v * (1.0 - spk)
        acc = jax.lax.dynamic_update_index_in_dim(acc, spk, t, 0)
        return v, spk, acc

    v, s, spikes = jax.lax.fori_loop(
        0, ct, step, (v_scr[...], s_scr[...],
                      jnp.zeros(cur.shape, jnp.float32)))
    s_out_ref[...] = spikes.astype(s_out_ref.dtype)
    v_scr[...] = v
    s_scr[...] = s

    @pl.when(t_idx == nt - 1)
    def _():
        vT_ref[...] = v.astype(vT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "bb", "v_th", "interpret"))
def lifrec_pallas(current: jax.Array, w_rec: jax.Array, tau: jax.Array,
                  v0: jax.Array, s0: jax.Array, *, v_th: float = 1.0,
                  ct: int = 128, bb: int = 8, interpret: bool = False):
    """current: (T, B, N); w_rec: (N, N); tau: (N,); v0/s0: (B, N).

    T % ct == 0, B % bb == 0, N a multiple of 128 (wrapper pads).
    """
    T, B, N = current.shape
    assert T % ct == 0 and B % bb == 0
    grid = (B // bb, T // ct)
    tau2 = tau.reshape(1, N)

    return pl.pallas_call(
        functools.partial(_lifrec_kernel, ct=ct, v_th=v_th),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ct, bb, N), lambda i, t: (t, i, 0)),   # current
            pl.BlockSpec((N, N), lambda i, t: (0, 0)),           # w_rec
            pl.BlockSpec((1, N), lambda i, t: (0, 0)),           # tau
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # v0
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # s0
        ],
        out_specs=[
            pl.BlockSpec((ct, bb, N), lambda i, t: (t, i, 0)),   # spikes
            pl.BlockSpec((bb, N), lambda i, t: (i, 0)),          # vT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, N), jnp.float32),
                        pltpu.VMEM((bb, N), jnp.float32)],
        interpret=interpret,
    )(current, w_rec, tau2, v0, s0)
