from repro.kernels.lifrec.ops import lifrec_scan
from repro.kernels.lifrec.ref import lifrec_scan_ref

__all__ = ["lifrec_scan", "lifrec_scan_ref"]
