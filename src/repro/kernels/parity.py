"""Ref <-> Pallas parity harness: the backbone of the fast CI tier.

Every kernel family registers canonical inputs, tolerances, and its
differentiable argument set in its `KernelSpec`; this module turns that
into a uniform check that the Pallas path (interpret mode off-TPU, real
Mosaic on TPU) agrees with the pure-jnp oracle on

  * the forward outputs (every leaf of the output pytree), and
  * the VJP: gradients of a fixed nonlinear scalar loss with respect to
    every `diff_argnums` input.

`check_kernel` raises AssertionError with the offending kernel/leaf on
mismatch and returns a numeric report on success, so it doubles as a test
assertion (tests/test_registry.py) and a health probe
(`python -m repro.kernels.parity`).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry


def _loss(out) -> jax.Array:
    """Fixed nonlinear scalar reduction: weights every output leaf, keeps
    cotangents O(1), and breaks the symmetry a plain sum() would miss."""
    total = 0.0
    for i, leaf in enumerate(jax.tree_util.tree_leaves(out)):
        total = total + jnp.sum(jnp.sin(leaf.astype(jnp.float32) * (0.7 + i)))
    return total


def _max_err(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32) -
                                 jnp.asarray(b, jnp.float32))))


def check_kernel(name: str, *, seed: int = 0,
                 check_vjp: bool = True) -> Dict[str, float]:
    """Assert forward + VJP parity for one registered kernel."""
    spec = registry.get(name)
    if spec.make_inputs is None:
        raise ValueError(f"kernel {name!r} registered without make_inputs")
    args = spec.make_inputs(jax.random.PRNGKey(seed))

    ref_out = spec.apply(args, False)
    pal_out = spec.apply(args, True)
    ref_leaves = jax.tree_util.tree_leaves(ref_out)
    pal_leaves = jax.tree_util.tree_leaves(pal_out)
    assert len(ref_leaves) == len(pal_leaves), (
        f"{name}: output pytree mismatch")
    report = {}
    fwd_err = 0.0
    for i, (r, p) in enumerate(zip(ref_leaves, pal_leaves)):
        assert r.shape == p.shape, (
            f"{name}: leaf {i} shape {p.shape} != ref {r.shape}")
        err = _max_err(r, p)
        fwd_err = max(fwd_err, err)
        assert err <= spec.tol, (
            f"{name}: forward leaf {i} max|err| {err:.3e} > tol {spec.tol}")
    report["forward_max_err"] = fwd_err

    if check_vjp and spec.diff_argnums:
        grad_ref = jax.grad(lambda *a: _loss(spec.apply(a, False)),
                            spec.diff_argnums)(*args)
        grad_pal = jax.grad(lambda *a: _loss(spec.apply(a, True)),
                            spec.diff_argnums)(*args)
        vjp_err = 0.0
        for argnum, r, p in zip(spec.diff_argnums, grad_ref, grad_pal):
            err = _max_err(r, p)
            vjp_err = max(vjp_err, err)
            assert err <= spec.tol, (
                f"{name}: VJP wrt arg {argnum} max|err| {err:.3e} "
                f"> tol {spec.tol}")
        report["vjp_max_err"] = vjp_err
    return report


def check_all(*, seed: int = 0,
              names: Optional[Tuple[str, ...]] = None) -> Dict[str, Dict]:
    """Parity-check every registered kernel; raises on first failure."""
    registry.ensure_registered()
    return {name: check_kernel(name, seed=seed)
            for name in (names or registry.names())}


def main() -> None:
    reports = check_all()
    width = max(len(n) for n in reports)
    for name, rep in reports.items():
        vjp = rep.get("vjp_max_err")
        vjp_s = f"vjp {vjp:.3e}" if vjp is not None else "forward-only"
        print(f"{name:<{width}}  fwd {rep['forward_max_err']:.3e}  {vjp_s}")
    print(f"parity OK for {len(reports)} kernels "
          f"(backend={jax.default_backend()})")


if __name__ == "__main__":
    main()


__all__ = ["check_kernel", "check_all"]
