from repro.kernels.lif.ops import lif_scan
from repro.kernels.lif.ref import lif_scan_ref

__all__ = ["lif_scan", "lif_scan_ref"]
