"""Pure-jnp oracle for the fused LIF time scan (paper eqs. (1)-(3))."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_scan_ref(current: jax.Array, tau: jax.Array, v0: jax.Array,
                 v_th: float = 1.0, reset: str = "zero"):
    """current: (T, B, N); tau: (N,) per-neuron decay; v0: (B, N).

    v_t = tau * v_{t-1} + I_t;  s_t = [v_t >= v_th];  then the reset:
    "zero"     v_t <- v_t * (1 - s_t)   (hard reset, eq. (3))
    "subtract" v_t <- v_t - v_th * s_t  (soft reset: keep the residue)
    Returns (spikes (T, B, N), v_final (B, N)). fp32 state.
    """
    dt = current.dtype
    tau32 = tau.astype(jnp.float32)

    def body(v, i_t):
        v = tau32 * v + i_t.astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        v = v - v_th * s if reset == "subtract" else v * (1.0 - s)
        return v, s.astype(dt)

    vT, spikes = jax.lax.scan(body, v0.astype(jnp.float32), current)
    return spikes, vT.astype(dt)
