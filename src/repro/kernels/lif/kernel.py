"""Fused LIF integrate-and-fire Pallas kernel (DIFF + threshold + SEND).

Unlike the pure linear recurrence, LIF's reset makes the scan
non-associative, so time is processed serially *inside* the kernel — but the
whole (T_chunk, bb, bn) current block lives in VMEM, so the serial loop is
VPU-bound with zero HBM traffic per step, and states never round-trip to HBM
(on chip, this is exactly why TaiBai keeps v in NC-local memory).

grid = (B/bb, N/bn, T/ct), time innermost; VMEM scratch v:(bb, bn) carries
the membrane across chunks. Default tile (256, 8, 512): current + spikes
blocks = 8.4 MiB VMEM.

The threshold is a scalar; per-neuron decay arrives as a (1, bn) block so
heterogeneous populations (ALIF/PLIF-trained taus) use the same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lif_kernel(cur_ref, tau_ref, v0_ref, s_ref, vT_ref, v_scr, *,
                ct: int, v_th: float, reset: str):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _():
        v_scr[...] = v0_ref[...].astype(jnp.float32)

    cur = cur_ref[...].astype(jnp.float32)           # (ct, bb, bn)
    tau = tau_ref[...].astype(jnp.float32)           # (1, bn)
    v = v_scr[...]

    def step(t, carry):
        v, s_acc = carry
        v = tau * v + cur[t]
        s = (v >= v_th).astype(jnp.float32)
        v = v - v_th * s if reset == "subtract" else v * (1.0 - s)
        s_acc = jax.lax.dynamic_update_index_in_dim(s_acc, s, t, 0)
        return v, s_acc

    v, spikes = jax.lax.fori_loop(
        0, ct, step, (v, jnp.zeros(cur.shape, jnp.float32)))
    s_ref[...] = spikes.astype(s_ref.dtype)
    v_scr[...] = v

    @pl.when(t_idx == nt - 1)
    def _():
        vT_ref[...] = v.astype(vT_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("ct", "bb", "bn", "v_th", "reset",
                                    "interpret"))
def lif_pallas(current: jax.Array, tau: jax.Array, v0: jax.Array, *,
               v_th: float = 1.0, reset: str = "zero", ct: int = 256,
               bb: int = 8, bn: int = 512, interpret: bool = False):
    """current: (T, B, N); tau: (N,); v0: (B, N). Dims divisible by tiles."""
    T, B, N = current.shape
    assert T % ct == 0 and B % bb == 0 and N % bn == 0
    grid = (B // bb, N // bn, T // ct)
    tau2 = tau.reshape(1, N)

    return pl.pallas_call(
        functools.partial(_lif_kernel, ct=ct, v_th=v_th, reset=reset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ct, bb, bn), lambda i, j, t: (t, i, j)),  # current
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),          # tau
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # v0
        ],
        out_specs=[
            pl.BlockSpec((ct, bb, bn), lambda i, j, t: (t, i, j)),  # spikes
            pl.BlockSpec((bb, bn), lambda i, j, t: (i, j)),         # vT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, N), current.dtype),
            jax.ShapeDtypeStruct((B, N), current.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(current, tau2, v0)
