"""Public fused-LIF entry point with surrogate-gradient VJP.

Forward dispatches through the kernel registry (Pallas kernel when forced,
scan reference otherwise); backward applies STBP surrogate gradients
through threshold + reset and the membrane-decay chain — implemented as a
reverse-time linear recurrence, so it reuses the `linrec` machinery (and
its kernel) rather than storing per-step residuals.

Adjoint derivation (hard reset, rectangle surrogate g(u) = d s/d u):
    u_t   = tau * v_{t-1} + I_t          (pre-reset potential)
    s_t   = H(u_t - v_th)
    v_t   = u_t (1 - s_t)
Let  Gu_t = dL/du_t. With  Gs_t  the spike cotangent and  Gv_t  the
(recursively accumulated) membrane cotangent:
    Gu_t = Gv_t (1 - s_t) + (Gs_t - Gv_t u_t) g(u_t - v_th)
    Gv_{t-1} = tau * Gu_t                    (+ external Gv for t-1)
    dL/dI_t  = Gu_t,   dL/dtau += Gu_t v_{t-1},   dL/dv0 = tau Gu_0
The Gv recursion is linear -> reverse linrec with decay tau(1-s)+... no:
Gu couples through (1-s_t) and g terms that depend on stored u_t, so we
save u (recomputable from spikes+current, but u is the natural residual).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.surrogate import _SURROGATES
from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.lif.kernel import lif_pallas
from repro.kernels.lif.ref import lif_scan_ref


def _pallas_impl(current, tau, v0, *, blocks, interpret, v_th=1.0,
                 reset="zero"):
    T, B, N = current.shape
    ct, bb, bn = blocks["ct"], blocks["bb"], blocks["bn"]
    # 'ct' is an exact-policy axis (see lifrec/ops.py): zero-padded time
    # steps would keep decaying v past T, so non-divisors must fail loudly.
    assert T % ct == 0, (T, ct)
    c_p, _ = pad_axis(current, 1, bb)
    c_p, _ = pad_axis(c_p, 2, bn)
    tau_p, _ = pad_axis(tau, 0, bn, value=1.0)
    v0_p, _ = pad_axis(v0, 0, bb)
    v0_p, _ = pad_axis(v0_p, 1, bn)
    s, vT = lif_pallas(c_p, tau_p, v0_p, v_th=v_th, reset=reset, ct=ct,
                       bb=bb, bn=bn, interpret=interpret)
    return s[:T, :B, :N], vT[:B, :N]


def _fwd_impl(current, tau, v0, v_th, reset, force_pallas):
    return registry.dispatch("lif", (current, tau, v0),
                             force_pallas=force_pallas, v_th=v_th,
                             reset=reset)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def lif_scan(current: jax.Array, tau: jax.Array, v0: jax.Array,
             v_th: float = 1.0, surrogate: str = "rectangle",
             alpha: float = 1.0, force_pallas: bool = False,
             reset: str = "zero"):
    """Fused LIF over time. current: (T,B,N); tau: (N,); v0: (B,N).

    reset: "zero" (hard reset) or "subtract" (v <- v - v_th on spike).
    Returns (spikes (T,B,N), v_final (B,N)). Differentiable via STBP.
    """
    return _fwd_impl(current, tau, v0, v_th, reset, force_pallas)


def _lif_fwd(current, tau, v0, v_th, surrogate, alpha, force_pallas, reset):
    s, vT = _fwd_impl(current, tau, v0, v_th, reset, force_pallas)
    return (s, vT), (current, tau, v0, s)


def _lif_bwd(v_th, surrogate, alpha, force_pallas, reset, res, cts):
    current, tau, v0, s = res
    gs, gvT = cts
    g_fn = _SURROGATES[surrogate]
    tau32 = tau.astype(jnp.float32)
    c32 = current.astype(jnp.float32)
    s32 = s.astype(jnp.float32)
    subtract = reset == "subtract"

    # Recompute u_t (pre-reset potential) forward — cheap (one linrec) and
    # avoids storing it: u_t = tau v_{t-1} + I_t, then v_t = u_t (1 - s_t)
    # (zero reset) or v_t = u_t - v_th s_t (subtract reset). The v sequence
    # is reconstructible from s and u; do one fused scan.
    def fwd_body(v, ts):
        i_t, s_t = ts
        u = tau32 * v + i_t
        v = u - v_th * s_t if subtract else u * (1.0 - s_t)
        return v, (u, v)

    _, (u, v_seq) = jax.lax.scan(fwd_body, v0.astype(jnp.float32), (c32, s32))
    v_prev = jnp.concatenate([v0[None].astype(jnp.float32), v_seq[:-1]], 0)

    surr = g_fn(u - v_th, jnp.asarray(alpha, jnp.float32))

    # Adjoints through the reset (g = surrogate ds/du):
    #   zero:     v = u (1 - s)      Gu = Gv (1 - s) + (Gs - Gv u) g
    #   subtract: v = u - v_th s     Gu = Gv (1 - v_th g) + Gs g
    def bwd_body(gv_next, ts):
        gs_t, u_t, s_t, surr_t = ts
        if subtract:
            gu = gv_next * (1.0 - v_th * surr_t) + gs_t * surr_t
        else:
            gu = gv_next * (1.0 - s_t) + (gs_t - gv_next * u_t) * surr_t
        gv_prev = tau32 * gu
        return gv_prev, gu

    gv_last = gvT.astype(jnp.float32)
    _, gu = jax.lax.scan(bwd_body, gv_last,
                         (gs.astype(jnp.float32), u, s32, surr), reverse=True)
    g_current = gu.astype(current.dtype)
    g_tau = jnp.sum(gu * v_prev, axis=(0, 1)).astype(tau.dtype)
    g_v0 = (tau32 * gu[0]).astype(v0.dtype)
    return g_current, g_tau, g_v0


lif_scan.defvjp(_lif_fwd, _lif_bwd)


def _make_inputs(key):
    k1, k2 = jax.random.split(key)
    T, B, N = 20, 3, 130                      # non-multiples exercise padding
    current = 0.6 * jax.random.normal(k1, (T, B, N), jnp.float32)
    tau = jax.random.uniform(k2, (N,), jnp.float32, 0.7, 0.98)
    v0 = jnp.zeros((B, N), jnp.float32)
    return current, tau, v0


registry.register(registry.KernelSpec(
    name="lif",
    ref=lif_scan_ref,
    pallas=_pallas_impl,
    apply=lambda args, force=False: lif_scan(*args, 1.0, "rectangle", 1.0,
                                             force),
    block_axes=(registry.BlockAxis("ct", "T", preferred=256, align=8,
                                   exact=True),
                registry.BlockAxis("bb", "B", preferred=8, align=8),
                registry.BlockAxis("bn", "N", preferred=512, align=128)),
    dims_of=lambda current, tau, v0: {"T": current.shape[0],
                                      "B": current.shape[1],
                                      "N": current.shape[2]},
    candidates=({"ct": 128, "bn": 256}, {"ct": 128, "bn": 512},
                {"ct": 256, "bn": 256}, {"ct": 512, "bn": 512}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1, 2),
    tol=1e-4,
    # current + spikes blocks dominate; v scratch/v0/vT + tau ride along
    vmem_bytes=lambda dims, b: 4 * (2 * b["ct"] * b["bb"] * b["bn"]
                                    + 3 * b["bb"] * b["bn"] + b["bn"]),
    tile_model=registry.TileModel(
        out=(("T", "ct"), ("B", "bb"), ("N", "bn")),
        tiles=lambda dims, b: {
            "current": (b["ct"], b["bb"], b["bn"]),
            "spikes_out": (b["ct"], b["bb"], b["bn"]),
            "v": (b["bb"], b["bn"]), "v0": (b["bb"], b["bn"]),
            "vT": (b["bb"], b["bn"]), "tau": (b["bn"],)}),
))
