"""Public spikemm entry: occupancy computation + registry dispatch +
straight-through gradient.

The forward skips silent blocks; the backward uses the dense oracle
gradients (dL/dW = s^T g gated by the same occupancy is an *exact* identity,
since silent rows contribute zero — we exploit that: the dW matmul is also
event-gated, which is the paper's point that learning, too, is event-driven).

Block sizes: `bm`/`bk`/`bn` default to None, meaning the registry resolves
them (tuning cache, then the spec defaults 128/512/512); an explicit int
pins that axis for the call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.spikemm.kernel import spikemm_pallas
from repro.kernels.spikemm.ref import spikemm_ref


def block_occupancy(spikes: jax.Array, bm: int, bk: int) -> jax.Array:
    """(M/bm, K/bk) int32: 1 where the spike block has any nonzero."""
    M, K = spikes.shape
    blk = spikes.reshape(M // bm, bm, K // bk, bk)
    return (jnp.max(jnp.abs(blk), axis=(1, 3)) > 0).astype(jnp.int32)


def occupancy_fraction(spikes: jax.Array, bm: int = 128, bk: int = 512):
    """Fraction of blocks with events — the kernel's effective FLOP fraction."""
    s, _ = pad_axis(spikes, 0, bm)
    s, _ = pad_axis(s, 1, bk)
    f = block_occupancy(s, bm, bk)
    return jnp.mean(f.astype(jnp.float32))


def _pallas_impl(spikes, w, *, blocks, interpret):
    M, K = spikes.shape
    N = w.shape[1]
    bm, bk, bn = blocks["bm"], blocks["bk"], blocks["bn"]
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p, _ = pad_axis(s_p, 1, bk)
    w_p, _ = pad_axis(w.astype(spikes.dtype), 0, bk)
    w_p, _ = pad_axis(w_p, 1, bn)
    flags = block_occupancy(s_p, bm, bk)
    out = spikemm_pallas(flags, s_p, w_p, bm=bm, bk=bk, bn=bn,
                         interpret=interpret)
    return out[:M, :N]


def _ref_impl(spikes, w):
    return spikemm_ref(spikes, w.astype(spikes.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def spikemm(spikes: jax.Array, w: jax.Array, bm: int = None, bk: int = None,
            bn: int = None, force_pallas: bool = False) -> jax.Array:
    """Event-gated spikes @ w. spikes: (M, K) 0/1; w: (K, N)."""
    return _impl(spikes, w, bm, bk, bn, force_pallas)


def _impl(spikes, w, bm, bk, bn, force_pallas):
    overrides = {k: v for k, v in (("bm", bm), ("bk", bk), ("bn", bn))
                 if v is not None}
    return registry.dispatch("spikemm", (spikes, w),
                             force_pallas=force_pallas, overrides=overrides)


def _fwd(spikes, w, bm, bk, bn, force_pallas):
    return _impl(spikes, w, bm, bk, bn, force_pallas), (spikes, w)


def _bwd(bm, bk, bn, force_pallas, res, g):
    spikes, w = res
    # dL/dspikes = g @ w^T (dense: spike cotangents feed the surrogate);
    # dL/dw = spikes^T @ g — event-gated with the SAME occupancy (exact).
    g_spikes = jnp.dot(g, w.T.astype(g.dtype),
                       preferred_element_type=jnp.float32).astype(spikes.dtype)
    g_w = _impl(spikes.T, g, bm, bk, bn, force_pallas).astype(w.dtype)
    return g_spikes, g_w


spikemm.defvjp(_fwd, _bwd)


def _make_inputs(key):
    k1, k2 = jax.random.split(key)
    M, K, N = 100, 300, 200                   # non-multiples exercise padding
    spikes = (jax.random.uniform(k1, (M, K)) < 0.13).astype(jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    return spikes, w


registry.register(registry.KernelSpec(
    name="spikemm",
    ref=_ref_impl,
    pallas=_pallas_impl,
    apply=lambda args, force=False: spikemm(*args, None, None, None, force),
    block_axes=(registry.BlockAxis("bm", "M", preferred=128, align=8),
                registry.BlockAxis("bk", "K", preferred=512, align=128),
                registry.BlockAxis("bn", "N", preferred=512, align=128)),
    dims_of=lambda spikes, w: {"M": spikes.shape[0], "K": spikes.shape[1],
                               "N": w.shape[1]},
    candidates=({"bm": 128, "bk": 256}, {"bm": 128, "bk": 512},
                {"bm": 256, "bk": 512}, {"bm": 128, "bk": 512, "bn": 256}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1),
    tol=1e-4,
    # spike + weight blocks in, out block + fp32 accumulator
    vmem_bytes=lambda dims, b: 4 * (b["bm"] * b["bk"] + b["bk"] * b["bn"]
                                    + 2 * b["bm"] * b["bn"]),
))
