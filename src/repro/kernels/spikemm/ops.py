"""Public spikemm entry: occupancy computation + registry dispatch +
straight-through gradient.

The forward skips silent blocks; the backward uses the dense oracle
gradients (dL/dW = s^T g gated by the same occupancy is an *exact* identity,
since silent rows contribute zero — we exploit that: the dW matmul is also
event-gated, which is the paper's point that learning, too, is event-driven).

Block sizes: `bm`/`bk`/`bn` default to None, meaning the registry resolves
them (tuning cache, then the spec defaults 128/512/512); an explicit int
pins that axis for the call.

Two implementation channels share those blocks:

  * **dense** (the default pair): full (M/bm, N/bn, K/bk) grid, MXU work
    gated per block on the occupancy bitmap;
  * **sparse** (`sparse.py`): the grid iterates a compacted list of
    occupied blocks via scalar-prefetch index maps; off-TPU the gather
    ref does compute proportional to occupancy.

`_select_channel` routes between them at dispatch time: the
`REPRO_SPIKEMM_SPARSE=never|auto|always` env pins the choice; `auto` (the
default) measures the block-occupancy fraction when the raster is
concrete and goes sparse below the tuned threshold
(`sparse.tune_sparse_threshold`, cached per backend/shape bucket;
`_SPARSE_THRESHOLD_DEFAULT` on a cache miss). Tracers route dense: the
occupancy of an abstract raster is unknowable, and a wrong sparse guess
(capacity-padded grid) would cost rather than save.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import registry, tuning
from repro.kernels.common import pad_axis
from repro.kernels.spikemm.kernel import spikemm_pallas
from repro.kernels.spikemm.ref import spikemm_ref
from repro.kernels.spikemm.sparse import (compact_blocks,
                                          spikemm_sparse_pallas,
                                          spikemm_sparse_ref)

_ENV_SPARSE = "REPRO_SPIKEMM_SPARSE"
_SPARSE_THRESHOLD_DEFAULT = 0.25


@functools.partial(jax.jit, static_argnums=(1, 2))
def block_occupancy(spikes: jax.Array, bm: int, bk: int) -> jax.Array:
    """(M/bm, K/bk) int32: 1 where the spike block has any nonzero.

    Jitted (static block shape): eager callers — the dispatch router and
    the sparse ref channel measure occupancy on concrete rasters every
    call — must not pay op-by-op reduction cost on an M*K pass. The
    reduction runs contiguous-axis-first on booleans (any over bk, then
    over bm): a strided (bm, bk) max lowers ~6x slower on CPU."""
    M, K = spikes.shape
    nz = (spikes != 0).reshape(M, K // bk, bk).any(-1)
    return nz.reshape(M // bm, bm, K // bk).any(1).astype(jnp.int32)


def resolve_block_shape(M: int, K: int) -> dict:
    """The (bm, bk) the kernel actually skips with for an (M, K) raster:
    the spec's per-axis fit of the preferred sizes. (Cache-tuned overrides
    additionally need N; callers holding resolved blocks pass them
    directly.)"""
    spec = registry.get("spikemm")
    out = {}
    for ax in spec.block_axes:
        n = {"M": M, "K": K}.get(ax.dim)
        if n is not None:
            out[ax.name] = registry.fit_block(n, ax.preferred, ax.align)
    return out


def occupancy_fraction(spikes: jax.Array, bm: int = None, bk: int = None):
    """Fraction of blocks with events — the kernel's effective FLOP fraction.

    `bm`/`bk` default to the block shape dispatch resolves for this raster
    (NOT a fixed 512: for e.g. K=300 the kernel pads to bk=384 and skips
    384-wide blocks, and the reported fraction must match what is actually
    skipped). Callers that already hold the resolved blocks pass them."""
    if bm is None or bk is None:
        resolved = resolve_block_shape(*spikes.shape)
        bm = bm if bm is not None else resolved["bm"]
        bk = bk if bk is not None else resolved["bk"]
    s, _ = pad_axis(spikes, 0, bm)
    s, _ = pad_axis(s, 1, bk)
    f = block_occupancy(s, bm, bk)
    return jnp.mean(f.astype(jnp.float32))


def sparse_threshold(dims) -> float:
    """Occupancy fraction below which dispatch routes to the sparse channel.

    Tuned per (backend, shape bucket) by `sparse.tune_sparse_threshold`
    (stored as permille under kernel key "spikemm.sparse_th", seeded in the
    CI cache for the bench shapes); conservative default on a miss."""
    tuned = tuning.lookup_tuned("spikemm.sparse_th", dims)
    if tuned and "permille" in tuned:
        return tuned["permille"] / 1000.0
    return _SPARSE_THRESHOLD_DEFAULT


def _pallas_impl(spikes, w, *, blocks, interpret):
    M, K = spikes.shape
    N = w.shape[1]
    bm, bk, bn = blocks["bm"], blocks["bk"], blocks["bn"]
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p, _ = pad_axis(s_p, 1, bk)
    w_p, _ = pad_axis(w.astype(spikes.dtype), 0, bk)
    w_p, _ = pad_axis(w_p, 1, bn)
    flags = block_occupancy(s_p, bm, bk)
    out = spikemm_pallas(flags, s_p, w_p, bm=bm, bk=bk, bn=bn,
                         interpret=interpret)
    return out[:M, :N]


def _ref_impl(spikes, w):
    return spikemm_ref(spikes, w.astype(spikes.dtype))


def _sparse_ref_impl(spikes, w, *, blocks):
    bm, bk = blocks["bm"], blocks["bk"]
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p, _ = pad_axis(s_p, 1, bk)
    w_p, _ = pad_axis(w.astype(spikes.dtype), 0, bk)
    flags = block_occupancy(s_p, bm, bk)
    out = spikemm_sparse_ref(flags, s_p, w_p, bm=bm, bk=bk)
    return out[:spikes.shape[0], :w.shape[1]]


def _sparse_pallas_impl(spikes, w, *, blocks, interpret):
    M, K = spikes.shape
    N = w.shape[1]
    bm, bk, bn = blocks["bm"], blocks["bk"], blocks["bn"]
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p, _ = pad_axis(s_p, 1, bk)
    w_p, _ = pad_axis(w.astype(spikes.dtype), 0, bk)
    w_p, _ = pad_axis(w_p, 1, bn)
    flags = block_occupancy(s_p, bm, bk)
    ii, kk, act = compact_blocks(flags)
    out = spikemm_sparse_pallas(ii, kk, act, s_p, w_p, bm=bm, bk=bk, bn=bn,
                                interpret=interpret)
    return out[:M, :N]


def _select_channel(spikes, w, *, blocks):
    """Dispatch-time router: sparse below the tuned occupancy threshold."""
    mode = os.environ.get(_ENV_SPARSE, "auto")
    if mode not in ("never", "auto", "always"):
        raise ValueError(f"{_ENV_SPARSE}={mode!r}: "
                         "expected 'never', 'auto', or 'always'")
    if mode == "never":
        return None
    if mode == "always":
        return "sparse"
    if isinstance(spikes, jax.core.Tracer):
        return None                  # abstract raster: occupancy unknowable
    occ = float(occupancy_fraction(spikes, blocks["bm"], blocks["bk"]))
    dims = {"M": spikes.shape[0], "K": spikes.shape[1], "N": w.shape[1]}
    return "sparse" if occ <= sparse_threshold(dims) else None


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def spikemm(spikes: jax.Array, w: jax.Array, bm: int = None, bk: int = None,
            bn: int = None, force_pallas: bool = False) -> jax.Array:
    """Event-gated spikes @ w. spikes: (M, K) 0/1; w: (K, N)."""
    return _impl(spikes, w, bm, bk, bn, force_pallas)


def _impl(spikes, w, bm, bk, bn, force_pallas):
    overrides = {k: v for k, v in (("bm", bm), ("bk", bk), ("bn", bn))
                 if v is not None}
    return registry.dispatch("spikemm", (spikes, w),
                             force_pallas=force_pallas, overrides=overrides)


def _fwd(spikes, w, bm, bk, bn, force_pallas):
    return _impl(spikes, w, bm, bk, bn, force_pallas), (spikes, w)


def _bwd(bm, bk, bn, force_pallas, res, g):
    spikes, w = res
    # dL/dspikes = g @ w^T (dense: spike cotangents feed the surrogate);
    # dL/dw = spikes^T @ g — event-gated with the SAME occupancy (exact).
    g_spikes = jnp.dot(g, w.T.astype(g.dtype),
                       preferred_element_type=jnp.float32).astype(spikes.dtype)
    g_w = _impl(spikes.T, g, bm, bk, bn, force_pallas).astype(w.dtype)
    return g_spikes, g_w


spikemm.defvjp(_fwd, _bwd)


def _make_inputs(key):
    k1, k2 = jax.random.split(key)
    M, K, N = 100, 300, 200                   # non-multiples exercise padding
    spikes = (jax.random.uniform(k1, (M, K)) < 0.13).astype(jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    return spikes, w


registry.register(registry.KernelSpec(
    name="spikemm",
    ref=_ref_impl,
    pallas=_pallas_impl,
    apply=lambda args, force=False: spikemm(*args, None, None, None, force),
    block_axes=(registry.BlockAxis("bm", "M", preferred=128, align=8),
                registry.BlockAxis("bk", "K", preferred=512, align=128),
                registry.BlockAxis("bn", "N", preferred=512, align=128)),
    dims_of=lambda spikes, w: {"M": spikes.shape[0], "K": spikes.shape[1],
                               "N": w.shape[1]},
    candidates=({"bm": 128, "bk": 256}, {"bm": 128, "bk": 512},
                {"bm": 256, "bk": 512}, {"bm": 128, "bk": 512, "bn": 256}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1),
    tol=1e-4,
    # spike + weight blocks in, out block + fp32 accumulator
    vmem_bytes=lambda dims, b: 4 * (b["bm"] * b["bk"] + b["bk"] * b["bn"]
                                    + 2 * b["bm"] * b["bn"]),
    # the K axis is a reduction: it never appears in the output tiling
    tile_model=registry.TileModel(
        out=(("M", "bm"), ("N", "bn")),
        tiles=lambda dims, b: {
            "spikes": (b["bm"], b["bk"]), "w": (b["bk"], b["bn"]),
            "acc": (b["bm"], b["bn"]), "out": (b["bm"], b["bn"])}),
    channels={"sparse": registry.Channel(ref=_sparse_ref_impl,
                                         pallas=_sparse_pallas_impl)},
    select_channel=_select_channel,
))
