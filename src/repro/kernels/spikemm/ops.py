"""Public spikemm entry: occupancy computation + dispatch + straight-through
gradient.

The forward skips silent blocks; the backward uses the dense oracle
gradients (dL/dW = s^T g gated by the same occupancy is an *exact* identity,
since silent rows contribute zero — we exploit that: the dW matmul is also
event-gated, which is the paper's point that learning, too, is event-driven).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_mode, pad_axis
from repro.kernels.spikemm.kernel import spikemm_pallas
from repro.kernels.spikemm.ref import spikemm_ref


def block_occupancy(spikes: jax.Array, bm: int, bk: int) -> jax.Array:
    """(M/bm, K/bk) int32: 1 where the spike block has any nonzero."""
    M, K = spikes.shape
    blk = spikes.reshape(M // bm, bm, K // bk, bk)
    return (jnp.max(jnp.abs(blk), axis=(1, 3)) > 0).astype(jnp.int32)


def occupancy_fraction(spikes: jax.Array, bm: int = 128, bk: int = 512):
    """Fraction of blocks with events — the kernel's effective FLOP fraction."""
    s, _ = pad_axis(spikes, 0, bm)
    s, _ = pad_axis(s, 1, bk)
    f = block_occupancy(s, bm, bk)
    return jnp.mean(f.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def spikemm(spikes: jax.Array, w: jax.Array, bm: int = 128, bk: int = 512,
            bn: int = 512, force_pallas: bool = False) -> jax.Array:
    """Event-gated spikes @ w. spikes: (M, K) 0/1; w: (K, N)."""
    return _impl(spikes, w, bm, bk, bn, force_pallas)


def _impl(spikes, w, bm, bk, bn, force_pallas):
    if not force_pallas:
        return spikemm_ref(spikes, w.astype(spikes.dtype))
    M, K = spikes.shape
    N = w.shape[1]
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p, _ = pad_axis(s_p, 1, bk)
    w_p, _ = pad_axis(w.astype(spikes.dtype), 0, bk)
    w_p, _ = pad_axis(w_p, 1, bn)
    flags = block_occupancy(s_p, bm, bk)
    out = spikemm_pallas(flags, s_p, w_p, bm=bm, bk=bk, bn=bn,
                         interpret=interpret_mode())
    return out[:M, :N]


def _fwd(spikes, w, bm, bk, bn, force_pallas):
    return _impl(spikes, w, bm, bk, bn, force_pallas), (spikes, w)


def _bwd(bm, bk, bn, force_pallas, res, g):
    spikes, w = res
    # dL/dspikes = g @ w^T (dense: spike cotangents feed the surrogate);
    # dL/dw = spikes^T @ g — event-gated with the SAME occupancy (exact).
    g_spikes = jnp.dot(g, w.T.astype(g.dtype),
                       preferred_element_type=jnp.float32).astype(spikes.dtype)
    g_w = _impl(spikes.T, g, bm, bk, bn, force_pallas).astype(w.dtype)
    return g_spikes, g_w


spikemm.defvjp(_fwd, _bwd)
