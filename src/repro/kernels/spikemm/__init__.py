from repro.kernels.spikemm.ops import spikemm, block_occupancy
from repro.kernels.spikemm.ref import spikemm_ref

__all__ = ["spikemm", "block_occupancy", "spikemm_ref"]
