from repro.kernels.spikemm.ops import spikemm, block_occupancy
from repro.kernels.spikemm.ref import spikemm_ref
from repro.kernels.spikemm.gather import (GatherTables, build_gather_tables,
                                          spikemm_gather)

__all__ = ["spikemm", "block_occupancy", "spikemm_ref",
           "GatherTables", "build_gather_tables", "spikemm_gather"]
