"""Event-gated block-sparse spike matmul (FINDIDX + LOCACC on TPU).

TaiBai skips computation for silent neurons at word granularity via the
event-driven NoC. The MXU's granularity is a 128x128 tile, so the TPU-native
translation is: partition the spike matrix into (bm x bk) blocks, precompute
a per-block occupancy bitmap (the FINDIDX bitmap, lifted to block level),
and skip the matmul + accumulation for blocks with no events. At the paper's
measured spike rates (1.2-13 %, §V) most K-blocks of a well-laid-out spike
matrix are silent, so the MXU executes a fraction of the dense FLOPs.

grid = (M/bm, N/bn, K/bk), K innermost; fp32 VMEM scratch accumulates across
K. The occupancy flag is prefetched as a (1,1) block; `@pl.when` gates BOTH
the weight load (no HBM->VMEM stream for dead blocks under Mosaic's lazy
block fetch) and the MXU op.

VMEM per step (defaults bm=128, bk=512, bn=512, bf16 in / fp32 acc):
  spikes 128*512*2 = 128 KiB, w 512*512*2 = 512 KiB, acc 128*512*4 = 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spikemm_kernel(flags_ref, s_ref, w_ref, o_ref, acc_scr):
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(flags_ref[0, 0] > 0)
    def _():
        s_blk = s_ref[...]
        w_blk = w_ref[...]
        acc_scr[...] += jax.lax.dot_general(
            s_blk, w_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def spikemm_pallas(flags: jax.Array, spikes: jax.Array, w: jax.Array, *,
                   bm: int = 128, bk: int = 512, bn: int = 512,
                   interpret: bool = False) -> jax.Array:
    """flags: (M/bm, K/bk) int32 block occupancy; spikes: (M, K); w: (K, N)."""
    M, K = spikes.shape
    N = w.shape[1]
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    grid = (M // bm, N // bn, K // bk)

    return pl.pallas_call(
        _spikemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),    # flags
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # spikes
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # weights
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), spikes.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(flags, spikes, w)
