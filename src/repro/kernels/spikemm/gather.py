"""Block-gather spikemm channel: execute IE tables, not dense matrices.

`core/topology.py` stores connectivity the way the chip does — typed fan-in
IE tables (sparse pairs, FINDIDX bitmaps, conv axon arithmetic). This module
is their execution form: the (pre, post, weight) triples an `EncodedTopology`
derives from its IE tables are packed ONCE into a block-level COO —

    jj[t], kk[t]   post-/pre- block coordinates of occupied (bk, bn) blocks,
                   sorted post-block-major (the accumulation order),
    wblk[t]        the (bk, bn) dense patch of weights inside that block,
    act[t]         0 marks sentinels (one per empty post block, so every
                   output tile is visited and initialized exactly once)

— and `spikemm_gather` contracts an (M, n_pre) spike raster against those
tables. Compute scales with the number of *occupied* blocks E, never with
n_pre * n_post: the dense matrix is never materialized, which is what makes
10^5-10^6-neuron topologies executable at all.

Two implementations, registered as the `spikemm_gather` family so dispatch,
parity, autotuning, and incident fallbacks come from the registry:

  * the Pallas kernel scalar-prefetches (jj, kk, act) — the IE tables ARE
    the index maps — over a grid (M/bm, E), accumulating consecutive
    same-jj entries in a VMEM scratch tile exactly like the block-sparse
    spikemm channel;
  * the XLA ref scans entry slabs: gather the spike block each entry names,
    one batched (bk x bn) matmul per slab, scatter-add into the output by
    post block. On CPU this is what converts table sparsity into wall-clock.

The VJP needs no weight cotangent (topology weights are host-side tables,
not trainable params): d_spikes runs the SAME kernel on the transposed
tables, so the backward pass is as event-bounded as the forward.
"""

from __future__ import annotations

import dataclasses
import functools
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry
from repro.kernels.common import pad_axis

DEFAULT_BK = 128
DEFAULT_BN = 128

_REF_SLAB = 128   # entries contracted per scan step in the XLA ref


@dataclasses.dataclass(eq=False)
class GatherTables:
    """Packed block-level COO for one encoded topology (host-side numpy).

    Identity-hashed (eq=False): instances ride through jit/custom_vjp as
    static values and through pytrees as leafless containers, so the jj/kk
    index maps become embedded constants — exactly how the chip's IE tables
    are configuration, not data.
    """

    jj: np.ndarray        # (E,) int32 post-block ids, non-decreasing
    kk: np.ndarray        # (E,) int32 pre-block ids
    act: np.ndarray       # (E,) int32, 0 = sentinel (empty post block)
    wblk: np.ndarray      # (E, bk, bn) float32 packed weight blocks
    n_pre: int
    n_post: int
    bk: int
    bn: int

    def __post_init__(self):
        self._device = None
        self._transposed = None

    @property
    def n_entries(self) -> int:
        return int(self.act.sum())

    def device(self):
        """Memoized device copies of the tables."""
        if self._device is None:
            self._device = SimpleNamespace(
                jj=jnp.asarray(self.jj), kk=jnp.asarray(self.kk),
                act=jnp.asarray(self.act), wblk=jnp.asarray(self.wblk))
        return self._device

    def transpose(self) -> "GatherTables":
        """Tables for x @ W^T: swap block roles, transpose each patch."""
        if self._transposed is None:
            real = self.act != 0
            self._transposed = _finalize_tables(
                self.kk[real], self.jj[real],
                self.wblk[real].transpose(0, 2, 1),
                n_pre=self.n_post, n_post=self.n_pre,
                bk=self.bn, bn=self.bk)
            self._transposed._transposed = self
        return self._transposed


def _tables_flatten(t):
    return (), t


def _tables_unflatten(aux, children):
    del children
    return aux


jax.tree_util.register_pytree_node(GatherTables, _tables_flatten,
                                   _tables_unflatten)


def _finalize_tables(jj, kk, wblk, *, n_pre, n_post, bk, bn) -> GatherTables:
    """Sort entries post-block-major and add one inactive sentinel per empty
    post block so the kernel visits (and zero-initializes) every output
    tile."""
    jj = np.asarray(jj, np.int32)
    kk = np.asarray(kk, np.int32)
    wblk = np.asarray(wblk, np.float32).reshape(-1, bk, bn)
    act = np.ones(len(jj), np.int32)
    n_post_blocks = max(1, -(-n_post // bn))
    missing = np.setdiff1d(np.arange(n_post_blocks, dtype=np.int32),
                           np.unique(jj))
    if len(missing):
        jj = np.concatenate([jj, missing])
        kk = np.concatenate([kk, np.zeros(len(missing), np.int32)])
        act = np.concatenate([act, np.zeros(len(missing), np.int32)])
        wblk = np.concatenate(
            [wblk, np.zeros((len(missing), bk, bn), np.float32)])
    order = np.lexsort((kk, jj))
    return GatherTables(jj=np.ascontiguousarray(jj[order]),
                        kk=np.ascontiguousarray(kk[order]),
                        act=np.ascontiguousarray(act[order]),
                        wblk=np.ascontiguousarray(wblk[order]),
                        n_pre=int(n_pre), n_post=int(n_post),
                        bk=int(bk), bn=int(bn))


def build_gather_tables(pre, post, w, n_pre: int, n_post: int, *,
                        bk: int = DEFAULT_BK, bn: int = DEFAULT_BN
                        ) -> GatherTables:
    """Pack (pre, post, weight) COO triples into block tables.

    Duplicated (pre, post) pairs accumulate into the same block slot,
    matching the event-driven `propagate()` semantics. Out-of-range indices
    raise — ghost IE entries must never silently scatter.
    """
    pre = np.asarray(pre, np.int64).ravel()
    post = np.asarray(post, np.int64).ravel()
    w = np.asarray(w, np.float32).ravel()
    if not (len(pre) == len(post) == len(w)):
        raise ValueError("pre/post/weight lengths differ")
    if len(pre):
        if pre.min() < 0 or pre.max() >= n_pre:
            raise ValueError(f"ghost pre index outside [0, {n_pre})")
        if post.min() < 0 or post.max() >= n_post:
            raise ValueError(f"ghost post index outside [0, {n_post})")
    n_pre_blocks = max(1, -(-n_pre // bk))
    bid = (post // bn) * n_pre_blocks + (pre // bk)
    uniq = np.unique(bid)
    wblk = np.zeros((len(uniq), bk, bn), np.float32)
    if len(pre):
        rank = np.searchsorted(uniq, bid)
        np.add.at(wblk, (rank, pre % bk, post % bn), w)
    jj = (uniq // n_pre_blocks).astype(np.int32)
    kk = (uniq % n_pre_blocks).astype(np.int32)
    return _finalize_tables(jj, kk, wblk, n_pre=n_pre, n_post=n_post,
                            bk=bk, bn=bn)


# ---------------------------------------------------------------------------
# Pallas kernel: IE tables as scalar-prefetched index maps
# ---------------------------------------------------------------------------


def _gather_kernel(jj_ref, kk_ref, act_ref, s_ref, w_ref, o_ref, acc_scr):
    del kk_ref  # consumed by the index maps only
    t = pl.program_id(1)
    prev = jj_ref[jnp.maximum(t - 1, 0)]

    @pl.when((t == 0) | (jj_ref[t] != prev))
    def _():                                  # first entry for this post block
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(act_ref[t] > 0)
    def _():                                  # sentinels skip the MXU
        acc_scr[...] += jax.lax.dot_general(
            s_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # Same-jj entries are contiguous, so consecutive writes land in the same
    # VMEM-resident output tile; Mosaic flushes it once per (i, jj).
    o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "jb", "interpret"))
def _gather_pallas(jj, kk, act, spikes, wblk, *, bm, bk, bn, jb,
                   interpret=False):
    """spikes: (M, Kb*bk) padded; wblk: (E, bk, bn); out: (M, jb*bn)."""
    M = spikes.shape[0]
    grid = (M // bm, jj.shape[0])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, t, jj, kk, act: (i, kk[t])),
            pl.BlockSpec((1, bk, bn), lambda i, t, jj, kk, act: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, t, jj, kk, act: (i, jj[t])),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, jb * bn), spikes.dtype),
        interpret=interpret,
    )(jj, kk, act, spikes, wblk)


def _pallas_impl(spikes, tables, *, blocks, interpret):
    bm = blocks["bm"]
    bk, bn = tables.bk, tables.bn
    kb = max(1, -(-tables.n_pre // bk))
    jb = max(1, -(-tables.n_post // bn))
    s_p, _ = pad_axis(spikes, 0, bm)
    s_p = jnp.pad(s_p, ((0, 0), (0, kb * bk - spikes.shape[1])))
    dt = tables.device()
    out = _gather_pallas(dt.jj, dt.kk, dt.act, s_p, dt.wblk,
                         bm=bm, bk=bk, bn=bn, jb=jb, interpret=interpret)
    return out[:spikes.shape[0], :tables.n_post]


# ---------------------------------------------------------------------------
# XLA reference: slab-scanned gather + scatter-add (compute ∝ E)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_post", "jb", "bk", "bn"))
def _ref_scan(spikes, jj, kk, wblk, *, n_post, jb, bk, bn):
    M = spikes.shape[0]
    sb = spikes.reshape(M, spikes.shape[1] // bk, bk)
    n_slabs = wblk.shape[0] // _REF_SLAB
    slabs = (jj.reshape(n_slabs, _REF_SLAB),
             kk.reshape(n_slabs, _REF_SLAB),
             wblk.reshape(n_slabs, _REF_SLAB, bk, bn))

    def body(out, sl):
        jj_s, kk_s, w_s = sl
        s_sel = jnp.take(sb, kk_s, axis=1)            # (M, C, bk)
        prod = jnp.einsum("mck,ckn->cmn", s_sel, w_s,
                          preferred_element_type=jnp.float32)
        return out.at[jj_s].add(prod), None

    out0 = jnp.zeros((jb, M, bn), jnp.float32)
    out, _ = jax.lax.scan(body, out0, slabs)
    return (out.transpose(1, 0, 2).reshape(M, jb * bn)[:, :n_post]
            .astype(spikes.dtype))


def _ref_impl(spikes, tables):
    bk, bn = tables.bk, tables.bn
    kb = max(1, -(-tables.n_pre // bk))
    jb = max(1, -(-tables.n_post // bn))
    s_p = jnp.pad(spikes, ((0, 0), (0, kb * bk - spikes.shape[1])))
    dt = tables.device()
    pad = -len(tables.jj) % _REF_SLAB
    jj = jnp.pad(dt.jj, (0, pad))                     # padded slots carry
    kk = jnp.pad(dt.kk, (0, pad))                     # zero wblk: no effect
    wblk = jnp.pad(dt.wblk, ((0, pad), (0, 0), (0, 0)))
    return _ref_scan(s_p, jj, kk, wblk, n_post=tables.n_post, jb=jb,
                     bk=bk, bn=bn)


# ---------------------------------------------------------------------------
# public entry + VJP + registration
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def spikemm_gather(spikes: jax.Array, tables: GatherTables,
                   bm: Optional[int] = None,
                   force_pallas: bool = False) -> jax.Array:
    """IE-table contraction: (M, n_pre) spikes -> (M, n_post) currents."""
    return _impl(spikes, tables, bm, force_pallas)


def _impl(spikes, tables, bm, force_pallas):
    overrides = {"bm": bm} if bm is not None else {}
    return registry.dispatch("spikemm_gather", (spikes, tables),
                             force_pallas=force_pallas, overrides=overrides)


def _fwd(spikes, tables, bm, force_pallas):
    return _impl(spikes, tables, bm, force_pallas), None


def _bwd(tables, bm, force_pallas, _res, g):
    # d_spikes = g @ W^T: the same gather kernel on the transposed tables —
    # the backward pass touches exactly the occupied blocks too. Weight
    # cotangents don't exist: topology weights are tables, not params.
    return (_impl(g, tables.transpose(), bm, force_pallas).astype(g.dtype),)


spikemm_gather.defvjp(_fwd, _bwd)


def _make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    m, n_pre, n_post = 96, 260, 200               # non-multiples: padding
    mask = np.asarray(jax.random.uniform(k1, (n_pre, n_post)) < 0.05)
    pre, post = np.nonzero(mask)
    w = np.asarray(jax.random.normal(k2, (len(pre),), jnp.float32))
    tables = build_gather_tables(pre, post, w, n_pre, n_post)
    spikes = (jax.random.uniform(k3, (m, n_pre)) < 0.3).astype(jnp.float32)
    return spikes, tables


registry.register(registry.KernelSpec(
    name="spikemm_gather",
    ref=_ref_impl,
    pallas=_pallas_impl,
    apply=lambda args, force=False: spikemm_gather(*args, None, force),
    # bk/bn are frozen at table-build time (they shape wblk); only the
    # spike-row tile is dispatch-tunable.
    block_axes=(registry.BlockAxis("bm", "M", preferred=128, align=8),),
    dims_of=lambda spikes, tables: {
        "M": spikes.shape[0], "K": tables.n_pre, "N": tables.n_post,
        "E": len(tables.jj), "bk": tables.bk, "bn": tables.bn},
    candidates=({"bm": 64}, {"bm": 128}, {"bm": 256}),
    make_inputs=_make_inputs,
    diff_argnums=(0,),
    tol=1e-4,
    # spike block + weight block in, out tile + fp32 accumulator
    vmem_bytes=lambda dims, b: 4 * (b["bm"] * dims["bk"]
                                    + dims["bk"] * dims["bn"]
                                    + 2 * b["bm"] * dims["bn"]),
    # Per row-block sweep the sorted entry list covers every post block
    # (sentinels included), i.e. the full N extent exactly once.
    tile_model=registry.TileModel(
        out=(("M", "bm"), ("N", None)),
        tiles=lambda dims, b: {
            "spikes": (b["bm"], dims["bk"]), "wblk": (dims["bk"], dims["bn"]),
            "acc": (b["bm"], dims["bn"]), "out": (b["bm"], dims["bn"])}),
))


__all__ = ["GatherTables", "build_gather_tables", "spikemm_gather",
           "DEFAULT_BK", "DEFAULT_BN"]
