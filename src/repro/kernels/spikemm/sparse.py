"""Block-sparse spikemm channel: visit ONLY the occupied MXU blocks.

The dense kernel (`kernel.py`) already *gates* the MXU op on the per-block
occupancy bitmap, but its grid still iterates every (M/bm, N/bn, K/bk)
step: silent blocks cost a grid iteration and, off the `@pl.when` fast
path, a spike-block DMA. This module goes the rest of the way — the
paper's event-driven claim is that silent work is never *issued*:

  1. `compact_blocks` turns the (M/bm, K/bk) occupancy bitmap into a
     row-major compacted list of occupied (i, k) block coordinates.
  2. `spikemm_sparse_pallas` launches a grid over (N/bn, n_selected) —
     the compacted list, not the dense block lattice — using a
     scalar-prefetch index map (`pltpu.PrefetchScalarGridSpec`) so Mosaic
     streams exactly the occupied spike/weight blocks and accumulates
     into the output tile across consecutive same-row entries.
  3. `spikemm_sparse_ref` is the XLA twin: drop silent block-rows and
     block-columns, one dense matmul over the occupied slab, scatter the
     row blocks back. On CPU this is what converts low occupancy into
     wall-clock (compute scales with the occupied slab, not M*K), so the
     efficiency claim is measurable off-TPU too.

Compaction subtleties (both paths share `compact_blocks`):

  * Every row block contributes at least one entry — silent rows get a
    single *inactive* sentinel — so the kernel's output-revisit
    accounting initializes and writes every output block exactly once
    per (row, j); no aliased zero-init of the output is needed.
  * When `flags` is a tracer (sparse channel forced under jit), the
    entry count is data-dependent, so the list is padded to the static
    Mb*Kb capacity. Padding replicates the *last* row's block
    coordinates, inactive: the out-block index never moves after the
    last real entry, so padded steps neither thrash DMA nor write back
    a stale tile. Correctness is preserved; the grid shrink (and hence
    the speedup) needs concrete occupancy, which eager dispatch has.

The density threshold that routes spikemm here lives in the tuning cache
(`tune_sparse_threshold` times dense-vs-sparse on a density ladder and
persists the crossover under kernel key "spikemm.sparse_th"), so the
policy is autotuned per (backend, shape bucket) like block sizes are.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def compact_blocks(flags: jax.Array,
                   size: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compact an occupancy bitmap into (idx_i, idx_k, active) lists.

    flags: (Mb, Kb) int; returns three (n,) int32 arrays sorted by row
    block. `active[t] == 0` marks sentinel entries (one per silent row so
    every output block is visited) and capacity padding (traced path) —
    the kernel skips their MXU work. Padded entries point at the last
    row's block so the output tile never revisits an already-flushed
    block.
    """
    Mb, Kb = flags.shape
    occ = flags != 0
    # one sentinel column flagging rows with no occupied block
    aug = jnp.concatenate([occ, ~jnp.any(occ, axis=1, keepdims=True)], axis=1)
    if size is None:
        if isinstance(aug, jax.core.Tracer):
            size = Mb * Kb          # nnz + sentinels <= Mb*Kb (each row <= Kb)
        else:
            size = int(jnp.sum(aug))
    ii, cc = jnp.nonzero(aug, size=size, fill_value=(Mb - 1, Kb))
    active = cc < Kb
    kk = jnp.where(active, cc, 0)
    return (ii.astype(jnp.int32), kk.astype(jnp.int32),
            active.astype(jnp.int32))


def _sparse_kernel(ii_ref, kk_ref, act_ref, s_ref, w_ref, o_ref, acc_scr):
    del kk_ref  # consumed by the index maps only
    t = pl.program_id(1)
    prev_i = ii_ref[jnp.maximum(t - 1, 0)]

    @pl.when((t == 0) | (ii_ref[t] != prev_i))
    def _():                                   # first entry for this row block
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(act_ref[t] > 0)
    def _():                                   # sentinels/padding skip the MXU
        acc_scr[...] += jax.lax.dot_general(
            s_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # Same-row entries are contiguous, so consecutive writes land in the
    # same VMEM-resident output block; Mosaic flushes it once per (row, j).
    o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def spikemm_sparse_pallas(idx_i: jax.Array, idx_k: jax.Array,
                          active: jax.Array, spikes: jax.Array, w: jax.Array,
                          *, bm: int = 128, bk: int = 512, bn: int = 512,
                          interpret: bool = False) -> jax.Array:
    """Gather-style spikemm over the compacted block list.

    idx_i/idx_k/active: (n,) int32 from `compact_blocks`; spikes: (M, K);
    w: (K, N); all dims divisible by their block size. grid = (N/bn, n)
    with the compacted list innermost — the scalar-prefetch index maps
    pull block (idx_i[t], idx_k[t]) instead of walking the dense lattice.
    """
    M, K = spikes.shape
    N = w.shape[1]
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    grid = (N // bn, idx_i.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, t, ii, kk, act: (ii[t], kk[t])),
            pl.BlockSpec((bk, bn), lambda j, t, ii, kk, act: (kk[t], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, t, ii, kk, act: (ii[t], j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _sparse_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), spikes.dtype),
        interpret=interpret,
    )(idx_i, idx_k, active, spikes, w)


@jax.jit
def _rowcol_any(flags: jax.Array) -> Tuple[jax.Array, jax.Array]:
    occ = flags != 0
    return jnp.any(occ, axis=1), jnp.any(occ, axis=0)


def _pad_count(n: int) -> int:
    """Round a selection count up the {1, 1.5} * 2^k ladder (1, 2, 3, 4,
    6, 8, 12, ...): recompiles stay logarithmic in the raster shape while
    padding waste stays <= 33% (a pure pow2 ladder wastes up to 2x)."""
    p = 1 << (max(1, n) - 1).bit_length()
    if n <= (p // 4) * 3:
        return (p // 4) * 3
    return p


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def _slab_matmul(spikes: jax.Array, w: jax.Array, ridx: jax.Array,
                 cidx: jax.Array, *, bm: int, bk: int) -> jax.Array:
    """Occupied-slab matmul: gather the selected block-rows/-columns, one
    dense matmul over the compacted slab, scatter the rows back. Sentinel
    indices (== Mb / Kb, out of range) gather zeros and scatter into a
    discarded overflow row, so pow2-padded index lists stay exact."""
    M, K = spikes.shape
    N = w.shape[1]
    Mb, Kb = M // bm, K // bk
    r, c = ridx.shape[0], cidx.shape[0]
    sb = spikes.reshape(Mb, bm, Kb, bk)
    s_sel = jnp.take(sb, ridx, axis=0, mode="fill", fill_value=0)
    s_sel = jnp.take(s_sel, cidx, axis=2, mode="fill", fill_value=0)
    w_sel = jnp.take(w.reshape(Kb, bk, N), cidx, axis=0, mode="fill",
                     fill_value=0)
    prod = jnp.dot(s_sel.reshape(r * bm, c * bk), w_sel.reshape(c * bk, N),
                   preferred_element_type=jnp.float32)
    out = jnp.zeros((Mb + 1, bm, N), jnp.float32)
    out = out.at[ridx].set(prod.reshape(r, bm, N))
    return out[:Mb].reshape(M, N).astype(spikes.dtype)


def spikemm_sparse_ref(flags: jax.Array, spikes: jax.Array, w: jax.Array, *,
                       bm: int, bk: int) -> jax.Array:
    """XLA twin of the sparse kernel: skip silent block-rows and -columns.

    flags: (M/bm, K/bk) occupancy; spikes: (M, K) with M, K divisible by
    bm, bk; w: (K, N), N unconstrained. XLA has no compacted-grid analogue
    of the Pallas kernel, so the gather happens at slab granularity: block
    rows/columns with no events anywhere are dropped before ONE dense
    matmul over the occupied slab — compute and bandwidth scale with the
    occupied fraction, which is what converts low occupancy into
    wall-clock on backends without the Mosaic kernel. Index lists are
    padded up a {1, 1.5} * 2^k ladder (sentinel entries gather zeros /
    scatter into a discarded row) so recompiles stay logarithmic in the
    raster shape.

    Needs concrete occupancy to shrink anything; under tracing it degrades
    to the dense oracle (same values, no skip) — the Pallas channel is the
    one that stays block-sparse under jit via capacity padding.
    """
    if isinstance(flags, jax.core.Tracer):
        return jnp.dot(spikes, w, preferred_element_type=jnp.float32
                       ).astype(spikes.dtype)
    Mb, Kb = flags.shape
    row_any, col_any = _rowcol_any(flags)
    rows = jnp.nonzero(row_any)[0]
    cols = jnp.nonzero(col_any)[0]
    if rows.shape[0] == 0:
        return jnp.zeros((spikes.shape[0], w.shape[1]), spikes.dtype)
    ridx = jnp.full((_pad_count(rows.shape[0]),), Mb, jnp.int32
                    ).at[:rows.shape[0]].set(rows.astype(jnp.int32))
    cidx = jnp.full((_pad_count(cols.shape[0]),), Kb, jnp.int32
                    ).at[:cols.shape[0]].set(cols.astype(jnp.int32))
    return _slab_matmul(spikes, w, ridx, cidx, bm=bm, bk=bk)


# ---------------------------------------------------------------------------
# threshold autotuning: where does sparse stop paying?
# ---------------------------------------------------------------------------


def tune_sparse_threshold(M: int, K: int, N: int, *,
                          densities: Tuple[float, ...] = (
                              0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 0.75),
                          repeats: int = 3, cache=None, save: bool = True,
                          key: Optional[jax.Array] = None):
    """Time dense vs sparse dispatch on a block-occupancy ladder and persist
    the crossover occupancy (as permille) to the tuning cache under kernel
    key "spikemm.sparse_th", bucketed like block configs. The dispatch
    policy (`ops._select_channel`) looks it up per shape; a miss falls back
    to the conservative default.

    Returns (threshold fraction, report). Rasters are population-packed
    (active corner), the layout the mapping pass produces and the only one
    where word sparsity survives to block granularity.
    """
    import time

    from repro.kernels import registry, tuning

    spec = registry.get("spikemm")
    key = jax.random.PRNGKey(0) if key is None else key
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    dims = {"M": M, "K": K, "N": N}
    blocks = spec.resolve_blocks(dims, use_cache=False)

    def timed(fn, reps):
        fn().block_until_ready()                         # warm/compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn().block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    use_pallas = registry.use_pallas()
    interpret = registry.interpret_mode()

    def dense(s):
        if use_pallas:
            return spec.pallas(s, w, blocks=blocks, interpret=interpret)
        return spec.ref(s, w)

    def sparse(s):
        ch = spec.channels["sparse"]
        if use_pallas:
            return ch.pallas(s, w, blocks=blocks, interpret=interpret)
        return ch.ref(s, w, blocks=blocks)

    report = {"dims": dims, "blocks": blocks, "ladder": []}
    threshold = 0.0
    for d in densities:
        s = _packed_raster(key, M, K, d)
        occ = _occupancy(s, blocks["bm"], blocks["bk"])
        t_dense = timed(lambda: dense(s), repeats)
        t_sparse = timed(lambda: sparse(s), repeats)
        win = t_dense / max(t_sparse, 1e-12)
        report["ladder"].append({"density": d, "occupancy": occ,
                                 "dense_s": t_dense, "sparse_s": t_sparse,
                                 "speedup_x": win})
        if win >= 1.0:
            threshold = max(threshold, occ)
    report["threshold"] = threshold
    if cache is None:
        cache = tuning.default_cache()
    cache.put("spikemm.sparse_th", jax.default_backend(),
              tuning.shape_bucket(dims),
              {"permille": int(round(1000 * threshold))},
              stats={"ladder_points": len(densities)})
    if save:
        cache.save()
    return threshold, report


def _packed_raster(key, M: int, K: int, density: float,
                   rate: float = 0.5) -> jax.Array:
    """Population-packed spike raster at a target word density: activity
    fills a dense corner (the mapping pass's channel-order packing), so
    block occupancy tracks density instead of being defeated by it."""
    f = min(1.0, float(density / rate) ** 0.5)
    m_act, k_act = max(1, int(M * f)), max(1, int(K * f))
    body = (jax.random.uniform(key, (m_act, k_act)) < rate
            ).astype(jnp.float32)
    return jnp.zeros((M, K), jnp.float32).at[:m_act, :k_act].set(body)


def _occupancy(s, bm: int, bk: int) -> float:
    from repro.kernels.spikemm.ops import occupancy_fraction

    return float(occupancy_fraction(s, bm, bk))


__all__ = ["compact_blocks", "spikemm_sparse_pallas", "spikemm_sparse_ref",
           "tune_sparse_threshold"]
