"""Oracle for event-driven current accumulation: plain dense matmul.

The event-driven semantics (only firing neurons contribute) is exactly what
a dense matmul with 0/1 spikes computes; the kernel's value is *skipping*
the silent blocks, which must not change the result.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spikemm_ref(spikes: jax.Array, w: jax.Array) -> jax.Array:
    """spikes: (M, K) 0/1 (any float dtype); w: (K, N). fp32 accumulate."""
    return jnp.dot(spikes, w, preferred_element_type=jnp.float32
                   ).astype(spikes.dtype)
