from repro.kernels.stdp.ops import stdp_seq, stdp_update
