from repro.kernels.stdp.ops import stdp_update
