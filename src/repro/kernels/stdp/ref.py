"""Oracle for the fused STDP update — the einsum form of
core/plasticity.stdp_step's weight half."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stdp_update_ref(x_pre, s_post, s_pre, x_post, w, *,
                    a_plus, a_minus, w_min, w_max):
    dw_pot = a_plus * jnp.einsum("bi,bj->ij", x_pre.astype(jnp.float32),
                                 s_post.astype(jnp.float32))
    dw_dep = a_minus * jnp.einsum("bi,bj->ij", s_pre.astype(jnp.float32),
                                  x_post.astype(jnp.float32))
    return jnp.clip(w.astype(jnp.float32) + dw_pot - dw_dep,
                    w_min, w_max).astype(w.dtype)
