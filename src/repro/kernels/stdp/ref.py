"""Oracles for the STDP family.

`stdp_update_ref` — one step of the classic pair rule given precomputed
traces (the einsum form of core/plasticity.stdp_step's weight half).

`stdp_seq_ref` — the generalized multi-step form the plan compiler lowers
`SynapseProgram`s to: K signed outer-product term planes applied serially
over T steps with a per-step clip (the clip makes the recurrence
non-associative, hence the scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stdp_update_ref(x_pre, s_post, s_pre, x_post, w, *,
                    a_plus, a_minus, w_min, w_max):
    dw_pot = a_plus * jnp.einsum("bi,bj->ij", x_pre.astype(jnp.float32),
                                 s_post.astype(jnp.float32))
    dw_dep = a_minus * jnp.einsum("bi,bj->ij", s_pre.astype(jnp.float32),
                                  x_post.astype(jnp.float32))
    return jnp.clip(w.astype(jnp.float32) + dw_pot - dw_dep,
                    w_min, w_max).astype(w.dtype)


def stdp_seq_ref(P, Q, w, *, amps, w_min, w_max, batch):
    """P: (K, T*B, M) pre-side term planes; Q: (K, T*B, N) post-side planes;
    w: (M, N). Per step t: w <- clip(w + sum_k amps[k] * P_k_t^T @ Q_k_t)."""
    K, TB, M = P.shape
    T = TB // batch
    amps_a = jnp.asarray(amps, jnp.float32)
    Pt = P.reshape(K, T, batch, M).transpose(1, 0, 2, 3).astype(jnp.float32)
    Qt = Q.reshape(K, T, batch, -1).transpose(1, 0, 2, 3).astype(jnp.float32)

    def body(w, pq):
        p, q = pq                                  # (K, B, M), (K, B, N)
        dw = jnp.einsum("k,kbi,kbj->ij", amps_a, p, q)
        return jnp.clip(w + dw, w_min, w_max), None

    wT, _ = jax.lax.scan(body, w.astype(jnp.float32), (Pt, Qt))
    return wT.astype(w.dtype)
