"""Fused STDP weight-update Pallas kernel (the paper's on-chip learning in
one pass over the weight tile).

One STDP step over a batch of B parallel synapse-update events:

    dw = a_plus * x_pre^T @ s_post  -  a_minus * s_pre^T @ x_post
    w' = clip(w + dw, w_min, w_max)

Both outer products are MXU matmuls with the BATCH as the contraction dim;
the clip and accumulate fuse into the same VMEM tile visit, so the weight
matrix streams HBM->VMEM->HBM exactly once per step (on chip, this is the
FIRE-stage weight update touching each synapse once — §III-B).

grid = (N_pre/bm, N_post/bn); B (the contraction) is kept whole per tile —
STDP batches are small (events of one timestep), so B<=512 fits VMEM:
tiles at defaults (bm=bn=256, B=256, f32): x_pre 256 KiB, s_post 256 KiB,
w 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stdp_kernel(xpre_ref, spost_ref, spre_ref, xpost_ref, w_ref, out_ref, *,
                 a_plus: float, a_minus: float, w_min: float, w_max: float):
    xpre = xpre_ref[...].astype(jnp.float32)      # (B, bm)
    spost = spost_ref[...].astype(jnp.float32)    # (B, bn)
    spre = spre_ref[...].astype(jnp.float32)      # (B, bm)
    xpost = xpost_ref[...].astype(jnp.float32)    # (B, bn)
    pot = jax.lax.dot_general(xpre, spost, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dep = jax.lax.dot_general(spre, xpost, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    w = w + a_plus * pot - a_minus * dep
    out_ref[...] = jnp.clip(w, w_min, w_max).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "a_plus", "a_minus",
                                             "w_min", "w_max", "interpret"))
def stdp_pallas(x_pre: jax.Array, s_post: jax.Array, s_pre: jax.Array,
                x_post: jax.Array, w: jax.Array, *,
                a_plus: float, a_minus: float, w_min: float, w_max: float,
                bm: int = 256, bn: int = 256,
                interpret: bool = False) -> jax.Array:
    """x_pre/s_pre: (B, N_pre); x_post/s_post: (B, N_post); w: (N_pre, N_post)."""
    B, M = x_pre.shape
    N = x_post.shape[1]
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_stdp_kernel, a_plus=a_plus, a_minus=a_minus,
                          w_min=w_min, w_max=w_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bm), lambda i, j: (0, i)),   # x_pre
            pl.BlockSpec((B, bn), lambda i, j: (0, j)),   # s_post
            pl.BlockSpec((B, bm), lambda i, j: (0, i)),   # s_pre
            pl.BlockSpec((B, bn), lambda i, j: (0, j)),   # x_post
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), w.dtype),
        interpret=interpret,
    )(x_pre, s_post, s_pre, x_post, w)
