"""Fused STDP weight-update Pallas kernels (the paper's on-chip learning in
one pass over the weight tile).

Two kernels share the tile layout: `stdp_pallas` applies ONE pair-rule
step given precomputed traces; `stdp_seq_pallas` is the generalized form
the plan compiler lowers `SynapseProgram`s to — K signed outer-product
term planes applied over T serial steps with the weight tile VMEM-resident
for the whole window (one HBM round-trip per window, not per step).

One STDP step over a batch of B parallel synapse-update events:

    dw = a_plus * x_pre^T @ s_post  -  a_minus * s_pre^T @ x_post
    w' = clip(w + dw, w_min, w_max)

Both outer products are MXU matmuls with the BATCH as the contraction dim;
the clip and accumulate fuse into the same VMEM tile visit, so the weight
matrix streams HBM->VMEM->HBM exactly once per step (on chip, this is the
FIRE-stage weight update touching each synapse once — §III-B).

grid = (N_pre/bm, N_post/bn); B (the contraction) is kept whole per tile —
STDP batches are small (events of one timestep), so B<=512 fits VMEM:
tiles at defaults (bm=bn=256, B=256, f32): x_pre 256 KiB, s_post 256 KiB,
w 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stdp_kernel(xpre_ref, spost_ref, spre_ref, xpost_ref, w_ref, out_ref, *,
                 a_plus: float, a_minus: float, w_min: float, w_max: float):
    xpre = xpre_ref[...].astype(jnp.float32)      # (B, bm)
    spost = spost_ref[...].astype(jnp.float32)    # (B, bn)
    spre = spre_ref[...].astype(jnp.float32)      # (B, bm)
    xpost = xpost_ref[...].astype(jnp.float32)    # (B, bn)
    pot = jax.lax.dot_general(xpre, spost, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dep = jax.lax.dot_general(spre, xpost, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    w = w + a_plus * pot - a_minus * dep
    out_ref[...] = jnp.clip(w, w_min, w_max).astype(out_ref.dtype)


def _stdp_seq_kernel(p_ref, q_ref, w_ref, out_ref, *,
                     amps: tuple, w_min: float, w_max: float,
                     batch: int, nsteps: int):
    w = w_ref[...].astype(jnp.float32)            # (bm, bn), VMEM-resident

    def step(t, w):
        dw = jnp.zeros_like(w)
        for k, amp in enumerate(amps):            # K static: unrolled
            p = p_ref[k, pl.ds(t * batch, batch), :].astype(jnp.float32)
            q = q_ref[k, pl.ds(t * batch, batch), :].astype(jnp.float32)
            dw = dw + amp * jax.lax.dot_general(
                p, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        return jnp.clip(w + dw, w_min, w_max)

    w = jax.lax.fori_loop(0, nsteps, step, w)
    out_ref[...] = w.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("amps", "w_min", "w_max",
                                             "batch", "bm", "bn", "interpret"))
def stdp_seq_pallas(P: jax.Array, Q: jax.Array, w: jax.Array, *,
                    amps: tuple, w_min: float, w_max: float, batch: int,
                    bm: int = 256, bn: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Generalized multi-step STDP: K term planes over T serial steps.

    P: (K, T*B, M); Q: (K, T*B, N); w: (M, N). The weight tile stays
    VMEM-resident across ALL T steps — one HBM->VMEM->HBM pass over the
    weight matrix per *window*, vs per step for the single-step kernel.
    Both outer products per step are MXU matmuls with the batch as the
    contraction dim; the clip fuses into the same tile visit.
    """
    K, TB, M = P.shape
    N = Q.shape[2]
    assert M % bm == 0 and N % bn == 0 and TB % batch == 0, (M, N, TB)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_stdp_seq_kernel, amps=amps, w_min=w_min,
                          w_max=w_max, batch=batch, nsteps=TB // batch),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, TB, bm), lambda i, j: (0, 0, i)),   # P
            pl.BlockSpec((K, TB, bn), lambda i, j: (0, 0, j)),   # Q
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),         # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), w.dtype),
        interpret=interpret,
    )(P, Q, w)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "a_plus", "a_minus",
                                             "w_min", "w_max", "interpret"))
def stdp_pallas(x_pre: jax.Array, s_post: jax.Array, s_pre: jax.Array,
                x_post: jax.Array, w: jax.Array, *,
                a_plus: float, a_minus: float, w_min: float, w_max: float,
                bm: int = 256, bn: int = 256,
                interpret: bool = False) -> jax.Array:
    """x_pre/s_pre: (B, N_pre); x_post/s_post: (B, N_post); w: (N_pre, N_post)."""
    B, M = x_pre.shape
    N = x_post.shape[1]
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_stdp_kernel, a_plus=a_plus, a_minus=a_minus,
                          w_min=w_min, w_max=w_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bm), lambda i, j: (0, i)),   # x_pre
            pl.BlockSpec((B, bn), lambda i, j: (0, j)),   # s_post
            pl.BlockSpec((B, bm), lambda i, j: (0, i)),   # s_pre
            pl.BlockSpec((B, bn), lambda i, j: (0, j)),   # x_post
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), w.dtype),
        interpret=interpret,
    )(x_pre, s_post, s_pre, x_post, w)
