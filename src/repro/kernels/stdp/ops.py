"""Public STDP-update entry point: padding + dispatch (Pallas on TPU /
interpret, einsum reference otherwise). Plugged into core/plasticity via
`stdp_step(..., use_kernel=True)`."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_mode, pad_axis, pick_block
from repro.kernels.stdp.kernel import stdp_pallas
from repro.kernels.stdp.ref import stdp_update_ref


def stdp_update(x_pre: jax.Array, s_post: jax.Array, s_pre: jax.Array,
                x_post: jax.Array, w: jax.Array, *,
                a_plus: float = 0.01, a_minus: float = 0.012,
                w_min: float = -1.0, w_max: float = 1.0,
                force_pallas: bool = False) -> jax.Array:
    """One STDP weight step. Traces/spikes: (B, N_*); w: (N_pre, N_post)."""
    if not force_pallas:
        return stdp_update_ref(x_pre, s_post, s_pre, x_post, w,
                               a_plus=a_plus, a_minus=a_minus,
                               w_min=w_min, w_max=w_max)
    M, N = w.shape
    bm = pick_block(M, 256, 8)
    bn = pick_block(N, 256, 128)
    xpre_p, _ = pad_axis(x_pre, 1, bm)
    spre_p, _ = pad_axis(s_pre, 1, bm)
    spost_p, _ = pad_axis(s_post, 1, bn)
    xpost_p, _ = pad_axis(x_post, 1, bn)
    w_p, _ = pad_axis(w, 0, bm)
    w_p, _ = pad_axis(w_p, 1, bn)
    out = stdp_pallas(xpre_p, spost_p, spre_p, xpost_p, w_p,
                      a_plus=a_plus, a_minus=a_minus, w_min=w_min,
                      w_max=w_max, bm=bm, bn=bn, interpret=interpret_mode())
    return out[:M, :N]
