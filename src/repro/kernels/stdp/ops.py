"""Public STDP entry points, dispatched via the kernel registry (Pallas on
TPU / interpret, einsum reference otherwise).

`stdp_update` — single pair-rule step on precomputed traces; plugged into
core/plasticity via `stdp_step(..., use_kernel=True)`.

`stdp_seq` — the generalized multi-step family: K signed outer-product
term planes applied over T serial steps with a per-step clip and the
weight tile VMEM-resident for the window. This is what the plan compiler
lowers matching `SynapseProgram`s to (core/plan.py).

Both are weight writes, not differentiable ops, so the specs register
forward-only parity (`diff_argnums=()`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.common import pad_axis
from repro.kernels.stdp.kernel import stdp_pallas, stdp_seq_pallas
from repro.kernels.stdp.ref import stdp_seq_ref, stdp_update_ref


def _pallas_impl(x_pre, s_post, s_pre, x_post, w, *, blocks, interpret,
                 a_plus=0.01, a_minus=0.012, w_min=-1.0, w_max=1.0):
    M, N = w.shape
    bm, bn = blocks["bm"], blocks["bn"]
    xpre_p, _ = pad_axis(x_pre, 1, bm)
    spre_p, _ = pad_axis(s_pre, 1, bm)
    spost_p, _ = pad_axis(s_post, 1, bn)
    xpost_p, _ = pad_axis(x_post, 1, bn)
    w_p, _ = pad_axis(w, 0, bm)
    w_p, _ = pad_axis(w_p, 1, bn)
    out = stdp_pallas(xpre_p, spost_p, spre_p, xpost_p, w_p,
                      a_plus=a_plus, a_minus=a_minus, w_min=w_min,
                      w_max=w_max, bm=bm, bn=bn, interpret=interpret)
    return out[:M, :N]


def stdp_update(x_pre: jax.Array, s_post: jax.Array, s_pre: jax.Array,
                x_post: jax.Array, w: jax.Array, *,
                a_plus: float = 0.01, a_minus: float = 0.012,
                w_min: float = -1.0, w_max: float = 1.0,
                force_pallas: bool = False) -> jax.Array:
    """One STDP weight step. Traces/spikes: (B, N_*); w: (N_pre, N_post)."""
    return registry.dispatch("stdp", (x_pre, s_post, s_pre, x_post, w),
                             force_pallas=force_pallas,
                             a_plus=a_plus, a_minus=a_minus,
                             w_min=w_min, w_max=w_max)


def _make_inputs(key):
    ks = jax.random.split(key, 5)
    B, M, N = 6, 130, 140                     # non-multiples exercise padding
    x_pre = jax.random.uniform(ks[0], (B, M), jnp.float32)
    x_post = jax.random.uniform(ks[1], (B, N), jnp.float32)
    s_pre = (jax.random.uniform(ks[2], (B, M)) < 0.2).astype(jnp.float32)
    s_post = (jax.random.uniform(ks[3], (B, N)) < 0.2).astype(jnp.float32)
    w = 0.5 * jax.random.normal(ks[4], (M, N), jnp.float32)
    return x_pre, s_post, s_pre, x_post, w


def _seq_pallas_impl(P, Q, w, *, blocks, interpret,
                     amps, w_min, w_max, batch):
    M, N = w.shape
    bm, bn = blocks["bm"], blocks["bn"]
    # zero-padded pre/post planes contribute zero dw; the padded weight
    # fringe only sees the (harmless) clip and is sliced away
    P_p, _ = pad_axis(P, 2, bm)
    Q_p, _ = pad_axis(Q, 2, bn)
    w_p, _ = pad_axis(w, 0, bm)
    w_p, _ = pad_axis(w_p, 1, bn)
    out = stdp_seq_pallas(P_p, Q_p, w_p, amps=amps, w_min=w_min, w_max=w_max,
                          batch=batch, bm=bm, bn=bn, interpret=interpret)
    return out[:M, :N]


def stdp_seq(P: jax.Array, Q: jax.Array, w: jax.Array, *,
             amps: tuple, w_min: float, w_max: float, batch: int,
             force_pallas: bool = False) -> jax.Array:
    """Multi-step STDP window. P: (K, T*B, M); Q: (K, T*B, N); w: (M, N).

    Per step t: w <- clip(w + sum_k amps[k] * P_k_t^T @ Q_k_t, w_min, w_max).
    `amps` must be a (hashable) tuple of K floats.
    """
    return registry.dispatch("stdp_seq", (P, Q, w), force_pallas=force_pallas,
                             amps=tuple(amps), w_min=w_min, w_max=w_max,
                             batch=batch)


def _make_seq_inputs(key):
    ks = jax.random.split(key, 3)
    K, T, B, M, N = 2, 12, 4, 130, 140        # non-multiples exercise padding
    P = jax.random.uniform(ks[0], (K, T * B, M), jnp.float32)
    Q = (jax.random.uniform(ks[1], (K, T * B, N)) < 0.2).astype(jnp.float32)
    w = 0.5 * jax.random.normal(ks[2], (M, N), jnp.float32)
    return P, Q, w


_SEQ_STATIC = dict(amps=(0.01, -0.012), w_min=-1.0, w_max=1.0, batch=4)


registry.register(registry.KernelSpec(
    name="stdp_seq",
    ref=stdp_seq_ref,
    pallas=_seq_pallas_impl,
    apply=lambda args, force=False: stdp_seq(*args, force_pallas=force,
                                             **_SEQ_STATIC),
    block_axes=(registry.BlockAxis("bm", "M", preferred=256, align=8),
                registry.BlockAxis("bn", "N", preferred=256, align=128)),
    dims_of=lambda P, Q, w: {"K": P.shape[0], "TB": P.shape[1],
                             "M": w.shape[0], "N": w.shape[1]},
    candidates=({"bm": 128, "bn": 128}, {"bm": 128, "bn": 256},
                {"bm": 256, "bn": 128}, {"bm": 512, "bn": 256}),
    make_inputs=_make_seq_inputs,
    tune_static=_SEQ_STATIC,
    diff_argnums=(),                          # weight write: forward-only
    tol=1e-4,
    # w block in/out + the K (TB, block) term-plane slabs
    vmem_bytes=lambda dims, b: 4 * (2 * b["bm"] * b["bn"]
                                    + dims["K"] * dims["TB"]
                                    * (b["bm"] + b["bn"])),
    tile_model=registry.TileModel(
        out=(("M", "bm"), ("N", "bn")),
        tiles=lambda dims, b: {
            "w": (b["bm"], b["bn"]), "w_out": (b["bm"], b["bn"]),
            "P": (dims["K"], dims["TB"], b["bm"]),
            "Q": (dims["K"], dims["TB"], b["bn"])}),
))


registry.register(registry.KernelSpec(
    name="stdp",
    ref=stdp_update_ref,
    pallas=_pallas_impl,
    apply=lambda args, force=False: stdp_update(*args, force_pallas=force),
    block_axes=(registry.BlockAxis("bm", "M", preferred=256, align=8),
                registry.BlockAxis("bn", "N", preferred=256, align=128)),
    dims_of=lambda x_pre, s_post, s_pre, x_post, w: {"M": w.shape[0],
                                                     "N": w.shape[1],
                                                     "B": x_pre.shape[0]},
    candidates=({"bm": 128, "bn": 128}, {"bm": 128, "bn": 256},
                {"bm": 256, "bn": 128}, {"bm": 512, "bn": 256}),
    make_inputs=_make_inputs,
    diff_argnums=(),                          # weight write: forward-only
    tol=1e-4,
    # w block in/out + the four (B, block) trace/spike slabs
    vmem_bytes=lambda dims, b: 4 * (2 * b["bm"] * b["bn"]
                                    + 2 * dims["B"] * (b["bm"] + b["bn"])),
    tile_model=registry.TileModel(
        out=(("M", "bm"), ("N", "bn")),
        tiles=lambda dims, b: {
            "w": (b["bm"], b["bn"]), "w_out": (b["bm"], b["bn"]),
            "x_pre": (dims["B"], b["bm"]), "s_pre": (dims["B"], b["bm"]),
            "x_post": (dims["B"], b["bn"]), "s_post": (dims["B"], b["bn"])}),
))
