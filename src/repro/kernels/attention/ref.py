"""Oracle: dense softmax attention (single head-batch layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: (BH, T, d); k, v: (BH, S, d). Returns (BH, T, d)."""
    T, S = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    dpos = jnp.arange(T)[:, None] - jnp.arange(S)[None, :]
    ok = jnp.ones((T, S), bool)
    if causal:
        ok &= dpos >= 0
    if window > 0:
        ok &= dpos < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
