"""Flash attention forward Pallas kernel (online softmax, causal + window).

grid = (BH, Tq/bq, S/bk) with the KV dimension innermost. Scratch carries
(acc: (bq, d), m: (bq, 128), l: (bq, 128)) across KV blocks (m/l replicated
over the 128-lane minor dim — TPU VREGs have no efficient (bq, 1) layout).

Causality is exploited structurally: KV blocks entirely above the diagonal
are skipped with `@pl.when` (no MXU work, no softmax) — the same
block-granular event-skipping idea as spikemm, applied to the causal mask;
sliding-window attention additionally skips blocks below the window band,
making the kernel O(T*W) for window W (zamba2's 500k-context hybrid blocks).

VMEM at defaults (bq=512, bk=512, d<=256, bf16): q 256 KiB, k/v 512 KiB,
acc/m/l fp32 ~1.3 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_scr, m_scr, l_scr, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_start = qi * bq
    k_start = kj * bk

    def compute():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        d = q_pos - k_pos
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= d >= 0
        if window > 0:
            ok &= d < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...][:, :1]                 # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_new = l_scr[...][:, :1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    if causal or window > 0:
        gate = jnp.asarray(True)
        if causal:
            # skip blocks strictly above the diagonal
            gate = jnp.logical_and(gate, k_start <= q_start + bq - 1)
        if window > 0:
            # skip blocks entirely below the sliding-window band
            gate = jnp.logical_and(gate,
                                   k_start + bk - 1 >= q_start - window + 1)

        @pl.when(gate)
        def _():
            compute()
    else:
        compute()

    @pl.when(kj == nk - 1)
    def _():
        lsum = l_scr[...][:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(lsum, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bq", "bk", "causal", "window", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int = 512, bk: int = 512, causal: bool = True,
                           window: int = 0, interpret: bool = False):
    """q: (BH, T, d); k, v: (BH, S, d). T % bq == 0, S % bk == 0."""
    BH, T, d = q.shape
    S = k.shape[1]
    assert T % bq == 0 and S % bk == 0
    grid = (BH, T // bq, S // bk)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
