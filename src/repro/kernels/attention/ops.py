"""Flash attention public wrapper: head folding, padding, dispatch.

Forward-only kernel: training uses the XLA blockwise path
(`models/attention.py`) whose checkpointed scan gives the flash backward;
the kernel is the serving/prefill deployment path. `jax.lax.stop_gradient`
is NOT applied — a straight-through to the reference VJP is provided so the
kernel remains usable under jax.grad in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref
from repro.kernels.common import interpret_mode, pad_axis, pick_block


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512,
                    force_pallas: bool = False) -> jax.Array:
    """q: (BH, T, d); k, v: (BH, S, d) — heads pre-folded into batch."""
    if not force_pallas:
        return attention_ref(q, k, v, causal=causal, window=window)
    BH, T, d = q.shape
    S = k.shape[1]
    bq = pick_block(T, bq, 128)
    bk_ = pick_block(S, bk, 128)
    q_p, _ = pad_axis(q, 1, bq)
    k_p, _ = pad_axis(k, 1, bk_)
    v_p, _ = pad_axis(v, 1, bk_)
    # padded KV rows must not win the softmax: causal masking handles the
    # padded Q rows; padded KV columns are masked because their positions
    # exceed every valid q position only under causal. For non-causal, mask
    # via a window trick is not available — require exact multiples instead.
    if not causal:
        assert S % bk_ == 0, "non-causal path requires S % bk == 0"
    out = flash_attention_pallas(q_p, k_p, v_p, bq=bq, bk=bk_, causal=causal,
                                 window=window, interpret=interpret_mode())
    return out[:, :T]
