"""Flash attention public wrapper: registry dispatch, padding, and a
straight-through VJP.

Forward-only kernel: training uses the XLA blockwise path
(`models/attention.py`) whose checkpointed scan gives the flash backward;
the kernel is the serving/prefill deployment path. To keep the kernel
usable under `jax.grad` (tests, parity harness), the Pallas forward is
wrapped in a custom VJP whose backward differentiates the dense reference —
a straight-through gradient that is exact because forward parity holds.

`bq`/`bk` default to None, meaning the registry resolves them (tuning
cache, then the 512/512 spec defaults); an explicit int pins the axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref
from repro.kernels.common import pad_axis


def _flash_fwd_raw(q, k, v, causal, window, bq, bk, interpret):
    T = q.shape[1]
    S = k.shape[1]
    q_p, _ = pad_axis(q, 1, bq)
    k_p, _ = pad_axis(k, 1, bk)
    v_p, _ = pad_axis(v, 1, bk)
    # padded KV rows must not win the softmax: causal masking handles the
    # padded Q rows; padded KV columns are masked because their positions
    # exceed every valid q position only under causal. For non-causal, mask
    # via a window trick is not available — require exact multiples instead.
    if not causal:
        assert S % bk == 0, "non-causal path requires S % bk == 0"
    out = flash_attention_pallas(q_p, k_p, v_p, bq=bq, bk=bk, causal=causal,
                                 window=window, interpret=interpret)
    return out[:, :T]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_st(q, k, v, causal, window, bq, bk, interpret):
    return _flash_fwd_raw(q, k, v, causal, window, bq, bk, interpret)


def _flash_st_fwd(q, k, v, causal, window, bq, bk, interpret):
    out = _flash_fwd_raw(q, k, v, causal, window, bq, bk, interpret)
    return out, (q, k, v)


def _flash_st_bwd(causal, window, bq, bk, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_flash_st.defvjp(_flash_st_fwd, _flash_st_bwd)


def _pallas_impl(q, k, v, *, blocks, interpret, causal=True, window=0):
    return _flash_st(q, k, v, causal, window, blocks["bq"], blocks["bk"],
                     interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = None, bk: int = None,
                    force_pallas: bool = False) -> jax.Array:
    """q: (BH, T, d); k, v: (BH, S, d) — heads pre-folded into batch."""
    overrides = {n: v_ for n, v_ in (("bq", bq), ("bk", bk))
                 if v_ is not None}
    return registry.dispatch("attention", (q, k, v),
                             force_pallas=force_pallas, overrides=overrides,
                             causal=causal, window=window)


def _make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    BH, T, d = 2, 160, 64                     # non-multiple T exercises padding
    q = jax.random.normal(k1, (BH, T, d), jnp.float32)
    kk = jax.random.normal(k2, (BH, T, d), jnp.float32)
    v = jax.random.normal(k3, (BH, T, d), jnp.float32)
    return q, kk, v


registry.register(registry.KernelSpec(
    name="attention",
    ref=attention_ref,
    pallas=_pallas_impl,
    apply=lambda args, force=False: flash_attention(*args, causal=True,
                                                    force_pallas=force),
    block_axes=(registry.BlockAxis("bq", "T", preferred=512, align=128),
                registry.BlockAxis("bk", "S", preferred=512, align=128)),
    dims_of=lambda q, k, v: {"T": q.shape[1], "S": k.shape[1],
                             "d": q.shape[2]},
    candidates=({"bq": 128, "bk": 128}, {"bq": 256, "bk": 256},
                {"bq": 256, "bk": 512}, {"bq": 512, "bk": 512}),
    make_inputs=_make_inputs,
    diff_argnums=(0, 1, 2),
    tol=2e-3,
    # q/o blocks + k/v blocks + the (bq, bk) score tile & softmax stats
    vmem_bytes=lambda dims, b: 4 * (2 * b["bq"] * dims["d"]
                                    + 2 * b["bk"] * dims["d"]
                                    + b["bq"] * b["bk"] + 3 * b["bq"]),
    # output is (T, d): the S axis reduces over the k/v loop, d rides whole
    tile_model=registry.TileModel(
        out=(("T", "bq"), ("d", None)),
        tiles=lambda dims, b: {
            "q": (b["bq"], dims["d"]), "o": (b["bq"], dims["d"]),
            "k": (b["bk"], dims["d"]), "v": (b["bk"], dims["d"]),
            "scores": (b["bq"], b["bk"]),
            "m": (b["bq"],), "l": (b["bq"],), "acc_scale": (b["bq"],)}),
))
