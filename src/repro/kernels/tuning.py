"""Autotuner + persistent tuning cache for registered kernels.

Block shapes that are optimal for one (shape, backend) pair are rarely
optimal for another — VMEM working set, grid shape, and the serial-in-time
chunk trade all move. Instead of hand-picking per call site, the autotuner
sweeps each kernel's declared candidate block configs on representative
inputs, times the jitted Pallas path, and persists the winner to a JSON
cache keyed by

    (kernel name, jax backend, shape bucket)

where the shape bucket rounds every logical dimension up to a power of two
("B8_D512_T256") so one tuning run covers a neighborhood of shapes.
`registry.KernelSpec.resolve_blocks` consults the cache on every dispatch;
a cache miss silently falls back to the spec's hand-tuned defaults, so
tuning is always an optimization, never a correctness dependency.

Before anything is timed, candidates whose estimated VMEM working set
(`KernelSpec.vmem_bytes`) exceeds the budget (`REPRO_VMEM_LIMIT_MB`,
default 14 MiB — one TPU core's ~16 MiB minus headroom) are pruned: an
infeasible tile would either crash Mosaic or thrash, and either way timing
it wastes sweep budget. The spec-default config is never pruned — it is
what dispatch falls back to anyway, so it must stay the measured baseline.

Cache location: `$REPRO_TUNING_CACHE`, else `~/.cache/repro/kernel_tuning.json`.
Misses fall through to the checked-in cache (`kernels/tuned/ci_cache.json`),
which pins the winners for the CI / nightly-benchmark shapes so fresh
checkouts dispatch with tuned blocks from the first call.
`benchmarks/bench_kernels.py` exercises the sweep and archives the winners.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Mapping, Optional, Tuple

import jax

from repro.core import faults
from repro.kernels import registry
# import names from the submodule directly: the `repro.kernels` package
# re-exports an `incidents()` *function* shadowing the module attribute
from repro.kernels.incidents import (FallbackEvent, degrade, record,
                                     strict_mode)

_ENV_CACHE = "REPRO_TUNING_CACHE"
_ENV_VMEM_LIMIT = "REPRO_VMEM_LIMIT_MB"
_SCHEMA_VERSION = 1
_VMEM_LIMIT_MB_DEFAULT = 14.0

BUNDLED_CACHE_PATH = os.path.join(os.path.dirname(__file__), "tuned",
                                  "ci_cache.json")


def default_cache_path() -> str:
    return os.environ.get(
        _ENV_CACHE,
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "kernel_tuning.json"))


def shape_bucket(dims: Mapping[str, int]) -> str:
    """Canonical bucket key: dims sorted by name, sizes rounded up to pow2."""
    parts = []
    for k in sorted(dims):
        n = max(1, int(dims[k]))
        parts.append(f"{k}{1 << (n - 1).bit_length()}")
    return "_".join(parts)


class TuningCache:
    """JSON-backed map: kernel|backend|bucket -> winning block config."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: Optional[Dict[str, Any]] = None

    def _load(self) -> Dict[str, Any]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if raw.get("version") != _SCHEMA_VERSION:
                    raw = {"version": _SCHEMA_VERSION, "entries": {}}
            except (OSError, ValueError):
                raw = {"version": _SCHEMA_VERSION, "entries": {}}
            self._data = raw
        return self._data

    @staticmethod
    def _key(kernel: str, backend: str, bucket: str) -> str:
        return f"{kernel}|{backend}|{bucket}"

    def lookup(self, kernel: str, backend: str,
               bucket: str) -> Optional[Dict[str, int]]:
        entry = self._load()["entries"].get(self._key(kernel, backend, bucket))
        if entry is None:
            return None
        return {k: int(v) for k, v in entry["blocks"].items()}

    def put(self, kernel: str, backend: str, bucket: str,
            blocks: Mapping[str, int],
            stats: Optional[Mapping[str, Any]] = None) -> None:
        data = self._load()
        data["entries"][self._key(kernel, backend, bucket)] = {
            "blocks": dict(blocks), "stats": dict(stats or {})}

    def save(self) -> str:
        data = self._load()
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return self.path

    def entries(self):
        """Iterate (kernel, backend, bucket, blocks) over every cached
        winner — the static analyzer lints stored block keys against the
        owning spec's axes (`repro.analysis.check_kernel`, TB308)."""
        for key, entry in self._load()["entries"].items():
            kernel, backend, bucket = key.split("|", 2)
            yield kernel, backend, bucket, {
                k: int(v) for k, v in entry["blocks"].items()}

    def __len__(self) -> int:
        return len(self._load()["entries"])


_DEFAULT_CACHE: Optional[TuningCache] = None
_BUNDLED_CACHE: Optional[TuningCache] = None


def default_cache() -> TuningCache:
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != default_cache_path():
        _DEFAULT_CACHE = TuningCache()
    return _DEFAULT_CACHE


def bundled_cache() -> TuningCache:
    """The read-only cache checked into the package (CI / bench shapes)."""
    global _BUNDLED_CACHE
    if _BUNDLED_CACHE is None:
        _BUNDLED_CACHE = TuningCache(BUNDLED_CACHE_PATH)
    return _BUNDLED_CACHE


def lookup_tuned(kernel: str,
                 dims: Mapping[str, int]) -> Optional[Dict[str, int]]:
    """Dispatch-time hook used by `KernelSpec.resolve_blocks`.

    User/process cache first; a miss falls through to the checked-in CI
    cache so known shapes start tuned on a fresh checkout.
    """
    try:
        backend = jax.default_backend()
        bucket = shape_bucket(dims)
        hit = default_cache().lookup(kernel, backend, bucket)
        if hit is not None:
            return hit
        return bundled_cache().lookup(kernel, backend, bucket)
    except Exception:  # a corrupt cache must never break dispatch
        return None


def vmem_limit_bytes() -> int:
    """VMEM budget in bytes (MiB via REPRO_VMEM_LIMIT_MB) used by autotune
    pruning and the dispatch-time VMEM rejection guard. Simulated pressure
    (a `vmem_limit` fault, see repro.core.faults) only ever *shrinks* it."""
    try:
        mb = float(os.environ.get(_ENV_VMEM_LIMIT, _VMEM_LIMIT_MB_DEFAULT))
    except ValueError:
        mb = _VMEM_LIMIT_MB_DEFAULT
    limit = int(mb * 2 ** 20)
    injected = faults.vmem_limit_override_bytes()
    return limit if injected is None else min(limit, injected)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------


def _time_once(fn, args) -> float:
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def autotune(name: str, args: Optional[tuple] = None, *,
             cache: Optional[TuningCache] = None, repeats: int = 3,
             save: bool = True, **static) -> Tuple[Dict[str, int], Dict]:
    """Sweep `spec.candidates` (plus the spec defaults) for kernel `name`.

    Returns (winning blocks, report). The winner is persisted to `cache`
    (default: the process-wide cache) under the input's shape bucket, so
    subsequent `registry.dispatch` calls on same-bucket shapes pick it up.
    """
    spec = registry.get(name)
    if args is None:
        if spec.make_inputs is None:
            raise ValueError(f"kernel {name!r} has no make_inputs; "
                             "pass explicit args to autotune()")
        args = spec.make_inputs(jax.random.PRNGKey(0))
    static = {**spec.tune_static, **static}   # required statics (e.g. amps)
    if cache is None:  # NOT `or`: an empty TuningCache is falsy (__len__)
        cache = default_cache()
    dims = spec.dims_of(*args)
    bucket = shape_bucket(dims)
    backend = jax.default_backend()
    interpret = registry.interpret_mode()

    # Fit every candidate to the actual dims, dedupe, and always include the
    # spec's hand-tuned defaults as the baseline candidate. Candidates whose
    # modeled VMEM working set busts the budget are pruned before timing —
    # except the defaults, which dispatch uses on a cache miss regardless.
    limit = vmem_limit_bytes()
    seen, fitted, pruned = set(), [], []
    for i, cand in enumerate(({},) + tuple(spec.candidates)):
        blocks = spec.resolve_blocks(dims, overrides=cand, use_cache=False)
        key = tuple(sorted(blocks.items()))
        if key in seen:
            continue
        seen.add(key)
        est = spec.vmem_bytes(dims, blocks) if spec.vmem_bytes else None
        if i > 0 and est is not None and est > limit:
            pruned.append({"blocks": blocks, "vmem_bytes": int(est)})
            continue
        fitted.append(blocks)

    report: Dict[str, Any] = {"kernel": name, "backend": backend,
                              "bucket": bucket, "timings": [],
                              "pruned": pruned,
                              "vmem_limit_bytes": limit}
    best_blocks, best_t = None, float("inf")
    for blocks in fitted:
        def fn(*a, _b=blocks):
            faults.maybe_fail_compile(name, autotune=True)
            return spec.pallas(*a, blocks=_b, interpret=interpret, **static)

        fn = jax.jit(fn)
        try:
            compile_s = _time_once(fn, args)           # includes compilation
            runs = [_time_once(fn, args) for _ in range(repeats)]
        except Exception as e:
            # an infeasible tile is a loser, not a crash: record it and
            # keep sweeping the remaining candidates
            report["timings"].append({"blocks": blocks, "error": repr(e),
                                      "infeasible": True})
            record(FallbackEvent(
                kind="autotune", family=name, stage="candidate",
                error=repr(e), dims=dict(dims), blocks=dict(blocks)))
            continue
        t = min(runs)
        report["timings"].append({"blocks": blocks, "best_s": t,
                                  "runs_s": runs, "compile_s": compile_s})
        if t < best_t:
            best_blocks, best_t = blocks, t
    if best_blocks is None:
        # every candidate was infeasible: degrade to the spec defaults
        # (what dispatch uses on a cache miss anyway) rather than abort
        # the sweep; REPRO_STRICT=1 still makes this fatal.
        defaults = spec.resolve_blocks(dims, use_cache=False)
        degrade("autotune", name, "sweep",
                f"every candidate failed; falling back to spec "
                f"defaults {defaults}", dims=dims, blocks=defaults)
        report["winner"] = {"blocks": defaults, "best_s": None,
                           "degraded": True}
        return defaults, report
    report["winner"] = {"blocks": best_blocks, "best_s": best_t}
    cache.put(name, backend, bucket, best_blocks,
              stats={"best_s": best_t, "n_candidates": len(fitted)})
    if save:
        cache.save()
    return best_blocks, report


def autotune_all(*, cache: Optional[TuningCache] = None, repeats: int = 3,
                 save: bool = True) -> Dict[str, Dict]:
    """Tune every registered kernel on its canonical inputs.

    One kernel blowing up must not abort the whole sweep: its error is
    recorded (report entry + incident) and the sweep continues — except
    under REPRO_STRICT=1, where the failure propagates.
    """
    registry.ensure_registered()
    reports = {}
    for name in registry.names():
        if registry.get(name).make_inputs is None:
            continue
        try:
            _, reports[name] = autotune(name, cache=cache, repeats=repeats,
                                        save=save)
        except Exception as e:
            if strict_mode():
                raise
            record(FallbackEvent(
                kind="autotune", family=name, stage="kernel", error=repr(e)))
            reports[name] = {"kernel": name, "error": repr(e)}
    return reports


__all__ = ["TuningCache", "autotune", "autotune_all", "bundled_cache",
           "BUNDLED_CACHE_PATH", "default_cache", "default_cache_path",
           "lookup_tuned", "shape_bucket", "vmem_limit_bytes"]
