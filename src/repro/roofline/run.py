import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline driver: per-cell three-term analysis on the single-pod mesh.

  PYTHONPATH=src python -m repro.roofline.run --all --out experiments/roofline
  PYTHONPATH=src python -m repro.roofline.run --arch rwkv6-3b --shape train_4k

Reads nothing from the dry-run records (it compiles its own depth pairs);
the dry-run remains the memory-fit + full-schedule proof, this module is the
FLOP/byte/wire accounting (see compositional.py for why both exist).
"""

import argparse
import json
import traceback

from repro.configs import ARCH_IDS, cell_applicable, shape_adapted_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.roofline.compositional import roofline_totals
from repro.roofline.terms import V5E, model_flops


def analyse_cell(arch: str, shape: str, cfg_override=None, mesh=None) -> dict:
    cfg = cfg_override or shape_adapted_config(arch, shape)
    totals = roofline_totals(cfg, shape, mesh=mesh)
    chips = 256
    flops_dev = totals["flops_per_device"]
    bytes_dev = totals["bytes_per_device"]
    wire = totals["wire_bytes"]
    compute_s = flops_dev / V5E.peak_flops
    memory_s = bytes_dev / V5E.hbm_bw
    coll_s = wire / (V5E.ici_bw * V5E.ici_links)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    # roofline fraction: useful model FLOPs per step over what the dominant
    # term's wall-clock would let peak compute do
    step_time = max(terms.values())
    mfu_bound = mf / (chips * V5E.peak_flops * step_time) if step_time else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": "16x16", "n_chips": chips,
        "status": "ok",
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / max(hlo_total, 1.0),
        "roofline_fraction": mfu_bound,
        "totals": totals,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args(argv)

    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    for arch, shape in cells:
        tag = f"{arch}__{shape}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[skip-done] {tag}", flush=True)
                    continue
        ok, reason = cell_applicable(arch, shape)
        if not ok:
            rec = {"arch": arch, "shape": shape, "status": "skipped",
                   "reason": reason}
        else:
            print(f"[analyse ] {tag} ...", flush=True)
            try:
                rec = analyse_cell(arch, shape, mesh=mesh)
                print(f"[ok      ] {tag}: C {rec['compute_s']*1e3:.1f}ms "
                      f"M {rec['memory_s']*1e3:.1f}ms "
                      f"X {rec['collective_s']*1e3:.1f}ms "
                      f"-> {rec['dominant']}, useful {rec['useful_ratio']:.2f}, "
                      f"roofline {rec['roofline_fraction']:.2%}", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": repr(e), "traceback": traceback.format_exc()}
                print(f"[ERROR   ] {tag}: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
