"""roofline — compiled-artifact analysis against TPU v5e-class constants."""

from repro.roofline.hlo import collective_bytes
from repro.roofline.terms import (HW, RooflineTerms, roofline_from_record,
                                  model_flops)
