import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb diagnostic: compile a shrunk cell and rank its collectives.

  PYTHONPATH=src python -m repro.roofline.diagnose --arch rwkv6-3b \
      --shape train_4k [--layers 2] [--remat full]

Prints every collective op (bytes x trip count) sorted descending, plus the
totals per kind — the 'profile' the perf loop reads (DESIGN.md §5: the
lowered IR is the profile on this container)."""

import argparse
import re
from collections import defaultdict

import jax

from repro.configs import ARCH_IDS, shape_adapted_config
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.roofline.hlo import _COLL_KINDS, _shape_bytes
from repro.sharding import rules


def rank_collectives(hlo_text: str, top: int = 25):
    trip_of_comp = {}
    for line in hlo_text.splitlines():
        if " while(" in line and "body=" in line:
            bm = re.search(r"body=\s*%?([\w.\-]+)", line)
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if bm:
                trip_of_comp[bm.group(1)] = int(tm.group(1)) if tm else 1
    rows = []
    current = ""
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            current = m.group(1)
        for kind in _COLL_KINDS:
            if f"{kind}(" in line and "=" in line:
                head = line.split("=", 1)
                if kind not in head[1]:
                    continue
                res_type = head[1].split(kind)[0]
                nbytes = _shape_bytes(res_type)
                if nbytes:
                    trip = trip_of_comp.get(current, 1)
                    rows.append((nbytes * trip, trip, kind,
                                 res_type.strip()[:60], current[:28]))
                break
    rows.sort(reverse=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=False)
    rules.set_mesh(mesh)
    cfg = shape_adapted_config(args.arch, args.shape)
    kw = dict(n_layers=args.layers, scan_layers=False)
    if cfg.family == "encdec":
        kw["encoder_layers"] = args.layers
    if args.remat:
        kw["remat"] = args.remat
    cfg = cfg.replace(**kw)
    mode, inputs, shardings = specs_mod.cell_inputs(cfg, args.shape, mesh)
    step = specs_mod.step_fn_for(cfg, mode)
    compiled = jax.jit(step, in_shardings=shardings).lower(*inputs).compile()
    text = compiled.as_text()
    rows = rank_collectives(text, args.top)
    per_kind = defaultdict(float)
    for b, _, kind, _, _ in rows:
        per_kind[kind] += b
    total = sum(per_kind.values())
    print(f"== {args.arch} {args.shape} L={args.layers} "
          f"({len(rows)} collectives, {total:.3e} B) ==")
    for kind, b in sorted(per_kind.items(), key=lambda kv: -kv[1]):
        print(f"  {kind:20s} {b:.3e} B ({100*b/max(total,1):.1f}%)")
    print(f"-- top {args.top} ops --")
    for b, trip, kind, shape, comp in rows[:args.top]:
        print(f"  {b:.3e} B x{trip:<4d} {kind:18s} {shape:60s} in {comp}")


if __name__ == "__main__":
    main()
