"""Parse collective traffic out of compiled HLO text.

`cost_analysis()` does not attribute collective bytes, so we sum operand
sizes over every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the optimized module. Ops inside `while` bodies (from
lax.scan) execute trip-count times; we multiply by the trip count, which XLA
publishes in the loop backend_config ("known_trip_count") — scan-over-layers
would otherwise undercount collectives by ~L x.

Shapes are parsed from the HLO result/operand types, e.g.
  bf16[2048,4096]{1,0} all-gather(...), replica_groups=...
The *operand* bytes are what cross the wire for all-reduce/all-to-all/
permute; for all-gather the wire bytes are (output - shard) ~= output, and
for reduce-scatter they are ~input; we record input and output bytes per op
class and use the conventional wire estimate per class.
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of one HLO shape or tuple of shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum wire bytes per collective kind over the optimized module,
    weighting ops inside while-loops by their known trip count."""
    # 1. find trip counts of while loops and which computations they call
    trip_of_comp: Dict[str, int] = {}
    for m in re.finditer(
            r'while\(.*?\).*?body=([%\w.\-]+)(?:.*?known_trip_count.*?"n":"?(\d+))?',
            hlo_text):
        comp, trip = m.group(1), m.group(2)
        trip_of_comp[comp.lstrip("%")] = int(trip) if trip else 1
    # also match backend_config trip counts appearing after body= on the line
    for line in hlo_text.splitlines():
        if " while(" in line and "body=" in line:
            bm = re.search(r"body=\s*%?([\w.\-]+)", line)
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if bm:
                trip_of_comp[bm.group(1)] = int(tm.group(1)) if tm else \
                    trip_of_comp.get(bm.group(1), 1)

    # 2. walk computations, tracking which one we're inside
    out = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    current_comp = ""
    for line in hlo_text.splitlines():
        # computation header: `%name (args) -> type {` (args may nest parens)
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            current_comp = m.group(1)
        for kind in _COLL_KINDS:
            if f" {kind}(" in line or f"= {kind}(" in line or \
                    re.search(rf"\b{kind}\b", line) and "=" in line and "(" in line:
                # result type = text between '=' and the op name
                head = line.split("=", 1)
                if len(head) != 2 or kind not in head[1]:
                    continue
                res_type = head[1].split(kind)[0]
                nbytes = _shape_bytes(res_type)
                if nbytes == 0:
                    continue
                trip = trip_of_comp.get(current_comp, 1)
                out[kind] += nbytes * trip
                counts[kind] += trip
                break
    total = sum(out.values())
    return {**out, "counts": counts, "total_bytes": total}
