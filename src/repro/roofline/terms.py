"""Three-term roofline from dry-run records (TPU v5e-class constants).

    compute    = FLOPs_total    / (chips * PEAK_FLOPS)
    memory     = bytes_total    / (chips * HBM_BW)
    collective = wire_bytes     / (chips * ICI_BW_per_chip)

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE numbers
(verified by probe in this container), so chip totals are per_device * chips
and the division by chips cancels: term = per_device_quantity / per_chip_peak.

lax.scan bodies are counted ONCE by cost_analysis (verified), so the
compositional path (bench-compiled per-layer artifacts x L) is used for the
§Roofline table; the full-step artifact proves memory fit + a valid
collective schedule. `MODEL_FLOPS = 6*N*D` (dense) or `6*N_active*D` (MoE)
gives the useful-compute ratio.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig, SHAPES


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # B/s / chip
    ici_bw: float = 50e9                # B/s / link; ~2 usable links per axis
    ici_links: int = 2                  # effective concurrent links per chip


V5E = HW()


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float                # MODEL_FLOPS / HLO_FLOPs
    bottleneck: str

    def asdict(self):
        return dataclasses.asdict(self)


def param_count(cfg: ModelConfig, active_only: bool = False) -> float:
    """Analytic parameter count (embeddings included once)."""
    d, f, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.padded_vocab
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * (H * hd) + 2 * d * (Hk * hd) + (H * hd) * d
    if cfg.family == "moe":
        E = cfg.top_k if active_only else cfg.n_experts
        ffn = E * 3 * d * f
        per_layer = attn + ffn
    elif cfg.family in ("ssm", "hybrid"):
        di, st, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        mixer = 2 * d * di + 2 * d * st + d * Hs + di * d + cfg.d_conv * di
        per_layer = mixer
    elif cfg.family == "rwkv":
        per_layer = 5 * d * d + 2 * d * cfg.decay_lora + 2 * d * f + d * d
    else:
        per_layer = attn + 3 * d * f if cfg.act == "swiglu" else attn + 2 * d * f
    total = L * per_layer + V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "hybrid" and cfg.attn_every:
        # the shared block's weights are stored ONCE but APPLIED at every
        # attn_every-th layer: weight sharing shares storage, not compute
        # (TaiBai's type-3 multiplexing makes the same trade). For the
        # useful-FLOPs denominator the block counts once per APPLICATION;
        # param_count for memory/storage purposes would count it once.
        n_apps = (L + cfg.attn_every - 1) // cfg.attn_every
        shared = 2 * d * d + attn + 3 * d * f
        total += n_apps * shared
    if cfg.family == "encdec":
        total += cfg.encoder_layers * (attn + 2 * d * f)
        total += L * attn                            # cross-attention
    return float(total)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6*N*D (train) / 2*N*D (inference forward) / 2*N per token (decode)."""
    sh = SHAPES[shape_name]
    N = param_count(cfg, active_only=(cfg.family == "moe"))
    if sh.mode == "train":
        D = sh.global_batch * sh.seq_len
        return 6.0 * N * D
    if sh.mode == "prefill":
        D = sh.global_batch * sh.seq_len
        return 2.0 * N * D
    # decode: one token per sequence; attention reads the KV cache too but
    # 2N dominates the matmul FLOPs
    return 2.0 * N * sh.global_batch


def roofline_from_record(rec: Dict, cfg: ModelConfig,
                         hw: HW = V5E,
                         flops_total: Optional[float] = None,
                         bytes_total: Optional[float] = None) -> RooflineTerms:
    """rec: one dryrun JSON record. flops/bytes_total override the record
    (the compositional per-layer path supplies scan-corrected totals)."""
    chips = rec["n_chips"]
    flops_dev = (flops_total / chips if flops_total
                 else rec["flops_per_device"])
    bytes_dev = (bytes_total / chips if bytes_total
                 else rec["bytes_accessed_per_device"])
    wire = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = wire / (hw.ici_bw * hw.ici_links)
    mf = model_flops(cfg, rec["shape"])
    hlo_total = flops_dev * chips
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(compute_s, memory_s, collective_s, mf, hlo_total,
                         mf / max(hlo_total, 1.0), bottleneck)
