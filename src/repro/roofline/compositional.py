"""Scan-corrected roofline totals via the unrolled-delta method.

XLA's cost_analysis counts a lax.scan body ONCE regardless of trip count
(probe-verified in this container), so the full-production artifact
under-reports layer work by ~L x. The delta method recovers the true
schedule totals without hand-assembled estimates:

  compile the SAME cell with scan_layers=False at two depths L_a < L_b
  (structure-preserving: hybrid uses multiples of attn_every, encdec varies
  encoder+decoder together), then

     total(L) = f(L_a) + (f(L_b) - f(L_a)) * (L - L_a) / (L_b - L_a)

  for FLOPs, bytes-accessed, and collective wire bytes alike. This measures
  the *executed* schedule — remat recompute, collective placement, fusion —
  not an analytic model.

Attention caveat: the blockwise-attention inner scans are also counted once,
so FLOPs come from a SECOND delta pair lowered with single-block attention
(numerically identical matmul count, no inner scan); bytes/collectives come
from the production-settings pair (single-block attention would materialize
O(T^2) scores that the deployment flash kernel never does).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Tuple

import jax

from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig, SHAPES
from repro.roofline.hlo import collective_bytes
from repro.sharding import rules


@dataclasses.dataclass
class CompiledStats:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes: float
    compile_s: float


def _depth_pair(cfg: ModelConfig) -> Tuple[int, int]:
    if cfg.family == "hybrid" and cfg.attn_every:
        return cfg.attn_every, 2 * cfg.attn_every
    return 2, 4


def _shrink(cfg: ModelConfig, L: int) -> ModelConfig:
    kw = dict(n_layers=L, scan_layers=False)
    if cfg.family == "encdec":
        kw["encoder_layers"] = L
    return cfg.replace(**kw)


def compile_stats(cfg: ModelConfig, shape_name: str, mesh) -> CompiledStats:
    mode, inputs, shardings = specs_mod.cell_inputs(cfg, shape_name, mesh)
    step = specs_mod.step_fn_for(cfg, mode)
    t0 = time.perf_counter()
    compiled = jax.jit(step, in_shardings=shardings).lower(*inputs).compile()
    dt = time.perf_counter() - t0
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return CompiledStats(cost.get("flops", 0.0),
                         cost.get("bytes accessed", 0.0),
                         coll["total_bytes"], dt)


def _extrapolate(a: float, b: float, La: int, Lb: int, L: int) -> float:
    return a + (b - a) * (L - La) / (Lb - La)


def roofline_totals(cfg: ModelConfig, shape_name: str, *,
                    mesh=None, verbose: bool = False) -> Dict[str, float]:
    """-> scan-corrected per-device totals for one (arch x shape) cell on the
    single-pod mesh: flops/bytes/wire per step."""
    mesh = mesh or make_production_mesh(multi_pod=False)
    rules.set_mesh(mesh)
    try:
        La, Lb = _depth_pair(cfg)
        mode = SHAPES[shape_name].mode

        # pair B: production attention settings -> bytes + collectives
        sa = compile_stats(_shrink(cfg, La), shape_name, mesh)
        sb = compile_stats(_shrink(cfg, Lb), shape_name, mesh)
        L = cfg.n_layers
        bytes_dev = _extrapolate(sa.bytes_per_device, sb.bytes_per_device,
                                 La, Lb, L)
        wire = _extrapolate(sa.wire_bytes, sb.wire_bytes, La, Lb, L)
        flops_prod = _extrapolate(sa.flops_per_device, sb.flops_per_device,
                                  La, Lb, L)

        # pair A: single-block attention -> true FLOPs (train/prefill only;
        # decode has no inner attention scan)
        needs_dense = (mode in ("train", "prefill")
                       and cfg.family not in ("rwkv", "ssm"))
        if needs_dense:
            dcfg = cfg.replace(attn_impl="dense")
            fa = compile_stats(_shrink(dcfg, La), shape_name, mesh)
            fb = compile_stats(_shrink(dcfg, Lb), shape_name, mesh)
            flops_dev = _extrapolate(fa.flops_per_device, fb.flops_per_device,
                                     La, Lb, L)
        else:
            flops_dev = flops_prod
        if verbose:
            print(f"  delta pairs L={La}/{Lb}: flops/dev {flops_dev:.3e} "
                  f"bytes/dev {bytes_dev:.3e} wire {wire:.3e}")
        return {"flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "wire_bytes": wire,
                "flops_per_device_prod_attn": flops_prod,
                "depth_pair": (La, Lb)}
    finally:
        rules.set_mesh(None)
