"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

Moments share the parameter sharding (sharding/rules.state_specs), so the
optimizer is ZeRO-compatible by construction: whatever spec a parameter has,
its m/v carry the same spec and the update is purely local."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def adamw_init(params: Any) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: Dict[str, Any],
                 params: Any) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = opt_state["step"] + 1
    lr = cfg.lr_at(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh, vh = m / b1c, v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["mu"])
    flat_v = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
