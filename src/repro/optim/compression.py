"""int8 gradient compression for DP all-reduce (distributed-optimization trick).

Per-tensor symmetric int8 quantization with stochastic rounding; used by the
train loop's `compress_grads=True` path: gradients are quantized *before*
the data-parallel reduction (4x wire bytes saved on the `data`/`pod` axes —
the inter-pod axis is the slow one) and dequantized after. Stochastic
rounding keeps the estimator unbiased; the scale rides along as fp32.

Under shard_map the reduce happens over int8 via sum-of-int32 (psum of int8
upcast); with plain pjit the quantize/dequantize pair still reduces HBM
traffic of the fused reduce. Exposed as pure functions + a grads transform.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 values, fp32 scale). Stochastic rounding."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    y = x.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads(grads: Any, key: jax.Array) -> Any:
    """Round-trip int8 quantization of every gradient leaf (unbiased)."""
    leaves, tdef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, s = compress_int8(g, k)
        out.append(decompress_int8(q, s, g.dtype))
    return tdef.unflatten(out)
