"""LR schedules: cosine (llama-class) and WSD (MiniCPM's warmup-stable-decay).

MiniCPM (arXiv:2404.06395) trains with WSD: linear warmup -> long stable
plateau -> short (10%) exponential/linear decay; the assigned minicpm-2b
config selects `wsd_schedule` to match."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(peak: float, warmup: int, total: int, decay_frac: float = 0.1,
                 floor: float = 0.01):
    """Warmup -> Stable -> Decay (exponential tail over the last decay_frac)."""
    decay_start = int(total * (1.0 - decay_frac))

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        decay = peak * (floor ** prog)        # exponential to floor*peak
        stable = jnp.where(step >= decay_start, decay, peak)
        return jnp.where(step < warmup, warm, stable)
    return lr
