"""optim — AdamW + schedules + gradient transforms (self-contained, no optax)."""

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.optim.compression import compress_int8, decompress_int8
