"""Shared building blocks: norms, RoPE, MLPs, embeddings, initialization."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.surrogate import spike
from repro.models.config import ModelConfig

Array = jax.Array


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape).astype(dtype)


@jax.custom_vjp
def f32_boundary(x: Array) -> Array:
    """Upcast to fp32 whose COTANGENT comes back in the input dtype.

    Plain `x.astype(f32)` makes the backward cotangent fp32, and under TP
    the activation-gradient all-reduces then move 4 B/elt instead of 2
    (measured 89% of olmoe's collective bytes — EXPERIMENTS.md §Perf
    olmoe-iter-4). Numerics: standard mixed-precision practice; the fp32
    mean/var math INSIDE the norm is unchanged."""
    return x.astype(jnp.float32)


def _f32b_fwd(x):
    # residual: zero-size carrier of the input dtype (dtypes aren't jax types)
    return x.astype(jnp.float32), jnp.zeros((0,), x.dtype)


def _f32b_bwd(res, ct):
    return (ct.astype(res.dtype),)


f32_boundary.defvjp(_f32b_fwd, _f32b_bwd)


def rms_norm(x: Array, w: Array, eps: float) -> Array:
    dt = x.dtype
    x = f32_boundary(x)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, w: Array, b: Array, eps: float) -> Array:
    dt = x.dtype
    x = f32_boundary(x)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def group_norm(x: Array, w: Array, b: Array, n_groups: int, eps: float) -> Array:
    """Per-head group norm (RWKV6 wkv output)."""
    dt = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (half-split / llama convention)
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, hd); positions: (..., T) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU) with optional spiking (event-driven) activations
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if cfg.act == "swiglu":
        return {"w_gate": truncated_normal(ks[0], (d, f), s_in),
                "w_up": truncated_normal(ks[1], (d, f), s_in),
                "w_down": truncated_normal(ks[2], (f, d), s_out)}
    return {"w_up": truncated_normal(ks[0], (d, f), s_in),
            "b_up": jnp.zeros((f,)),
            "w_down": truncated_normal(ks[1], (f, d), s_out),
            "b_down": jnp.zeros((cfg.d_model,))}


def mlp_apply(params, x: Array, cfg: ModelConfig) -> Array:
    dt = x.dtype
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(dt)) * (x @ params["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(dt) + params["b_up"].astype(dt))
    if cfg.spiking_ffn:
        # TaiBai technique: binarize hidden activations into spike events
        # (surrogate grad for training); the down projection then runs on the
        # event-gated spikemm kernel on TPU (block-sparse skip of silent
        # tiles). Threshold 0.05 sits inside the silu-gated activation
        # distribution at init (0.5 silences the layer outright — measured);
        # the sigmoid surrogate keeps gradients alive across the threshold.
        h = spike(h - 0.05, "sigmoid", 4.0)
    out = h @ params["w_down"].astype(dt)
    if cfg.act != "swiglu":
        out = out + params["b_down"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    v, d = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"tok": truncated_normal(ks[0], (v, d), 0.02)}
    if cfg.learned_pos:
        p["pos"] = truncated_normal(ks[1], (cfg.max_position, d), 0.02)
    if not cfg.tie_embeddings:
        p["head"] = truncated_normal(ks[2], (d, v), d ** -0.5)
    return p


def embed_apply(params, tokens: Array, dtype) -> Array:
    return params["tok"].astype(dtype)[tokens]


def lm_head(params, x: Array, cfg: ModelConfig) -> Array:
    w = params.get("head")
    if w is None:
        w = params["tok"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)
