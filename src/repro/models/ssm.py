"""Mamba2 (state-space duality, chunked) — zamba2's backbone layers.

The SSM recurrence per head h with scalar decay a_t = exp(dt_t * A_h):

    S_t = a_t * S_{t-1} + dt_t * (B_t (x) x_t)        S: (headdim, state)
    y_t = S_t @ C_t + D_h * x_t

is EXACTLY the paper's DIFF primitive (v = tau*v + c) over the flattened
state — the inter-chunk scan below runs on the `linrec` kernel. Within a
chunk the recurrence is unrolled into MXU matmuls via the standard SSD
segment-sum form (stable: all exponentials are of non-positive numbers).

Layer structure (Mamba2, n_groups=1):
    in_proj -> [z | xBC | dt];  causal depthwise conv1d over xBC;
    SSD over chunks; gated y * silu(z); RMSNorm; out_proj.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.linrec import linrec
from repro.models.blocks import rms_norm, truncated_normal
from repro.models.config import ModelConfig

Array = jax.Array


def ssm_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    """Projections are SEPARATE tensors (not one fused w_in) so each shards
    cleanly: z/x/dt slice along d_inner/heads (TP over `model`), B/C are
    small and replicate. The depthwise conv covers only the x stream (B/C
    streams are convolved separately in reference Mamba2; keeping conv on x
    alone is the zamba2 configuration)."""
    d, di, st, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "w_z": truncated_normal(ks[0], (d, di), d ** -0.5),
        "w_x": truncated_normal(ks[4], (d, di), d ** -0.5),
        "w_B": truncated_normal(ks[5], (d, st), d ** -0.5),
        "w_C": truncated_normal(ks[6], (d, st), d ** -0.5),
        "w_dt": truncated_normal(ks[2], (d, H), d ** -0.5),
        "conv_w": truncated_normal(ks[1], (cfg.d_conv, di),
                                   cfg.d_conv ** -0.5),
        "conv_b": jnp.zeros((di,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),     # A = -exp(A_log)
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))) )),
        "D": jnp.ones((H,)),
        "norm_w": jnp.ones((di,)),
        "w_out": truncated_normal(ks[3], (di, d), di ** -0.5),
    }


def _segsum(logdecay: Array) -> Array:
    """(..., L) per-step log decays -> (..., L, L) lower-tri pairwise sums:
    out[t, s] = sum_{u=s+1..t} logdecay_u  (t >= s), -inf above diagonal."""
    L = logdecay.shape[-1]
    cs = jnp.cumsum(logdecay, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # cum_t - cum_s
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array,
                chunk: int, h0: Optional[Array] = None,
                use_linrec_kernel: bool = False
                ) -> Tuple[Array, Array]:
    """Chunked state-space dual form.

    x:  (Bb, T, H, P)    per-head inputs (P = headdim)
    dt: (Bb, T, H)       discretization step (softplus'd, >0)
    A:  (H,)             negative decay rates (A < 0)
    B,C:(Bb, T, N)       input/output projections (N = state, n_groups=1)
    D:  (H,)             skip
    h0: (Bb, H, P, N)    initial state or None
    Returns (y: (Bb, T, H, P), h_final: (Bb, T==last chunk state)).
    """
    Bb, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    f32 = jnp.float32

    xc = x.reshape(Bb, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(f32)
    Bc = B.reshape(Bb, nc, chunk, N).astype(f32)
    Cc = C.reshape(Bb, nc, chunk, N).astype(f32)

    logdecay = dtc * A.astype(f32)                       # (Bb, nc, L, H) <= 0
    logdecay = jnp.moveaxis(logdecay, -1, -2)            # (Bb, nc, H, L)
    Lmat = jnp.exp(_segsum(logdecay))                    # (Bb, nc, H, L, L)

    xdt = xc * dtc[..., None]                            # dt-weighted input

    # ---- intra-chunk (quadratic within chunk, all MXU) --------------------
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)       # (Bb,nc,L,L)
    y_intra = jnp.einsum("bcls,bchls,bcshp->bclhp",
                         scores, Lmat, xdt)

    # ---- per-chunk final states ------------------------------------------
    cum = jnp.cumsum(logdecay, axis=-1)                  # (Bb,nc,H,L)
    total = cum[..., -1:]                                # (Bb,nc,H,1)
    decay_to_end = jnp.exp(total - cum)                  # prod_{u>s} a_u  (<=1)
    states = jnp.einsum("bchs,bcshp,bcsn->bchpn",
                        decay_to_end, xdt, Bc)           # (Bb,nc,H,P,N)

    # ---- inter-chunk scan: THE DIFF RECURRENCE ----------------------------
    chunk_decay = jnp.exp(total[..., 0])                 # (Bb,nc,H)
    a_seq = jnp.repeat(chunk_decay[..., None], P * N, -1
                       ).reshape(Bb, nc, H * P * N).swapaxes(0, 1)
    x_seq = states.reshape(Bb, nc, H * P * N).swapaxes(0, 1)
    h_init = (jnp.zeros((Bb, H * P * N), f32) if h0 is None
              else h0.reshape(Bb, H * P * N).astype(f32))
    carried, h_last = linrec(a_seq, x_seq, h_init, use_linrec_kernel)
    # carried[c] = state AFTER chunk c; we need the state BEFORE chunk c
    prev = jnp.concatenate([h_init[None], carried[:-1]], 0)
    prev = prev.swapaxes(0, 1).reshape(Bb, nc, H, P, N)

    # ---- inter-chunk contribution ----------------------------------------
    in_decay = jnp.exp(cum)                              # prod_{u<=t} (<=1)
    y_inter = jnp.einsum("bcln,bchl,bchpn->bclhp", Cc, in_decay, prev)

    y = (y_intra + y_inter + xc * D.astype(f32)[None, None, None, :, None])
    y = y.reshape(Bb, T, H, P).astype(x.dtype)
    return y, h_last.reshape(Bb, H, P, N).astype(x.dtype)


def _causal_conv(xbc: Array, w: Array, b: Array,
                 state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv1d. xbc: (B, T, Cdim); w: (K, Cdim).

    Returns (out (B, T, Cdim), new_state (B, K-1, Cdim))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([state, xbc], axis=1)
    out = sum(padded[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(K))
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    return out, padded[:, -(K - 1):] if K > 1 else state


def ssm_layer(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Mamba2 mixer. x: (B, T, d) -> (B, T, d)."""
    Bb, T, d = x.shape
    di, st, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dt_ = x.dtype
    z = x @ params["w_z"].astype(dt_)
    xin = x @ params["w_x"].astype(dt_)
    B = x @ params["w_B"].astype(dt_)
    C = x @ params["w_C"].astype(dt_)
    dt_raw = x @ params["w_dt"].astype(dt_)
    xs, _ = _causal_conv(xin, params["conv_w"], params["conv_b"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs.reshape(Bb, T, H, P), dt, A, B, C, params["D"],
                       min(cfg.ssm_chunk, T))
    y = y.reshape(Bb, T, di) * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return y @ params["w_out"].astype(dt_)


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    di, st, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return {"ssm": jnp.zeros((batch, H, P, st), dtype),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype)}


def ssm_decode_layer(params, x: Array, cache: Dict[str, Array],
                     cfg: ModelConfig) -> Tuple[Array, Dict[str, Array]]:
    """One-token step. x: (B, 1, d); cache: {ssm, conv}."""
    Bb = x.shape[0]
    di, st, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dt_ = x.dtype
    z = x @ params["w_z"].astype(dt_)
    xin = x @ params["w_x"].astype(dt_)
    B = (x @ params["w_B"].astype(dt_))[:, 0]
    C = (x @ params["w_C"].astype(dt_))[:, 0]
    dt_raw = x @ params["w_dt"].astype(dt_)
    xconv, conv_state = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                     cache["conv"])
    xs = xconv[:, 0]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B, H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a_t = jnp.exp(dt * A)                                          # (B, H)
    xh = xs.reshape(Bb, H, P).astype(jnp.float32)
    S = cache["ssm"].astype(jnp.float32)
    S = a_t[..., None, None] * S + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, C.astype(jnp.float32))
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, 1, di).astype(dt_) * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return y @ params["w_out"].astype(dt_), {
        "ssm": S.astype(cache["ssm"].dtype), "conv": conv_state}
