"""Whisper-style encoder-decoder backbone (the audio frontend is a STUB).

Per the assignment, `input_specs()` provides precomputed frame embeddings
(B, S_enc, d) in place of the log-mel conv frontend; everything downstream —
bidirectional encoder, causal decoder with cross-attention, KV-cache decode
— is real. Whisper uses LayerNorm (with bias), GELU MLPs, learned positional
embeddings, and tied input/output token embeddings.

Structure:
  encoder: L_enc x [LN -> self-attn (bidirectional) -> LN -> GELU MLP]
  decoder: L_dec x [LN -> self-attn (causal) -> LN -> cross-attn -> LN -> MLP]
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.attention import (attention_decode_layer, attn_init,
                                    cross_attention_layer, cross_kv,
                                    dense_attention, qkv_project)
from repro.models.blocks import layer_norm, mlp_init, mlp_apply, truncated_normal
from repro.models.config import ModelConfig
from repro.sharding.rules import constrain

Array = jax.Array


def _ln_init(d):
    return {"w": jnp.ones((d,)), "b": jnp.zeros((d,))}


def _enc_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_init(cfg.d_model), "ln2": _ln_init(cfg.d_model),
            "attn": attn_init(k1, cfg), "mlp": mlp_init(k2, cfg)}


def _dec_layer_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _ln_init(cfg.d_model), "ln2": _ln_init(cfg.d_model),
            "ln3": _ln_init(cfg.d_model),
            "attn": attn_init(k1, cfg), "xattn": attn_init(k2, cfg),
            "mlp": mlp_init(k3, cfg)}


def encdec_init(key, cfg: ModelConfig):
    ke, kd, kt, kp, kq = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": {"tok": truncated_normal(kt, (cfg.padded_vocab, cfg.d_model), 0.02),
                  "pos_dec": truncated_normal(kp, (cfg.max_position, cfg.d_model), 0.02),
                  "pos_enc": truncated_normal(kq, (cfg.encoder_len, cfg.d_model), 0.02)},
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "ln_enc": _ln_init(cfg.d_model),
        "ln_dec": _ln_init(cfg.d_model),
    }


def _ln(x, p, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, S_enc, d) stubbed frontend embeddings -> encoder memory."""
    dt = jnp.dtype(cfg.dtype)
    S = frames.shape[1]
    h = frames.astype(dt) + params["embed"]["pos_enc"][:S].astype(dt)
    h = constrain(h, "data", None, None)

    def body(h, layer_p):
        x = _ln(h, layer_p["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(layer_p["attn"], x, cfg,
                              jnp.arange(x.shape[1])[None])
        a = dense_attention(q, k, v, causal=False)
        h = h + a.reshape(*x.shape[:2], -1) @ layer_p["attn"]["wo"].astype(dt)
        h = h + mlp_apply(layer_p["mlp"], _ln(h, layer_p["ln2"], cfg.norm_eps), cfg)
        return constrain(h, "data", None, None), None

    h, _ = jax.lax.scan(body, h, params["encoder"])
    return _ln(h, params["ln_enc"], cfg.norm_eps)


def decode_forward(params, tokens: Array, memory: Array, cfg: ModelConfig
                   ) -> Array:
    """Teacher-forced decoder. tokens: (B, T); memory: (B, S_enc, d)."""
    dt = jnp.dtype(cfg.dtype)
    B, T = tokens.shape
    h = params["embed"]["tok"].astype(dt)[tokens] + \
        params["embed"]["pos_dec"][:T].astype(dt)
    h = constrain(h, "data", None, None)

    def body(h, layer_p):
        from repro.models.attention import attention_layer
        a = attention_layer(layer_p["attn"], _ln(h, layer_p["ln1"], cfg.norm_eps), cfg)
        h = h + a
        kv = cross_kv(layer_p["xattn"], memory, cfg)
        h = h + cross_attention_layer(layer_p["xattn"],
                                      _ln(h, layer_p["ln2"], cfg.norm_eps),
                                      kv, cfg)
        h = h + mlp_apply(layer_p["mlp"], _ln(h, layer_p["ln3"], cfg.norm_eps), cfg)
        return constrain(h, "data", None, None), None

    h, _ = jax.lax.scan(body, h, params["decoder"])
    h = _ln(h, params["ln_dec"], cfg.norm_eps)
    logits = (h @ params["embed"]["tok"].T.astype(dt)).astype(jnp.float32)
    return constrain(logits, "data", None, "model")


def encdec_forward(params, frames: Array, tokens: Array, cfg: ModelConfig
                   ) -> Array:
    return decode_forward(params, tokens, encode(params, frames, cfg), cfg)


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype):
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
        # cross K/V precomputed once per request at prefill
        "xk": jnp.zeros((L, batch, cfg.encoder_len, cfg.n_kv_heads, cfg.hd), dtype),
        "xv": jnp.zeros((L, batch, cfg.encoder_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def prefill_cross(params, memory: Array, cache, cfg: ModelConfig):
    """Fill the cross-attention K/V for all decoder layers."""
    def body(_, layer_p):
        k, v = cross_kv(layer_p["xattn"], memory, cfg)
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, params["decoder"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step(params, tokens: Array, cache, t: Array, cfg: ModelConfig):
    """One decoder token against self KV cache + precomputed cross K/V."""
    dt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    h = params["embed"]["tok"].astype(dt)[tokens] + \
        params["embed"]["pos_dec"].astype(dt)[t][None, None]

    def body(h, xs):
        layer_p, k_row, v_row, xk_row, xv_row = xs
        x = _ln(h, layer_p["ln1"], cfg.norm_eps)
        a, row = attention_decode_layer(layer_p["attn"], x,
                                        {"k": k_row, "v": v_row}, t, cfg)
        h = h + a
        h = h + cross_attention_layer(layer_p["xattn"],
                                      _ln(h, layer_p["ln2"], cfg.norm_eps),
                                      (xk_row, xv_row), cfg)
        h = h + mlp_apply(layer_p["mlp"], _ln(h, layer_p["ln3"], cfg.norm_eps), cfg)
        return h, (row["k"], row["v"])

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = _ln(h, params["ln_dec"], cfg.norm_eps)
    logits = (h @ params["embed"]["tok"].T.astype(dt)).astype(jnp.float32)
    return logits, dict(cache, k=k_new, v=v_new)
