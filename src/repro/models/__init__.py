"""models — LM substrate for the assigned architectures.

Families: dense decoder (llama-class), MoE, Mamba2 SSM, RWKV6, hybrid
(Mamba2 + shared attention), encoder-decoder (whisper), VLM backbone
(pixtral). All are composed from `blocks.py` + family modules and stacked by
`transformer.py` with scan-over-layers + configurable remat.
"""
