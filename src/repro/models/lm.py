"""Top-level LM API: init / forward dispatch, loss, train_step & serve_step.

These are the functions the dry-run lowers, the train loop drives, and the
roofline analyses — one construction site for every (arch x shape) cell:

  train_step(state, batch)             full fwd+bwd+AdamW over (B, T) tokens
  prefill_step(params, batch)          full-sequence forward (inference)
  serve_step(params, cache, tok, t)    one decode token against the cache

Batches:
  LM:      {"tokens": (B, T+1) int32}                (inputs/labels shifted)
  whisper: {"frames": (B, S_enc, d), "tokens": (B, T+1)}
  pixtral: {"patches": (B, n_patches, d), "tokens": (B, T+1)}
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads

Array = jax.Array

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


# ---------------------------------------------------------------------------
# init / forward
# ---------------------------------------------------------------------------


def model_init(key, cfg: ModelConfig) -> Any:
    if cfg.family == "encdec":
        return encdec_mod.encdec_init(key, cfg)
    return tf_mod.transformer_init(key, cfg)


def model_forward(params, batch: Dict[str, Array], cfg: ModelConfig
                  ) -> Tuple[Array, Dict[str, Array]]:
    """-> (logits over label positions, aux)."""
    tokens = batch["tokens"][:, :-1]
    if cfg.family == "encdec":
        logits = encdec_mod.encdec_forward(params, batch["frames"], tokens, cfg)
        return logits, {}
    if cfg.family == "vlm":
        logits, aux = tf_mod.transformer_forward(
            params, tokens, cfg, patch_embeds=batch.get("patches"))
        # loss only on the text positions (skip the patch prefix)
        if batch.get("patches") is not None:
            logits = logits[:, batch["patches"].shape[1]:]
        return logits, aux
    return tf_mod.transformer_forward(params, tokens, cfg)


def cross_entropy(logits: Array, labels: Array, vocab_size: int
                  ) -> Tuple[Array, Array]:
    """Mean NLL over valid labels (label < vocab_size); also accuracy."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    acc = jnp.sum(jnp.where(valid, jnp.argmax(logits, -1) == safe, False)) / denom
    return loss, acc


def loss_fn(params, batch: Dict[str, Array], cfg: ModelConfig
            ) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = model_forward(params, batch, cfg)
    labels = batch["tokens"][:, 1:]
    loss, acc = cross_entropy(logits, labels, cfg.vocab_size)
    metrics = {"loss": loss, "accuracy": acc}
    total = loss
    if cfg.family == "moe":
        total = total + MOE_LB_WEIGHT * aux["lb_loss"] + MOE_Z_WEIGHT * aux["z_loss"]
        metrics.update(lb_loss=aux["lb_loss"], z_loss=aux["z_loss"])
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def init_train_state(key, cfg: ModelConfig) -> Dict[str, Any]:
    params = model_init(key, cfg)
    opt = adamw_init(params)
    return {"params": params, "mu": opt["mu"], "nu": opt["nu"],
            "step": opt["step"]}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, compress: bool = False):
    """Build the jit-able train step.

    `microbatches > 1` accumulates gradients over sequential micro-batches
    (within-step slack for straggler mitigation + memory control);
    `compress` round-trips gradients through int8 before the (data, pod)
    reduction (optim/compression.py).
    """

    def grad_one(params, mb):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, mb, cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, Array]
                   ) -> Tuple[Dict[str, Any], Dict[str, Array]]:
        params = state["params"]
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (lo, m), g = grad_one(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + lo), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = jax.tree.map(lambda m: m[-1], ms)
            metrics["loss"] = lsum / microbatches
        else:
            (_, metrics), grads = grad_one(params, batch)
        if compress:
            key = jax.random.fold_in(jax.random.PRNGKey(0), state["step"])
            grads = compress_grads(grads, key)
        new_params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, {"mu": state["mu"], "nu": state["nu"],
                             "step": state["step"]}, params)
        metrics.update(opt_metrics)
        return {"params": new_params, **opt_state}, metrics

    return train_step


# ---------------------------------------------------------------------------
# inference steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch: Dict[str, Array]) -> Array:
        logits, _ = model_forward(params, batch, cfg)
        return logits[:, -1]                      # next-token logits
    return prefill_step


def sample_next(logits: Array, t: Array, greedy: bool) -> Array:
    """Next-token choice from (B, vocab) logits at position t.

    Greedy argmax (deterministic) or gumbel sampling keyed by fold_in(t) —
    shared by the per-token decode step and the full-sequence prefill so
    both paths pick identical tokens."""
    logits = logits.astype(jnp.float32)
    if greedy:
        nxt = jnp.argmax(logits, axis=-1)
    else:
        key = jax.random.fold_in(jax.random.PRNGKey(17), t)
        g = -jnp.log(-jnp.log(
            jax.random.uniform(key, logits.shape, jnp.float32, 1e-9, 1.0)))
        nxt = jnp.argmax(logits + g, axis=-1)
    return nxt.astype(jnp.int32)[:, None]


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    """One decode token: (params, cache, tokens (B,1), t) -> (next, cache).

    Lowered for the decode_32k / long_500k dry-run cells."""

    def serve_step(params, cache, tokens: Array, t: Array):
        if cfg.family == "encdec":
            logits, cache = encdec_mod.decode_step(params, tokens, cache, t, cfg)
        else:
            logits, cache = tf_mod.decode_step(params, tokens, cache, t, cfg)
        return sample_next(logits[:, -1], t, greedy), cache

    return serve_step


def can_full_prefill(cfg: ModelConfig) -> bool:
    """Whether the family is stateless per step (KV-cache attention only),
    so the prompt can be prefilled with ONE full-sequence forward instead
    of a token-at-a-time scan. SSM/RWKV/hybrid carry step-recurrent state
    and keep the scan path."""
    return cfg.family in ("dense", "moe", "vlm")


def make_full_prefill(cfg: ModelConfig, *, greedy: bool = True):
    """Full-sequence prefill: (params, cache, tokens (B, L)) ->
    (next token (B, 1) sampled at position L-1, cache filled for [0, L))."""

    def full_prefill(params, cache, tokens: Array):
        logits, cache = tf_mod.prefill_forward(params, tokens, cache, cfg)
        return sample_next(logits[:, -1], tokens.shape[1] - 1, greedy), cache

    return full_prefill


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        return encdec_mod.init_cache(cfg, batch, seq, dtype)
    return tf_mod.init_cache(cfg, batch, seq, dtype)
