"""Decoder stack: scan-over-layers, configurable remat, per-family blocks.

Families share one skeleton — embed -> scan(L x block) -> norm -> head —
with the block body dispatched per family:

  dense / vlm   pre-norm GQA attention + (SwiGLU | GELU) MLP
  moe           pre-norm GQA attention + top-k MoE FFN (aux losses carried
                through the scan)
  rwkv          RWKV6 time mix + channel mix (attention-free)
  hybrid        Mamba2 mixer every layer; a SHARED attention block (one set
                of weights, zamba2-style) applied at every `attn_every`-th
                layer via lax.cond inside the scan — weight sharing across
                depth is the transformer-scale analogue of TaiBai's type-3
                convolutional weight multiplexing (one filter, many sites),
                and is encoded the same way: the shared block's parameters
                are closure constants of the scan body, stored ONCE.

Scan-over-layers keeps the lowered HLO O(1) in depth (the 40-cell dry-run
compiles 38-layer models with the same HLO as 2-layer ones); remat policy is
selectable per config ('none' | 'full' | 'dots_saveable').

Decode paths thread per-layer caches as scan carries; the hybrid's shared-
attention KV caches are per *application site* (n_layers // attn_every of
them), indexed by layer position inside the scan.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_decode_layer, attention_layer,
                                    attention_prefill_layer, attn_init)
from repro.models.blocks import (embed_apply, embed_init, lm_head, mlp_apply,
                                 mlp_init, rms_norm, truncated_normal)
from repro.models.config import ModelConfig
from repro.sharding.rules import constrain

Array = jax.Array
P = Any  # params pytree


# ---------------------------------------------------------------------------
# per-family block definitions
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig) -> P:
    """Parameters of ONE layer (unstacked)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.family == "rwkv":
        return {"ln1": jnp.ones((cfg.d_model,)), "ln1b": jnp.zeros((cfg.d_model,)),
                "ln2": jnp.ones((cfg.d_model,)), "ln2b": jnp.zeros((cfg.d_model,)),
                "mix": rwkv_mod.rwkv_init(k1, cfg)}
    if cfg.family in ("ssm", "hybrid"):
        return {"norm1": jnp.ones((cfg.d_model,)),
                "mixer": ssm_mod.ssm_init(k1, cfg)}
    p = {"norm1": jnp.ones((cfg.d_model,)),
         "norm2": jnp.ones((cfg.d_model,)),
         "attn": attn_init(k1, cfg)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def _shared_attn_init(key, cfg: ModelConfig) -> P:
    """zamba2's shared attention+MLP block: consumes concat(h, embed0)."""
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {"proj_in": truncated_normal(k1, (2 * d, d), (2 * d) ** -0.5),
            "norm1": jnp.ones((d,)), "norm2": jnp.ones((d,)),
            "attn": attn_init(k2, cfg), "mlp": mlp_init(k3, cfg)}


def _attn_block_body(params: P, h: Array, cfg: ModelConfig, attn_fn
                     ) -> Tuple[Array, Any, Any]:
    """The ONE dense/moe/vlm block definition (pre-norm attention +
    residual scale + MLP-or-MoE), shared by the train/forward path and the
    full-sequence prefill so the two can never drift apart. `attn_fn`
    supplies the attention flavour: (layer params, normed x) -> (attention
    output, extra) — extra threads the prefill path's new cache row."""
    a, extra = attn_fn(params, rms_norm(h, params["norm1"], cfg.norm_eps))
    h = h + cfg_residual_scale(cfg) * a
    x2 = rms_norm(h, params["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, moe_aux = moe_mod.moe_layer(params["moe"], x2, cfg)
    else:
        m = mlp_apply(params["mlp"], x2, cfg)
        moe_aux = None
    return h + cfg_residual_scale(cfg) * m, extra, moe_aux


def _block_apply(params: P, h: Array, cfg: ModelConfig, aux: Dict[str, Array]
                 ) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence block body (train / prefill)."""
    if cfg.family == "rwkv":
        from repro.models.blocks import layer_norm
        a, _, _ = rwkv_mod.rwkv_time_mix(
            params["mix"], layer_norm(h, params["ln1"], params["ln1b"],
                                      cfg.norm_eps), cfg)
        h = h + a
        c, _ = rwkv_mod.rwkv_channel_mix(
            params["mix"], layer_norm(h, params["ln2"], params["ln2b"],
                                      cfg.norm_eps), cfg)
        return h + c, aux
    if cfg.family in ("ssm", "hybrid"):
        a = ssm_mod.ssm_layer(params["mixer"],
                              rms_norm(h, params["norm1"], cfg.norm_eps), cfg)
        return h + a, aux
    # dense / moe / vlm
    h, _, moe_aux = _attn_block_body(
        params, h, cfg,
        lambda p, xn: (attention_layer(p["attn"], xn, cfg), None))
    if cfg.family == "moe":
        aux = {k: aux.get(k, 0.0) + moe_aux[k] for k in ("lb_loss", "z_loss")}
    return h, aux


def cfg_residual_scale(cfg: ModelConfig) -> float:
    """MiniCPM 'scale_depth': residual branches scaled by s/sqrt(L)."""
    return cfg.residual_scale if cfg.residual_scale else 1.0


def _shared_attn_apply(params: P, h: Array, emb0: Array, cfg: ModelConfig
                       ) -> Array:
    x = jnp.concatenate([h, emb0], axis=-1) @ params["proj_in"].astype(h.dtype)
    a = attention_layer(params["attn"],
                        rms_norm(x, params["norm1"], cfg.norm_eps), cfg)
    x = x + a
    m = mlp_apply(params["mlp"], rms_norm(x, params["norm2"], cfg.norm_eps), cfg)
    return h + x + m - h  # residual handled inside (x carries h via proj)


# ---------------------------------------------------------------------------
# model init / forward
# ---------------------------------------------------------------------------


def n_shared_attn(cfg: ModelConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every \
        if cfg.attn_every else 0


def transformer_init(key, cfg: ModelConfig) -> P:
    ke, kl, ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p = {"embed": embed_init(ke, cfg),
         "layers": layers,
         "final_norm": jnp.ones((cfg.d_model,))}
    if cfg.family == "hybrid" and cfg.attn_every:
        p["shared_attn"] = _shared_attn_init(ks, cfg)
    if cfg.family == "vlm" and cfg.n_patches:
        p["patch_proj"] = truncated_normal(ks, (cfg.d_model, cfg.d_model),
                                           cfg.d_model ** -0.5)
    return p


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)


def _run_layers(params: P, h: Array, cfg: ModelConfig, emb0: Optional[Array]
                ) -> Tuple[Array, Dict[str, Array]]:
    aux0 = ({"lb_loss": jnp.zeros((), jnp.float32),
             "z_loss": jnp.zeros((), jnp.float32)}
            if cfg.family == "moe" else {})
    shared = params.get("shared_attn")

    def body(carry, xs):
        h, aux = carry
        layer_p, idx = xs
        if shared is not None:
            h = jax.lax.cond(
                idx % cfg.attn_every == 0,
                lambda hh: _shared_attn_apply(shared, hh, emb0, cfg),
                lambda hh: hh, h)
        h, aux = _block_apply(layer_p, h, cfg, aux)
        h = constrain(h, "data", None, None)
        return (h, aux), None

    body = _remat(body, cfg)
    idxs = jnp.arange(cfg.n_layers)
    if cfg.scan_layers:
        (h, aux), _ = jax.lax.scan(body, (h, aux0), (params["layers"], idxs))
    else:
        carry = (h, aux0)
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda x: x[i], params["layers"])
            carry, _ = body(carry, (layer_p, idxs[i]))
        h, aux = carry
    if cfg.family == "moe":
        aux = {k: v / cfg.n_layers for k, v in aux.items()}
    return h, aux


def transformer_forward(params: P, tokens: Array, cfg: ModelConfig, *,
                        patch_embeds: Optional[Array] = None
                        ) -> Tuple[Array, Dict[str, Array]]:
    """tokens: (B, T) int32 -> logits (B, T', padded_vocab) fp32.

    VLM (pixtral): `patch_embeds` (B, n_patches, d) — the stubbed modality
    frontend output — is projected and prepended; logits cover the full
    (patches + text) sequence.
    """
    dt = jnp.dtype(cfg.dtype)
    h = embed_apply(params["embed"], tokens, dt)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds.astype(dt) @ params["patch_proj"].astype(dt)
        h = jnp.concatenate([pe, h], axis=1)
    h = constrain(h, "data", None, None)
    emb0 = h if cfg.family == "hybrid" else None
    h, aux = _run_layers(params, h, cfg, emb0)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params["embed"], h, cfg)
    return constrain(logits, "data", None, "model"), aux


# ---------------------------------------------------------------------------
# decode (KV / state caches)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> P:
    L = cfg.n_layers
    if cfg.family == "rwkv":
        one = rwkv_mod.rwkv_init_cache(cfg, batch, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
    if cfg.family in ("ssm", "hybrid"):
        one = ssm_mod.ssm_init_cache(cfg, batch, dtype)
        cache = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
        if cfg.family == "hybrid" and cfg.attn_every:
            A = n_shared_attn(cfg)
            cache = dict(cache)
            cache["attn_k"] = jnp.zeros((A, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
            cache["attn_v"] = jnp.zeros((A, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)
        return cache
    return {"k": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)}


def prefill_forward(params: P, tokens: Array, cache: P, cfg: ModelConfig
                    ) -> Tuple[Array, P]:
    """Full-sequence prefill for STATELESS (attention-family) models.

    One causal forward over the (B, L) prompt that writes K/V for positions
    [0, L) into the (empty) cache — replacing L serial `decode_step` calls;
    the decode loop continues from position L. Families with step-recurrent
    state (ssm / rwkv / hybrid) must keep the scan path: their cache is a
    running state, not a position-indexed table.

    Returns (logits (B, L, vocab) fp32-headed as in decode, new cache).
    """
    assert cfg.family in ("dense", "moe", "vlm"), cfg.family
    dt = jnp.dtype(cfg.dtype)
    B, L = tokens.shape
    h = embed_apply(params["embed"], tokens, dt)
    h = constrain(h, "data", None, None)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    def body(h, xs):
        layer_p, cache_row = xs
        h, new_row, _ = _attn_block_body(
            layer_p, h, cfg,
            lambda p, xn: attention_prefill_layer(p["attn"], xn, cache_row,
                                                  positions, cfg))
        return h, new_row

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(params["embed"], h, cfg), new_cache


def decode_step(params: P, tokens: Array, cache: P, t: Array,
                cfg: ModelConfig) -> Tuple[Array, P]:
    """One token for the whole stack. tokens: (B, 1); t: scalar position.

    Returns (logits (B, 1, vocab), new cache). The layer loop is a scan with
    the per-layer cache rows as scanned-over/updated ys.
    """
    dt = jnp.dtype(cfg.dtype)
    h = embed_apply(params["embed"], tokens, dt)
    h = constrain(h, "data", None, None)
    emb0 = h if cfg.family == "hybrid" else None
    shared = params.get("shared_attn")
    idxs = jnp.arange(cfg.n_layers)

    if cfg.family == "hybrid" and cfg.attn_every:
        attn_kv = {"k": cache["attn_k"], "v": cache["attn_v"]}
        layer_cache = {k: v for k, v in cache.items()
                       if k not in ("attn_k", "attn_v")}
    else:
        attn_kv = None
        layer_cache = cache

    def body(carry, xs):
        h, attn_kv = carry
        layer_p, cache_row, idx = xs
        if shared is not None:
            def do_attn(args):
                h, kv = args
                app = idx // cfg.attn_every
                x = jnp.concatenate([h, emb0], -1) @ layer_shared_proj
                xn = rms_norm(x, shared["norm1"], cfg.norm_eps)
                row = {"k": kv["k"][app], "v": kv["v"][app]}
                a, row = attention_decode_layer(shared["attn"], xn, row, t, cfg)
                kv = {"k": kv["k"].at[app].set(row["k"]),
                      "v": kv["v"].at[app].set(row["v"])}
                x = x + a
                m = mlp_apply(shared["mlp"],
                              rms_norm(x, shared["norm2"], cfg.norm_eps), cfg)
                return h + x + m - h, kv

            layer_shared_proj = shared["proj_in"].astype(h.dtype)
            h, attn_kv = jax.lax.cond(idx % cfg.attn_every == 0, do_attn,
                                      lambda a: a, (h, attn_kv))
        h, new_row = _decode_block(layer_p, h, cache_row, t, cfg)
        return (h, attn_kv), new_row

    (h, attn_kv), new_cache = jax.lax.scan(
        body, (h, attn_kv), (params["layers"], layer_cache, idxs))
    if attn_kv is not None:
        new_cache = dict(new_cache)
        new_cache["attn_k"] = attn_kv["k"]
        new_cache["attn_v"] = attn_kv["v"]
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params["embed"], h, cfg)
    return logits, new_cache


def _decode_block(params: P, h: Array, cache_row: P, t: Array,
                  cfg: ModelConfig) -> Tuple[Array, P]:
    if cfg.family == "rwkv":
        from repro.models.blocks import layer_norm
        a, row = rwkv_mod.rwkv_decode_layer(
            params["mix"], layer_norm(h, params["ln1"], params["ln1b"],
                                      cfg.norm_eps), cache_row, cfg)
        h = h + a
        c, row = rwkv_mod.rwkv_channel_decode(
            params["mix"], layer_norm(h, params["ln2"], params["ln2b"],
                                      cfg.norm_eps), row, cfg)
        return h + c, row
    if cfg.family in ("ssm", "hybrid"):
        a, row = ssm_mod.ssm_decode_layer(
            params["mixer"], rms_norm(h, params["norm1"], cfg.norm_eps),
            cache_row, cfg)
        return h + a, row
    a, row = attention_decode_layer(
        params["attn"], rms_norm(h, params["norm1"], cfg.norm_eps),
        cache_row, t, cfg)
    h = h + cfg_residual_scale(cfg) * a
    x2 = rms_norm(h, params["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, _ = moe_mod.moe_layer(params["moe"], x2, cfg)
    else:
        m = mlp_apply(params["mlp"], x2, cfg)
    return h + cfg_residual_scale(cfg) * m, row
