"""Model + shape configuration dataclasses used across the framework."""

from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 512           # GShard routing-group size (tokens)

    # --- Mamba2 / SSM (zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    d_conv: int = 4
    attn_every: int = 0            # hybrid: shared attn block after every N ssm layers

    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    rwkv_pad_heads: int = 0        # pad wkv path to this head count (TP align)
    decay_lora: int = 64           # low-rank width of the data-dependent decay
    tshift_lora: int = 32          # low-rank width of the ddlerp token shift

    # --- attention details ---
    qkv_bias: bool = False
    residual_scale: float = 0.0    # MiniCPM scale_depth/sqrt(L); 0 = 1.0
    fsdp: bool = False             # ZeRO-3: shard params over `data` too
    pure_dp: bool = False          # no TP: ZeRO over (pod,data,model) axes
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full causal; set for long-context hybrid
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    learned_pos: bool = False      # whisper-style learned positional embedding
    max_position: int = 1 << 20

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_len: int = 1500        # stub conv-frontend output frames

    # --- VLM (pixtral) ---
    n_patches: int = 0             # stub patch-embedding prefix length

    # --- paper technique / execution options ---
    spiking_ffn: bool = False      # event-driven (spiking) FFN activations
    use_pallas: bool = False       # deployment kernels vs XLA reference path
    remat: str = "full"            # none | full | dots_saveable
    attn_impl: str = "auto"        # dense | blockwise | ring | auto
    scan_layers: bool = True
    dtype: str = "bfloat16"
    block_q: int = 512             # blockwise attention tile sizes
    block_kv: int = 1024
    ssm_chunk: int = 128           # mamba2 / rwkv6 chunk length

    # --- derived ---
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 32)

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def rwkv_heads(self) -> int:
        if self.rwkv_pad_heads:
            return self.rwkv_pad_heads
        return self.d_model // self.rwkv_head_dim

    @property
    def d_wkv(self) -> int:        # padded wkv-path width
        return self.rwkv_heads * self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128, vocab_size=256, head_dim=16,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        max_position=4096,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if cfg.attn_every:
            kw.update(attn_every=1)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, encoder_len=16)
    if cfg.family == "vlm":
        kw.update(n_patches=4)
    if cfg.family == "rwkv":
        kw.update(rwkv_head_dim=16, decay_lora=8, tshift_lora=8, ssm_chunk=8)
        kw.update(n_heads=4, n_kv_heads=4)
    return cfg.replace(**kw)
