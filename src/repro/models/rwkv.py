"""RWKV6 "Finch" — attention-free time mixing with data-dependent decay.

Per head (hd = head_dim), the wkv6 recurrence over state S: (hd, hd):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          w_t = exp(-exp(decay_t))
    y_t = (r_t S_t) + (r_t . k_t) * u * v_t      (u = bonus for current token)

This is the paper's DIFF primitive with a *data-dependent* tau — exactly the
heterogeneous-decay neuron TaiBai programs per-neuron, here programmed
per-token. The sequence path runs chunked: intra-chunk via MXU matmuls with
decay-weighted masks, inter-chunk carry via the `linrec` kernel over the
flattened (hd*hd) state — the same kernel that serves LIF membranes and the
Mamba2 scan.

Token-shift (ddlerp) uses low-rank data-dependent interpolation between x_t
and x_{t-1} per RWKV6; the channel-mix FFN uses squared-relu with its own
token shift.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.linrec import linrec
from repro.models.blocks import group_norm, truncated_normal
from repro.models.config import ModelConfig

Array = jax.Array


def rwkv_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    d = cfg.d_model
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dw = cfg.d_wkv          # head-padded wkv width (= d unless rwkv_pad_heads:
                            # 40 heads don't divide a 16-way model axis, so
                            # rwkv6-3b pads the wkv path to 48 heads — perf
                            # iter rwkv-1, EXPERIMENTS.md §Perf)
    L, Lt = cfg.decay_lora, cfg.tshift_lora
    ks = jax.random.split(key, 16)
    s = d ** -0.5
    return {
        # --- time mix (wkv6) ---
        "mu_x": 0.5 * jnp.ones((5, d)),             # base lerp for r,k,v,w,g
        "A_tsh": truncated_normal(ks[0], (d, 5 * Lt), s),        # ddlerp lora A
        "B_tsh": truncated_normal(ks[1], (5, Lt, d), Lt ** -0.5),
        "wr": truncated_normal(ks[2], (d, dw), s),
        "wk": truncated_normal(ks[3], (d, dw), s),
        "wv": truncated_normal(ks[4], (d, dw), s),
        "wg": truncated_normal(ks[5], (d, dw), s),
        "wo": truncated_normal(ks[6], (dw, d), dw ** -0.5),
        "w_base": -6.0 * jnp.ones((dw,)),           # decay base (logit space)
        "A_dec": truncated_normal(ks[7], (d, L), s),             # decay lora
        "B_dec": truncated_normal(ks[8], (L, dw), L ** -0.5),
        "u_bonus": jnp.zeros((H, hd)),
        "ln_x_w": jnp.ones((dw,)),
        "ln_x_b": jnp.zeros((dw,)),
        # --- channel mix ---
        "mu_ffn": 0.5 * jnp.ones((2, d)),
        "wk_ffn": truncated_normal(ks[9], (d, cfg.d_ff), s),
        "wv_ffn": truncated_normal(ks[10], (cfg.d_ff, d), cfg.d_ff ** -0.5),
        "wr_ffn": truncated_normal(ks[11], (d, d), s),
    }


def _token_shift(x: Array, x_prev: Optional[Array] = None) -> Array:
    """x_{t-1} along the sequence. x: (B, T, d); x_prev: (B, d) carry."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(params, x: Array, xs: Array) -> Tuple[Array, ...]:
    """Data-dependent lerp (RWKV6): five mixed tensors for r,k,v,w,g."""
    dt = x.dtype
    mu = params["mu_x"].astype(dt)                    # (5, d)
    base = x[:, :, None] + (xs - x)[:, :, None] * mu  # (B,T,5,d)
    lora = jnp.tanh(x @ params["A_tsh"].astype(dt))   # (B,T,5*Lt)
    B, T, _ = x.shape
    Lt = params["B_tsh"].shape[1]
    lora = lora.reshape(B, T, 5, Lt)
    adj = jnp.einsum("btfl,fld->btfd", lora, params["B_tsh"].astype(dt))
    mixed = base + (xs - x)[:, :, None] * adj
    return tuple(mixed[:, :, i] for i in range(5))


def _decay(params, xw: Array) -> Array:
    """Data-dependent per-channel log-decay: w = -exp(base + lora(xw)) <= 0."""
    dt = jnp.float32
    lora = jnp.tanh(xw.astype(dt) @ params["A_dec"].astype(dt)) @ \
        params["B_dec"].astype(dt)
    return -jnp.exp(params["w_base"].astype(dt) + lora)   # log w_t (<= 0)


def wkv6_chunked(r: Array, k: Array, v: Array, logw: Array, u: Array,
                 chunk: int, S0: Optional[Array] = None,
                 use_kernel: bool = False) -> Tuple[Array, Array]:
    """Chunked wkv6. r,k,v: (B, T, H, hd); logw: (B, T, H, hd) (<=0);
    u: (H, hd). Returns (y: (B, T, H, hd), S_T: (B, H, hd, hd)).

    Within a chunk, for t >= s (strict causality: s < t):
        y_t += r_t . (prod_{u=s+1..t} w_u) * k_s  v_s     [decay-masked MXU]
        y_t += (r_t . u . k_t) v_t                         [current-token bonus]
    Chunk-final states carry through the linrec (DIFF) kernel.
    """
    B, T, H, hd = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    f32 = jnp.float32

    rc = r.reshape(B, nc, chunk, H, hd).astype(f32)
    kc = k.reshape(B, nc, chunk, H, hd).astype(f32)
    vc = v.reshape(B, nc, chunk, H, hd).astype(f32)
    lw = logw.reshape(B, nc, chunk, H, hd).astype(f32)

    cum = jnp.cumsum(lw, axis=2)                      # prod_{u<=t} w_u (log)
    # RWKV6 applies decay AFTER use: y_t reads S_{t-1}, so the pairwise
    # decay product for s < t is prod_{u=s+1..t-1} w_u = exp(cum_{t} - lw_t
    # - cum_s). cum_prev carries the "to t-1" cumulative.
    cum_prev = cum - lw
    # guard: exp(-cum) can overflow for long chunks; stabilize per chunk by
    # shifting with the chunk-min (exact: factors cancel in the product).
    shift = jnp.min(cum, axis=2, keepdims=True)
    ri = rc * jnp.exp(cum_prev - shift)               # decay-in weights
    ki = kc * jnp.exp(shift - cum)                    # decay-out weights
    scores = jnp.einsum("bclhd,bcshd->bchls", ri, ki)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)   # strict lower
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y_intra = jnp.einsum("bchls,bcshd->bclhd", scores, vc)
    # current-token bonus
    bonus = jnp.einsum("bclhd,hd,bclhd->bclh", rc, u.astype(f32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # per-chunk state contribution: S_chunk = sum_s (prod_{u>s} w_u) k_s^T v_s
    total = cum[:, :, -1:]                            # (B,nc,1,H,hd)
    decay_to_end = jnp.exp(total - cum)               # prod_{u>s}
    states = jnp.einsum("bcshd,bcshe->bchde",
                        kc * decay_to_end, vc)        # (B,nc,H,hd,hd)

    # inter-chunk DIFF: S_c = diag(chunk_decay) S_{c-1} + states_c
    chunk_decay = jnp.exp(total[:, :, 0])             # (B,nc,H,hd)
    a_seq = jnp.broadcast_to(chunk_decay[..., None],
                             (B, nc, H, hd, hd)).reshape(B, nc, -1).swapaxes(0, 1)
    x_seq = states.reshape(B, nc, -1).swapaxes(0, 1)
    S_init = (jnp.zeros((B, H * hd * hd), f32) if S0 is None
              else S0.reshape(B, -1).astype(f32))
    carried, S_last = linrec(a_seq, x_seq, S_init, use_kernel)
    prev = jnp.concatenate([S_init[None], carried[:-1]], 0)
    prev = prev.swapaxes(0, 1).reshape(B, nc, H, hd, hd)

    # inter-chunk contribution: y_t += (r_t . prod_{u<=t-1} w_u) S_prev
    y_inter = jnp.einsum("bclhd,bchde->bclhe", rc * jnp.exp(cum_prev), prev)

    y = (y_intra + y_inter).reshape(B, T, H, hd)
    return y, S_last.reshape(B, H, hd, hd)


def rwkv_time_mix(params, x: Array, cfg: ModelConfig, *,
                  x_prev: Optional[Array] = None,
                  S0: Optional[Array] = None) -> Tuple[Array, Array, Array]:
    """Full-sequence time mix. Returns (out, last_x, S_T)."""
    B, T, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dw = cfg.d_wkv
    dt = x.dtype
    # NOTE (perf iter rwkv-2, REFUTED): forcing x replicated here to fuse
    # the five ddlerp input gathers made X/M ~20% WORSE — XLA's sharding
    # propagation already places the gathers better than the manual
    # Megatron-style pattern. Left unconstrained on purpose.
    xs = _token_shift(x, x_prev)
    xr, xk, xv, xw, xg = _ddlerp(params, x, xs)
    r = (xr @ params["wr"].astype(dt)).reshape(B, T, H, hd)
    k = (xk @ params["wk"].astype(dt)).reshape(B, T, H, hd)
    v = (xv @ params["wv"].astype(dt)).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ params["wg"].astype(dt))
    logw = _decay(params, xw).reshape(B, T, H, hd)
    y, S_T = wkv6_chunked(r, k, v, logw, params["u_bonus"],
                          min(cfg.ssm_chunk, T), S0,
                          use_kernel=cfg.use_pallas)
    y = y.reshape(B, T, dw).astype(dt)
    y = group_norm(y, params["ln_x_w"], params["ln_x_b"], H, 64e-5)
    return (y * g) @ params["wo"].astype(dt), x[:, -1], S_T


def rwkv_channel_mix(params, x: Array, cfg: ModelConfig, *,
                     x_prev: Optional[Array] = None) -> Tuple[Array, Array]:
    """Squared-relu channel mix with token shift. Returns (out, last_x)."""
    dt = x.dtype
    xs = _token_shift(x, x_prev)
    mu = params["mu_ffn"].astype(dt)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params["wk_ffn"].astype(dt)))
    kv = k @ params["wv_ffn"].astype(dt)
    return jax.nn.sigmoid(xr @ params["wr_ffn"].astype(dt)) * kv, x[:, -1]


def rwkv_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_tmix": jnp.zeros((batch, cfg.d_model), dtype),
        "x_cmix": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv_decode_layer(params, x: Array, cache: Dict[str, Array],
                      cfg: ModelConfig) -> Tuple[Array, Dict[str, Array]]:
    """One-token step for both mixers. x: (B, 1, d)."""
    B, _, d = x.shape  # note: wkv path runs at cfg.d_wkv (head-padded)
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = x.dtype
    # --- time mix (serial form: S = diag(w) S + k^T v) ---
    xs = cache["x_tmix"][:, None]
    xr, xk, xv, xw, xg = _ddlerp(params, x, xs)
    r = (xr @ params["wr"].astype(dt)).reshape(B, H, hd).astype(jnp.float32)
    k = (xk @ params["wk"].astype(dt)).reshape(B, H, hd).astype(jnp.float32)
    v = (xv @ params["wv"].astype(dt)).reshape(B, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"].astype(dt))[:, 0]
    w = jnp.exp(_decay(params, xw).reshape(B, H, hd))      # (B,H,hd)
    u = params["u_bonus"].astype(jnp.float32)
    S = cache["S"]                                          # (B,H,hd,hd)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = jnp.einsum("bhd,bhde->bhe", r, S + u[None, :, :, None] * kv)
    S = w[..., None] * S + kv
    y = y.reshape(B, cfg.d_wkv).astype(dt)
    y = group_norm(y, params["ln_x_w"], params["ln_x_b"], H, 64e-5)
    out_t = (y * g) @ params["wo"].astype(dt)
    return out_t[:, None], dict(cache, S=S, x_tmix=x[:, 0])


def rwkv_channel_decode(params, x: Array, cache: Dict[str, Array],
                        cfg: ModelConfig) -> Tuple[Array, Dict[str, Array]]:
    out, last = rwkv_channel_mix(params, x, cfg, x_prev=cache["x_cmix"])
    return out, dict(cache, x_cmix=last)
