"""GQA attention: dense, blockwise (memory-efficient), and KV-cache decode.

Three execution paths, selected by `cfg.attn_impl` (or 'auto'):

  dense      — materializes (B, H, T, S) scores. Fine for short seq / smoke.
  blockwise  — FlashAttention-style online softmax as a lax.scan over KV
               blocks nested in a scan over Q blocks. Memory O(T·d) instead
               of O(T²); the inner body is rematerialized in backward. This
               is the XLA reference path used for the roofline; the Pallas
               `kernels/attention` is the numerically-identical deployment
               kernel.
  decode     — one new token against a (B, S, Hkv, hd) KV cache.

Sliding-window masking (zamba2 long-context hybrid blocks) is supported in
all paths. All paths share one parameter layout, initialized in `attn_init`.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import apply_rope, truncated_normal
from repro.models.config import ModelConfig

Array = jax.Array

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (d, H * hd), s),
        "wk": truncated_normal(ks[1], (d, Hk * hd), s),
        "wv": truncated_normal(ks[2], (d, Hk * hd), s),
        "wo": truncated_normal(ks[3], (H * hd, d), (H * hd) ** -0.5),
    }
    if cfg.qkv_bias:  # qwen2
        p["bq"] = jnp.zeros((H * hd,))
        p["bk"] = jnp.zeros((Hk * hd,))
        p["bv"] = jnp.zeros((Hk * hd,))
    return p


def qkv_project(params, x: Array, cfg: ModelConfig, positions: Array
                ) -> Tuple[Array, Array, Array]:
    """x: (B, T, d) -> q (B, T, H, hd), k/v (B, T, Hk, hd), RoPE applied."""
    B, T, _ = x.shape
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, cfg.hd)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.hd)
    if not cfg.learned_pos:  # whisper uses learned positions, no RoPE
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: Array, kv_pos: Array, causal: bool, window: int,
               kv_valid: Optional[Array] = None) -> Array:
    """(..., Tq, Tk) additive mask. window>0 limits lookback (sliding)."""
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok = ok & (d >= 0)
    if window > 0:
        ok = ok & (d < window)
    if kv_valid is not None:
        ok = ok & kv_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _repeat_kv(k: Array, n_rep: int) -> Array:
    """(B, S, Hk, hd) -> (B, S, Hk*n_rep, hd) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    B, S, Hk, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hk, n_rep, hd)
                            ).reshape(B, S, Hk * n_rep, hd)


# ---------------------------------------------------------------------------
# dense path
# ---------------------------------------------------------------------------


def dense_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_pos: Optional[Array] = None,
                    kv_pos: Optional[Array] = None) -> Array:
    """q: (B, T, H, hd); k/v: (B, S, Hk, hd). Returns (B, T, H, hd)."""
    B, T, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // Hk)
    v = _repeat_kv(v, H // Hk)
    if q_pos is None:
        q_pos = jnp.arange(T)
    if kv_pos is None:
        kv_pos = jnp.arange(S)
    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5) + _mask_bias(q_pos, kv_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bshd->bthd", probs, v)


# ---------------------------------------------------------------------------
# blockwise (memory-efficient / flash-style) path
# ---------------------------------------------------------------------------


def _pad_to(x: Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def blockwise_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, block_q: int = 512,
                        block_kv: int = 1024) -> Array:
    """Online-softmax attention, O(T·d) memory.

    Outer scan over Q blocks; inner scan over KV blocks carries
    (acc, row_max, row_sum). The inner body is jax.checkpoint'ed so backward
    recomputes block scores instead of storing the (T, S) probability matrix
    — the same storage/recompute trade the paper's accumulated-spike
    learning makes on-chip (§IV-B).
    """
    B, T0, H, hd = q.shape
    Hk = k.shape[2]
    k = _repeat_kv(k, H // Hk)
    v = _repeat_kv(v, H // Hk)
    q, T = _pad_to(q, 1, block_q)
    k, S = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    Tp, Sp = q.shape[1], k.shape[1]
    nq, nk = Tp // block_q, Sp // block_kv
    scale = hd ** -0.5

    # (nq, B, block, H, hd) blocks; scan over leading axis
    qb = q.reshape(B, nq, block_q, H, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, block_kv, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_kv, H, hd).transpose(1, 0, 2, 3, 4)
    kv_valid = (jnp.arange(Sp) < S).reshape(nk, 1, block_kv)  # (nk, 1, bkv)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def kv_body(carry, inp, q_i, q_pos):
        acc, m, lsum = carry                    # (B,bq,H,hd), (B,H,bq), (B,H,bq)
        k_j, v_j, valid_j, j = inp
        kv_pos = j * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bthd,bshd->bhts", q_i, k_j).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, kv_pos, causal, window,
                           jnp.broadcast_to(valid_j, (1, block_kv)))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lsum = lsum * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhts,bshd->bthd", p.astype(q_i.dtype), v_j).astype(jnp.float32)
        return (acc, m_new, lsum), None

    def q_body(_, inp):
        q_i, i = inp
        q_pos = i * block_q + jnp.arange(block_q)
        acc0 = jnp.zeros((B, block_q, H, hd), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)

        # causal: skip KV blocks strictly after this Q block's last row.
        (acc, m, lsum), _ = jax.lax.scan(
            functools.partial(kv_body, q_i=q_i, q_pos=q_pos),
            (acc0, m0, l0), (kb, vb, kv_valid, jnp.arange(nk)))
        out = acc / jnp.maximum(lsum, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out.astype(q_i.dtype)

    _, ob = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, hd)
    return out[:, :T0]


# ---------------------------------------------------------------------------
# decode path (one token vs cache)
# ---------------------------------------------------------------------------


def decode_attention(q: Array, k_cache: Array, v_cache: Array, t: Array, *,
                     window: int = 0) -> Array:
    """q: (B, 1, H, hd); caches: (B, S, Hk, hd); t: current position (scalar).

    Attends to cache positions < t+1 (the cache holds positions 0..t).
    """
    B, _, H, hd = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    k = _repeat_kv(k_cache, H // Hk)
    v = _repeat_kv(v_cache, H // Hk)
    kv_pos = jnp.arange(S)
    valid = kv_pos <= t
    if window > 0:
        valid = valid & (kv_pos > t - window)
    s = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bshd->bthd", p, v)


# ---------------------------------------------------------------------------
# full layer entry points
# ---------------------------------------------------------------------------


def _impl_attention(q: Array, k: Array, v: Array, cfg: ModelConfig,
                    causal: bool) -> Array:
    """cfg.attn_impl selection shared by every full-sequence caller:
    long sequences must take the O(T*d)-memory blockwise path."""
    T = q.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "blockwise" if T > 2048 else "dense"
    if impl == "blockwise":
        return blockwise_attention(q, k, v, causal=causal,
                                   window=cfg.sliding_window,
                                   block_q=cfg.block_q, block_kv=cfg.block_kv)
    return dense_attention(q, k, v, causal=causal, window=cfg.sliding_window)


def attention_layer(params, x: Array, cfg: ModelConfig, *,
                    positions: Optional[Array] = None,
                    causal: bool = True) -> Array:
    """Self-attention over a full sequence (train / prefill)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :]
    q, k, v = qkv_project(params, x, cfg, positions)
    o = _impl_attention(q, k, v, cfg, causal)
    return o.reshape(B, T, -1) @ params["wo"].astype(x.dtype)


def attention_decode_layer(params, x: Array, cache: Dict[str, Array],
                           t: Array, cfg: ModelConfig
                           ) -> Tuple[Array, Dict[str, Array]]:
    """One decode step. x: (B, 1, d); cache: {k,v}: (B, S, Hk, hd)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), t, jnp.int32)
    q, k_new, v_new = qkv_project(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), t, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), t, axis=1)
    o = decode_attention(q, k_cache, v_cache, t, window=cfg.sliding_window)
    out = o.reshape(B, 1, -1) @ params["wo"].astype(x.dtype)
    return out, {"k": k_cache, "v": v_cache}


def attention_prefill_layer(params, x: Array, cache: Dict[str, Array],
                            positions: Array, cfg: ModelConfig
                            ) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence prefill against an EMPTY cache. x: (B, L, d).

    Computes causal self-attention over the prompt itself (the cache holds
    nothing yet, so the prompt is the whole visible context) and writes K/V
    for positions [0, L) into the cache in one shot — the batched
    equivalent of L `attention_decode_layer` calls.
    """
    B, L, _ = x.shape
    q, k_new, v_new = qkv_project(params, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), 0, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), 0, axis=1)
    o = _impl_attention(q, k_new, v_new, cfg, causal=True)
    out = o.reshape(B, L, -1) @ params["wo"].astype(x.dtype)
    return out, {"k": k_cache, "v": v_cache}


def cross_attention_layer(params, x: Array, memory_kv: Tuple[Array, Array],
                          cfg: ModelConfig) -> Array:
    """Whisper decoder cross-attention against precomputed encoder K/V.

    Long decoder sequences use the blockwise (online-softmax) path: the
    dense form materializes (B, H, T, S_enc) — measured 316 GB/device temp
    on the whisper train_4k dry-run cell; blockwise cut the cell to 205 GB
    (-35%; the rest is encoder attention + remat buffers — EXPERIMENTS.md
    §Perf, post-hillclimb probes)."""
    B, T, _ = x.shape
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, T, cfg.n_heads, cfg.hd)
    k, v = memory_kv
    if T > 2048:
        o = blockwise_attention(q, k, v, causal=False,
                                block_q=cfg.block_q, block_kv=cfg.block_kv)
    else:
        o = dense_attention(q, k, v, causal=False)
    return o.reshape(B, T, -1) @ params["wo"].astype(dt)


def cross_kv(params, memory: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Precompute cross-attention K/V from encoder output (B, S, d)."""
    B, S, _ = memory.shape
    dt = memory.dtype
    k = (memory @ params["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (memory @ params["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v
