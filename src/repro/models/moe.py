"""Mixture-of-Experts FFN: top-k router + capacity-bounded GROUPED dispatch.

The paper-connection (DESIGN.md §6): top-k expert routing IS event-driven
regional multicast — a token "fires" toward k of E experts exactly as a
TaiBai spike packet multicasts to a destination region; the dispatch tensor
below is a materialized fan-out Information Table (type 2, parallel-send).
The event sparsity the chip exploits per-spike, the TPU exploits per-token:
only top-k/E of the expert FLOPs execute.

GROUPED routing (GShard): tokens are routed within groups of `moe_group`
tokens, so the one-hot dispatch/combine tensors are (G, g, E, C_g) with
C_g = cap·k·g/E — dispatch cost 2·Bt·E·C_g·d scales with GROUP size, not
global batch. [Perf log, EXPERIMENTS.md §Perf olmoe-iter-1: the ungrouped
form made dispatch O(Bt^2): 88.9 s compute / 179 s memory per step at
train_4k; grouping was the first fix.]

Dense one-hot einsums keep shapes static and shard cleanly: groups over
`data`, experts over `model` (EP). Aux losses: load-balance (Switch) +
router z-loss (ST-MoE).

olmoe-1b-7b: 64 experts, top-8;  phi3.5-moe: 16 experts, top-2.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import truncated_normal
from repro.models.config import ModelConfig
from repro.sharding.rules import constrain

Array = jax.Array


def moe_init(key, cfg: ModelConfig) -> Dict[str, Array]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": truncated_normal(ks[0], (d, E), s_in),
        "w_gate": truncated_normal(ks[1], (E, d, f), s_in),
        "w_up": truncated_normal(ks[2], (E, d, f), s_in),
        "w_down": truncated_normal(ks[3], (E, f, d), s_out),
    }


def _capacity(group: int, cfg: ModelConfig) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * group / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)     # sublane-aligned


def _group_size(n_tokens: int, cfg: ModelConfig) -> int:
    g = min(cfg.moe_group, n_tokens)
    while n_tokens % g:
        g -= 1
    return g


def route(params, x: Array, cfg: ModelConfig
          ) -> Tuple[Array, Array, Dict[str, Array]]:
    """Grouped top-k routing with capacity. x: (G, g, d).

    Returns:
      dispatch: (G, g, E, C) 0/1 — token -> (expert, slot)  [fan-out table]
      combine:  (G, g, E, C)     — dispatch * router prob    [weighted return]
      aux: {lb_loss, z_loss}
    """
    G, g, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(g, cfg)
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, g, E)

    _, top_idx = jax.lax.top_k(probs, K)                     # (G, g, K)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)   # (G, g, K, E)

    # capacity slots: priority k-major then token order, per group
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * g, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                     # (G, K*g, E)
    pos = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)      # (G, g, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (G, g, K)
    fits = pos < C

    slot_onehot = jax.nn.one_hot(pos.astype(jnp.int32), C,
                                 dtype=jnp.float32) * fits[..., None]
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, slot_onehot)

    gate = jnp.take_along_axis(probs, top_idx, axis=-1)      # (G, g, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, slot_onehot, gate)

    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))       # fraction routed
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * frac_probs) / cfg.top_k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return dispatch.astype(x.dtype), combine.astype(x.dtype), {
        "lb_loss": lb_loss, "z_loss": z_loss}


def moe_layer(params, x: Array, cfg: ModelConfig
              ) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, T, d) -> (B, T, d), plus aux losses.

    Expert compute is einsum over the (G, E, C, d) dispatched block — under
    EP sharding (experts over `model`, groups over `data`) XLA turns the
    dispatch/combine einsums into all-to-alls, exactly the chip's spike-
    packet exchange.
    """
    B, T, d = x.shape
    n_tokens = B * T
    g = _group_size(n_tokens, cfg)
    G = n_tokens // g
    xg = x.reshape(G, g, d)
    dispatch, combine, aux = route(params, xg, cfg)
    dt = x.dtype
    # pin the EP layout: groups over data, experts over model — the
    # dispatch/combine einsums then lower to all-to-alls (token exchange),
    # not all-gathers of the full expert buffers
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)   # (G, E, C, d)
    expert_in = constrain(expert_in, "data", "model", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                               params["w_gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"].astype(dt))
    h = constrain(h, "data", "model", None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dt))
    expert_out = constrain(expert_out, "data", "model", None, None)
    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    return out.reshape(B, T, d), aux
