"""Checkpointing: chunked npz shards + JSON manifest with integrity hashes.

Design constraints (DESIGN.md §4):
  * mesh-agnostic — tensors are saved in LOGICAL (unsharded) layout; restore
    re-shards onto whatever mesh the restarted job has (elastic rescale:
    512 -> 256 chips restores fine).
  * chunked — leaves are grouped into ~CHUNK_BYTES .npz shards so a 1000-node
    cluster's hosts can write/read in parallel (here: one process writes all
    shards; the layout is what matters).
  * integrity — every shard carries a crc32 in the manifest; restore verifies
    before handing tensors to jax (a half-written shard from a preempted node
    fails loudly, and the manager falls back to the previous step).
  * async — `save_async` hands the host copy to a writer thread; training
    continues; `wait()` joins before the next save (bounded staleness 1).

`StreamCheckpointer` builds on the manager for always-on chunked-online
SNN runs: one snapshot per window captures the full streaming tree —
`state[node]` neuron tensors, `syn:<conn>` plasticity state, params, and
the host RNG key — and restores it bit-identically (same-dtype leaves
round-trip exactly), so an interrupted stream resumes mid-sequence with
no numerical drift.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16/fp8 numpy dtypes)
import numpy as np

CHUNK_BYTES = 256 * 1024 * 1024

# numpy .npz cannot serialize ml_dtypes extension types; store them as raw
# unsigned views and reconstruct from the manifest's dtype record.
_RAW_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _to_savable(arr: np.ndarray):
    if arr.dtype.kind in "fiub?" and arr.dtype.name != "bfloat16":
        return arr, str(arr.dtype)
    view = arr.view(_RAW_VIEW[arr.dtype.itemsize])
    return view, str(arr.dtype)


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    return arr.view(np.dtype(dtype_name))


def _flatten(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, np.asarray(leaf)))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Write step checkpoint atomically (tmp dir + rename)."""
    leaves, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    shards: List[List[Tuple[str, np.ndarray]]] = [[]]
    size = 0
    for name, arr in leaves:
        if size > CHUNK_BYTES:
            shards.append([])
            size = 0
        shards[-1].append((name, arr))
        size += arr.nbytes

    manifest = {"step": step, "extra": extra or {}, "shards": []}
    for i, shard in enumerate(shards):
        fname = f"shard_{i:05d}.npz"
        arrays, dtypes = {}, {}
        for name, arr in shard:
            savable, dt = _to_savable(arr)
            arrays[name.replace("/", "%")] = savable
            dtypes[name] = dt
        path = os.path.join(tmp, fname)
        np.savez(path, **arrays)
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["shards"].append(
            {"file": fname, "crc32": crc,
             "names": [n for n, _ in shard], "dtypes": dtypes})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def _load_arrays(path: str) -> Dict[str, np.ndarray]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for sh in manifest["shards"]:
        fpath = os.path.join(path, sh["file"])
        with open(fpath, "rb") as f:
            if zlib.crc32(f.read()) != sh["crc32"]:
                raise IOError(f"checksum mismatch in {fpath}")
        dtypes = sh.get("dtypes", {})
        with np.load(fpath) as z:
            for key in z.files:
                name = key.replace("%", "/")
                arr = z[key]
                if name in dtypes:
                    arr = _from_savable(arr, dtypes[name])
                out[name] = arr
    return out


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `like`, placing each leaf with its
    sharding (None = jax default device placement)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    arrays = _load_arrays(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (pathk, leaf), shd in zip(leaves, shard_leaves):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pathk)
        arr = arrays[name]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{name}: ckpt {arr.shape} != model {leaf.shape}")
        if shd is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), shd))
        else:
            out.append(jax.numpy.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """keep-last-k manager with an async writer thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)     # device->host now

        def work():
            save_checkpoint(self.dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        save_checkpoint(self.dir, step, jax.tree.map(np.asarray, tree), extra)
        self._gc()

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Tuple[Optional[int], Any]:
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, like
        try:
            return step, restore_checkpoint(self.dir, step, like, shardings)
        except Exception:
            # half-written / corrupt latest: fall back one step
            steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                           if d.startswith("step_"))
            for s in reversed(steps[:-1]):
                try:
                    return s, restore_checkpoint(self.dir, s, like, shardings)
                except Exception:
                    continue
            raise

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)


class StreamCheckpointer:
    """Durable snapshots of a chunked-online streaming run.

    One snapshot per processed window holds the complete resume tree:
    the engine state dict (neuron states, rings, `syn:<conn>` plasticity
    tensors), the current params (carrying weights already merged by
    `plasticity.apply_learned`), and the host-side RNG key driving the
    input stream. Restoring the latest snapshot and replaying from the
    recorded window is bit-identical to never having stopped: npz
    round-trips same-dtype leaves exactly, and `restore_checkpoint`
    coerces with `jnp.asarray(arr, leaf.dtype)` (a no-op cast).

    ``save`` is synchronous by default — a streaming snapshot must be
    durable before its window's effects are published downstream; pass
    ``sync=False`` for the async writer (bounded staleness 1).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.manager = CheckpointManager(ckpt_dir, keep)

    @staticmethod
    def _tree(state: Any, params: Any, rng: Any) -> Dict[str, Any]:
        # None members flatten to empty subtrees, so save/restore stay
        # structurally consistent as long as the caller is consistent
        return {"state": state, "params": params, "rng": rng}

    def save(self, window: int, state: Any, params: Any = None,
             rng: Any = None, extra: Optional[Dict] = None,
             sync: bool = True) -> None:
        """Snapshot the streaming tree after window `window` completed."""
        tree = self._tree(state, params, rng)
        meta = {"window": int(window), **(extra or {})}
        if sync:
            self.manager.save_sync(window, tree, extra=meta)
        else:
            self.manager.save_async(window, tree, extra=meta)

    def restore_latest(self, state: Any, params: Any = None, rng: Any = None
                       ) -> Tuple[Optional[int], Any, Any, Any]:
        """-> (last completed window or None, state, params, rng).

        The passed trees are templates (shapes/dtypes) AND the cold-start
        values: with no checkpoint on disk they come back unchanged with
        window None, so callers can write one resume loop for both cases.
        """
        window, tree = self.manager.restore_latest(
            self._tree(state, params, rng))
        if window is None:
            return None, state, params, rng
        return window, tree["state"], tree["params"], tree["rng"]

    def wait(self) -> None:
        self.manager.wait()
