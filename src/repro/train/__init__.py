"""train — fault-tolerant training runtime.

checkpoint.py   chunked-npz checkpoints with manifest + integrity hashes,
                mesh-agnostic restore (save logical, reshard on load),
                async save, keep-last-k, preemption-signal emergency save
loop.py         the driver: restore-on-start, periodic checkpointing,
                straggler detection, metrics, deterministic data skip-ahead
"""

from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.loop import TrainLoopConfig, train_loop
