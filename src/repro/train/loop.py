"""The training driver: restore -> step -> checkpoint, with failure handling.

Fault-tolerance posture (DESIGN.md §4), all exercised by tests:
  * restore-on-start from the latest intact checkpoint (corrupt/partial
    checkpoints are skipped by the manager);
  * periodic async checkpoints (training is never blocked by I/O);
  * preemption: SIGTERM/SIGINT trigger one synchronous emergency save;
  * deterministic data skip-ahead — the TokenStream is indexed by step, so
    resume needs no data-state;
  * straggler mitigation: per-step wall times tracked with an EWMA; steps
    slower than `straggler_factor` x EWMA are counted and logged (on a real
    cluster this feeds the controller that re-shards around slow hosts;
    within-step slack comes from gradient-accumulation microbatches);
  * elastic rescale: checkpoints are logical, so a restart may present a
    different mesh/data width — restore re-shards (tests cover save on one
    "mesh", restore on another).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 200
    keep: int = 3
    log_every: int = 20
    straggler_factor: float = 3.0
    metrics_hook: Optional[Callable[[int, Dict[str, float]], None]] = None


@dataclasses.dataclass
class TrainReport:
    start_step: int
    end_step: int
    losses: List[float]
    step_times: List[float]
    stragglers: int
    restored: bool


def train_loop(step_fn: Callable, state: Any, batches: Callable[[int], Any],
               loop_cfg: TrainLoopConfig, state_shardings: Any = None
               ) -> tuple[Any, TrainReport]:
    """Run `step_fn(state, batch) -> (state, metrics)` with full FT plumbing.

    `batches(step)` returns the batch for a global step (deterministic
    skip-ahead). `state_shardings` (optional) re-shards on restore.
    """
    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    start, state = mgr.restore_latest(state, state_shardings)
    restored = start is not None
    start = (start or 0)

    interrupted = {"flag": False}

    def on_signal(signum, frame):
        interrupted["flag"] = True

    old_term = signal.signal(signal.SIGTERM, on_signal)
    old_int = signal.signal(signal.SIGINT, on_signal)

    losses: List[float] = []
    times: List[float] = []
    ewma = None
    stragglers = 0
    step = start
    try:
        for step in range(start, loop_cfg.total_steps):
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batches(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > loop_cfg.straggler_factor * ewma and len(times) > 5:
                stragglers += 1
            if loop_cfg.metrics_hook and step % loop_cfg.log_every == 0:
                loop_cfg.metrics_hook(step, {k: float(v)
                                             for k, v in metrics.items()})
            if (step + 1) % loop_cfg.ckpt_every == 0:
                mgr.save_async(step + 1, state)
            if interrupted["flag"]:
                mgr.save_sync(step + 1, state)     # emergency checkpoint
                break
        else:
            step = loop_cfg.total_steps - 1
        if not interrupted["flag"]:
            mgr.save_sync(loop_cfg.total_steps, state)
    finally:
        mgr.wait()
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
    return state, TrainReport(start, step + 1, losses, times, stragglers,
                              restored)
