"""Synthetic spike datasets, shape/statistics-faithful to the paper (§V-B3).

QTDB, SHD and the macaque BCI recordings are not redistributable inside this
container, so each generator reproduces the *documented* dimensions and
first-order statistics; the benchmarks report relative (heterogeneous vs
homogeneous) orderings, which is what these generators support.

  gen_ecg_qtdb   759-record-style waveforms: six bands (P, PQ, QR, RS, ST,
                 TP) cycled per beat, level-crossing coded -> (T=1301, 4)
                 spike channels (2 leads x {+,-}), labels per timestep.
  gen_shd_spikes Heidelberg SHD-style: (T, 700) binary rasters, 20 classes,
                 class-dependent cochlear activation center; input spike
                 rate calibrated to the paper's measured 1.2 %.
  gen_bci_trials M1-style: 128 channels x 50 bins (20 ms), 4 movement
                 classes, with a per-"day" drift parameter — cross-day
                 decoding (the paper's fine-tuning task) needs day shift.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def level_crossing_encode(x: np.ndarray, delta: float = 0.1) -> np.ndarray:
    """Level-crossing coding (paper §V-B3): continuous (T, C) -> spike
    (T, 2C): one positive and one negative channel per input channel."""
    T, C = x.shape
    out = np.zeros((T, 2 * C), np.float32)
    ref = x[0].copy()
    for t in range(1, T):
        up = x[t] > ref + delta
        dn = x[t] < ref - delta
        out[t, :C] = up
        out[t, C:] = dn
        ref = np.where(up | dn, x[t], ref)
    return out


def gen_ecg_qtdb(n: int, seed: int = 0, T: int = 1301
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """-> spikes (n, T, 4), labels (n, T) in [0, 6). Two synthetic leads."""
    rng = np.random.default_rng(seed)
    # band template durations (fractions of one beat) for P,PQ,QR,RS,ST,TP
    frac = np.array([0.12, 0.08, 0.10, 0.10, 0.20, 0.40])
    spikes = np.zeros((n, T, 4), np.float32)
    labels = np.zeros((n, T), np.int64)
    for i in range(n):
        beat = int(rng.integers(180, 260))
        durs = np.maximum(2, (frac * beat).astype(int))
        amps = {0: 0.25, 1: 0.02, 2: 1.2, 3: -0.9, 4: 0.15, 5: 0.01}
        sig = np.zeros(T)
        lab = np.zeros(T, np.int64)
        t = int(rng.integers(0, beat))
        while t < T:
            for band, d in enumerate(durs):
                seg = min(d, T - t)
                if seg <= 0:
                    break
                phase = np.linspace(0, np.pi, seg)
                sig[t:t + seg] = amps[band] * np.sin(phase) \
                    + 0.02 * rng.standard_normal(seg)
                lab[t:t + seg] = band
                t += seg
            if t >= T:
                break
        lead2 = 0.6 * sig + 0.02 * rng.standard_normal(T)
        spikes[i] = level_crossing_encode(
            np.stack([sig, lead2], 1), delta=0.05)
        labels[i] = lab
    return spikes, labels


def gen_shd_spikes(n: int, T: int = 100, seed: int = 0, n_in: int = 700,
                   n_classes: int = 20) -> Tuple[np.ndarray, np.ndarray]:
    """-> spikes (n, T, 700) with ~1.2% rate, labels (n,) in [0, 20)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n)
    spikes = np.zeros((n, T, n_in), np.float32)
    ch = np.arange(n_in)
    for i in range(n):
        c = labels[i]
        center = (c + 0.5) * n_in / n_classes
        width = n_in / n_classes * 1.5
        prof = np.exp(-0.5 * ((ch - center) / width) ** 2)     # cochlear bump
        # temporal envelope: onset sweep with class-dependent velocity
        tt = np.arange(T)[:, None]
        drift = center + (c % 5 - 2) * 1.2 * tt / T * width
        prof_t = np.exp(-0.5 * ((ch[None] - drift) / width) ** 2)
        rate = 0.012 * n_in / prof.sum() * prof_t              # ~1.2% mean
        spikes[i] = rng.random((T, n_in)) < rate
    return spikes, labels


def gen_bci_trials(n: int, day: int = 0, seed: int = 0, n_channels: int = 128,
                   n_bins: int = 50, n_classes: int = 4
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """-> rates (n, 128, 50) binned firing, labels (n,) in [0, 4).

    `day` adds a fixed random rotation + gain drift to the channel tuning —
    the cross-day distribution shift the paper's on-chip fine-tuning corrects.
    """
    rng = np.random.default_rng(seed)
    day_rng = np.random.default_rng(1000 + day)
    # The class->channel tuning defines the TASK and must be identical for
    # every (seed, day): only trial noise varies with `seed`, only the
    # drift/gain shift with `day`. Drawing it from `rng` (as this function
    # originally did) gave each seed a different task, so cross-day
    # fine-tuning could never transfer.
    task_rng = np.random.default_rng(424242)
    base_tuning = task_rng.standard_normal((n_classes, n_channels))
    drift = 0.35 * day * day_rng.standard_normal((n_channels,))
    gain = 1.0 + 0.1 * day * day_rng.standard_normal((n_channels,))
    labels = rng.integers(0, n_classes, n)
    t_env = np.sin(np.linspace(0, np.pi, n_bins))              # movement env
    x = np.empty((n, n_channels, n_bins), np.float32)
    for i in range(n):
        mu = gain * (base_tuning[labels[i]] + drift)
        x[i] = (mu[:, None] * t_env[None, :]
                + 0.8 * rng.standard_normal((n_channels, n_bins)))
    return x, labels
