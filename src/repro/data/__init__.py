"""data — deterministic synthetic pipelines.

tokens.py   LM token stream: stateless, indexed by (step, shard), so a
            restarted/rescaled job resumes mid-stream without replaying
            (fault-tolerance: skip-ahead is O(1)); markov-chain structure so
            loss actually decreases.
spikes.py   shape/statistics-faithful generators for the paper's three
            applications (QTDB ECG, SHD speech, macaque M1 BCI) — the real
            datasets are not redistributable here; generators are documented
            against the paper's stated dimensions.
"""

from repro.data.tokens import TokenStream
from repro.data.spikes import (gen_ecg_qtdb, gen_shd_spikes, gen_bci_trials,
                               level_crossing_encode)
