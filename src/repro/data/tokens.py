"""Deterministic synthetic LM token stream (markov-chain text).

Every batch is a pure function of (seed, step, shard_index) — the property
the fault-tolerance story needs: a job restarted at step S, or rescaled to a
different data-parallel width, regenerates exactly the stream it would have
seen, with no state to checkpoint and O(1) skip-ahead.

The stream is a vocab-sized markov chain with a few hundred high-probability
transitions (so a real model can learn it: loss drops well below ln(V)) plus
uniform noise tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch: int                      # per-shard (host-local) batch
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    order: int = 3                  # markov order (determinism window)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """{"tokens": (batch, seq_len+1) int32} for this (step, shard)."""
        rng = self._rng(step)
        V = self.vocab_size
        # structured chain: next = (a*tok + b) % V with prob 0.8, noise else
        a = 31 + 2 * (self.seed % 50)
        b = 17
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, self.batch)
        noise = rng.random((self.batch, self.seq_len))
        rand = rng.integers(0, V, (self.batch, self.seq_len))
        for t in range(self.seq_len):
            nxt = (a * toks[:, t] + b) % V
            toks[:, t + 1] = np.where(noise[:, t] < 0.8, nxt, rand[:, t])
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
