"""Ragged continuous batching: cohort assembly, admission, backpressure.

Sessions arrive with uneven rates and lengths; the engine's scheduling
quantum is one fixed-length window of `window` timesteps (the
chunked-online quantum `plan.run` state already round-trips at). The
scheduler's job is to pack whichever sessions have a runnable window into
fixed-shape cohorts — (window, capacity, n_in) — so the resident jitted
step never retraces, while staying fair and bounded:

  * **Readiness.** A session is schedulable when it has `window` buffered
    timesteps, or it is closed with a partial tail (which is zero-padded
    for shape and trimmed on output — padded state never feeds a later
    real step because closed means no more input).
  * **Fairness.** The ready queue is FIFO; a session served this window
    re-enters at the *tail* if still ready, so a firehose tenant streams
    at most one window ahead per cohort of everyone else (round-robin at
    window granularity).
  * **Admission control.** Total buffered-but-unserved windows across all
    sessions are bounded by `queue_limit`; a submit that would exceed it
    is rejected — the caller sees `False` (backpressure) and the
    rejection is recorded on the incident log (kind="serve",
    stage="admission") so operators can see shed load. `record()` only:
    shedding is the *designed* response, not a degradation to raise on.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels.incidents import FallbackEvent, record
from repro.serve.metrics import ServeMetrics
from repro.serve.sessions import Session


class Scheduler:
    def __init__(self, window: int, n_in: int,
                 queue_limit: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self.n_in = n_in
        self.queue_limit = queue_limit
        self.metrics = metrics or ServeMetrics()
        self.sessions: Dict[str, Session] = {}
        self._ready: Deque[str] = deque()
        self._queued: set = set()       # sids currently in the ready queue

    # -- session lifecycle --------------------------------------------------

    def open(self, sid: str) -> Session:
        if sid in self.sessions:
            raise ValueError(f"session {sid!r} already open")
        s = Session(sid=sid, n_in=self.n_in)
        self.sessions[sid] = s
        self.metrics.bump("sessions_opened")
        return s

    def close(self, sid: str) -> None:
        s = self.sessions[sid]
        if s.closed:
            return
        s.closed = True
        self.metrics.bump("sessions_closed")
        if s.buffered == 0:
            s.finished = True
            self.metrics.bump("sessions_finished")
        self._requeue(sid)

    # -- admission ----------------------------------------------------------

    @property
    def pending_windows(self) -> int:
        return sum(math.ceil(s.buffered / self.window)
                   for s in self.sessions.values())

    def submit(self, sid: str, chunk: np.ndarray) -> bool:
        """Buffer `chunk` (T, n_in) for `sid`; False = backpressure."""
        s = self.sessions[sid]
        chunk = np.asarray(chunk)
        if self.queue_limit is not None:
            after = (self.pending_windows
                     - math.ceil(s.buffered / self.window)
                     + math.ceil((s.buffered + len(chunk)) / self.window))
            if after > self.queue_limit:
                self.metrics.bump("chunks_rejected")
                record(FallbackEvent(
                    kind="serve", family="engine", stage="admission",
                    error=f"queue_limit={self.queue_limit} windows: "
                          f"rejected {len(chunk)}-step chunk for "
                          f"session {sid!r}",
                    dims={"pending_windows": self.pending_windows,
                          "chunk_steps": int(len(chunk))}))
                return False
        s.push(chunk)
        self.metrics.bump("chunks_admitted")
        self._requeue(sid)
        return True

    # -- cohort assembly ----------------------------------------------------

    def _requeue(self, sid: str) -> None:
        if sid not in self._queued and self.sessions[sid].ready(self.window):
            self._ready.append(sid)
            self._queued.add(sid)

    @property
    def ready_count(self) -> int:
        return len(self._ready)

    def next_cohort(self, capacity: int
                    ) -> List[Tuple[Session, np.ndarray, int]]:
        """Pop up to `capacity` ready sessions (FIFO) with their window
        inputs: [(session, x (window, n_in), valid_steps)]. Served
        sessions that remain ready re-enter at the tail (fair round-robin);
        a closed session whose buffer drains is marked finished."""
        out: List[Tuple[Session, np.ndarray, int]] = []
        served: List[str] = []
        while self._ready and len(out) < capacity:
            sid = self._ready.popleft()
            self._queued.discard(sid)
            s = self.sessions[sid]
            if not s.ready(self.window):
                continue                      # stale queue entry
            x, valid = s.pop_window(self.window)
            s.windows += 1
            s.steps += valid
            out.append((s, x, valid))
            served.append(sid)
            if s.closed and s.buffered == 0:
                s.finished = True
                self.metrics.bump("sessions_finished")
        for sid in served:
            self._requeue(sid)
        return out


__all__ = ["Scheduler"]
