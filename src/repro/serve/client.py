"""Generator-based streaming client over the batched engine.

`BatchedEngine` exposes an operator's API: open / submit / step / drain /
retire, with explicit backpressure and a shared cohort loop. Application
code mostly wants the dual view — "here is my input stream, give me the
output stream" — without owning the stepping loop. `StreamClient` is that
facade:

    client = StreamClient(make_engine(nodes, params, cfg))
    for window in client.stream(chunks):
        ...  # (steps, n_out) blocks, in order, as they are produced

`stream` drives the engine lazily: it submits each input chunk (stepping
the shared engine through backpressure instead of dropping data), yields
every new output window as soon as the cohort loop produces it, then
closes and drains the session. Multiple clients — or one client with many
concurrent `stream` generators — share one engine, so interleaved streams
are continuously batched into cohorts exactly like hand-driven sessions;
per-session state isolation is the engine's contract (solo == interleaved
bit-for-bit), which `tests/test_serve_client.py` pins down through this
facade too.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, Optional

import numpy as np

from repro.serve.engine import BatchedEngine


class StreamClient:
    """Thin per-application handle on a (possibly shared) engine."""

    def __init__(self, engine: BatchedEngine):
        self.engine = engine

    # -- one-shot convenience ------------------------------------------------

    def run(self, chunks: Iterable[np.ndarray]) -> np.ndarray:
        """Feed a whole stream, return all outputs (steps, n_out)."""
        return np.concatenate(list(self.stream(None, chunks)), axis=0)

    # -- streaming ------------------------------------------------------------

    def stream(self, session_id: Optional[str],
               chunks: Optional[Iterable[np.ndarray]] = None,
               max_idle_steps: int = 10_000) -> Iterator[np.ndarray]:
        """Drive one session through the engine, yielding output windows.

        `stream(session_id, chunks)` adopts a session the caller
        pre-opened (and leaves retiring it to them); `stream(None,
        chunks)` — or the `stream(chunks)` shorthand — opens a fresh one
        and retires it on exhaustion. Each (T, n_in) chunk is submitted,
        running engine cohorts while the scheduler pushes back instead of
        dropping steps, and each new block of outputs is yielded as soon
        as it exists. `max_idle_steps` bounds the backpressure loop (a
        stall means the queue is saturated by sessions this generator
        cannot advance — a deadlocked topology — and raises instead of
        spinning forever).
        """
        if chunks is None:  # stream(chunks) shorthand
            session_id, chunks = None, session_id
        eng = self.engine
        sid = session_id
        owned = sid is None
        if owned:
            sid = eng.open()
        emitted = 0
        try:
            for chunk in chunks:
                idle = 0
                while not eng.submit(sid, np.asarray(chunk)):
                    if eng.step() == 0:
                        idle += 1
                        if idle > max_idle_steps:
                            raise RuntimeError(
                                f"session {sid!r}: backpressure stall — "
                                f"queue full and no session can run")
                # opportunistic: run whatever cohort is ready and flush
                eng.step()
                out = eng.outputs(sid)
                if out.shape[0] > emitted:
                    yield out[emitted:]
                    emitted = out.shape[0]
            eng.close(sid)
            while not eng.finished(sid):
                if eng.step() == 0:
                    break
                out = eng.outputs(sid)
                if out.shape[0] > emitted:
                    yield out[emitted:]
                    emitted = out.shape[0]
            out = eng.outputs(sid)
            if out.shape[0] > emitted:
                yield out[emitted:]
        finally:
            if owned:
                eng.retire(sid)

    def stats(self) -> Dict[str, Any]:
        return self.engine.stats()


__all__ = ["StreamClient"]
