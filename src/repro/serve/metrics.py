"""Serve-engine observability: counters + latency/depth histograms.

The serving runtime answers operational questions the incident log alone
cannot: how many sessions per second, what a p99 window costs, how deep
the admission queue runs, how often the state cache spills. Everything
here is plain host-side Python (no tracing, thread-safe) and exports as
one flat dict (`ServeMetrics.snapshot()`) so benches, tests, and the CI
artifacts can archive it; `publish()` additionally records the snapshot
onto the kernel incident log (`kind="serve", stage="metrics"`) so a run's
operational story and its degradation story land in the same place —
`record()` only, never `degrade()`, so `REPRO_STRICT` CI stays green.

Histograms keep exact samples in a bounded ring (newest-wins, default
4096): percentiles are true order statistics over the retained window
rather than bucket interpolations, which is what a p99 claim in a bench
row should mean.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional

from repro.kernels.incidents import FallbackEvent, record

_MAX_SAMPLES = 4096


class Histogram:
    """Bounded-sample histogram with exact quantiles over the window."""

    def __init__(self, max_samples: int = _MAX_SAMPLES):
        self._max = max_samples
        self._samples: List[float] = []
        self._next = 0                      # ring cursor once full
        self.count = 0
        self.total = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if len(self._samples) < self._max:
            self._samples.append(value)
        else:
            self._samples[self._next] = value
            self._next = (self._next + 1) % self._max

    def quantile(self, q: float) -> float:
        """Exact order statistic over the retained samples (0 when empty)."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        i = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
        return s[i]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99),
                "max": max(self._samples) if self._samples else 0.0}


@dataclasses.dataclass
class ServeMetrics:
    """All counters + histograms one engine instance maintains.

    Counters (monotonic):
      sessions_opened/closed/finished, chunks_admitted, chunks_rejected
      (backpressure), windows_run, session_windows (slot-windows actually
      served), steps_run (timesteps x sessions), cache_hits/misses,
      cache_evictions, cache_restores.
    Histograms:
      window_latency_s   wall clock of one engine.step() cohort window
      queue_depth        ready-session count sampled at each step
      occupancy          served-slots / capacity per window (0..1)
    """

    sessions_opened: int = 0
    sessions_closed: int = 0
    sessions_finished: int = 0
    chunks_admitted: int = 0
    chunks_rejected: int = 0
    windows_run: int = 0
    session_windows: int = 0
    steps_run: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_restores: int = 0
    window_latency_s: Histogram = dataclasses.field(default_factory=Histogram)
    queue_depth: Histogram = dataclasses.field(default_factory=Histogram)
    occupancy: Histogram = dataclasses.field(default_factory=Histogram)
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock,
                                              repr=False)

    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + by)

    @property
    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 1.0

    def snapshot(self) -> Dict[str, object]:
        """One flat dict: every counter, every histogram's summary."""
        out: Dict[str, object] = {}
        for f in dataclasses.fields(self):
            if f.name.startswith("_"):
                continue
            v = getattr(self, f.name)
            if isinstance(v, Histogram):
                out[f.name] = v.snapshot()
            else:
                out[f.name] = v
        out["cache_hit_rate"] = self.cache_hit_rate
        return out

    def publish(self, family: str = "engine",
                extra: Optional[Dict[str, int]] = None) -> FallbackEvent:
        """Record the snapshot onto the kernel incident log (kind="serve",
        stage="metrics") — observability, not a degradation, so this goes
        through `record()` and never raises under REPRO_STRICT."""
        snap = self.snapshot()
        dims = {k: int(v) for k, v in snap.items() if isinstance(v, int)}
        dims.update(extra or {})
        return record(FallbackEvent(
            kind="serve", family=family, stage="metrics",
            error=f"p50_window_s={self.window_latency_s.quantile(0.5):.6f} "
                  f"p99_window_s={self.window_latency_s.quantile(0.99):.6f} "
                  f"cache_hit_rate={self.cache_hit_rate:.3f}",
            dims=dims))


__all__ = ["Histogram", "ServeMetrics"]
