"""serve — batched prompt loop + multi-tenant streaming session engine.

Two entry points live here:

  * `loop.py` — the request/response prompt path (`generate`,
    `generate_resilient`): pad a batch of prompts, run them to
    completion, return tokens.
  * the streaming stack — `sessions.py` / `scheduler.py` / `engine.py` /
    `metrics.py`: long-lived stateful sessions continuously batched into
    fixed-shape cohorts over one resident jitted `plan.run` window step,
    with an LRU byte-budgeted state cache (host spill + bit-identical
    restore) and operational metrics. See `engine.py` for the design;
    `client.py` adds the generator-based `StreamClient` facade for
    application code that wants chunks-in / windows-out.
"""

from repro.serve.loop import ServeConfig, ServeResult, Request, generate
from repro.serve.client import StreamClient
from repro.serve.engine import (EngineConfig, BatchedEngine, NaiveEngine,
                                make_engine)
from repro.serve.metrics import Histogram, ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.sessions import Session, StateCache

__all__ = [
    "ServeConfig", "ServeResult", "Request", "generate",
    "EngineConfig", "BatchedEngine", "NaiveEngine", "make_engine",
    "Histogram", "ServeMetrics", "Scheduler", "Session", "StateCache",
    "StreamClient",
]
