"""serve — batched KV-cache serving loop."""

from repro.serve.loop import ServeConfig, generate, Request
