"""Multi-tenant streaming serve engine over one resident `plan.run` step.

TaiBai amortizes one resident program across many concurrent spike
streams; this is the software analogue. ONE compiled plan — jitted once
per (window, capacity) shape — serves every open session: on each window
boundary the scheduler packs whichever sessions have a runnable window
into fixed cohort slots, the engine gathers their persistent state out of
the LRU cache (`plan.pack_states`), runs the resident step, scatters the
per-slot results back (`plan.unpack_state`), and retires/admits sessions
for the next window. Nothing ever retraces: free slots are zero-padded
and their results discarded.

Two engines share the scheduler/cache/metrics machinery:

  * `BatchedEngine` — the continuous-batching engine. Inference cohorts
    run the *flat* path (sessions concatenated along the batch axis, the
    MXU-shaped layout). With `learn=True` on a plastic model, cohorts run
    a per-session-`vmap`ped window instead: synapse weight planes have no
    batch axis, so the flat path would batch-sum every tenant's update
    into one tile — the vmap path keeps each session's learned weights in
    its own state (entry weights come from the session's last published
    `syn:` tensors via `plasticity.apply_learned`, the chunked-online
    contract, per lane).
  * `NaiveEngine` — the one-session-at-a-time baseline: same scheduler,
    same cache, same semantics, but every served session pays its own
    B=1 window launch. `bench_serving` measures the gap.

Isolation invariant (property-tested): a session's output trajectory and
final state are bit-identical whether it runs alone, interleaved with
strangers, or is evicted to host and restored mid-stream. The flat path
earns this because every per-slot computation in the fused kernels is
row-independent and the executable is shape-fixed (solo and packed
cohorts run the *same* compiled step); the vmap path because lanes are
independent by construction; evict/restore because spill is a pure
device<->host copy.

Resilience composes: kernel dispatch inside the resident step degrades
pallas -> interpret -> ref per the registry chain (incidents recorded;
REPRO_STRICT raises), `REPRO_FAULTS` / `REPRO_GUARD` thread through
`plan.run` unchanged. The step cache keys on the ambient
faults/engine/dispatch environment, so entering a fault context retraces
instead of silently replaying a clean executable.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events, faults, plasticity
from repro.core import plan as plan_mod
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.sessions import Session, StateCache

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the streaming engines.

    window:      scheduling quantum in timesteps (the chunked-online
                 window `plan.run` state round-trips at).
    capacity:    cohort slots — max sessions per window step.
    queue_limit: admission bound, in buffered-but-unserved windows summed
                 over all sessions; a submit that would exceed it is
                 rejected (backpressure). None = unbounded.
    cache_bytes: hot-state byte budget for the LRU cache; LRU sessions
                 spill to host beyond it. None = unbounded.
    learn:       run per-session on-chip plasticity (the `learn=` path of
                 `plan.run`, vmapped per session for isolation).
    guard:       numerical guardrail policy for `plan.run` (None defers
                 to REPRO_GUARD).
    """

    window: int = 32
    capacity: int = 8
    queue_limit: Optional[int] = 256
    cache_bytes: Optional[int] = None
    learn: bool = False
    guard: Optional[str] = None

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


# ---------------------------------------------------------------------------
# resident step cache
# ---------------------------------------------------------------------------
#
# Jitted window steps are cached per (nodes, path kind, guard, ambient
# environment). Keys hold id()s of the live node objects — the closures
# keep those objects alive, so ids cannot be recycled into a collision.
# The environment fingerprint (engine mode, dispatch pins, active fault
# spec) is part of the key because `plan.run` resolves all of those at
# TRACE time: a cached clean-world executable must not be replayed inside
# a `faults.inject(...)` context.

_STEP_CACHE: Dict[tuple, Callable] = {}


def _env_fingerprint(guard: Optional[str]) -> tuple:
    return (plan_mod.engine_mode(),
            os.environ.get("REPRO_KERNEL_IMPL"),
            os.environ.get("REPRO_SPIKEMM_SPARSE"),
            guard if guard is not None else os.environ.get("REPRO_GUARD"),
            faults.active())


def _resident_step(nodes, compiled, kind: str,
                   guard: Optional[str]) -> Callable:
    key = (tuple(id(n) for n in nodes), kind, _env_fingerprint(guard))
    fn = _STEP_CACHE.get(key)
    if fn is not None:
        return fn
    nodes = list(nodes)

    # Both step kinds take a TUPLE of per-session state trees and return a
    # tuple of per-session results: the gather (pack/stack) and scatter
    # (per-slot slice) both happen INSIDE the compiled program, so a
    # C-slot cohort costs one dispatch + one output transfer instead of
    # O(C x leaves) host-side slice ops per window.
    if kind == "flat":
        def step(params, states, x):
            packed = plan_mod.pack_states(list(states))
            ns, out, _ = plan_mod.run(nodes, params, x, state=packed,
                                      plan=compiled, learn=False,
                                      guard=guard)
            return tuple(plan_mod.unpack_state(ns, i)
                         for i in range(len(states))), out
        fn = jax.jit(step)
    elif kind == "vmap_learn":
        def step(params, states, x):
            st = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *states)

            def one(st_i, x_i):
                # chunked-online entry weights = the session's last
                # published learned tensors; fresh sessions carry seeds
                p = plasticity.apply_learned(nodes, params, st_i)
                ns, out, _ = plan_mod.run(nodes, p, x_i, state=st_i,
                                          plan=compiled, learn=True,
                                          guard=guard)
                return ns, out
            ns, out = jax.vmap(one)(st, x)
            return tuple(jax.tree_util.tree_map(lambda l, i=i: l[i], ns)
                         for i in range(len(states))), out
        fn = jax.jit(step)
    else:  # pragma: no cover
        raise ValueError(f"unknown step kind {kind!r}")
    _STEP_CACHE[key] = fn
    return fn


def _split_syn(state: Dict[str, Any]
               ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Split a session state into (packable core, per-session syn tree)."""
    core: Dict[str, Any] = {}
    syn: Dict[str, Any] = {}
    for node, nd in state.items():
        core[node] = {k: v for k, v in nd.items()
                      if not k.startswith("syn:")}
        s = {k: v for k, v in nd.items() if k.startswith("syn:")}
        if s:
            syn[node] = s
    return core, syn


def _merge_syn(core: Dict[str, Any], syn: Dict[str, Any]) -> Dict[str, Any]:
    out = {node: dict(nd) for node, nd in core.items()}
    for node, s in syn.items():
        out[node].update(s)
    return out


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------


class BatchedEngine:
    """Continuous-batching multi-tenant engine (see module docstring)."""

    kind = "batched"

    def __init__(self, nodes: List[events.LayerNode], params: Dict[str, Any],
                 cfg: EngineConfig = EngineConfig(),
                 plan: Optional[plan_mod.Plan] = None,
                 dtype=jnp.float32):
        self.nodes = list(nodes)
        self.params = params
        self.cfg = cfg
        self.plan = plan if plan is not None \
            else plan_mod.compile_program(self.nodes)
        self.dtype = events.state_dtype(dtype)
        self.n_in = self._infer_n_in()
        self.n_out = self.nodes[-1].out_dim
        self.metrics = ServeMetrics()
        self.scheduler = Scheduler(cfg.window, self.n_in,
                                   queue_limit=cfg.queue_limit,
                                   metrics=self.metrics)
        self.cache = StateCache(cfg.cache_bytes, metrics=self.metrics)
        self._learn = cfg.learn and bool(self.plan.plastic)
        self._sid_counter = 0
        # zero template for padding free cohort slots (results discarded)
        tmpl = events.init_state(self.nodes, 1, self.dtype, params)
        self._zero_full = jax.tree_util.tree_map(jnp.zeros_like, tmpl)
        self._zero_core, _ = _split_syn(self._zero_full)

    def _infer_n_in(self) -> int:
        for n in self.nodes:
            for c in n.connections:
                if c.src == "input":
                    topo = events.resolve_topology(c, n.name, self.params)
                    w = (topo if topo is not None
                         else self.params[n.name][c.weight_key])
                    return int(w.shape[-2])
        raise ValueError("no node reads 'input'; cannot infer n_in")

    # -- lifecycle ----------------------------------------------------------

    def open(self, sid: Optional[str] = None) -> str:
        """Open a streaming session with fresh state; returns its id."""
        if sid is None:
            sid = f"s{self._sid_counter}"
            self._sid_counter += 1
        self.scheduler.open(sid)
        state = events.init_state(self.nodes, 1, self.dtype, self.params)
        self.cache.put(sid, state)
        return sid

    def submit(self, sid: str, chunk: np.ndarray) -> bool:
        """Buffer (T, n_in) input steps; False = backpressure (rejected)."""
        return self.scheduler.submit(sid, chunk)

    def close(self, sid: str) -> None:
        """End of stream: remaining buffered steps still run (the final
        partial window is zero-padded and its outputs trimmed)."""
        self.scheduler.close(sid)

    def finished(self, sid: str) -> bool:
        return self.scheduler.sessions[sid].finished

    def outputs(self, sid: str) -> np.ndarray:
        """All output steps produced so far, (steps, n_out)."""
        s = self.scheduler.sessions[sid]
        if not s.outputs:
            return np.zeros((0, self.n_out), np.float32)
        return np.concatenate(s.outputs, axis=0)

    def state_of(self, sid: str) -> Dict[str, Any]:
        """The session's current state tree (restored to device)."""
        return self.cache.get(sid)

    def retire(self, sid: str) -> np.ndarray:
        """Drop a finished (or abandoned) session; returns its outputs."""
        out = self.outputs(sid)
        self.cache.drop(sid)
        self.scheduler.sessions.pop(sid, None)
        return out

    # -- execution ----------------------------------------------------------

    def step(self) -> int:
        """Run one cohort window; returns the number of sessions served."""
        self.metrics.queue_depth.observe(self.scheduler.ready_count)
        cohort = self.scheduler.next_cohort(self.cfg.capacity)
        if not cohort:
            return 0
        t0 = time.perf_counter()
        states = [self.cache.get(s.sid) for s, _, _ in cohort]
        new_states, outs = self._run_cohort(cohort, states)
        for (s, _, valid), ns in zip(cohort, new_states):
            self.cache.put(s.sid, ns)
        for (s, _, valid), out in zip(cohort, outs):
            s.outputs.append(np.asarray(out[:valid]))
        dt = time.perf_counter() - t0
        self.metrics.bump("windows_run")
        self.metrics.bump("session_windows", len(cohort))
        self.metrics.bump("steps_run", sum(v for _, _, v in cohort))
        self.metrics.window_latency_s.observe(dt)
        self.metrics.occupancy.observe(len(cohort) / self.cfg.capacity)
        return len(cohort)

    def drain(self) -> int:
        """Step until no session is schedulable; returns windows run."""
        n = 0
        while self.step():
            n += 1
        return n

    def stats(self) -> Dict[str, Any]:
        snap = self.metrics.snapshot()
        snap.update(engine=self.kind, window=self.cfg.window,
                    capacity=self.cfg.capacity,
                    cache_hot_bytes=self.cache.hot_bytes,
                    cache_spilled=len(self.cache.spilled),
                    sessions_open=len(self.scheduler.sessions))
        return snap

    def publish_metrics(self) -> None:
        """Snapshot onto the incident log (kind="serve", stage="metrics")."""
        self.metrics.publish(family=self.kind)

    # cohort execution — the part engines differ in ------------------------

    def _run_cohort(self, cohort: List[Tuple[Session, np.ndarray, int]],
                    states: List[Dict[str, Any]]
                    ) -> Tuple[List[Dict[str, Any]], List[np.ndarray]]:
        C, W = self.cfg.capacity, self.cfg.window
        n_live = len(cohort)
        if self._learn:
            # per-session vmap: every lane owns its learned weight planes
            sts = tuple(states) + (C - n_live) * (self._zero_full,)
            x = np.zeros((C, W, 1, self.n_in), self.dtype)
            for i, (_, xw, _) in enumerate(cohort):
                x[i, :, 0, :] = xw
            step = _resident_step(self.nodes, self.plan, "vmap_learn",
                                  self.cfg.guard)
            ns, out = step(self.params, sts, jnp.asarray(x))
            out_np = np.asarray(out)            # one transfer per window
            return list(ns[:n_live]), [out_np[i, :, 0, :]
                                       for i in range(n_live)]
        # flat path: sessions concatenated along the batch axis
        cores, syns = zip(*(_split_syn(s) for s in states))
        sts = tuple(cores) + (C - n_live) * (self._zero_core,)
        x = np.zeros((W, C, self.n_in), self.dtype)
        for i, (_, xw, _) in enumerate(cohort):
            x[:, i, :] = xw
        step = _resident_step(self.nodes, self.plan, "flat", self.cfg.guard)
        ns, out = step(self.params, sts, jnp.asarray(x))
        out_np = np.asarray(out)                # one transfer per window
        news = [_merge_syn(ns[i], syn) for i, syn in enumerate(syns)]
        return news, [out_np[:, i, :] for i in range(n_live)]


class NaiveEngine(BatchedEngine):
    """One-session-at-a-time baseline: same scheduler, cache, and
    semantics, but each served session pays its own B=1 window launch —
    the loop `bench_serving` measures the batching win against."""

    kind = "naive"

    def _run_cohort(self, cohort, states):
        W = self.cfg.window
        news: List[Dict[str, Any]] = []
        outs: List[np.ndarray] = []
        for (sess, xw, _), state in zip(cohort, states):
            if self._learn:
                x = jnp.asarray(xw, self.dtype).reshape(1, W, 1, self.n_in)
                step = _resident_step(self.nodes, self.plan, "vmap_learn",
                                      self.cfg.guard)
                ns, out = step(self.params, (state,), x)
                news.append(ns[0])
                outs.append(np.asarray(out)[0, :, 0, :])
            else:
                core, syn = _split_syn(state)
                x = jnp.asarray(xw, self.dtype).reshape(W, 1, self.n_in)
                step = _resident_step(self.nodes, self.plan, "flat",
                                      self.cfg.guard)
                ns, out = step(self.params, (core,), x)
                news.append(_merge_syn(ns[0], syn))
                outs.append(np.asarray(out)[:, 0, :])
        return news, outs


def make_engine(nodes, params, cfg: EngineConfig = EngineConfig(),
                kind: str = "batched", **kw) -> BatchedEngine:
    """Factory: kind = "batched" (continuous batching) | "naive"."""
    cls = {"batched": BatchedEngine, "naive": NaiveEngine}.get(kind)
    if cls is None:
        raise ValueError(f"unknown engine kind {kind!r}; "
                         "expected 'batched' or 'naive'")
    return cls(nodes, params, cfg, **kw)


__all__ = ["EngineConfig", "BatchedEngine", "NaiveEngine", "make_engine"]
