"""Stateful streaming sessions + the LRU state cache behind the engine.

A `Session` is one tenant's streaming SNN run: an input buffer of
not-yet-executed timesteps, an output trail, and — held separately in the
`StateCache` — the persistent per-session neuron/synapse state tree that
`plan.run` threads between windows. The cache is the multi-tenant memory
story: hot sessions keep their state as device arrays ready to be packed
into the next cohort; once the hot set exceeds the byte budget, the
least-recently-used sessions are *spilled* to host memory (`numpy` copies)
and restored bit-identically on readmission. Spill -> restore is a pure
device<->host copy of every leaf (no re-quantization, no re-init), so a
session's trajectory is exactly the same whether it stayed resident or
bounced through the cache — the invariant the isolation property tests
pin down.

Byte accounting uses `plan.state_nbytes` over the full state tree
(synapse entries included: they travel with the session even though they
never enter a packed cohort).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.plan import state_nbytes
from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass
class Session:
    """One streaming tenant: buffered input, output trail, lifecycle."""

    sid: str
    n_in: int
    chunks: List[np.ndarray] = dataclasses.field(default_factory=list)
    offset: int = 0                 # consumed steps inside chunks[0]
    buffered: int = 0               # total unconsumed timesteps
    closed: bool = False            # no more submits accepted
    finished: bool = False          # closed AND buffer drained
    windows: int = 0                # cohort windows served
    steps: int = 0                  # timesteps executed
    outputs: List[np.ndarray] = dataclasses.field(default_factory=list)

    def push(self, chunk: np.ndarray) -> None:
        if self.closed:
            raise ValueError(f"session {self.sid!r} is closed")
        if chunk.ndim != 2 or chunk.shape[1] != self.n_in:
            raise ValueError(
                f"session {self.sid!r}: chunk shape {chunk.shape} != "
                f"(T, {self.n_in})")
        if len(chunk):
            self.chunks.append(np.asarray(chunk))
            self.buffered += len(chunk)

    def pop_window(self, window: int) -> Tuple[np.ndarray, int]:
        """Next `window` timesteps, zero-padded at stream end.

        Returns (x (window, n_in), valid) where `valid` is the number of
        real (unpadded) steps. Padding only ever happens on the final
        partial window of a *closed* stream, so padded state never feeds a
        later real step.
        """
        take = min(window, self.buffered)
        parts: List[np.ndarray] = []
        got = 0
        while got < take:
            head = self.chunks[0]
            n = min(take - got, len(head) - self.offset)
            parts.append(head[self.offset:self.offset + n])
            got += n
            self.offset += n
            if self.offset == len(head):
                self.chunks.pop(0)
                self.offset = 0
        self.buffered -= take
        x = (np.concatenate(parts, axis=0) if parts
             else np.zeros((0, self.n_in), np.float32))
        if take < window:
            x = np.concatenate(
                [x, np.zeros((window - take, self.n_in), x.dtype)], axis=0)
        return x, take

    def ready(self, window: int) -> bool:
        """Schedulable: a full window buffered, or a closed partial tail."""
        if self.finished:
            return False
        return self.buffered >= window or (self.closed and self.buffered > 0)


class StateCache:
    """LRU session-state cache with a hot-set byte budget.

    `put`/`get` move states in and out keyed by session id; every access
    refreshes recency. When hot bytes exceed `budget_bytes`, the
    least-recently-used entries spill to host (`numpy`) until the budget
    holds again — `get` of a spilled entry restores it to device
    bit-identically and counts a miss+restore. `budget_bytes=None` means
    unbounded (nothing ever spills).
    """

    def __init__(self, budget_bytes: Optional[int] = None,
                 metrics: Optional[ServeMetrics] = None):
        if budget_bytes is not None and budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive or None, "
                             f"got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self.metrics = metrics or ServeMetrics()
        # sid -> (state tree, nbytes, spilled?); insertion order = recency
        self._entries: "OrderedDict[str, Tuple[Any, int, bool]]" = \
            OrderedDict()

    # -- introspection ------------------------------------------------------

    def __contains__(self, sid: str) -> bool:
        return sid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hot_bytes(self) -> int:
        return sum(nb for _, nb, spilled in self._entries.values()
                   if not spilled)

    @property
    def spilled(self) -> Tuple[str, ...]:
        return tuple(sid for sid, (_, _, sp) in self._entries.items() if sp)

    def is_spilled(self, sid: str) -> bool:
        return self._entries[sid][2]

    # -- core ---------------------------------------------------------------

    def put(self, sid: str, state: Dict[str, Any]) -> None:
        """Insert/replace a session's state (hot) and enforce the budget."""
        self._entries.pop(sid, None)
        self._entries[sid] = (state, state_nbytes(state), False)
        self._enforce(keep=sid)

    def get(self, sid: str) -> Dict[str, Any]:
        """Fetch a session's state onto device, refreshing recency."""
        state, nb, spilled = self._entries.pop(sid)
        if spilled:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
            self.metrics.bump("cache_misses")
            self.metrics.bump("cache_restores")
        else:
            self.metrics.bump("cache_hits")
        self._entries[sid] = (state, nb, False)
        self._enforce(keep=sid)
        return state

    def drop(self, sid: str) -> None:
        self._entries.pop(sid, None)

    def _enforce(self, keep: Optional[str] = None) -> None:
        """Spill LRU-first until hot bytes fit the budget. The `keep`
        entry (the session about to run / just scattered) is exempt so a
        budget smaller than one session still serves — it just spills
        everything else."""
        if self.budget_bytes is None:
            return
        hot = self.hot_bytes
        if hot <= self.budget_bytes:
            return
        for sid in list(self._entries):
            if hot <= self.budget_bytes:
                break
            state, nb, spilled = self._entries[sid]
            if spilled or sid == keep:
                continue
            host = jax.tree_util.tree_map(np.asarray, state)
            self._entries[sid] = (host, nb, True)
            hot -= nb
            self.metrics.bump("cache_evictions")


__all__ = ["Session", "StateCache"]
