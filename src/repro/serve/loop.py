"""Batched serving: continuous-batch prefill + decode against shared caches.

A deliberately simple (but real) scheduler: requests are packed into a fixed
batch; prefill runs the full-sequence forward once per admitted request
cohort (right-padded to the cohort max), then the decode loop advances all
live slots one token per step with `lax.scan`, retiring slots that emit EOS
or reach max_new. Slots freed mid-flight admit queued requests on cohort
boundaries (continuous batching at cohort granularity — the TPU-shaped
version, since per-token re-batching would retrace).

The event-driven framing maps back to the paper: a decode step is the FIRE
stage (every live slot emits one "spike"/token), the cache update is the
INTEG stage; retired slots are silent neurons that cost nothing because the
batch is re-packed — block-granular sparsity again.

`generate_resilient` wraps the same cohort loop for deployments that must
answer every request: a failing cohort is retried with bounded,
deterministically-jittered backoff; exhausted retries (and per-request
deadline misses) come back as explicitly `degraded` `ServeResult`s instead
of an exception, each recorded on the incident log
(`repro.kernels.incidents()`). Under `REPRO_STRICT=1` failures propagate —
retry loops must not launder errors CI wants loud.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# direct submodule imports: the `repro.kernels` package re-exports an
# `incidents()` function that shadows the module attribute of that name
from repro.kernels.incidents import FallbackEvent, record, strict_mode
from repro.models import lm
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (len,) int32
    max_new: int = 32


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_seq: int = 512
    eos_id: int = -1                   # -1: never stops early
    greedy: bool = True
    # admission policy for prompts that cannot fit the KV cache alongside
    # their requested generation budget (len(prompt) > max_seq - max_new):
    # "truncate" keeps the most recent tokens (recency matters for LM
    # state), "reject" refuses the request. Either way the outcome is
    # explicit — recorded on the incident log, and flagged degraded by
    # `generate_resilient` — never a silent wrong-length serve.
    long_prompt: str = "truncate"      # "truncate" | "reject"
    # resilient-path knobs (generate_resilient only)
    deadline_s: Optional[float] = None  # per-request wall-clock budget
    max_retries: int = 2                # extra attempts per failing cohort
    retry_base_s: float = 0.05          # backoff base: base * 2**attempt
    retry_jitter: float = 0.5           # +- fraction of the backoff step
    retry_seed: int = 0                 # jitter PRNG seed (deterministic)


@dataclasses.dataclass
class ServeResult:
    """One request's outcome from `generate_resilient`.

    `degraded` marks responses that are not what a healthy serve would
    have produced: the cohort exhausted its retries (tokens is empty,
    `error` holds the last exception) or the request finished past its
    deadline (tokens are complete but late).
    """

    tokens: np.ndarray
    degraded: bool = False
    retries: int = 0
    latency_s: float = 0.0
    error: Optional[str] = None


def _admit(reqs: List[Request], serve_cfg: ServeConfig
           ) -> Tuple[List[Optional[Request]], List[Optional[str]]]:
    """Apply the long-prompt admission policy to every request.

    Returns (admitted, notes) aligned with `reqs`: an in-budget request
    passes through with note None; an over-budget one is either replaced
    by a truncated copy (policy "truncate", note describes the cut) or
    mapped to None (policy "reject", note holds the refusal). Every
    non-None note is also recorded on the incident log
    (kind="serve", stage="admission").
    """
    admitted: List[Optional[Request]] = []
    notes: List[Optional[str]] = []
    for r in reqs:
        budget = max(1, serve_cfg.max_seq - r.max_new)
        if len(r.prompt) <= budget:
            admitted.append(r)
            notes.append(None)
            continue
        if serve_cfg.long_prompt == "reject":
            msg = (f"rejected: prompt length {len(r.prompt)} exceeds "
                   f"admission budget {budget} (max_seq="
                   f"{serve_cfg.max_seq}, max_new={r.max_new})")
            admitted.append(None)
        elif serve_cfg.long_prompt == "truncate":
            msg = (f"truncated: prompt {len(r.prompt)} -> last {budget} "
                   f"tokens (max_seq={serve_cfg.max_seq}, "
                   f"max_new={r.max_new})")
            admitted.append(Request(prompt=np.asarray(r.prompt)[-budget:],
                                    max_new=r.max_new))
        else:
            raise ValueError(
                f"unknown long_prompt policy {serve_cfg.long_prompt!r}; "
                "expected 'truncate' or 'reject'")
        notes.append(msg)
        record(FallbackEvent(
            kind="serve", family="generate", stage="admission", error=msg,
            dims={"prompt_len": int(len(r.prompt)), "budget": int(budget)}))
    return admitted, notes


def _pad_prompts(reqs: List[Request], max_seq: int) -> Tuple[np.ndarray, np.ndarray]:
    lens = np.array([len(r.prompt) for r in reqs])
    L = int(lens.max())
    toks = np.zeros((len(reqs), L), np.int32)
    for i, r in enumerate(reqs):
        toks[i, :len(r.prompt)] = r.prompt
    return toks, lens


def generate(params: Any, cfg: ModelConfig, reqs: List[Request],
             serve_cfg: ServeConfig) -> List[np.ndarray]:
    """Serve a cohort of requests; returns generated token arrays.

    Prompts over the admission budget (max_seq - max_new) follow
    `serve_cfg.long_prompt`: truncated to the most recent tokens
    (default) or, under "reject", raise ValueError — use
    `generate_resilient` to get per-request degraded results instead.
    """
    assert cfg.family not in ("encdec",), "use serve.whisper for enc-dec"
    admitted, notes = _admit(reqs, serve_cfg)
    rejected = [n for a, n in zip(admitted, notes) if a is None]
    if rejected:
        raise ValueError(
            f"{len(rejected)} request(s) refused at admission "
            f"(long_prompt='reject'): {rejected[0]}")
    out: List[np.ndarray] = []
    for lo in range(0, len(admitted), serve_cfg.batch):
        cohort = admitted[lo:lo + serve_cfg.batch]
        out.extend(_generate_cohort(params, cfg, cohort, serve_cfg))
    return out


def generate_resilient(params: Any, cfg: ModelConfig, reqs: List[Request],
                       serve_cfg: ServeConfig) -> List[ServeResult]:
    """Serve every request, degrading instead of dying.

    Per cohort: run `_generate_cohort`; on failure, retry up to
    `max_retries` times with exponential backoff whose jitter comes from a
    PRNG seeded by (retry_seed, cohort index) — deterministic across
    processes, so incident timelines reproduce. A cohort that exhausts its
    retries yields empty-token degraded results carrying the error; a
    request that completes after `deadline_s` is flagged degraded but
    keeps its tokens. Under REPRO_STRICT=1 the first failure propagates.
    """
    assert cfg.family not in ("encdec",), "use serve.whisper for enc-dec"
    admitted, notes = _admit(reqs, serve_cfg)
    results: List[Optional[ServeResult]] = [None] * len(reqs)
    live: List[Tuple[int, Request]] = []
    for i, (a, note) in enumerate(zip(admitted, notes)):
        if a is None:       # refused at admission: degraded, no tokens
            results[i] = ServeResult(np.zeros((0,), np.int32),
                                     degraded=True, error=note)
        else:
            live.append((i, a))
    for ci, lo in enumerate(range(0, len(live), serve_cfg.batch)):
        pairs = live[lo:lo + serve_cfg.batch]
        cohort = [r for _, r in pairs]
        rng = random.Random(serve_cfg.retry_seed * 1000003 + ci)
        t0 = time.monotonic()
        tokens: Optional[List[np.ndarray]] = None
        err: Optional[BaseException] = None
        attempt = 0
        for attempt in range(serve_cfg.max_retries + 1):
            try:
                tokens = _generate_cohort(params, cfg, cohort, serve_cfg)
                break
            except Exception as e:
                if strict_mode():
                    raise   # never launder a failure CI asked to see
                err = e
                record(FallbackEvent(
                    kind="serve", family="generate", stage=f"attempt{attempt}",
                    error=repr(e), dims={"cohort": ci, "n": len(cohort)}))
                if attempt < serve_cfg.max_retries:
                    step = serve_cfg.retry_base_s * (2 ** attempt)
                    step *= 1.0 + serve_cfg.retry_jitter * (2 * rng.random() - 1)
                    time.sleep(max(0.0, step))
        latency = time.monotonic() - t0
        late = (serve_cfg.deadline_s is not None
                and latency > serve_cfg.deadline_s)
        if late and tokens is not None:
            record(FallbackEvent(
                kind="serve", family="generate", stage="deadline",
                error=f"cohort finished in {latency:.3f}s "
                      f"(deadline {serve_cfg.deadline_s}s)",
                dims={"cohort": ci, "n": len(cohort)}))
        for slot, (orig_i, _) in enumerate(pairs):
            note = notes[orig_i]
            if tokens is None:
                results[orig_i] = ServeResult(
                    np.zeros((0,), np.int32), degraded=True,
                    retries=attempt, latency_s=latency, error=repr(err))
            else:
                # a truncated prompt still serves, but the response is not
                # what the full prompt would have produced: flag it
                results[orig_i] = ServeResult(
                    tokens[slot], degraded=late or note is not None,
                    retries=attempt, latency_s=latency, error=note)
    return results


def _generate_cohort(params, cfg, cohort: List[Request],
                     serve_cfg: ServeConfig) -> List[np.ndarray]:
    B = len(cohort)
    toks, lens = _pad_prompts(cohort, serve_cfg.max_seq)
    Lp = toks.shape[1]
    max_new = max(r.max_new for r in cohort)
    S = min(serve_cfg.max_seq, Lp + max_new)

    cache = lm.init_cache(cfg, B, S)
    serve_step = lm.make_serve_step(cfg, greedy=serve_cfg.greedy)

    # Prefill. Stateless (attention-family) models consume the common
    # prompt prefix with ONE full-sequence forward that batch-writes the KV
    # cache — L0 decode launches collapse into a single MXU-shaped pass.
    # Stateful families (SSM/RWKV/hybrid) and the ragged tail of a
    # mixed-length cohort still scan token-at-a-time through the decode
    # path, which is correct for every family.
    start = 0
    cur = jnp.asarray(toks[:, :1])
    L0 = int(lens.min())
    # L0 must fit the KV cache: a prompt longer than S degrades via the
    # scan path's clamped writes (pre-existing semantics) instead of
    # crashing the batched cache write.
    if lm.can_full_prefill(cfg) and 0 < L0 <= S:
        nxt, cache = lm.make_full_prefill(cfg, greedy=serve_cfg.greedy)(
            params, cache, jnp.asarray(toks[:, :L0]))
        forced = jnp.asarray(toks[:, min(L0, Lp - 1):min(L0, Lp - 1) + 1])
        cur = jnp.where(L0 < lens[:, None], forced, nxt)
        start = L0

    def prefill_body(carry, t):
        cache, cur = carry
        nxt, cache = serve_step(params, cache, cur, t)
        # while still inside the prompt, force-feed the ground-truth token
        forced = jax.lax.dynamic_slice_in_dim(
            jnp.asarray(toks), jnp.minimum(t + 1, Lp - 1), 1, axis=1)
        cur = jnp.where(t + 1 < lens[:, None], forced, nxt)
        return (cache, cur), nxt

    (cache, cur), _ = jax.lax.scan(
        prefill_body, (cache, cur), jnp.arange(start, Lp))

    def decode_body(carry, i):
        cache, cur = carry
        nxt, cache = serve_step(params, cache, cur, Lp + i)
        return (cache, nxt), nxt

    (_, _), gen = jax.lax.scan(decode_body, (cache, cur),
                               jnp.arange(max_new - 1))
    gen = jnp.concatenate([cur[None], gen], 0)       # (max_new, B, 1)
    gen = np.asarray(gen[:, :, 0]).T                  # (B, max_new)

    results = []
    for i, r in enumerate(cohort):
        g = gen[i, :r.max_new]
        if serve_cfg.eos_id >= 0:
            stop = np.nonzero(g == serve_cfg.eos_id)[0]
            if len(stop):
                g = g[:stop[0] + 1]
        results.append(g)
    return results
