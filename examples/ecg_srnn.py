"""ECG band recognition with the heterogeneous SRNN (paper §V-B3, Fig. 15).

Trains the ALIF-hidden SRNN on level-crossing-coded synthetic QTDB-style
waveforms, per-timestep band classification (P/PQ/QR/RS/ST/TP), and compares
against the homogeneous (pure-LIF) ablation.

Run: PYTHONPATH=src python examples/ecg_srnn.py [--steps 150]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import plan
from repro.core.snn_layers import make_srnn_ecg
from repro.data.spikes import gen_ecg_qtdb


def train(heterogeneous: bool, steps: int, T: int = 200):
    xs, ys = gen_ecg_qtdb(16, T=T)
    x = jnp.asarray(xs.transpose(1, 0, 2))
    y = jnp.asarray(ys.T)
    nodes, params = make_srnn_ecg(jax.random.PRNGKey(0),
                                  heterogeneous=heterogeneous, n_hidden=48)
    print(f"  plan: {plan.compile_program(nodes).describe()}")

    @jax.jit
    def loss_grad(params):
        def loss(params):
            _, outs, _ = plan.run(nodes, params, x)
            logp = jax.nn.log_softmax(outs, -1)
            return -jnp.mean(jnp.take_along_axis(logp, y[..., None], -1))
        return jax.value_and_grad(loss)(params)

    for i in range(steps):
        loss, g = loss_grad(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(gg))
                          for gg in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        params = jax.tree.map(lambda p, gg: p - 0.1 * sc * gg
                              if gg is not None else p, params, g)
        if i % 25 == 0:
            print(f"  step {i:4d} loss {float(loss):.4f}")

    xt, yt = gen_ecg_qtdb(8, seed=7, T=T)
    _, outs, _ = plan.run(nodes, params, jnp.asarray(xt.transpose(1, 0, 2)))
    acc = float(jnp.mean(jnp.argmax(outs, -1) == jnp.asarray(yt.T)))
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    print("heterogeneous (ALIF hidden):")
    het = train(True, args.steps)
    print("homogeneous ablation (LIF hidden):")
    hom = train(False, args.steps)
    print(f"\nper-timestep band accuracy: ALIF {het:.3f} vs LIF {hom:.3f} "
          f"(paper Fig. 15a compares the same pair on real QTDB)")


if __name__ == "__main__":
    main()
