"""Whisper-style encoder-decoder serving: encode stubbed frame embeddings
once, prefill cross-attention K/V, then batched greedy decode.

Covers the enc-dec serving path (the other families use examples/serve_lm.py).

Run: PYTHONPATH=src python examples/whisper_asr.py [--max-new 12]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import encdec, lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config("whisper-small").replace(dtype="float32")
    key = jax.random.PRNGKey(args.seed)
    params = lm.model_init(key, cfg)

    # stubbed audio frontend output: (B, frames, d) embeddings
    frames = jax.random.normal(key, (args.batch, cfg.encoder_len, cfg.d_model))

    t0 = time.time()
    memory = encdec.encode(params, frames, cfg)
    cache = lm.init_cache(cfg, args.batch, args.max_new + 8)
    cache = encdec.prefill_cross(params, memory, cache, cfg)
    t_prefill = time.time() - t0

    serve = jax.jit(lm.make_serve_step(cfg))
    tok = jnp.zeros((args.batch, 1), jnp.int32)      # BOS
    out = []
    t0 = time.time()
    for t in range(args.max_new):
        tok, cache = serve(params, cache, tok, jnp.asarray(t))
        out.append(np.asarray(tok[:, 0]))
    t_decode = time.time() - t0

    out = np.stack(out, 1)
    print(f"encoded {args.batch}x{cfg.encoder_len} frames in {t_prefill:.2f}s; "
          f"decoded {args.batch}x{args.max_new} tokens in {t_decode:.2f}s")
    for i in range(min(args.batch, 3)):
        print(f"  seq{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
