"""On-chip learning in 50 lines: a plastic Connection under plan.run.

A 2-layer LIF network whose input synapses carry a declarative pair-STDP
`SynapseProgram`. The plan compiler pattern-matches the rule and lowers it
to the fused `stdp_seq` kernel family, so the weight updates run inside
the fused engine — no hand-rolled stepper loop. Chunked-online semantics:
each window's forward uses the entry weights; `apply_learned` merges the
window's updates before the next chunk, exactly how the chip drains its
FIRE-stage weight writes.

The input is two alternating spike populations; STDP potentiates the
synapses of whichever inputs reliably drive their postsynaptic neurons,
so the learned weight matrix develops visible structure.

Run: PYTHONPATH=src python examples/stdp_online.py
"""

import jax
import jax.numpy as jnp

from repro.core import plan, plasticity
from repro.core.snn_layers import make_plastic_ff

key = jax.random.PRNGKey(0)
n_in, n_hidden, T, B = 32, 16, 200, 4

rule = plasticity.pair_stdp(a_plus=0.02, a_minus=0.015, w_min=-1.0, w_max=1.0)
nodes, params = make_plastic_ff(key, n_in=n_in, n_hidden=n_hidden, n_out=4,
                                rule=rule)
compiled = plan.compile_program(nodes)
print(f"plan: {compiled.describe()}")

# two alternating input assemblies: first half vs second half of the inputs
def make_chunk(k, phase):
    rate = jnp.where((jnp.arange(n_in) < n_in // 2) ^ (phase % 2 == 1),
                     0.30, 0.02)
    return (jax.random.uniform(k, (T, B, n_in)) < rate).astype(jnp.float32)

w0 = params["hidden"]["w_input"]
for chunk in range(6):
    x = make_chunk(jax.random.fold_in(key, chunk), chunk)
    state, _, _ = plan.run(nodes, params, x, plan=compiled)
    params = plasticity.apply_learned(nodes, params, state)  # next chunk sees it
    dw = float(jnp.linalg.norm(params["hidden"]["w_input"] - w0))
    rate = float(jnp.mean(state["hidden"]["out"]))
    print(f"chunk {chunk}: |w - w0| = {dw:6.3f}, hidden rate {rate:.2%}")

w = params["hidden"]["w_input"]
print(f"learned weight range: [{float(w.min()):+.2f}, {float(w.max()):+.2f}] "
      f"(started at |w| <= {float(jnp.abs(w0).max()):.2f})")
