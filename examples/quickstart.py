"""Quickstart: the paper's stack in 60 lines.

1. program a heterogeneous spiking network with the neuron DSL,
2. encode its topology with the 2-level tables (storage accounting),
3. compile + run it through the fused execution-plan engine,
4. map it onto the chip grid with the compiler,
5. estimate energy with the behavioural simulator.

Run: PYTHONPATH=src python examples/quickstart.py
(Set REPRO_SNN_EXPLAIN=1 to see the compiled segment schedule for every
Program anywhere in the stack, not just the one printed here.)
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events, plan, topology
from repro.core.mapping import Op, compile_network
from repro.core.neuron import ALIF, LI
from repro.core.simulator import LayerStats, simulate
from repro.core.snn_layers import ff_integrate

key = jax.random.PRNGKey(0)

# 1. a 2-layer network: 64 ALIF neurons (adaptive threshold) -> 10 readouts
n_in, n_hidden, n_out = 32, 64, 10
nodes = [
    events.LayerNode("hidden", ALIF(surrogate="sigmoid", alpha=4.0),
                     ff_integrate, inputs=("input", "self"), out_dim=n_hidden),
    events.LayerNode("readout", LI(), ff_integrate, inputs=("hidden",),
                     out_dim=n_out),
]
params = {
    "hidden": {"w_input": 0.5 * jax.random.normal(key, (n_in, n_hidden)),
               "w_self": 0.05 * jax.random.normal(key, (n_hidden, n_hidden)),
               "neuron": ALIF().param_init(key, (n_hidden,))},
    "readout": {"w_hidden": 0.3 * jax.random.normal(key, (n_hidden, n_out))},
}

# 2. topology tables: the fan-in side of `hidden` as a type-2 FC entry
enc = topology.encode_fc(np.asarray(params["hidden"]["w_input"]), n_cores=4)
print(f"topology: {enc.storage_bits()/8:.0f} B encoded vs "
      f"{enc.baseline_bits()/8:.0f} B unrolled "
      f"({enc.baseline_bits()/enc.storage_bits():.0f}x smaller)")

# 3. compile the Program to a fused execution plan and run it: the ALIF
# hidden layer pattern-matches the adaptive-threshold kernel (fused_rec via
# `alifrec`), the LI readout the associative `linrec` scan — no stepper
# fallback. `plan.run` is a drop-in for `events.run` (same signature and
# numerics; REPRO_SNN_ENGINE=stepper brings the interpreted engine back).
compiled = plan.compile_program(nodes)
print(f"plan: {compiled.describe()}")
x = (jax.random.uniform(key, (100, 8, n_in)) < 0.05).astype(jnp.float32)
_, outs, recs = plan.run(nodes, params, x, record=("hidden",),
                         plan=compiled)
rate = float(jnp.mean(recs["hidden"]))
print(f"ran 100 INTEG/FIRE timesteps: hidden spike rate {rate:.1%}, "
      f"readout shape {outs.shape}")

# 4. compile onto the chip grid
ops = [Op("hidden", "fc", n_hidden, n_in + n_hidden, ("input",)),
       Op("readout", "fc", n_out, n_hidden, ("hidden",))]
mapping = compile_network(ops, anneal_iters=200)
print(f"mapped to {mapping.meta['n_cores']} cores, "
      f"placement cost {mapping.cost:.0f} packet-hops")

# 5. energy estimate vs a dense GPU
stats = [LayerStats("hidden", n_hidden, n_hidden + n_out, rate,
                    2.0 * n_hidden * (n_in + n_hidden))]
rep = simulate(stats, timesteps=100)
print(f"simulated: {rep.power_w:.2f} W, {rep.efficiency_x:.0f}x better "
      f"FPS/W than the dense-GPU baseline")
