"""End-to-end LM training driver: train a ~100M-param qwen2-family model for
a few hundred steps with the full production stack — config system, data
pipeline, AdamW+cosine, fault-tolerant loop with async checkpoints.

CPU note: the container trains a width-reduced (~10M) variant by default so
the run finishes in minutes; pass --full-100m for the 100M configuration
(sized for a real accelerator; the launch/dryrun.py artifacts prove the
full-scale lowering). Both use the identical code path.

Run: PYTHONPATH=src python examples/lm_train.py [--steps 300] [--full-100m]
"""

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_train")
    args = ap.parse_args()

    if args.full_100m:
        # ~100M params: 12 x d768 (llama-style ratios), 8k vocab
        extra = ["--d-model", "768", "--n-layers", "12",
                 "--batch", "16", "--seq", "512"]
    else:
        # ~10M params: CPU-friendly, same family/code path
        extra = ["--d-model", "256", "--n-layers", "6",
                 "--batch", "8", "--seq", "128"]

    report = train_cli.main([
        "--arch", "qwen2-1.5b", "--smoke", *extra,
        "--steps", str(args.steps), "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])
    print(f"\nloss trajectory: start {report.losses[0]:.3f} "
          f"-> end {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
