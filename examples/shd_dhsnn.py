"""SHD speech recognition with the dendritic DHSNN (paper §V-B3).

The DH-LIF hidden neurons have 4 dendritic branches with heterogeneous
per-branch time constants (Zheng et al. 2024). On TaiBai the 4x700 = 2800
fan-in exceeds the 2048-per-neuron hardware limit, so the chip deploys the
branches as PSUM neurons inside one core (fan-in expansion, Fig. 11); here
the same decomposition is the branch axis of the einsum — and, distributed,
a tensor-parallel partial sum (DESIGN.md §2).

Run: PYTHONPATH=src python examples/shd_dhsnn.py [--steps 150]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import plan
from repro.core.mapping import CORE_FANIN, Op, partition
from repro.core.snn_layers import make_dhsnn_shd
from repro.data.spikes import gen_shd_spikes


def train(dendritic: bool, steps: int):
    xs, ys = gen_shd_spikes(48, T=60)
    x = jnp.asarray(xs.transpose(1, 0, 2))
    y = jnp.asarray(ys)
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(1), n_hidden=64,
                                   dendritic=dendritic)
    print(f"  plan: {plan.compile_program(nodes).describe()}")

    @jax.jit
    def loss_grad(params):
        def loss(params):
            _, outs, _ = plan.run(nodes, params, x)
            logits = jnp.mean(outs, 0)
            return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])
        return jax.value_and_grad(loss)(params)

    for i in range(steps):
        loss, g = loss_grad(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(gg))
                          for gg in jax.tree.leaves(g)))
        params = jax.tree.map(
            lambda p, gg: p - 0.2 * jnp.minimum(1.0, 1.0 / (gn + 1e-9)) * gg
            if gg is not None else p, params, g)
        if i % 25 == 0:
            print(f"  step {i:4d} loss {float(loss):.4f}")

    xt, yt = gen_shd_spikes(48, T=60, seed=11)
    _, outs, recs = plan.run(nodes, params,
                             jnp.asarray(xt.transpose(1, 0, 2)),
                             record=("hidden",))
    acc = float(jnp.mean(jnp.argmax(jnp.mean(outs, 0), -1) == jnp.asarray(yt)))
    rate = float(jnp.mean(recs["hidden"]))
    return acc, rate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    # show the fan-in expansion the chip needs for this model
    op = Op("hidden", "fc", 64, 4 * 700, ("input",))
    cores = partition([op])
    print(f"DH-LIF fan-in 4x700 = 2800 > {CORE_FANIN} hardware limit -> "
          f"{len(cores)} cores after PSUM fan-in expansion\n")

    print("dendritic (DH-LIF, 4 branches):")
    acc_d, rate_d = train(True, args.steps)
    print("homogeneous ablation (plain LIF):")
    acc_h, _ = train(False, args.steps)
    print(f"\naccuracy: DH-LIF {acc_d:.3f} vs LIF {acc_h:.3f}; "
          f"hidden spike rate {rate_d:.1%} "
          f"(paper: 2.5% hidden rate on real SHD)")


if __name__ == "__main__":
    main()
