"""BCI cross-day decoding with on-chip learning (paper §V-B3, Fig. 15).

Pipeline exactly as the paper describes: a multi-sub-path network (linear
transform (x) channel attention + temporal conv per path), Hadamard fusion,
concat -> LIF -> fused BN1d+FC readout; train on day 0, then recover
cross-day accuracy by fine-tuning ONLY the FC with 32 samples using the
accumulated-spike backprop (the paper's on-chip learning trick).

Run: PYTHONPATH=src python examples/bci_onchip.py
"""

import jax
import jax.numpy as jnp

from repro.core.snn_layers import (BCIConfig, bci_finetune_fc, bci_forward,
                                   bci_init)
from repro.data.spikes import gen_bci_trials

cfg = BCIConfig(n_channels=64, n_steps=30, n_paths=8, d_path=16)
params = bci_init(jax.random.PRNGKey(2), cfg)

# day-0 training
x0, y0 = gen_bci_trials(128, day=0, n_channels=64, n_bins=30)
x0, y0 = jnp.asarray(x0), jnp.asarray(y0)


@jax.jit
def loss_grad(params):
    def loss(params):
        logits, _ = bci_forward(params, x0, cfg)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y0)), y0])
    return jax.value_and_grad(loss)(params)


print("training on day 0 ...")
for i in range(100):
    loss, g = loss_grad(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(gg)) for gg in jax.tree.leaves(g)))
    params = jax.tree.map(
        lambda p, gg: p - 0.05 * jnp.minimum(1.0, 1.0 / (gn + 1e-9)) * gg,
        params, g)
    if i % 25 == 0:
        print(f"  step {i:3d} loss {float(loss):.4f}")


def acc(p, x, y):
    logits, _ = bci_forward(p, jnp.asarray(x), cfg)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))


print(f"day-0 accuracy: {acc(params, x0, y0):.3f}\n")
print(f"{'day':>4s} {'before':>8s} {'after 32-sample on-chip FT':>28s}")
for day in (1, 2, 3):
    xt, yt = gen_bci_trials(64, day=day, n_channels=64, n_bins=30, seed=day)
    before = acc(params, xt, yt)
    xf, yf = gen_bci_trials(32, day=day, n_channels=64, n_bins=30,
                            seed=100 + day)
    tuned, losses = bci_finetune_fc(params, jnp.asarray(xf), jnp.asarray(yf),
                                    cfg, lr=0.05, steps=25)
    after = acc(tuned, xt, yt)
    print(f"{day:4d} {before:8.3f} {after:28.3f}")
print("\n(the FC-only fine-tune stores only accumulated spikes — the paper's"
      "\n on-chip memory optimization, exact for this readout; see"
      " core/plasticity.py)")
