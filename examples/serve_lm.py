"""Batched serving example: cohort prefill + KV-cache decode on a small
model, with greedy-determinism check.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-3b]
(any of the 10 assigned architectures works; SSM/RWKV families serve from
constant-size state instead of a KV cache — same API.)
"""

import argparse

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    args = ap.parse_args()
    serve_cli.main(["--arch", args.arch, "--smoke", "--requests", "8",
                    "--batch", "4", "--max-new", "16"])


if __name__ == "__main__":
    main()
