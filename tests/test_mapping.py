"""Compiler-stack tests: fusion, partition budgets, placement optimization,
resource merging, and the cores<->throughput trade-off (Fig. 12/13e)."""

import numpy as np
import pytest

from repro.core.mapping import (CORE_FANIN, CORE_NEURONS, Op, compile_network,
                                fuse_ops, merge_cores, optimize_placement,
                                partition, place_zigzag, traffic_cost)
from repro.configs.snn_models import MODELS, to_ops


def _toy_ops():
    return [
        Op("conv1", "conv", 4096, 27, ("input",)),
        Op("bn1", "bn", 4096, 1, ("conv1",)),
        Op("fc1", "fc", 512, 4096, ("bn1",)),
        Op("fc2", "fc", 10, 512, ("fc1",)),
    ]


def test_fuse_folds_bn_into_conv():
    ir = fuse_ops(_toy_ops())
    names = [o.name for o in ir]
    assert "bn1" not in names
    conv = next(o for o in ir if o.name == "conv1")
    assert "bn1" in conv.fused
    fc1 = next(o for o in ir if o.name == "fc1")
    assert fc1.inputs == ("conv1",)          # consumer re-routed


def test_partition_respects_neuron_budget():
    cores = partition(fuse_ops(_toy_ops()))
    for c in cores:
        assert c.neuron_hi - c.neuron_lo <= CORE_NEURONS
    covered = {}
    for c in cores:
        covered.setdefault(c.op, []).append((c.neuron_lo, c.neuron_hi))
    for op, spans in covered.items():
        spans.sort()
        assert spans[0][0] == 0
        for (a, b), (c_, d) in zip(spans, spans[1:]):
            assert b == c_                   # contiguous, no gaps


def test_fanin_expansion_charges_psum_parts():
    """fan-in 4096 > 2048 limit -> PSUM split halves the per-core capacity
    (TaiBai keeps PSUM + spiking neurons in ONE core, Fig. 11)."""
    big = [Op("fc", "fc", CORE_NEURONS, 2 * CORE_FANIN, ("input",))]
    small = [Op("fc", "fc", CORE_NEURONS, CORE_FANIN, ("input",))]
    assert len(partition(big)) == 2 * len(partition(small))


def test_merge_reduces_cores():
    ops = [Op(f"fc{i}", "fc", 40, 100, ()) for i in range(8)]
    cores = partition(ops)
    merged = merge_cores(cores, ops)
    assert len(merged) < len(cores)
    assert len(merged) >= int(np.ceil(8 * 40 / CORE_NEURONS))


def test_placement_optimizer_improves_cost():
    rng = np.random.default_rng(0)
    n = 24
    traffic = rng.random((n, n)) * (rng.random((n, n)) < 0.2)
    pos0 = place_zigzag(n)
    c0 = traffic_cost(traffic, pos0)
    _, c1 = optimize_placement(traffic, iters=1500, seed=1)
    assert c1 <= c0


def test_tradeoff_throughput_uses_more_cores():
    """Fig. 13e: throughput objective spreads populations over more cores."""
    specs, _ = MODELS["plif_net"]()
    ops = to_ops(specs)
    m_cores = compile_network(ops, objective="cores", anneal_iters=50)
    m_tp = compile_network(ops, objective="throughput", anneal_iters=50)
    assert m_tp.meta["n_cores"] > m_cores.meta["n_cores"]


@pytest.mark.parametrize("model", ["plif_net", "resnet19", "5blocks_net"])
def test_table2_models_compile(model):
    specs, name = MODELS[model]()
    ops = to_ops(specs)
    mapping = compile_network(ops, objective="cores", anneal_iters=20,
                              grid=(40, 40))
    assert mapping.meta["n_cores"] > 0
    assert mapping.positions.shape[0] == len(mapping.cores)
