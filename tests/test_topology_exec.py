"""Compressed-topology execution: `Connection(topology=...)` edges must be
numerically identical to the dense weights they encode, through BOTH
engines, for every IE type — without ever materializing
`dense_equivalent()` on the compressed path. Plus the streaming-memory
contract: `plan.run_stream` holds peak RSS constant in stream length while
the one-shot full-time path grows linearly."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events, plan
from repro.core import topology as topo
from repro.core.events import Connection
from repro.core.neuron import LI, LIF
from repro.core.snn_layers import ff_integrate
from repro.kernels.spikemm.gather import build_gather_tables, spikemm_gather

KEY = jax.random.PRNGKey(7)


def _spikes(key, shape, rate=0.35):
    return (jax.random.uniform(key, shape) < rate).astype(jnp.float32)


def _dense_w(enc):
    return jnp.asarray(enc.dense_equivalent(), jnp.float32)


def _encodings(rng):
    """One encoding per IE type (+pool), modest but non-block-aligned."""
    dense = rng.standard_normal((37, 29)).astype(np.float32) * 0.3
    sp = dense * (rng.random((37, 29)) < 0.15)
    filt = 0.4 * rng.standard_normal((3, 2, 3, 3)).astype(np.float32)
    pre, post = np.nonzero(rng.random((45, 33)) < 0.08)
    w = 0.5 * rng.standard_normal(len(pre)).astype(np.float32)
    return {
        "fc_t2": topo.encode(dense, kind="fc", n_cores=3),
        "sparse_t0": topo.encode(sp, kind="sparse", ie_type=0),
        "sparse_t1": topo.encode(sp, kind="sparse", ie_type=1),
        "sparse_coo_t1": topo.encode((pre, post, w), kind="sparse_coo",
                                     n_pre=45, n_post=33),
        "conv_t3": topo.encode(filt, kind="conv", h=6, w=5),
        "pool_t0": topo.encode(None, kind="pool", h=6, w=6, c=2, k=2),
    }


@pytest.fixture(scope="module")
def encodings():
    return _encodings(np.random.default_rng(3))


# ---------------------------------------------------------------------------
# kernel-level: apply_spikes == dense matmul for every IE type
# ---------------------------------------------------------------------------


def test_apply_spikes_matches_dense_all_types(encodings):
    for i, (name, enc) in enumerate(encodings.items()):
        x = _spikes(jax.random.fold_in(KEY, i), (9, enc.n_pre))
        got = np.asarray(enc.apply_spikes(x))
        want = np.asarray(x @ _dense_w(enc))
        np.testing.assert_allclose(got, want, atol=plan.CROSS_ENGINE_ATOL,
                                   rtol=1e-4, err_msg=name)


def test_gather_vjp_matches_dense(encodings):
    enc = encodings["sparse_t1"]
    x = _spikes(KEY, (6, enc.n_pre))
    w = _dense_w(enc)
    g1 = jax.grad(lambda s: jnp.sum(jnp.tanh(enc.apply_spikes(s))))(x)
    g2 = jax.grad(lambda s: jnp.sum(jnp.tanh(s @ w)))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_gather_tables_reject_ghost_indices():
    with pytest.raises(ValueError, match="ghost"):
        build_gather_tables(np.array([0, 99]), np.array([1, 2]),
                            np.ones(2, np.float32), 10, 10, bk=8, bn=8)


def test_gather_duplicate_entries_accumulate():
    t = build_gather_tables(np.array([1, 1]), np.array([2, 2]),
                            np.array([0.25, 0.75], np.float32), 4, 4,
                            bk=4, bn=4)
    x = jnp.zeros((1, 4)).at[0, 1].set(1.0)
    assert float(spikemm_gather(x, t)[0, 2]) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# program-level: topology-backed Connections through BOTH engines
# ---------------------------------------------------------------------------


def _two_layer(enc, conn):
    """input --(topology|dense)--> h --dense--> readout."""
    ks = jax.random.split(KEY, 2)
    w_ro = 0.5 * jax.random.normal(ks[0], (enc.n_post, 4), jnp.float32)
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.6), ff_integrate,
                         (conn,), enc.n_post),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4),
    ]
    return nodes, {"h": {}, "ro": {"w_h": w_ro}}


@pytest.mark.parametrize("name", ["fc_t2", "sparse_t0", "sparse_t1",
                                  "sparse_coo_t1", "conv_t3", "pool_t0"])
def test_topology_connection_matches_dense_both_engines(encodings, name):
    enc = encodings[name]
    x = _spikes(jax.random.fold_in(KEY, 11), (7, 2, enc.n_pre))

    nodes_t, params_t = _two_layer(enc, Connection("input", topology=enc))
    nodes_d, params_d = _two_layer(enc, Connection("input"))
    params_d["h"]["w_input"] = _dense_w(enc)

    for engine in (plan.run, events.run):
        _, o_t, _ = engine(nodes_t, params_t, x)
        _, o_d, _ = engine(nodes_d, params_d, x)
        np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_d),
                                   atol=plan.CROSS_ENGINE_ATOL, rtol=1e-4,
                                   err_msg=f"{name}:{engine.__module__}")


def test_topology_by_params_key_and_from_topology(encodings):
    """A str topology resolves through params; from_topology lifts the
    skip delay out of meta — and a delayed skip edge equals a plain
    delayed dense edge."""
    base = encodings["sparse_t1"]
    skip = topo.encode(base, kind="skip", delay=2)
    conn = Connection.from_topology("a", skip)
    assert conn.delay == 2 and conn.topology is skip

    # input --dense--> a --(skip@2)--> h --dense--> ro; delayed edges need
    # a stateful source (the stepper keeps rings per node, not for input)
    ks = jax.random.split(KEY, 2)
    w_in = 0.5 * jax.random.normal(ks[0], (6, skip.n_pre), jnp.float32)
    w_ro = 0.5 * jax.random.normal(ks[1], (skip.n_post, 4), jnp.float32)

    def net(edge):
        nodes = [
            events.LayerNode("a", LIF(tau=0.7, v_th=0.5), ff_integrate,
                             ("input",), skip.n_pre),
            events.LayerNode("h", LIF(tau=0.8, v_th=0.6), ff_integrate,
                             (edge,), skip.n_post),
            events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4),
        ]
        return nodes, {"a": {"w_input": w_in}, "h": {},
                       "ro": {"w_h": w_ro}}

    nodes_t, params_t = net(Connection("a", topology="T", delay=2))
    params_t["h"]["T"] = skip
    nodes_d, params_d = net(Connection("a", delay=2))
    params_d["h"]["w_a"] = _dense_w(skip)
    x = _spikes(KEY, (9, 2, 6))
    for engine in (plan.run, events.run):
        _, o_t, _ = engine(nodes_t, params_t, x)
        _, o_d, _ = engine(nodes_d, params_d, x)
        np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_d),
                                   atol=plan.CROSS_ENGINE_ATOL, rtol=1e-4)


def test_topology_connection_validation(encodings):
    enc = encodings["sparse_t1"]
    with pytest.raises(ValueError, match="plastic"):
        from repro.core.plasticity import pair_stdp
        Connection("input", topology=enc, plastic=pair_stdp())
    with pytest.raises(ValueError, match="weight"):
        Connection("input", topology=enc, weight="w_x")
    with pytest.raises(TypeError, match="topology"):
        Connection("input", topology=42)
    with pytest.raises(KeyError, match="no such"):
        events.resolve_topology(Connection("input", topology="nope"),
                                "h", {"h": {}})


def test_run_stream_equals_one_shot_with_topology(encodings):
    enc = encodings["conv_t3"]
    nodes, params = _two_layer(enc, Connection("input", topology=enc))
    x = _spikes(KEY, (20, 2, enc.n_pre))
    _, o1, _ = plan.run(nodes, params, x)
    outs = [o for _, o in plan.run_stream(
        nodes, params, [x[:6], x[6:7], x[7:15], x[15:]])]
    np.testing.assert_allclose(np.asarray(o1),
                               np.asarray(jnp.concatenate(outs, 0)),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# streaming memory: constant in T for run_stream, linear for one-shot
# ---------------------------------------------------------------------------

_MEM_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import events, plan
    from repro.core import topology as topo
    from repro.core.events import Connection
    from repro.core.neuron import LI, LIF
    from repro.core.snn_layers import ff_integrate

    mode, T = sys.argv[1], int(sys.argv[2])
    n, band, chunk = 8192, 64, 64
    rows = np.repeat(np.arange(n), 2 * band + 1)
    cols = rows + np.tile(np.arange(-band, band + 1), n)
    keep = (cols >= 0) & (cols < n)
    w = 0.05 * np.ones(keep.sum(), np.float32)
    enc = topo.encode((rows[keep], cols[keep], w), kind="sparse_coo",
                      n_pre=n, n_post=n)
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.6), ff_integrate,
                         (Connection("input", topology=enc),), n),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 8),
    ]
    params = {"h": {}, "ro": {"w_h": 0.1 * np.ones((n, 8), np.float32)}}
    rng = np.random.default_rng(0)

    def chunks():
        for _ in range(T // chunk):
            yield jnp.asarray((rng.random((chunk, 1, n)) < 0.2),
                              jnp.float32)

    if mode == "stream":
        for st, out in plan.run_stream(nodes, params, chunks()):
            out.block_until_ready()
    else:  # one-shot: the delay-shifted full-time path
        x = jnp.concatenate(list(chunks()), axis=0)
        _, out, _ = plan.run(nodes, params, x)
        out.block_until_ready()
    # peak RSS via VmHWM: unlike ru_maxrss it resets on exec, so a large
    # launching process (e.g. pytest with other suites resident) cannot
    # taint the measurement through fork
    hwm = [l for l in open("/proc/self/status") if l.startswith("VmHWM")]
    print(hwm[0].split()[1])
""")


def _peak_rss_kb(mode, T):
    r = subprocess.run([sys.executable, "-c", _MEM_SCRIPT, mode, str(T)],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    return int(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_streaming_memory_constant_in_stream_length():
    """ISSUE acceptance: 16x more stream steps must not move streaming
    peak RSS (beyond allocator noise), while the one-shot path — which
    materializes (T, B, n) activity tensors — pays linearly."""
    short = _peak_rss_kb("stream", 256)
    long_ = _peak_rss_kb("stream", 4096)
    oneshot = _peak_rss_kb("oneshot", 4096)
    # constant: 16x longer stream costs < 25% + 64MB slack
    assert long_ < short * 1.25 + 64 * 1024, (short, long_)
    # linear: the full-time path carries >= the raw input tensor extra
    # (4096 * 8192 * 4 bytes = 128 MB) over the streaming footprint
    assert oneshot > long_ + 100 * 1024, (oneshot, long_)
