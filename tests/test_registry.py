"""Kernel-registry tests: parity harness over every registered kernel,
block resolution, dispatch policy, and the tuning-cache round trip."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import parity, registry, tuning

registry.ensure_registered()
ALL_KERNELS = registry.names()


# ---------------------------------------------------------------------------
# registration + parity (the CI backbone: every kernel, forward AND VJP)
# ---------------------------------------------------------------------------


def test_all_families_registered():
    assert set(ALL_KERNELS) == {"linrec", "lif", "lifrec", "alif", "alifrec",
                                "spikemm", "spikemm_gather", "attention",
                                "stdp", "stdp_seq"}
    for name in ALL_KERNELS:
        spec = registry.get(name)
        assert spec.make_inputs is not None, name
        assert spec.block_axes, name
        assert spec.candidates, name


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_parity_forward_and_vjp(name):
    report = parity.check_kernel(name)
    assert report["forward_max_err"] <= registry.get(name).tol
    if registry.get(name).diff_argnums:
        assert "vjp_max_err" in report


def test_parity_check_all_covers_every_kernel():
    reports = parity.check_all()
    assert set(reports) == set(ALL_KERNELS)


@pytest.mark.tpu
@pytest.mark.parametrize("name", ALL_KERNELS)
def test_parity_real_mosaic(name):
    """Same harness, real compiled kernels (auto-skipped off-TPU)."""
    assert not registry.interpret_mode()
    parity.check_kernel(name)


def test_ops_files_have_no_direct_dispatch_logic():
    """Acceptance guard: block sizing + interpret policy live ONLY in the
    registry; a new kernel must not reintroduce per-family copies."""
    import repro.kernels as kpkg

    root = os.path.dirname(kpkg.__file__)
    offenders = []
    for fam in os.listdir(root):
        ops = os.path.join(root, fam, "ops.py")
        if not os.path.isfile(ops):
            continue
        src = open(ops).read()
        for banned in ("pick_block", "interpret_mode"):
            if banned in src:
                offenders.append((fam, banned))
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# block resolution
# ---------------------------------------------------------------------------


def test_fit_block_alignment_and_cap():
    assert registry.fit_block(100, 256, 8) == 104    # round up to align
    assert registry.fit_block(1000, 256, 8) == 256   # capped at preferred
    assert registry.fit_block(3, 256, 128) == 128    # floor at align


def test_exact_block_divides():
    assert registry.exact_block(20, 256) == 20       # whole axis fits
    assert registry.exact_block(1000, 256) == 250    # largest divisor <= pref
    assert registry.exact_block(97, 64) == 1         # prime: serial fallback
    for n, pref in [(20, 8), (256, 256), (1000, 256), (7, 512)]:
        b = registry.exact_block(n, pref)
        assert n % b == 0 and 1 <= b <= max(n, 1)


def test_lif_time_axis_never_padded():
    """Regression for the bug the parity harness caught: zero-padding the
    LIF time axis runs extra decay steps and corrupts v_final. The ct axis
    is `exact`, so any T (incl. primes) must agree with the reference."""
    from repro.kernels.lif.ops import lif_scan
    from repro.kernels.lif.ref import lif_scan_ref

    for T in (20, 23, 37):
        k = jax.random.PRNGKey(T)
        cur = 0.6 * jax.random.normal(k, (T, 2, 130))
        tau = jnp.full((130,), 0.9)
        v0 = jnp.zeros((2, 130))
        s_ref, v_ref = lif_scan_ref(cur, tau, v0)
        s_k, v_k = lif_scan(cur, tau, v0, 1.0, "rectangle", 1.0, True)
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref),
                                   rtol=1e-5, atol=1e-5)


def test_dispatch_policy_env(monkeypatch):
    from repro.kernels.common import on_tpu

    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    assert registry.use_pallas(False)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    assert not registry.use_pallas(False)
    assert registry.use_pallas(True)          # explicit force always wins
    monkeypatch.delenv("REPRO_KERNEL_IMPL")
    assert registry.use_pallas(False) == on_tpu()  # auto: pallas on TPU only


# ---------------------------------------------------------------------------
# tuning cache
# ---------------------------------------------------------------------------


def test_shape_bucket_pow2_and_canonical():
    assert tuning.shape_bucket({"T": 100, "B": 8}) == "B8_T128"
    assert tuning.shape_bucket({"B": 8, "T": 100}) == "B8_T128"  # order-free
    assert tuning.shape_bucket({"D": 1}) == "D1"


def test_tuning_cache_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = tuning.TuningCache(path)
    assert cache.lookup("linrec", "cpu", "B8_T128") is None
    cache.put("linrec", "cpu", "B8_T128", {"ct": 128, "bb": 8, "bd": 256},
              stats={"best_s": 1e-3})
    cache.save()

    reloaded = tuning.TuningCache(path)
    assert reloaded.lookup("linrec", "cpu", "B8_T128") == {
        "ct": 128, "bb": 8, "bd": 256}
    assert reloaded.lookup("linrec", "cpu", "B8_T256") is None
    assert len(reloaded) == 1
    raw = json.load(open(path))
    assert raw["version"] == 1


def test_tuning_cache_corrupt_file_is_ignored(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write("{not json")
    cache = tuning.TuningCache(path)
    assert cache.lookup("lif", "cpu", "X1") is None
    cache.put("lif", "cpu", "X1", {"ct": 8})
    cache.save()
    assert tuning.TuningCache(path).lookup("lif", "cpu", "X1") == {"ct": 8}


def test_autotune_persists_winner_and_dispatch_uses_it(tmp_path,
                                                       monkeypatch):
    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)

    spec = registry.get("linrec")
    args = spec.make_inputs(jax.random.PRNGKey(0))
    dims = spec.dims_of(*args)

    blocks, report = tuning.autotune("linrec", args, repeats=1)
    assert os.path.exists(path)
    assert report["winner"]["blocks"] == blocks
    assert {t["blocks"]["ct"] for t in report["timings"] if "best_s" in t}

    # dispatch-time resolution picks the persisted winner for this bucket...
    assert spec.resolve_blocks(dims) == blocks
    # ...and ignores it for a different bucket (falls back to defaults)
    other_dims = {"T": 4 * dims["T"], "B": dims["B"], "D": dims["D"]}
    default_blocks = spec.resolve_blocks(other_dims, use_cache=False)
    assert spec.resolve_blocks(other_dims) == default_blocks


def test_autotune_prunes_vmem_hogs(tmp_path, monkeypatch):
    """With a tiny VMEM budget every non-default candidate is pruned before
    timing; the spec-default baseline must survive and win."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "t.json"))
    monkeypatch.setenv("REPRO_VMEM_LIMIT_MB", "0.01")
    spec = registry.get("lif")
    # serving-scale shape: candidates fit to DISTINCT block configs (the
    # canonical parity inputs are so small they all collapse to one)
    k = jax.random.PRNGKey(0)
    args = (0.6 * jax.random.normal(k, (256, 8, 512)),
            jnp.full((512,), 0.9), jnp.zeros((8, 512)))
    blocks, report = tuning.autotune("lif", args, repeats=1)
    assert report["pruned"], "expected candidates above the 10 KiB budget"
    assert len([t for t in report["timings"] if "best_s" in t]) >= 1
    defaults = spec.resolve_blocks(spec.dims_of(*args), use_cache=False)
    assert blocks == defaults


def test_every_spec_has_vmem_model():
    for name in ALL_KERNELS:
        spec = registry.get(name)
        assert spec.vmem_bytes is not None, name
        args = spec.make_inputs(jax.random.PRNGKey(0))
        dims = spec.dims_of(*args)
        blocks = spec.resolve_blocks(dims, use_cache=False)
        est = spec.vmem_bytes(dims, blocks)
        assert 0 < est < 2 ** 30, (name, est)


def test_bundled_cache_fallback(tmp_path, monkeypatch):
    """A user-cache miss falls through to the checked-in CI cache; a user
    entry for the same bucket wins over the bundled one."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "user.json"))
    spec = registry.get("spikemm")
    args = spec.make_inputs(jax.random.PRNGKey(0))
    dims = spec.dims_of(*args)
    bucket = tuning.shape_bucket(dims)
    bundled = tuning.bundled_cache().lookup("spikemm", jax.default_backend(),
                                            bucket)
    if bundled is None:
        pytest.skip(f"no bundled entry for backend/bucket {bucket}")
    assert tuning.lookup_tuned("spikemm", dims) == bundled

    planted = {"bm": 8, "bk": 128, "bn": 128}
    tuning.default_cache().put("spikemm", jax.default_backend(), bucket,
                               planted)
    assert tuning.lookup_tuned("spikemm", dims) == planted


def test_tuned_blocks_still_produce_correct_results(tmp_path, monkeypatch):
    """End-to-end: plant a deliberately odd tuned config and check the
    kernel output is still exact — tuning may only change performance."""
    from repro.kernels.linrec.ops import linrec
    from repro.kernels.linrec.ref import linrec_naive

    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    spec = registry.get("linrec")
    k = jax.random.PRNGKey(1)
    a = jax.random.uniform(k, (48, 2, 130), jnp.float32, 0.5, 0.99)
    x = jax.random.normal(jax.random.fold_in(k, 1), (48, 2, 130))
    h0 = jnp.zeros((2, 130))
    dims = spec.dims_of(a, x, h0)

    cache = tuning.TuningCache(path)
    cache.put("linrec", jax.default_backend(), tuning.shape_bucket(dims),
              {"ct": 16, "bb": 8, "bd": 128})
    cache.save()
    assert spec.resolve_blocks(dims)["ct"] == 16    # the planted config wins

    y_ref, h_ref = linrec_naive(a, x, h0)
    y_k, h_k = linrec(a, x, h0, True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# implementation channels (block-sparse spikemm dispatch policy)
# ---------------------------------------------------------------------------


def _channel_rasters():
    k = jax.random.PRNGKey(11)
    M, K = 512, 1024
    sparse = jnp.zeros((M, K), jnp.float32).at[:64, :128].set(1.0)
    dense = (jax.random.uniform(k, (M, K)) < 0.5).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, 64), jnp.float32)
    return sparse, dense, w


def test_spikemm_channel_env_policy(monkeypatch):
    """never/auto/always routing, tracer conservatism, invalid value."""
    from repro.kernels.spikemm import ops
    sparse, dense, w = _channel_rasters()
    spec = registry.get("spikemm")
    blocks = spec.resolve_blocks(spec.dims_of(sparse, w))

    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "never")
    assert ops._select_channel(sparse, w, blocks=blocks) is None
    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "always")
    assert ops._select_channel(dense, w, blocks=blocks) == "sparse"
    monkeypatch.delenv("REPRO_SPIKEMM_SPARSE")
    assert ops._select_channel(sparse, w, blocks=blocks) == "sparse"
    assert ops._select_channel(dense, w, blocks=blocks) is None

    # abstract raster (under jit): occupancy unknowable -> dense
    seen = []

    def probe(s):
        seen.append(ops._select_channel(s, w, blocks=blocks))
        return s

    jax.jit(probe)(sparse)
    assert seen == [None]

    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "bogus")
    with pytest.raises(ValueError, match="REPRO_SPIKEMM_SPARSE"):
        ops._select_channel(sparse, w, blocks=blocks)


def test_spikemm_auto_threshold_from_tuning_cache(tmp_path, monkeypatch):
    """The auto policy honors a tuned per-(backend, bucket) threshold: a
    zero threshold pins even a near-empty raster to the dense channel."""
    from repro.kernels.spikemm import ops
    sparse, _, w = _channel_rasters()
    spec = registry.get("spikemm")
    blocks = spec.resolve_blocks(spec.dims_of(sparse, w))
    dims = spec.dims_of(sparse, w)
    monkeypatch.delenv("REPRO_SPIKEMM_SPARSE", raising=False)
    # fresh cache path per scenario: the default-cache singleton caches
    # its first load of a given path
    for permille, expect in ((0, None), (1000, "sparse")):
        path = str(tmp_path / f"cache_{permille}.json")
        monkeypatch.setenv("REPRO_TUNING_CACHE", path)
        cache = tuning.TuningCache(path)
        cache.put("spikemm.sparse_th", jax.default_backend(),
                  tuning.shape_bucket(dims), {"permille": permille})
        cache.save()
        assert ops.sparse_threshold(dims) == permille / 1000.0
        assert ops._select_channel(sparse, w, blocks=blocks) == expect


def test_dispatch_routes_through_selected_channel(monkeypatch):
    """dispatch() must hand the call to the channel pair the router picks
    (observed via a wrapped spec), and fall through when it returns None."""
    from repro.kernels.spikemm import ops
    sparse, _, w = _channel_rasters()
    calls = []
    spec = registry.get("spikemm")
    wrapped = dataclasses.replace(
        spec,
        ref=lambda *a, **kw: calls.append("dense") or spec.ref(*a, **kw),
        channels={"sparse": registry.Channel(
            ref=lambda *a, **kw: calls.append("sparse")
            or spec.channels["sparse"].ref(*a, **kw),
            pallas=spec.channels["sparse"].pallas)})
    monkeypatch.setitem(registry._REGISTRY, "spikemm", wrapped)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "always")
    registry.dispatch("spikemm", (sparse, w))
    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "never")
    registry.dispatch("spikemm", (sparse, w))
    assert calls == ["sparse", "dense"]
