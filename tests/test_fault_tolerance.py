"""Fault-tolerance tests: checkpoint roundtrip + integrity, restart-resume,
corrupt-checkpoint fallback, elastic re-shard, deterministic skip-ahead,
and mid-stream chunked-online snapshot/resume bit-identity."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import faults, plan, plasticity
from repro.data.tokens import TokenStream
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import (CheckpointManager, StreamCheckpointer,
                                    latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import TrainLoopConfig, train_loop
from tests._faults import plastic_net, spikes


def _tree_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16), "step": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = restore_checkpoint(str(tmp_path), 3, like)
    assert _tree_equal(tree, out)


def test_corrupt_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones(4)}
    mgr.save_sync(1, tree)
    mgr.save_sync(2, jax.tree.map(lambda x: 2 * x, tree))
    # corrupt the latest shard
    p = os.path.join(str(tmp_path), "step_0000000002", "shard_00000.npz")
    with open(p, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00garbage\x00")
    step, out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree))
    assert step == 1                      # fell back past the corrupt one
    assert _tree_equal(out, tree)


def test_train_resume_matches_uninterrupted(tmp_path):
    """Train 10 steps straight vs 5 + restart + 5: identical final params
    (deterministic data skip-ahead + exact state restore)."""
    cfg = get_smoke_config("qwen2-1.5b").replace(dtype="float32")
    stream = TokenStream(cfg.vocab_size, 16, 2, seed=3)
    step_fn = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=1e-3)))

    def batches(step):
        return {"tokens": jnp.asarray(stream.batch_at(step)["tokens"])}

    def fresh_state():
        return lm.init_train_state(jax.random.PRNGKey(0), cfg)

    # uninterrupted
    d1 = tmp_path / "a"
    s1, _ = train_loop(step_fn, fresh_state(), batches,
                       TrainLoopConfig(10, str(d1), ckpt_every=100))

    # interrupted at 5 (simulated preemption: separate loop runs)
    d2 = tmp_path / "b"
    train_loop(step_fn, fresh_state(), batches,
               TrainLoopConfig(5, str(d2), ckpt_every=100))
    s2, report = train_loop(step_fn, fresh_state(), batches,
                            TrainLoopConfig(10, str(d2), ckpt_every=100))
    assert report.restored and report.start_step == 5
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(s1["params"])[0]),
        np.asarray(jax.tree.leaves(s2["params"])[0]), rtol=1e-6)


def test_elastic_reshard_restore(tmp_path):
    """Checkpoints are mesh-agnostic: save from one device layout, restore
    onto a different sharding (here: replicated -> explicitly placed)."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    shard = jax.sharding.SingleDeviceSharding(dev)
    out = restore_checkpoint(str(tmp_path), 1, tree, {"w": shard})
    assert _tree_equal(tree, out)
    assert out["w"].sharding == shard


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_sync(s, {"w": jnp.full(3, float(s))})
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_save_equivalent(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.arange(5.0)}
    mgr.save_async(7, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 7


def _tree_bit_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def _stream_windows(nodes, params, state, key, start, stop, ckpt=None):
    """Run chunked-online windows [start, stop); optionally snapshot each."""
    for w in range(start, stop):
        x = spikes(jax.random.fold_in(key, w), n=24)
        state, _, _ = plan.run(nodes, params, x, state=state)
        params = plasticity.apply_learned(nodes, params, state)
        if ckpt is not None:
            ckpt.save(w, state, params=params,
                      rng=jax.random.key_data(key))
    return params, state


def test_stream_checkpoint_resume_bit_identical(tmp_path):
    """The acceptance scenario: interrupt a plastic chunked-online stream
    mid-sequence, restore from the StreamCheckpointer, finish — final
    weights, neuron state, AND synapse traces match the uninterrupted run
    bit for bit."""
    key = jax.random.PRNGKey(7)
    with faults.inject(""):
        # uninterrupted: 6 windows straight
        nodes, params0 = plastic_net()
        from repro.core import events
        state0 = events.init_state(nodes, 4, jnp.float32, params0)
        p_ref, s_ref = _stream_windows(nodes, dict(params0), state0,
                                       key, 0, 6)

        # interrupted: 3 windows + snapshot each, then a cold process
        ck = StreamCheckpointer(str(tmp_path), keep=2)
        _stream_windows(nodes, dict(params0), state0, key, 0, 3, ckpt=ck)

        # "restart": fresh templates, restore, resume from window+1
        nodes2, params2 = plastic_net()
        state2 = events.init_state(nodes2, 4, jnp.float32, params2)
        ck2 = StreamCheckpointer(str(tmp_path), keep=2)
        window, state2, params2, rng = ck2.restore_latest(
            state2, params=params2, rng=jax.random.key_data(key))
        assert window == 2                       # windows 0..2 completed
        key2 = jax.random.wrap_key_data(jnp.asarray(rng))
        p_res, s_res = _stream_windows(nodes2, params2, state2,
                                       key2, window + 1, 6)

    assert _tree_bit_equal(p_ref, p_res)
    assert _tree_bit_equal(s_ref, s_res)


def test_stream_checkpoint_cold_start_passthrough(tmp_path):
    nodes, params = plastic_net()
    from repro.core import events
    state = events.init_state(nodes, 4, jnp.float32, params)
    ck = StreamCheckpointer(str(tmp_path / "empty"))
    window, s, p, r = ck.restore_latest(state, params=params, rng=None)
    assert window is None
    assert _tree_bit_equal(s, state) and _tree_bit_equal(p, params)
    assert r is None


def test_stream_checkpoint_keeps_last_k(tmp_path):
    nodes, params = plastic_net()
    from repro.core import events
    state = events.init_state(nodes, 4, jnp.float32, params)
    ck = StreamCheckpointer(str(tmp_path), keep=2)
    for w in range(4):
        ck.save(w, state, params=params)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [2, 3]


def test_token_stream_skip_ahead_deterministic():
    s1 = TokenStream(100, 8, 4, seed=1)
    s2 = TokenStream(100, 8, 4, seed=1)
    for _ in range(5):
        pass
    np.testing.assert_array_equal(s1.batch_at(17)["tokens"],
                                  s2.batch_at(17)["tokens"])
    assert not np.array_equal(s1.batch_at(17)["tokens"],
                              s1.batch_at(18)["tokens"])


def test_token_stream_shards_differ():
    a = TokenStream(100, 8, 4, seed=1, shard=0, n_shards=2).batch_at(3)
    b = TokenStream(100, 8, 4, seed=1, shard=1, n_shards=2).batch_at(3)
    assert not np.array_equal(a["tokens"], b["tokens"])
