"""Full-sequence prefill vs the token-at-a-time scan path: identical caches,
logits, and generated tokens for the stateless attention families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.models import transformer as tf_mod
from repro.serve.loop import Request, ServeConfig, generate


def _cfg(arch):
    return get_smoke_config(arch).replace(dtype="float32")


def _generate_both(cfg, monkeypatch_target=None):
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(1, 200, size=n).astype(np.int32), max_new=5)
            for n in (5, 9, 9, 3)]
    scfg = ServeConfig(batch=4, max_seq=48)
    out_fast = generate(params, cfg, reqs, scfg)
    orig = lm.can_full_prefill
    try:
        lm.can_full_prefill = lambda c: False
        out_scan = generate(params, cfg, reqs, scfg)
    finally:
        lm.can_full_prefill = orig
    return out_fast, out_scan


def test_prefill_forward_matches_decode_steps_dense():
    cfg = _cfg("llama3.2-3b")
    params = lm.model_init(jax.random.PRNGKey(1), cfg)
    B, L, S = 2, 7, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 1, 200)
    cache0 = lm.init_cache(cfg, B, S)

    logits_full, cache_full = tf_mod.prefill_forward(params, toks, cache0, cfg)

    cache_step = cache0
    step_logits = []
    for t in range(L):
        lg, cache_step = tf_mod.decode_step(params, toks[:, t:t + 1],
                                            cache_step, jnp.asarray(t), cfg)
        step_logits.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(step_logits[-1]),
                               atol=1e-4, rtol=1e-4)
    for k in ("k", "v"):
        np.testing.assert_allclose(np.asarray(cache_full[k]),
                                   np.asarray(cache_step[k]),
                                   atol=1e-5, rtol=1e-5)


def test_generate_dense_full_prefill_token_identical():
    out_fast, out_scan = _generate_both(_cfg("llama3.2-3b"))
    for a, b in zip(out_fast, out_scan):
        np.testing.assert_array_equal(a, b)


def test_generate_with_empty_prompt_in_cohort():
    """L0 = 0 must skip the full-sequence prefill (logits[:, -1] on a
    zero-length axis would crash) and fall back to the scan path."""
    cfg = _cfg("llama3.2-3b")
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    reqs = [Request(np.array([], np.int32), max_new=3),
            Request(np.array([5, 7], np.int32), max_new=3)]
    out = generate(params, cfg, reqs, ServeConfig(batch=2, max_seq=16))
    assert [o.shape for o in out] == [(3,), (3,)]


def test_generate_prompt_longer_than_cache_degrades_not_crashes():
    """A prompt exceeding S = min(max_seq, Lp + max_new) must take the scan
    path's clamped-write semantics (pre-existing behavior), not crash the
    full-prefill batched cache write."""
    cfg = _cfg("llama3.2-3b")
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    reqs = [Request(np.arange(1, 61, dtype=np.int32), max_new=3)]
    out = generate(params, cfg, reqs, ServeConfig(batch=1, max_seq=48))
    assert out[0].shape == (3,)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "olmoe-1b-7b",
                                  "zamba2-1.2b"])
def test_generate_families_token_identical(arch):
    """qwen2: qkv-bias + sliding window; olmoe: moe; zamba2: hybrid keeps
    the scan path (can_full_prefill False) and must be unaffected."""
    cfg = _cfg(arch)
    out_fast, out_scan = _generate_both(cfg)
    for a, b in zip(out_fast, out_scan):
        np.testing.assert_array_equal(a, b)
    if cfg.family == "hybrid":
        assert not lm.can_full_prefill(cfg)
