"""StreamClient facade: chunks-in / windows-out over the batched engine.

The client owns no execution semantics — it drives open/submit/step/
retire — so the load-bearing property is inherited and re-asserted here
through the facade: a session's output stream is bit-identical whether
its generator runs alone or interleaved with strangers on a shared
engine (continuous batching must not leak state across sessions)."""

import functools

import jax
import numpy as np
import pytest

from repro.core.snn_layers import make_dhsnn_shd
from repro.serve import EngineConfig, StreamClient, make_engine

W, C = 8, 4


@functools.lru_cache(maxsize=None)
def _model():
    return make_dhsnn_shd(jax.random.PRNGKey(0), n_in=12, n_hidden=16,
                          n_out=5, dendritic=False)


def _engine(**kw):
    nodes, params = _model()
    return make_engine(nodes, params,
                       EngineConfig(window=W, capacity=C, **kw))


def _stream_data(seed, T=50):
    rng = np.random.default_rng(seed)
    return (rng.random((T, 12)) < 0.25).astype(np.float32)


def _chunked(x, size):
    return [x[i:i + size] for i in range(0, len(x), size)]


def _solo_reference(x):
    eng = _engine()
    sid = eng.open()
    assert eng.submit(sid, x)
    eng.close(sid)
    eng.drain()
    return eng.outputs(sid)


def test_client_run_matches_hand_driven_engine():
    x = _stream_data(0)
    out = StreamClient(_engine()).run(_chunked(x, 7))
    np.testing.assert_array_equal(_solo_reference(x), out)


def test_client_stream_yields_incrementally_and_in_order():
    x = _stream_data(1, T=64)
    windows = list(StreamClient(_engine()).stream(None, _chunked(x, 9)))
    assert len(windows) > 1                       # actually streaming
    assert sum(w.shape[0] for w in windows) == 64
    np.testing.assert_array_equal(_solo_reference(x),
                                  np.concatenate(windows, axis=0))


def test_client_adopted_session_not_retired():
    x = _stream_data(2)
    eng = _engine()
    client = StreamClient(eng)
    sid = eng.open("mine")
    out = np.concatenate(list(client.stream("mine", _chunked(x, 13))),
                         axis=0)
    np.testing.assert_array_equal(_solo_reference(x), out)
    assert "mine" in eng.scheduler.sessions      # caller still owns it
    np.testing.assert_array_equal(eng.retire("mine"), out)


def test_interleaved_client_streams_equal_solo():
    """Two generators round-robin on ONE engine: continuous batching puts
    both sessions in shared cohorts, yet each output stream must equal
    its solo run exactly."""
    xa, xb = _stream_data(3, T=60), _stream_data(4, T=60)
    eng = _engine()
    client = StreamClient(eng)
    ga = client.stream(None, _chunked(xa, 7))
    gb = client.stream(None, _chunked(xb, 11))
    outs = {"a": [], "b": []}
    live = {"a": ga, "b": gb}
    while live:
        for k, g in list(live.items()):
            try:
                outs[k].append(next(g))
            except StopIteration:
                del live[k]
    np.testing.assert_array_equal(_solo_reference(xa),
                                  np.concatenate(outs["a"], axis=0))
    np.testing.assert_array_equal(_solo_reference(xb),
                                  np.concatenate(outs["b"], axis=0))


def test_client_backpressure_does_not_drop_steps():
    """A tiny admission queue forces submit() rejections; the client must
    absorb them by stepping the engine, never by losing input."""
    x = _stream_data(5, T=96)
    eng = _engine(queue_limit=W)     # one window of buffer, max pushback
    out = StreamClient(eng).run(_chunked(x, 5))
    np.testing.assert_array_equal(_solo_reference(x), out)


def test_client_stats_passthrough():
    client = StreamClient(_engine())
    client.run(_chunked(_stream_data(6, T=16), 8))
    stats = client.stats()
    assert stats["windows_run"] >= 1 and stats["engine"] == "batched"
