"""Plan-compiler parity: fused execution plans vs the stepper, plus the
block-occupancy helper the hoisted INTEG relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import events, plan
from repro.core.neuron import (ALIF, LI, LIF, PLIF, Decay, NeuronProgram,
                               ProgramNeuron, StateVar, Threshold)
from repro.core.snn_layers import (branch_integrate, ff_integrate,
                                   make_dhsnn_shd, make_srnn_ecg)
from repro.kernels.spikemm.ops import block_occupancy, occupancy_fraction

KEY = jax.random.PRNGKey(0)


def _w(key, n_in, n_out, scale=0.6):
    return scale * jax.random.normal(key, (n_in, n_out), jnp.float32)


def _spikes(key, shape, rate=0.3):
    return (jax.random.uniform(key, shape) < rate).astype(jnp.float32)


def _assert_equiv(nodes, params, x, record=(), state=None, tol=1e-5):
    st1, o1, r1 = events.run(nodes, params, x, state=state, record=record)
    st2, o2, r2 = plan.run(nodes, params, x, state=state, record=record)
    np.testing.assert_allclose(o1, o2, atol=tol, rtol=tol)
    for r in record:
        np.testing.assert_allclose(r1[r], r2[r], atol=tol, rtol=tol)
    for name in st1:
        assert set(st1[name]) == set(st2[name]), name
        for k in st1[name]:
            np.testing.assert_allclose(st1[name][k], st2[name][k],
                                       atol=tol, rtol=tol,
                                       err_msg=f"{name}.{k}")
    return st1, o1


# ---------------------------------------------------------------------------
# occupancy helper (the hoisted INTEG's FINDIDX bitmap)
# ---------------------------------------------------------------------------


def test_block_occupancy_flags():
    s = jnp.zeros((4, 6))
    s = s.at[0, 1].set(1.0).at[3, 5].set(1.0)
    flags = block_occupancy(s, bm=2, bk=3)          # (2, 2) blocks
    np.testing.assert_array_equal(np.asarray(flags),
                                  [[1, 0], [0, 1]])
    # negative values count as events too (currents, not just 0/1 spikes)
    flags2 = block_occupancy(s.at[1, 4].set(-2.0), bm=2, bk=3)
    np.testing.assert_array_equal(np.asarray(flags2), [[1, 1], [0, 1]])


def test_occupancy_fraction_pads_to_blocks():
    # 5x7 with one event pads to one (128, 512) block: fraction 1.0
    s = jnp.zeros((5, 7)).at[2, 3].set(1.0)
    assert float(occupancy_fraction(s)) == 1.0
    assert float(occupancy_fraction(jnp.zeros((5, 7)))) == 0.0
    # two row-blocks, events only in the first
    s = jnp.zeros((200, 16)).at[0, 0].set(1.0)
    assert float(occupancy_fraction(s, bm=128, bk=512)) == 0.5


# ---------------------------------------------------------------------------
# plan structure
# ---------------------------------------------------------------------------


def test_compile_segments_and_lowerings():
    nodes = [
        events.LayerNode("a", LIF(), ff_integrate, ("input",), 8),
        events.LayerNode("b", ALIF(), ff_integrate, ("a",), 8),
        events.LayerNode("c", LIF(), ff_integrate, ("b", "self"), 8),
        events.LayerNode("d", LI(), ff_integrate, ("c",), 4),
    ]
    p = plan.compile_program(nodes)
    kinds = [s.kind for s in p.segments]
    assert kinds == [plan.FUSED_FF, plan.FUSED_FF, plan.FUSED_REC,
                     plan.FUSED_FF]
    assert [s.lower for s in p.segments] == [
        plan.LOWER_LIF, plan.LOWER_ALIF, plan.LOWER_LIF, plan.LOWER_LI]


def test_compile_is_structural_not_nominal():
    """Classification is driven by NeuronProgram structure alone: a
    user-space ProgramNeuron whose program matches a kernel pattern fuses;
    an extra state breaks the pattern and falls back — and the compiler
    itself never dispatches on neuron classes."""
    import inspect

    src = inspect.getsource(plan)
    assert "isinstance" not in src and "type(neuron)" not in src

    lif_like = ProgramNeuron(prog=NeuronProgram(
        states=(StateVar("m", Decay("const", 0.8)),),
        threshold=Threshold(base=0.7, on="m")))
    alif_like = ProgramNeuron(prog=NeuronProgram(
        states=(StateVar("m", Decay("const", 0.85)),
                StateVar("trace", Decay("const", 0.9), drive="spikes")),
        threshold=Threshold(base=0.9, on="m", adapt="trace", scale=0.4)))
    three_state = ProgramNeuron(prog=NeuronProgram(
        states=(StateVar("m", Decay("const", 0.85)),
                StateVar("t1", Decay("const", 0.9), drive="spikes"),
                StateVar("t2", Decay("const", 0.5), drive="spikes")),
        threshold=Threshold(base=0.9, on="m", adapt="t1", scale=0.4)))
    nodes = [
        events.LayerNode("a", lif_like, ff_integrate, ("input",), 8),
        events.LayerNode("b", alif_like, ff_integrate, ("a", "self"), 8),
        events.LayerNode("c", three_state, ff_integrate, ("b",), 4),
    ]
    p = plan.compile_program(nodes)
    assert [(s.kind, s.lower) for s in p.segments] == [
        (plan.FUSED_FF, plan.LOWER_LIF), (plan.FUSED_REC, plan.LOWER_ALIF),
        (plan.FALLBACK, "")]
    assert "no fused FIRE kernel" in p.segments[2].reason
    ks = jax.random.split(KEY, 4)
    params = {"a": {"w_input": _w(ks[0], 5, 8)},
              "b": {"w_a": _w(ks[1], 8, 8), "w_self": _w(ks[2], 8, 8, 0.3)},
              "c": {"w_b": _w(ks[3], 8, 4)}}
    _assert_equiv(nodes, params, _spikes(KEY, (14, 2, 5), rate=0.4),
                  record=("a", "b"))


def test_compile_backref_forces_whole_program_fallback():
    nodes = [
        events.LayerNode("a", LIF(), ff_integrate, ("input", "b"), 8),
        events.LayerNode("b", LIF(), ff_integrate, ("a",), 8),
    ]
    p = plan.compile_program(nodes)
    assert p.fully_fallback and len(p.segments) == 1
    ks = jax.random.split(KEY, 3)
    params = {"a": {"w_input": _w(ks[0], 5, 8), "w_b": _w(ks[1], 8, 8)},
              "b": {"w_a": _w(ks[2], 8, 8)}}
    _assert_equiv(nodes, params, _spikes(KEY, (12, 3, 5)))


def test_force_stepper_env(monkeypatch):
    monkeypatch.setenv("REPRO_SNN_ENGINE", "stepper")
    assert plan.engine_mode() == "stepper"
    monkeypatch.setenv("REPRO_SNN_ENGINE", "bogus")
    with pytest.raises(ValueError):
        plan.engine_mode()


# ---------------------------------------------------------------------------
# numerical parity vs the stepper
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_plan_ff_stack_matches_stepper(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    nodes = [
        events.LayerNode("h1", LIF(tau=0.85, v_th=0.7), ff_integrate,
                         ("input",), 24),
        events.LayerNode("h2", LIF(tau=0.9), ff_integrate, ("h1", "input"),
                         16),
        events.LayerNode("ro", LI(tau=0.95), ff_integrate, ("h2",), 6),
    ]
    params = {"h1": {"w_input": _w(ks[0], 10, 24)},
              "h2": {"w_h1": _w(ks[1], 24, 16), "w_input": _w(ks[2], 10, 16)},
              "ro": {"w_h2": _w(ks[3], 16, 6)}}
    x = _spikes(ks[4], (17, 3, 10))
    _assert_equiv(nodes, params, x, record=("h1", "h2"))


def test_plan_recurrent_uses_lifrec():
    ks = jax.random.split(KEY, 4)
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.8), ff_integrate,
                         ("input", "self"), 20),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4),
    ]
    params = {"h": {"w_input": _w(ks[0], 7, 20),
                    "w_self": _w(ks[1], 20, 20, scale=0.3)},
              "ro": {"w_h": _w(ks[2], 20, 4)}}
    p = plan.compile_program(nodes)
    assert p.segments[0].kind == plan.FUSED_REC
    _assert_equiv(nodes, params, _spikes(ks[3], (19, 2, 7), rate=0.4))


def test_plan_delayed_feeds_fused_and_fallback():
    """'@d' reads of fused sources must match the stepper's ring buffers —
    both when the reader is fused and when it sits in a fallback segment."""
    ks = jax.random.split(KEY, 6)
    nodes = [
        events.LayerNode("a", LIF(tau=0.5, v_th=0.6), ff_integrate,
                         ("input",), 12),
        events.LayerNode("b", LIF(tau=0.7), ff_integrate, ("a@2",), 10),
        events.LayerNode("c", ALIF(), ff_integrate, ("a@3", "b@1"), 8),
        events.LayerNode("ro", LI(), ff_integrate, ("c", "b"), 4),
    ]
    params = {"a": {"w_input": _w(ks[0], 6, 12)},
              "b": {"w_a": _w(ks[1], 12, 10)},
              "c": {"w_a": _w(ks[2], 12, 8), "w_b": _w(ks[3], 10, 8)},
              "ro": {"w_c": _w(ks[4], 8, 4), "w_b": _w(ks[5], 10, 4)}}
    x = _spikes(KEY, (15, 2, 6), rate=0.5)
    st, _ = _assert_equiv(nodes, params, x, record=("a", "b", "c"))
    # delay shorter than ring depth and T shorter than delays still agree
    _assert_equiv(nodes, params, x[:2])
    # resuming from a mid-run state must thread ring contents through
    _assert_equiv(nodes, params, x, state=st)


def test_plan_heterogeneous_taus_plif():
    ks = jax.random.split(KEY, 3)
    neuron = PLIF()
    nodes = [
        events.LayerNode("h", neuron, ff_integrate, ("input",), 16),
        events.LayerNode("ro", LI(), ff_integrate, ("h",), 4),
    ]
    params = {"h": {"w_input": _w(ks[0], 5, 16),
                    "neuron": {"w_tau": 2.0 + jax.random.normal(ks[1], (16,))}},
              "ro": {"w_h": _w(ks[2], 16, 4)}}
    p = plan.compile_program(nodes)
    assert p.segments[0].kind == plan.FUSED_FF
    _assert_equiv(nodes, params, _spikes(KEY, (14, 3, 5), rate=0.4))


def test_plan_app_models_parity_and_zero_fallback():
    """All Program-based application-model variants agree with the stepper
    AND compile with zero fallback segments (acceptance criterion: the ECG
    SRNN's ALIF hidden layer and the SHD DHSNN's DH-LIF hidden layer now
    pattern-lower to fused kernels; BCI is not a Program — its fused LIF is
    exercised by test_events_and_apps)."""
    cases = [
        make_srnn_ecg(jax.random.PRNGKey(0), heterogeneous=True, n_hidden=24),
        make_srnn_ecg(jax.random.PRNGKey(1), heterogeneous=False, n_hidden=24),
        make_dhsnn_shd(jax.random.PRNGKey(2), n_hidden=16),
        make_dhsnn_shd(jax.random.PRNGKey(3), n_hidden=16, dendritic=False),
    ]
    for i, (nodes, params) in enumerate(cases):
        p = plan.compile_program(nodes)
        assert not any(s.kind == plan.FALLBACK for s in p.segments), \
            p.describe()
        n_in = 4 if i < 2 else 700
        x = _spikes(jax.random.PRNGKey(10 + i), (12, 2, n_in), rate=0.25)
        _assert_equiv(nodes, params, x, record=("hidden",))
    ecg = plan.compile_program(cases[0][0])
    assert ecg.segments[0] == plan.Segment(plan.FUSED_REC, ("hidden",),
                                           lower=plan.LOWER_ALIF)
    shd = plan.compile_program(cases[2][0])
    assert shd.segments[0] == plan.Segment(plan.FUSED_FF, ("hidden",),
                                           lower=plan.LOWER_DHLIF)


def test_plan_gradients_match_stepper():
    """Training through the plan path (spikemm/lif/lifrec/linrec custom
    VJPs) must give the stepper's STBP gradients."""
    nodes, params = make_srnn_ecg(jax.random.PRNGKey(4), heterogeneous=False,
                                  n_hidden=20)
    x = _spikes(KEY, (15, 3, 4), rate=0.4)

    def make_loss(run_fn):
        def loss(p):
            _, o, _ = run_fn(nodes, p, x)
            return jnp.sum(jnp.sin(o * 1.3))
        return loss

    g1 = jax.grad(make_loss(events.run))(params)
    g2 = jax.grad(make_loss(plan.run))(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=2e-4,
                                                         rtol=2e-4), g1, g2)


@pytest.mark.parametrize("variant", ["alif", "dhlif"])
def test_plan_gradients_match_stepper_alif_dhlif(variant):
    """The newly fused FIRE lowerings (alifrec kernel, DH-LIF branch
    prologue) must reproduce the stepper's STBP gradients — including the
    heterogeneous tau/rho/tau_d logits trained through sigmoid."""
    if variant == "alif":
        nodes, params = make_srnn_ecg(jax.random.PRNGKey(6),
                                      heterogeneous=True, n_hidden=20)
        x = _spikes(KEY, (15, 3, 4), rate=0.4)
    else:
        nodes, params = make_dhsnn_shd(jax.random.PRNGKey(7), n_hidden=12)
        x = _spikes(KEY, (15, 3, 700), rate=0.1)
    assert not any(s.kind == plan.FALLBACK
                   for s in plan.compile_program(nodes).segments)

    def make_loss(run_fn):
        def loss(p):
            _, o, _ = run_fn(nodes, p, x)
            return jnp.sum(jnp.sin(o * 1.3))
        return loss

    g1 = jax.grad(make_loss(events.run))(params)
    g2 = jax.grad(make_loss(plan.run))(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=3e-4,
                                                         rtol=3e-4), g1, g2)


# ---------------------------------------------------------------------------
# property test: any valid random program, plan == stepper
# ---------------------------------------------------------------------------


def _random_program(variant: int, tau: float, rho: float, beta: float,
                    with_threshold: bool) -> NeuronProgram:
    """Enumerate structurally distinct valid programs: fusable LIF/ALIF
    shapes, a non-spiking integrator, and shapes the matcher must refuse
    (subtractive-like extra traces, membrane readout of a spiking model)."""
    if not with_threshold:
        return NeuronProgram(states=(StateVar("m", Decay("const", tau)),),
                             threshold=None, reset="none", output="m")
    states = [StateVar("m", Decay("const", tau))]
    th = Threshold(base=0.8, on="m")
    output = "spikes"
    if variant == 1:          # adaptive threshold (fuses via alif)
        states.append(StateVar("tr", Decay("const", rho), drive="spikes"))
        th = Threshold(base=0.8, on="m", adapt="tr", scale=beta)
    elif variant == 2:        # spike trace NOT in the threshold (fallback)
        states.append(StateVar("tr", Decay("const", rho), drive="spikes"))
        output = "tr"
    elif variant == 3:        # membrane readout of a spiking model (fallback)
        output = "m"
    return NeuronProgram(states=tuple(states), threshold=th, output=output)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 3), st.floats(0.3, 0.95), st.floats(0.5, 0.95),
       st.floats(0.1, 1.5), st.booleans(), st.booleans())
def test_plan_matches_stepper_on_random_programs(variant, tau, rho, beta,
                                                 with_threshold, recurrent):
    """For ANY valid NeuronProgram — fused or fallback, recurrent or not —
    the compiled plan must equal the stepper bit-for-tolerance."""
    neuron = ProgramNeuron(prog=_random_program(variant, tau, rho, beta,
                                                with_threshold))
    inputs = ("input", "self") if recurrent else ("input",)
    nodes = [events.LayerNode("h", neuron, ff_integrate, inputs, 12),
             events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4)]
    ks = jax.random.split(jax.random.PRNGKey(variant + int(tau * 997)), 3)
    params = {"h": {"w_input": _w(ks[0], 6, 12)},
              "ro": {"w_h": _w(ks[1], 12, 4)}}
    if recurrent:
        params["h"]["w_self"] = _w(ks[2], 12, 12, scale=0.3)
    x = _spikes(jax.random.fold_in(KEY, variant), (11, 2, 6), rate=0.4)
    _assert_equiv(nodes, params, x, record=("h",))


def test_plan_soma_before_branches_falls_back():
    """Regression: a dendritic program declaring the sum-driven soma BEFORE
    its branch state means the soma integrates the branches' previous-step
    values — the fused prologue always feeds the NEW values, so the matcher
    must refuse and the stepper must carry it (and agree with the plan)."""
    soma_first = ProgramNeuron(prog=NeuronProgram(
        states=(StateVar("v", Decay("const", 0.85), drive="sum:d"),
                StateVar("d", Decay("const", 0.7), branch=True)),
        threshold=Threshold(base=0.8, on="v"), n_branches=2))
    nodes = [events.LayerNode("h", soma_first, branch_integrate, ("input",),
                              10),
             events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 3)]
    p = plan.compile_program(nodes)
    assert p.segments[0].kind == plan.FALLBACK
    assert "soma declared before its branches" in p.segments[0].reason
    ks = jax.random.split(KEY, 2)
    params = {"h": {"w_input": 0.5 * jax.random.normal(ks[0], (2, 6, 10))},
              "ro": {"w_h": _w(ks[1], 10, 3)}}
    _assert_equiv(nodes, params, _spikes(KEY, (11, 2, 6), rate=0.4))


def test_plan_multi_feed_branch_integrate_falls_back():
    """Regression: the branch-hoist convention carries exactly one feed
    through w_input; a branch-tagged integrate with two inbound feeds must
    fall back instead of silently dropping the second feed."""
    def two_feed_branch(params, feeds):
        cur = 0.0
        for s in feeds.values():
            cur = cur + jnp.einsum("bi,kio->bko", s, params["w_input"])
        return cur
    two_feed_branch.hoist = "branch"

    from repro.core.neuron import DHLIF
    neuron = DHLIF(n_branches=2)
    nodes = [events.LayerNode("a", LIF(tau=0.8, v_th=0.7), ff_integrate,
                              ("input",), 6),
             events.LayerNode("h", neuron, two_feed_branch, ("input", "a"),
                              8),
             events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 3)]
    p = plan.compile_program(nodes)
    assert p.segments[1].kind == plan.FALLBACK
    assert "branch integrate with 2 feeds" in p.segments[1].reason
    ks = jax.random.split(KEY, 3)
    params = {"a": {"w_input": _w(ks[0], 6, 6)},
              "h": {"w_input": 0.4 * jax.random.normal(ks[1], (2, 6, 8)),
                    "neuron": neuron.param_init(ks[1], (8,))},
              "ro": {"w_h": _w(ks[2], 8, 3)}}
    _assert_equiv(nodes, params, _spikes(KEY, (10, 2, 6), rate=0.4))


# ---------------------------------------------------------------------------
# subtract reset: the newest structural pattern
# ---------------------------------------------------------------------------


def test_plan_subtract_reset_fuses_ff_and_matches_stepper():
    """A feed-forward LIF with reset="subtract" must pattern-lower to the
    `lif` kernel (no fallback) and agree with the stepper — forward AND
    STBP gradients (the soft-reset adjoint differs from the hard reset)."""
    ks = jax.random.split(KEY, 3)
    nodes = [
        events.LayerNode("h", LIF(tau=0.85, v_th=0.7, reset="subtract",
                                  surrogate="sigmoid", alpha=3.0),
                         ff_integrate, ("input",), 16),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4),
    ]
    p = plan.compile_program(nodes)
    assert p.segments[0] == plan.Segment(plan.FUSED_FF, ("h",),
                                         lower=plan.LOWER_LIF)
    params = {"h": {"w_input": _w(ks[0], 5, 16)},
              "ro": {"w_h": _w(ks[1], 16, 4)}}
    x = _spikes(ks[2], (14, 3, 5), rate=0.5)
    _assert_equiv(nodes, params, x, record=("h",))

    def make_loss(run_fn):
        def loss(pp):
            _, o, _ = run_fn(nodes, pp, x)
            return jnp.sum(jnp.sin(o * 1.3))
        return loss

    g1 = jax.grad(make_loss(events.run))(params)
    g2 = jax.grad(make_loss(plan.run))(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=2e-4,
                                                         rtol=2e-4), g1, g2)


def test_plan_recurrent_subtract_reset_falls_back():
    """The lifrec kernel implements the hard reset only: a self-recurrent
    subtract-reset LIF must take the stepper (and still agree)."""
    ks = jax.random.split(KEY, 3)
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.7, reset="subtract"),
                         ff_integrate, ("input", "self"), 10),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 3),
    ]
    p = plan.compile_program(nodes)
    assert p.segments[0].kind == plan.FALLBACK
    assert "recurrent subtract reset" in p.segments[0].reason
    params = {"h": {"w_input": _w(ks[0], 5, 10),
                    "w_self": _w(ks[1], 10, 10, 0.3)},
              "ro": {"w_h": _w(ks[2], 10, 3)}}
    _assert_equiv(nodes, params, _spikes(KEY, (12, 2, 5), rate=0.5))


# ---------------------------------------------------------------------------
# dtype hygiene: integer spike inputs must not build integer membranes
# ---------------------------------------------------------------------------


def test_integer_spike_input_keeps_float_state():
    """Regression: init_state(nodes, B, x.dtype) used to inherit int dtypes
    from integer spike tensors, truncating every DIFF step to zero. Both
    engines must coerce neuron state to float and agree with the float run."""
    nodes = [events.LayerNode("h", LIF(tau=0.85, v_th=0.7), ff_integrate,
                              ("input",), 10),
             events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 3)]
    ks = jax.random.split(KEY, 2)
    params = {"h": {"w_input": _w(ks[0], 5, 10)},
              "ro": {"w_h": _w(ks[1], 10, 3)}}
    x_int = (jax.random.uniform(KEY, (9, 2, 5)) < 0.4).astype(jnp.int32)
    st = events.init_state(nodes, 2, x_int.dtype)
    assert all(v.dtype == jnp.float32 for s in st.values()
               for v in s.values())
    _, o_float, _ = events.run(nodes, params, x_int.astype(jnp.float32))
    for run_fn in (events.run, plan.run):
        _, o_int, _ = run_fn(nodes, params, x_int)
        assert jnp.issubdtype(o_int.dtype, jnp.floating)
        np.testing.assert_allclose(o_int, o_float,
                                   atol=plan.CROSS_ENGINE_ATOL, rtol=1e-5)


def test_plan_runs_under_jit():
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(5), n_hidden=16,
                                   dendritic=False)
    x = _spikes(KEY, (10, 2, 700), rate=0.1)

    @jax.jit
    def f(p, xx):
        _, o, _ = plan.run(nodes, p, xx)
        return o

    _, o_ref, _ = events.run(nodes, params, x)
    np.testing.assert_allclose(f(params, x), o_ref,
                               atol=plan.CROSS_ENGINE_ATOL, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 3), st.floats(0.3, 0.95), st.booleans(),
       st.floats(0.02, 0.6))
def test_plan_outputs_identical_under_sparse_dispatch(variant, tau,
                                                      recurrent, rate):
    """Property: pinning the spikemm channel (never vs always) must not
    change ANY plan output bit — the block-sparse path only skips blocks
    that are exactly zero, so eager plan.run is bit-identical either way
    on arbitrary random programs and input densities."""
    import os

    neuron = ProgramNeuron(prog=_random_program(variant, tau, 0.8, 0.5,
                                                True))
    inputs = ("input", "self") if recurrent else ("input",)
    nodes = [events.LayerNode("h", neuron, ff_integrate, inputs, 12),
             events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 4)]
    ks = jax.random.split(jax.random.PRNGKey(variant + int(rate * 991)), 3)
    params = {"h": {"w_input": _w(ks[0], 6, 12)},
              "ro": {"w_h": _w(ks[1], 12, 4)}}
    if recurrent:
        params["h"]["w_self"] = _w(ks[2], 12, 12, scale=0.3)
    x = _spikes(jax.random.fold_in(KEY, variant), (11, 2, 6), rate=rate)
    env, prev = "REPRO_SPIKEMM_SPARSE", os.environ.get("REPRO_SPIKEMM_SPARSE")
    try:
        os.environ[env] = "never"
        _, o1, r1 = plan.run(nodes, params, x, record=("h",))
        os.environ[env] = "always"
        _, o2, r2 = plan.run(nodes, params, x, record=("h",))
    finally:
        if prev is None:
            os.environ.pop(env, None)
        else:
            os.environ[env] = prev
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(r1["h"]), np.asarray(r2["h"]))
