"""Shared plumbing for the resilience / fault-injection tests.

Tiny deterministic SNNs + spike rasters, and an env-var context manager,
so test modules assert on behavior instead of rebuilding fixtures. Also
the place where chaos-CI compatibility lives: every helper pins its own
seeds, and tests that need a *clean* world wrap themselves in
`faults.inject("")`, which overrides any `REPRO_FAULTS` the environment
(e.g. the nightly chaos job) carries.
"""

import contextlib
import os

import jax
import jax.numpy as jnp

from repro.core.snn_layers import make_dhsnn_shd, make_plastic_ff


@contextlib.contextmanager
def env(**kv):
    """Temporarily set (value) or unset (None) environment variables."""
    old = {k: os.environ.get(k) for k in kv}
    try:
        for k, v in kv.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def forced_pallas():
    """Select the Pallas (interpret on CPU) stage so dispatch's fallback
    chain is actually reachable off-TPU. Also clears any ambient
    REPRO_STRICT (the CI fast tier runs strict): tests built on this
    helper exercise *degradation*, and pin their own strict world —
    enter `env(REPRO_STRICT="1")` after this to assert strict behavior."""
    return env(REPRO_KERNEL_IMPL="pallas", REPRO_STRICT=None)


def spikes(key, T=12, B=4, n=32, rate=0.3, dtype=jnp.float32):
    return (jax.random.uniform(key, (T, B, n)) < rate).astype(dtype)


def dh_net(key=None, n_in=32, n_hidden=24, n_out=8):
    """Feed-forward DH-LIF net: exercises linrec + lif + spikemm through
    the fused plan engine, with no recurrence (so fault masks are
    bit-identical across engines)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return make_dhsnn_shd(key, n_in=n_in, n_hidden=n_hidden, n_out=n_out)


def plastic_net(key=None, n_in=24, n_hidden=16, n_out=4):
    """2-layer LIF whose input edge learns on-chip (stdp_seq lowering)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return make_plastic_ff(key, n_in=n_in, n_hidden=n_hidden, n_out=n_out)
