"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus gradient checks for the custom VJPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.linrec.ops import linrec
from repro.kernels.linrec.ref import linrec_naive, linrec_ref
from repro.kernels.lif.ops import lif_scan
from repro.kernels.lif.ref import lif_scan_ref
from repro.kernels.spikemm.ops import occupancy_fraction, spikemm
from repro.kernels.spikemm.ref import spikemm_ref
from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# linrec (DIFF)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,B,D", [(8, 2, 128), (33, 3, 130), (256, 8, 512),
                                   (100, 1, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linrec_matches_naive(T, B, D, dtype):
    k = jax.random.PRNGKey(T * 1000 + D)
    k1, k2, k3 = jax.random.split(k, 3)
    a = jax.random.uniform(k1, (T, B, D), dtype, 0.5, 1.0)
    x = jax.random.normal(k2, (T, B, D), dtype)
    h0 = jax.random.normal(k3, (B, D), dtype)
    y_ref, hT_ref = linrec_naive(a, x, h0)
    y_k, hT_k = linrec(a, x, h0, True)       # Pallas interpret path
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(hT_k, np.float32),
                               np.asarray(hT_ref, np.float32),
                               rtol=tol, atol=tol)


def test_linrec_assoc_scan_matches_naive():
    k = jax.random.PRNGKey(0)
    a = jax.random.uniform(k, (17, 2, 5), jnp.float32, 0.1, 0.99)
    x = jax.random.normal(k, (17, 2, 5))
    h0 = jnp.zeros((2, 5))
    y1, h1 = linrec_naive(a, x, h0)
    y2, h2 = linrec_ref(a, x, h0)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("force_pallas", [False, True])
def test_linrec_grad_matches_autodiff(force_pallas):
    k = jax.random.PRNGKey(3)
    T, B, D = 12, 2, 6
    a = jax.random.uniform(k, (T, B, D), jnp.float32, 0.3, 0.95)
    x = jax.random.normal(jax.random.fold_in(k, 1), (T, B, D))
    h0 = jax.random.normal(jax.random.fold_in(k, 2), (B, D))

    def loss_custom(a, x, h0):
        y, hT = linrec(a, x, h0, force_pallas)
        return jnp.sum(jnp.sin(y)) + jnp.sum(hT ** 2)

    def loss_scan(a, x, h0):
        y, hT = linrec_naive(a, x, h0)
        return jnp.sum(jnp.sin(y)) + jnp.sum(hT ** 2)

    g1 = jax.grad(loss_custom, (0, 1, 2))(a, x, h0)
    g2 = jax.grad(loss_scan, (0, 1, 2))(a, x, h0)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# lif (DIFF + threshold + reset)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,B,N", [(16, 4, 128), (256, 8, 512), (40, 3, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_kernel_matches_ref(T, B, N, dtype):
    k = jax.random.PRNGKey(N)
    cur = 0.6 * jax.random.normal(k, (T, B, N), dtype)
    tau = jax.random.uniform(jax.random.fold_in(k, 1), (N,), jnp.float32,
                             0.7, 0.98)
    v0 = jnp.zeros((B, N), dtype)
    s_ref, v_ref = lif_scan_ref(cur, tau, v0)
    s_k, v_k = lif_scan(cur, tau, v0, 1.0, "rectangle", 1.0, True)
    # spikes are binary events: require exact agreement
    np.testing.assert_array_equal(np.asarray(s_k, np.float32),
                                  np.asarray(s_ref, np.float32))
    np.testing.assert_allclose(np.asarray(v_k, np.float32),
                               np.asarray(v_ref, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_lif_surrogate_grad_matches_explicit_bptt():
    """The fused backward (reverse recurrence) must equal autodiff through
    an explicitly unrolled LIF with the same surrogate."""
    from repro.core.surrogate import spike

    k = jax.random.PRNGKey(7)
    T, B, N = 10, 2, 5
    cur = 0.8 * jax.random.normal(k, (T, B, N))
    tau = jnp.full((N,), 0.9)
    v0 = jnp.zeros((B, N))

    def loss_fused(cur, tau):
        s, vT = lif_scan(cur, tau, v0, 1.0, "sigmoid", 2.0)
        return jnp.sum(s * jnp.arange(1, T + 1)[:, None, None]) + jnp.sum(vT)

    def loss_unrolled(cur, tau):
        v = v0
        tot = 0.0
        for t in range(T):
            u = tau * v + cur[t]
            s = spike(u - 1.0, "sigmoid", 2.0)
            v = u * (1.0 - s)
            tot += jnp.sum(s * (t + 1))
        return tot + jnp.sum(v)

    g1 = jax.grad(loss_fused, (0, 1))(cur, tau)
    g2 = jax.grad(loss_unrolled, (0, 1))(cur, tau)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# spikemm (FINDIDX + LOCACC)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(128, 512, 512), (256, 1024, 256),
                                   (100, 300, 200)])
@pytest.mark.parametrize("rate", [0.0, 0.02, 0.13, 0.5])
def test_spikemm_matches_dense(M, K, N, rate):
    k = jax.random.PRNGKey(int(rate * 100) + M)
    spikes = (jax.random.uniform(k, (M, K)) < rate).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (K, N), jnp.float32)
    ref = spikemm_ref(spikes, w)
    out = spikemm(spikes, w, 128, 512, 512, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_spikemm_occupancy_tracks_rate():
    k = jax.random.PRNGKey(0)
    dense = (jax.random.uniform(k, (512, 2048)) < 0.5).astype(jnp.float32)
    sparse = jnp.zeros((512, 2048)).at[:64, :512].set(1.0)
    assert float(occupancy_fraction(dense)) == 1.0
    assert float(occupancy_fraction(sparse)) == 0.0625  # 1 of 16 blocks


def test_spikemm_grad_is_exact():
    k = jax.random.PRNGKey(1)
    spikes = (jax.random.uniform(k, (128, 512)) < 0.1).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 256))

    g1 = jax.grad(lambda w: jnp.sum(spikemm(spikes, w) ** 2))(w)
    g2 = jax.grad(lambda w: jnp.sum(spikemm_ref(spikes, w) ** 2))(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,S,d", [(256, 256, 64), (512, 512, 128),
                                   (384, 640, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention_matches_ref(T, S, d, causal, window):
    if not causal and T != S:
        pytest.skip("non-causal path requires T == S blocks")
    k = jax.random.PRNGKey(T + S)
    q = jax.random.normal(k, (4, T, d), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (4, S, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (4, S, d), jnp.float32)
    ref = attention_ref(q, kk, v, causal=causal, window=window)
    out = flash_attention(q, kk, v, causal=causal, window=window,
                          bq=128, bk=128, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    k = jax.random.PRNGKey(5)
    q = jax.random.normal(k, (2, 256, 64), jnp.bfloat16)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 256, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 256, 64), jnp.bfloat16)
    ref = attention_ref(q, kk, v, causal=True)
    out = flash_attention(q, kk, v, causal=True, bq=128, bk=128,
                          force_pallas=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# stdp (on-chip learning weight update)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,M,N", [(8, 256, 256), (16, 300, 200),
                                   (4, 128, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stdp_kernel_matches_ref(B, M, N, dtype):
    from repro.kernels.stdp.ops import stdp_update
    from repro.kernels.stdp.ref import stdp_update_ref
    k = jax.random.PRNGKey(B * M + N)
    ks = jax.random.split(k, 5)
    x_pre = jax.random.uniform(ks[0], (B, M), dtype)
    x_post = jax.random.uniform(ks[1], (B, N), dtype)
    s_pre = (jax.random.uniform(ks[2], (B, M)) < 0.2).astype(dtype)
    s_post = (jax.random.uniform(ks[3], (B, N)) < 0.2).astype(dtype)
    w = 0.5 * jax.random.normal(ks[4], (M, N), jnp.float32)
    kw = dict(a_plus=0.05, a_minus=0.06, w_min=-0.4, w_max=0.4)
    ref = stdp_update_ref(x_pre, s_post, s_pre, x_post, w, **kw)
    out = stdp_update(x_pre, s_post, s_pre, x_post, w, force_pallas=True, **kw)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_stdp_kernel_through_plasticity_step():
    """core/plasticity.stdp_step(use_kernel=True) == einsum path."""
    from repro.core.plasticity import STDPConfig, stdp_init, stdp_step
    cfg = STDPConfig()
    k = jax.random.PRNGKey(0)
    s_pre = (jax.random.uniform(k, (8, 256)) < 0.3).astype(jnp.float32)
    s_post = (jax.random.uniform(jax.random.fold_in(k, 1), (8, 128)) < 0.3
              ).astype(jnp.float32)
    w = jnp.zeros((256, 128))
    tr = stdp_init(256, 128, batch=8)
    tr1, w1 = stdp_step(cfg, tr, w, s_pre, s_post, use_kernel=False)
    tr2, w2 = stdp_step(cfg, tr, w, s_pre, s_post, use_kernel=True)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# spikemm block-sparse channel
# ---------------------------------------------------------------------------


def _sparse_blocks(M, K, N):
    from repro.kernels.spikemm.ops import resolve_block_shape
    blocks = resolve_block_shape(M, K)
    blocks["bn"] = min(512, max(128, N))
    return blocks


def _density_rasters(M, K):
    """The extremes the sparse channel must survive: all-empty, a single
    occupied block, low-density packed, and fully dense."""
    k = jax.random.PRNGKey(M * 7 + K)
    return {
        "all_empty": jnp.zeros((M, K), jnp.float32),
        "single_block": jnp.zeros((M, K), jnp.float32).at[1, 2].set(1.0),
        "packed_2pct": jnp.zeros((M, K), jnp.float32).at[
            :max(1, M // 8), :max(1, K // 8)].set(
            (jax.random.uniform(k, (max(1, M // 8), max(1, K // 8))) < 0.5
             ).astype(jnp.float32)),
        "dense": (jax.random.uniform(k, (M, K)) < 0.5).astype(jnp.float32),
    }


@pytest.mark.parametrize("M,K,N", [(256, 1024, 256), (100, 300, 200),
                                   (130, 700, 64)])
def test_spikemm_sparse_channel_matches_ref(M, K, N):
    """Both sparse implementations == dense oracle at density extremes,
    including shapes not divisible by the block sizes."""
    from repro.kernels.spikemm.ops import (_sparse_pallas_impl,
                                           _sparse_ref_impl)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    blocks = _sparse_blocks(M, K, N)
    for label, s in _density_rasters(M, K).items():
        ref = spikemm_ref(s, w)
        out_ref = _sparse_ref_impl(s, w, blocks=blocks)
        out_pal = _sparse_pallas_impl(s, w, blocks=blocks, interpret=True)
        np.testing.assert_allclose(np.asarray(out_ref), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=label)
        np.testing.assert_allclose(np.asarray(out_pal), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=label)


def test_spikemm_sparse_channel_under_jit(monkeypatch):
    """Forced sparse under jit exercises the capacity-padded compaction
    (data-dependent count -> static Mb*Kb list with inactive padding)."""
    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "always")
    k = jax.random.PRNGKey(2)
    s = (jax.random.uniform(k, (256, 512)) < 0.05).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 128), jnp.float32)
    out = jax.jit(spikemm)(s, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(spikemm_ref(s, w)),
                               rtol=1e-4, atol=1e-4)


def test_spikemm_sparse_grad_matches_dense(monkeypatch):
    """Grad parity: the custom VJP's dW pass re-dispatches spikemm, so the
    sparse channel must be exact under differentiation too."""
    k = jax.random.PRNGKey(3)
    s = (jax.random.uniform(k, (256, 512)) < 0.08).astype(jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 128), jnp.float32)

    def loss(w):
        return jnp.sum(spikemm(s, w) ** 2)

    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "always")
    g_sparse = jax.grad(loss)(w)
    monkeypatch.setenv("REPRO_SPIKEMM_SPARSE", "never")
    g_dense = jax.grad(loss)(w)
    np.testing.assert_allclose(np.asarray(g_sparse), np.asarray(g_dense),
                               rtol=1e-4, atol=1e-4)


def test_occupancy_fraction_consistent_with_block_occupancy():
    """Regression (ISSUE 6 bugfix): the default-argument fraction must use
    the block shape dispatch actually resolves, not a fixed bk=512 — for
    K=300 the kernel pads to bk=384, and the reported fraction has to
    match what is actually skipped."""
    from repro.kernels.spikemm.ops import block_occupancy as bo
    from repro.kernels.spikemm.ops import resolve_block_shape
    from repro.kernels.common import pad_axis
    k = jax.random.PRNGKey(4)
    for M, K in [(100, 300), (130, 700), (256, 2048)]:
        s = (jax.random.uniform(k, (M, K)) < 0.02).astype(jnp.float32)
        blocks = resolve_block_shape(M, K)
        s_p, _ = pad_axis(s, 0, blocks["bm"])
        s_p, _ = pad_axis(s_p, 1, blocks["bk"])
        expect = float(jnp.mean(bo(s_p, blocks["bm"], blocks["bk"]
                                   ).astype(jnp.float32)))
        assert float(occupancy_fraction(s)) == expect, (M, K, blocks)
