"""End-to-end integration: training actually learns (loss drops materially),
hypothesis property tests on system invariants, optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedules import cosine_schedule, wsd_schedule


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-3b", "zamba2-1.2b"])
def test_training_learns_markov_stream(arch):
    """Loss on the structured token stream must drop well below ln(V)."""
    cfg = get_smoke_config(arch).replace(dtype="float32", vocab_size=64)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    state = lm.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=3e-3)))
    losses = []
    for i in range(60):
        state, m = step(state, {"tokens": jnp.asarray(
            stream.batch_at(i)["tokens"])})
        losses.append(float(m["loss"]))
    lnv = np.log(cfg.vocab_size)
    assert losses[-1] < 0.7 * lnv, (losses[0], losses[-1], lnv)


def test_microbatch_accumulation_matches_full_batch():
    """grad(batch) == mean over microbatch grads: the accumulation path must
    give the same update (straggler slack must not change the math)."""
    cfg = get_smoke_config("llama3.2-3b").replace(dtype="float32")
    state = lm.init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 17),
                                          0, cfg.vocab_size)}
    s1, m1 = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=1e-3)))(
        dict(state), batch)
    s2, m2 = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=1e-3),
                                        microbatches=2))(dict(state), batch)
    w1 = jax.tree.leaves(s1["params"])[0]
    w2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=2e-4, atol=2e-5)


def test_adamw_descends_quadratic():
    w = {"x": jnp.array([3.0, -2.0])}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = {"x": 2 * w["x"]}
        w, opt, _ = adamw_update(cfg, g, opt, w)
    assert float(jnp.max(jnp.abs(w["x"]))) < 0.05


def test_clip_bounds_update():
    w = {"x": jnp.zeros(3)}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0)
    _, _, metrics = adamw_update(cfg, {"x": jnp.full(3, 1e6)}, opt, w)
    assert metrics["grad_norm"] > 1e5          # reported pre-clip


@given(st.integers(1, 1000), st.integers(10, 100))
@settings(max_examples=20, deadline=None)
def test_wsd_schedule_shape(step, total_x10):
    total = total_x10 * 10
    lr = wsd_schedule(1.0, warmup=10, total=total)
    v = float(lr(jnp.asarray(step)))
    assert 0.0 <= v <= 1.0
    if 10 <= step <= int(total * 0.9):
        assert v == pytest.approx(1.0)         # stable plateau


@given(st.floats(1e-5, 1e-2), st.integers(0, 499))
@settings(max_examples=20, deadline=None)
def test_cosine_schedule_bounded(peak, step):
    lr = cosine_schedule(peak, warmup=50, total=500)
    v = float(lr(jnp.asarray(step)))
    assert 0.0 <= v <= peak * (1 + 1e-6)


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_global_norm_is_l2(vals):
    tree = {"a": jnp.asarray(vals, jnp.float32)}
    expected = np.linalg.norm(np.asarray(vals, np.float32))
    assert float(global_norm(tree)) == pytest.approx(expected, rel=1e-4)


# ---------------------------------------------------------------------------
# hypothesis: system invariants of the paper's core primitives
# ---------------------------------------------------------------------------


@given(st.integers(2, 30), st.floats(0.0, 0.99), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_linrec_bounded_for_stable_decay(T, tau, D):
    """For |a|<1 and bounded input, the DIFF recurrence stays bounded by
    sup|x| / (1 - tau) — the stability invariant all neuron models rely on."""
    from repro.kernels.linrec.ref import linrec_naive
    a = jnp.full((T, 1, D), tau)
    x = jnp.ones((T, 1, D))
    y, _ = linrec_naive(a, x, jnp.zeros((1, D)))
    bound = 1.0 / (1.0 - tau) + 1e-4
    assert float(jnp.max(jnp.abs(y))) <= bound


@given(st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_topology_fc_propagate_random_shapes(n_pre_x8, n_post_x8):
    from repro.core import topology as topo
    rng = np.random.default_rng(n_pre_x8 * 7 + n_post_x8)
    n_pre, n_post = 8 * n_pre_x8, 8 * n_post_x8
    w = rng.standard_normal((n_pre, n_post)).astype(np.float32)
    enc = topo.encode_fc(w, n_cores=min(4, n_post))
    s = (rng.random(n_pre) < 0.5).astype(np.float32)
    np.testing.assert_allclose(enc.propagate(s), s @ w, rtol=2e-5, atol=2e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_spike_binary_everywhere(seed):
    from repro.core.surrogate import spike
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    s = spike(x, "arctan", 2.0)
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}
