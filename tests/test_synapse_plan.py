"""Connection API + synapse-program plan lowering.

Covers the string->Connection back-compat adapter (old "name@d"/"self"
micro-syntax parses to identical Connections; mixed old/new Programs run
bit-identically), the plastic-connection learning pass under `plan.run`
(fused `stdp_seq` lowering vs the per-step `synapse_step` reference —
weights AND traces), modulator plumbing, and a hypothesis property test
over random valid SynapsePrograms (fused vs fallback weight-trajectory
parity)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import events, plan, plasticity
from repro.core.events import Connection
from repro.core.neuron import LI, LIF, Decay
from repro.core.plasticity import (SynapseProgram, TraceVar, UpdateTerm,
                                   pair_stdp, synapse_run, triplet_stdp)
from repro.core.snn_layers import ff_integrate, make_plastic_ff

KEY = jax.random.PRNGKey(0)


def _w(key, n_in, n_out, scale=0.6):
    return scale * jax.random.normal(key, (n_in, n_out), jnp.float32)


def _spikes(key, shape, rate=0.35):
    return (jax.random.uniform(key, shape) < rate).astype(jnp.float32)


# ---------------------------------------------------------------------------
# the back-compat adapter: strings are a thin spelling of Connections
# ---------------------------------------------------------------------------


def test_connection_parse_equals_explicit():
    assert Connection.parse("x@2") == Connection("x", delay=2)
    assert Connection.parse("self") == Connection("self")
    assert Connection.parse("input") == Connection("input")
    # parse is idempotent on Connections
    c = Connection("a", delay=3)
    assert Connection.parse(c) is c
    # key round-trips the legacy spelling
    assert Connection("x", 2).key == "x@2"
    assert Connection("self").key == "self"
    assert Connection("hidden").key == "hidden"
    # canonical weight keys
    assert Connection("hidden").weight_key == "w_hidden"
    assert Connection("self").weight_key == "w_self"
    assert Connection("x", weight="w_shared").weight_key == "w_shared"


def test_connection_validation():
    with pytest.raises(ValueError, match="source"):
        Connection("")
    with pytest.raises(ValueError, match="delay"):
        Connection("x", delay=-1)
    with pytest.raises(ValueError, match="at least one update term"):
        Connection("x", plastic=SynapseProgram(traces=(), terms=()))


def test_layernode_normalizes_mixed_inputs():
    node = events.LayerNode("h", LIF(), ff_integrate,
                            ("x@2", Connection("y", delay=1), "self"), 8)
    assert node.connections == (Connection("x", 2), Connection("y", 1),
                                Connection("self"))
    assert node.inputs == ("x@2", "y@1", "self")
    with pytest.raises(ValueError, match="duplicate"):
        events.LayerNode("h", LIF(), ff_integrate,
                         ("x@2", Connection("x", delay=2)), 8)


def test_mixed_string_and_connection_programs_run_bit_identically():
    """The same topology spelled as strings vs Connection objects must be
    indistinguishable: identical plans, identical outputs, identical state
    — under both engines."""
    ks = jax.random.split(KEY, 6)
    old = [
        events.LayerNode("a", LIF(tau=0.5, v_th=0.6), ff_integrate,
                         ("input",), 12),
        events.LayerNode("b", LIF(tau=0.7), ff_integrate, ("a@2", "self"),
                         10),
        events.LayerNode("ro", LI(), ff_integrate, ("b", "a@1"), 4),
    ]
    new = [
        events.LayerNode("a", LIF(tau=0.5, v_th=0.6), ff_integrate,
                         (Connection("input"),), 12),
        events.LayerNode("b", LIF(tau=0.7), ff_integrate,
                         (Connection("a", delay=2), Connection("self")), 10),
        events.LayerNode("ro", LI(), ff_integrate,
                         (Connection("b"), Connection("a", delay=1)), 4),
    ]
    params = {"a": {"w_input": _w(ks[0], 6, 12)},
              "b": {"w_a": _w(ks[1], 12, 10), "w_self": _w(ks[2], 10, 10, 0.3)},
              "ro": {"w_b": _w(ks[3], 10, 4), "w_a": _w(ks[4], 12, 4)}}
    x = _spikes(ks[5], (13, 2, 6), rate=0.5)
    assert (plan.compile_program(old).describe()
            == plan.compile_program(new).describe())
    for run_fn in (events.run, plan.run):
        st1, o1, r1 = run_fn(old, params, x, record=("a", "b"))
        st2, o2, r2 = run_fn(new, params, x, record=("a", "b"))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        for r in r1:
            np.testing.assert_array_equal(np.asarray(r1[r]),
                                          np.asarray(r2[r]))
        for name in st1:
            for k in st1[name]:
                np.testing.assert_array_equal(np.asarray(st1[name][k]),
                                              np.asarray(st2[name][k]))


# ---------------------------------------------------------------------------
# plastic connections under plan.run
# ---------------------------------------------------------------------------


def _reference_syn(nodes, params, x, conn_node, rule, mod=None, pre_src=None):
    """Per-step reference: realized spike trains through the stepper, then
    synapse_run (scan of synapse_step)."""
    record = tuple({conn_node} | ({pre_src} if pre_src else set()))
    _, out, recs = events.run(nodes, params, x, record=record)
    pre = x if pre_src is None else recs[pre_src]
    return synapse_run(rule, params[conn_node]["w_input"], pre,
                       recs[conn_node], mod=mod)


def _force_step(compiled: plan.Plan) -> plan.Plan:
    """Force every plastic lowering through the per-step fallback."""
    return dataclasses.replace(compiled, plastic=tuple(
        dataclasses.replace(p, lower=plan.SYN_STEP, reason="forced")
        for p in compiled.plastic))


@pytest.mark.parametrize("rule_name", ["pair_stdp", "triplet_stdp",
                                       "reward_stdp", "accumulated_spike"])
def test_builtin_rules_plan_lowered_match_reference(rule_name):
    """Acceptance: all four built-in rules lower to the fused stdp_seq
    family, run under plan.run WITHOUT falling back to the full stepper,
    and match the per-step reference on weights + traces."""
    rule = plasticity.make_synapse(rule_name)
    nodes, params = make_plastic_ff(jax.random.PRNGKey(3), n_in=9,
                                    n_hidden=14, rule=rule)
    x = _spikes(jax.random.PRNGKey(4), (11, 3, 9))
    T, B = x.shape[:2]
    compiled = plan.compile_program(nodes)
    assert not any(s.kind == plan.FALLBACK for s in compiled.segments), \
        compiled.describe()
    assert compiled.plastic == (plan.PlasticLower("hidden", "input",
                                                  plan.SYN_SEQ),)
    mod = None
    if rule_name == "reward_stdp":
        mod = jax.random.uniform(jax.random.PRNGKey(5), (T,))
    elif rule_name == "accumulated_spike":
        mod = jnp.zeros((T, B, 14)).at[-1].set(
            jax.random.normal(jax.random.PRNGKey(6), (B, 14)))
    st, _, _ = plan.run(nodes, params, x, plan=compiled, mod=mod)
    ref = _reference_syn(nodes, params, x, "hidden", rule, mod=mod)
    syn = st["hidden"]["syn:input"]
    assert set(syn) == set(ref)
    for k in ref:
        np.testing.assert_allclose(np.asarray(syn[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)
    if rule_name == "pair_stdp":
        assert float(jnp.linalg.norm(syn["w"] - params["hidden"]["w_input"])
                     ) > 1e-3                     # actually learned


def test_plastic_on_inter_layer_and_delayed_connection():
    """Plasticity on a node-to-node delayed edge: the pre train the rule
    sees must be the delay-shifted feed the stepper delivered."""
    rule = pair_stdp()
    ks = jax.random.split(KEY, 4)
    nodes = [
        events.LayerNode("a", LIF(tau=0.6, v_th=0.6), ff_integrate,
                         ("input",), 10),
        events.LayerNode("h", LIF(tau=0.8, v_th=0.7), ff_integrate,
                         (Connection("a", delay=2, plastic=rule),), 8),
        events.LayerNode("ro", LI(), ff_integrate, ("h",), 3),
    ]
    params = {"a": {"w_input": _w(ks[0], 5, 10)},
              "h": {"w_a": _w(ks[1], 10, 8)},
              "ro": {"w_h": _w(ks[2], 8, 3)}}
    x = _spikes(ks[3], (12, 2, 5), rate=0.5)
    compiled = plan.compile_program(nodes)
    assert compiled.plastic == (plan.PlasticLower("h", "a@2", plan.SYN_SEQ),)
    st, _, _ = plan.run(nodes, params, x, plan=compiled)
    # reference: shift the realized 'a' train by the delay (cold start)
    _, _, recs = events.run(nodes, params, x, record=("a", "h"))
    pre = jnp.concatenate([jnp.zeros((2,) + recs["a"].shape[1:]),
                           recs["a"][:-2]], axis=0)
    ref = synapse_run(rule, params["h"]["w_a"], pre, recs["h"])
    for k in ref:
        np.testing.assert_allclose(np.asarray(st["h"]["syn:a@2"][k]),
                                   np.asarray(ref[k]), atol=1e-5, rtol=1e-5)


def test_plastic_learning_identical_under_stepper_engine(monkeypatch):
    """REPRO_SNN_ENGINE=stepper still learns — same trajectories as the
    plan engine (the learning pass is engine-independent)."""
    nodes, params = make_plastic_ff(jax.random.PRNGKey(7), n_in=6,
                                    n_hidden=10)
    x = _spikes(KEY, (9, 2, 6))
    st_plan, o_plan, _ = plan.run(nodes, params, x)
    monkeypatch.setenv("REPRO_SNN_ENGINE", "stepper")
    st_step, o_step, _ = plan.run(nodes, params, x)
    np.testing.assert_allclose(np.asarray(o_plan), np.asarray(o_step),
                               atol=1e-5)
    for k in st_plan["hidden"]["syn:input"]:
        np.testing.assert_allclose(
            np.asarray(st_plan["hidden"]["syn:input"][k]),
            np.asarray(st_step["hidden"]["syn:input"][k]),
            atol=1e-5, rtol=1e-5)


def test_learn_false_freezes_and_apply_learned_merges():
    nodes, params = make_plastic_ff(jax.random.PRNGKey(8), n_in=6,
                                    n_hidden=10)
    x = _spikes(KEY, (9, 2, 6))
    st_frozen, _, _ = plan.run(nodes, params, x, learn=False)
    np.testing.assert_array_equal(
        np.asarray(st_frozen["hidden"]["syn:input"]["w"]),
        np.asarray(params["hidden"]["w_input"]))
    st, _, _ = plan.run(nodes, params, x)
    learned = plasticity.apply_learned(nodes, params, st)
    np.testing.assert_array_equal(
        np.asarray(learned["hidden"]["w_input"]),
        np.asarray(st["hidden"]["syn:input"]["w"]))
    # untouched entries survive the merge
    assert learned["readout"]["w_hidden"] is params["readout"]["w_hidden"]
    # chunked-online: the next window's forward sees the learned weight
    o1 = plan.run(nodes, learned, x, learn=False)[1]
    o0 = plan.run(nodes, params, x, learn=False)[1]
    assert float(jnp.max(jnp.abs(o1 - o0))) > 0


def test_learning_does_not_perturb_stbp_gradients():
    """The weight update is an optimizer-like write (stop_gradient): grads
    of the forward loss must be identical with learning on, off, and under
    the stepper."""
    nodes, params = make_plastic_ff(jax.random.PRNGKey(9), n_in=6,
                                    n_hidden=10)
    x = _spikes(KEY, (9, 2, 6))

    def loss(p, learn):
        _, o, _ = plan.run(nodes, p, x, learn=learn)
        return jnp.sum(jnp.sin(o * 1.3))

    g_on = jax.grad(lambda p: loss(p, True))(params)
    g_off = jax.grad(lambda p: loss(p, False))(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
                 g_on, g_off)

    def stepper_loss(p):
        _, o, _ = events.run(nodes, p, x)
        return jnp.sum(jnp.sin(o * 1.3))

    g_ref = jax.grad(stepper_loss)(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=2e-4,
                                                         rtol=2e-4),
                 g_on, g_ref)


def test_plastic_run_under_jit():
    nodes, params = make_plastic_ff(jax.random.PRNGKey(10), n_in=6,
                                    n_hidden=10)
    compiled = plan.compile_program(nodes)
    x = _spikes(KEY, (8, 2, 6))

    @jax.jit
    def f(p, xx):
        st, o, _ = plan.run(nodes, p, xx, plan=compiled)
        return o, st["hidden"]["syn:input"]["w"]

    o_jit, w_jit = f(params, x)
    st, o, _ = plan.run(nodes, params, x, plan=compiled)
    np.testing.assert_allclose(np.asarray(o_jit), np.asarray(o), atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_jit),
                               np.asarray(st["hidden"]["syn:input"]["w"]),
                               atol=1e-5)


def test_learned_decay_rule_hoists_fused_and_matches_interpreter():
    """Learned per-synapse trace decays no longer force the per-step
    fallback: a sigmoid-resolved decay plane hoists through linrec exactly
    like a constant, so the matcher keeps the rule on the fused stdp_seq
    path — and the fused weight trajectory matches the per-step
    interpreter bit-for-bit (within cross-engine tolerance)."""
    rule = SynapseProgram(
        traces=(TraceVar("x", "pre", Decay("learned", 0.9, "tau_x")),),
        terms=(UpdateTerm(0.02, pre=("x",), post=("spikes",)),))
    nodes, params = make_plastic_ff(jax.random.PRNGKey(11), n_in=6,
                                    n_hidden=10, rule=rule)
    # heterogeneous decay logits: each presynaptic trace gets its own tau
    params["hidden"]["syn:input"] = {
        "tau_x": jnp.linspace(-1.5, 2.0, 6, dtype=jnp.float32)}
    compiled = plan.compile_program(nodes)
    assert compiled.plastic[0].lower == plan.SYN_SEQ
    x = _spikes(KEY, (9, 2, 6))
    st, _, _ = plan.run(nodes, params, x, plan=compiled)
    # interpreter reference with the same learned-decay params
    _, _, recs = events.run(nodes, params, x, record=("hidden",))
    ref = plasticity.synapse_run(rule, params["hidden"]["w_input"], x,
                                 recs["hidden"],
                                 params=params["hidden"]["syn:input"])
    for k in ref:
        np.testing.assert_allclose(np.asarray(st["hidden"]["syn:input"][k]),
                                   np.asarray(ref[k]), atol=1e-5, rtol=1e-5,
                                   err_msg=k)
    # and the forced per-step fallback agrees with the fused path
    st2, _, _ = plan.run(nodes, params, x, plan=_force_step(compiled))
    for k in ref:
        np.testing.assert_allclose(np.asarray(st["hidden"]["syn:input"][k]),
                                   np.asarray(st2["hidden"]["syn:input"][k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


def test_custom_weight_key_honored_by_both_engines():
    """Regression: Connection(weight=...) overrides used to work in the
    fused plan but crash (or silently diverge) in the stepper, whose
    ff_integrate hard-codes w_<src>. The stepper now aliases the canonical
    key to the override, so both engines read the same tensor — and
    apply_learned round-trips through it."""
    ks = jax.random.split(KEY, 4)
    rule = pair_stdp()
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.6), ff_integrate,
                         (Connection("input", weight="w_shared",
                                     plastic=rule),), 10),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 3),
    ]
    params = {"h": {"w_shared": _w(ks[0], 6, 10)},
              "ro": {"w_h": _w(ks[1], 10, 3)}}
    x = _spikes(ks[2], (11, 2, 6))
    st1, o1, _ = events.run(nodes, params, x)
    st2, o2, _ = plan.run(nodes, params, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    # the learning pass reads and writes the override tensor
    learned = plasticity.apply_learned(nodes, params, st2)
    np.testing.assert_array_equal(
        np.asarray(learned["h"]["w_shared"]),
        np.asarray(st2["h"]["syn:input"]["w"]))
    assert float(jnp.linalg.norm(learned["h"]["w_shared"]
                                 - params["h"]["w_shared"])) > 1e-3
    with pytest.raises(ValueError, match="conflicting weight"):
        events.LayerNode("h", LIF(), ff_integrate,
                         (Connection("a", weight="w_one"),
                          Connection("a", delay=1, weight="w_two")), 4)


def test_plastic_backref_learns_from_delivered_train():
    """Regression: a plastic back-reference (source ordered after the node,
    read at t-1 by the stepper) used to learn from the source's same-step
    train. The learned weight must match synapse_run on the actually
    delivered (one-step-shifted) pre spikes."""
    rule = pair_stdp()
    ks = jax.random.split(KEY, 4)
    nodes = [
        events.LayerNode("a", LIF(tau=0.6, v_th=0.6), ff_integrate,
                         ("input", Connection("b", plastic=rule)), 10),
        events.LayerNode("b", LIF(tau=0.8, v_th=0.7), ff_integrate,
                         ("a",), 8),
    ]
    params = {"a": {"w_input": _w(ks[0], 5, 10), "w_b": _w(ks[1], 8, 10)},
              "b": {"w_a": _w(ks[2], 10, 8)}}
    x = _spikes(ks[3], (12, 2, 5), rate=0.5)
    compiled = plan.compile_program(nodes)
    assert compiled.fully_fallback          # backref -> whole-program stepper
    st, _, _ = plan.run(nodes, params, x, plan=compiled)
    _, _, recs = events.run(nodes, params, x, record=("a", "b"))
    pre = jnp.concatenate([jnp.zeros((1,) + recs["b"].shape[1:]),
                           recs["b"][:-1]], axis=0)      # delivered: t-1
    ref = synapse_run(rule, params["a"]["w_b"], pre, recs["a"])
    for k in ref:
        np.testing.assert_allclose(np.asarray(st["a"]["syn:b"][k]),
                                   np.asarray(ref[k]), atol=1e-5, rtol=1e-5,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# property test: random valid SynapsePrograms, fused == per-step fallback
# ---------------------------------------------------------------------------


def _random_rule(n_traces, n_terms, tau_a, tau_b, amp, variant):
    """Enumerate structurally diverse valid programs: pre/post traces with
    mixed before/after reads, multi-factor terms, optional mod gating."""
    sources = ["pre", "post"]
    traces = tuple(
        TraceVar(f"t{i}", sources[(i + variant) % 2],
                 Decay("const", tau_a if i % 2 == 0 else tau_b),
                 scale=1.0 if i % 2 == 0 else 0.7,
                 update="before" if (i + variant) % 3 else "after")
        for i in range(n_traces))
    pre_traces = [t.name for t in traces if t.source == "pre"]
    post_traces = [t.name for t in traces if t.source == "post"]
    terms = []
    for j in range(n_terms):
        pre = ("spikes",) if not pre_traces or j % 2 == 0 else \
            (pre_traces[j % len(pre_traces)],)
        post = ("spikes",) if not post_traces else \
            ("spikes", post_traces[j % len(post_traces)]) if j % 3 == 2 \
            else (post_traces[j % len(post_traces)],)
        if variant == 2 and j == 0:
            post = post + ("mod",)
        terms.append(UpdateTerm(amp * (-1.0 if j % 2 else 1.0),
                                pre=pre, post=post))
    return SynapseProgram(traces=traces, terms=tuple(terms),
                          w_min=-0.8, w_max=0.8)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 3), st.integers(1, 4), st.floats(0.3, 0.95),
       st.floats(0.5, 0.99), st.floats(0.005, 0.05), st.integers(0, 2))
def test_random_synapse_programs_fused_matches_fallback(
        n_traces, n_terms, tau_a, tau_b, amp, variant):
    """For ANY valid SynapseProgram the fused stdp_seq lowering and the
    per-step fallback must produce the same weight trajectory endpoint and
    final traces."""
    rule = plasticity.validate_synapse_program(
        _random_rule(n_traces, n_terms, tau_a, tau_b, amp, variant))
    nodes, params = make_plastic_ff(
        jax.random.PRNGKey(n_traces * 7 + n_terms), n_in=7, n_hidden=9,
        rule=rule)
    x = _spikes(jax.random.fold_in(KEY, variant + n_terms), (10, 2, 7))
    mod = (jax.random.uniform(jax.random.PRNGKey(variant), (10,))
           if variant == 2 else None)
    compiled = plan.compile_program(nodes)
    assert compiled.plastic[0].lower == plan.SYN_SEQ, compiled.describe()
    st_seq, _, _ = plan.run(nodes, params, x, plan=compiled, mod=mod)
    st_step, _, _ = plan.run(nodes, params, x, plan=_force_step(compiled),
                             mod=mod)
    a, b = st_seq["hidden"]["syn:input"], st_step["hidden"]["syn:input"]
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


def test_oversized_program_refused_by_matcher():
    rule = _random_rule(4, 4, 0.9, 0.8, 0.01, 0)
    big = dataclasses.replace(rule, terms=rule.terms + (
        UpdateTerm(0.001, pre=("spikes",), post=("spikes",)),))
    lower, code, why = plan._match_synapse_pattern(big)
    assert lower == plan.SYN_STEP and "update terms" in why
    assert code == "TB210"


def test_describe_names_plastic_lowerings():
    nodes, _ = make_plastic_ff(jax.random.PRNGKey(12), rule=triplet_stdp())
    desc = plan.compile_program(nodes).describe()
    assert "learn hidden.input:stdp_seq" in desc
