"""Pipeline-parallel tests: the GPipe schedule must match sequential stage
application exactly (values and gradients), verified on an 8-fake-device
mesh in a subprocess (device-count override must not leak into this process)."""

import json
import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.sharding.pipeline import microbatch, pipeline_apply, pipeline_loss_fn

S, M, mb, D = 4, 8, 4, 16
mesh = jax.make_mesh((S, 2), ("stage", "data"))

key = jax.random.PRNGKey(0)
Ws = 0.3 * jax.random.normal(key, (S, D, D))
bs = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (S, D))
params = {"w": Ws, "b": bs}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.fold_in(key, 2), (M * mb, D))
xm = microbatch(x, M)

# sequential reference
ref = xm
for s in range(S):
    ps = jax.tree.map(lambda a: a[s], params)
    ref = jax.vmap(lambda xx: stage_fn(ps, xx))(ref)

out = pipeline_apply(stage_fn, params, xm, mesh)
err_fwd = float(jnp.max(jnp.abs(out - ref)))

# gradients through the pipeline vs sequential
y = jax.random.normal(jax.random.fold_in(key, 3), (M * mb, D))
ym = microbatch(y, M)

def loss_seq(params):
    h = xm
    for s in range(S):
        ps = jax.tree.map(lambda a: a[s], params)
        h = jax.vmap(lambda xx: stage_fn(ps, xx))(h)
    return jnp.mean((h - ym) ** 2)

loss_pipe = pipeline_loss_fn(stage_fn, lambda o, t: jnp.mean((o - t) ** 2),
                             mesh, n_micro=M)
g1 = jax.grad(loss_seq)(params)
g2 = jax.grad(lambda p: loss_pipe(p, x, y))(params)
err_g = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print(json.dumps({"err_fwd": err_fwd, "err_grad": err_g}))
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err_fwd"] < 1e-5, rec
    assert rec["err_grad"] < 1e-5, rec
