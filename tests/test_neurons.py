"""Neuron-DSL dynamics tests: closed-form checks + programmability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.neuron import ALIF, DHLIF, LI, LIF, PLIF, diff, locacc, make_neuron
from repro.core.surrogate import spike, surrogate_names


def test_diff_closed_form():
    """v_T = tau^T v_0 for zero input (pure decay)."""
    v = jnp.full((3,), 2.0)
    for _ in range(10):
        v = diff(v, 0.9, 0.0)
    np.testing.assert_allclose(v, 2.0 * 0.9 ** 10, rtol=1e-6)


def test_lif_fires_at_threshold():
    lif = LIF(tau=0.0, v_th=1.0)
    st = lif.init_state((1, 4))
    st, s = lif.fire(st, jnp.array([[0.5, 0.99, 1.0, 3.0]]))
    np.testing.assert_array_equal(np.asarray(s[0]), [0.0, 0.0, 1.0, 1.0])
    # hard reset to zero where fired
    np.testing.assert_allclose(np.asarray(st["v"][0]), [0.5, 0.99, 0.0, 0.0],
                               rtol=1e-6)


def test_lif_subthreshold_integration():
    lif = LIF(tau=0.5, v_th=10.0)
    st = lif.init_state((1, 1))
    for _ in range(5):
        st, _ = lif.fire(st, jnp.ones((1, 1)))
    # v = sum_{i<5} 0.5^i = 1.9375
    np.testing.assert_allclose(st["v"][0, 0], 1.9375, rtol=1e-6)


def test_alif_threshold_adapts():
    """After a spike, ALIF's effective threshold rises (homeostasis)."""
    alif = ALIF(tau=0.9, rho=0.9, beta=2.0, v_th=1.0)
    st = alif.init_state((1, 1))
    st, s1 = alif.fire(st, jnp.full((1, 1), 1.5))     # fires
    assert s1[0, 0] == 1.0 and st["a"][0, 0] == 1.0
    st, s2 = alif.fire(st, jnp.full((1, 1), 1.5))     # th now 1 + 2*0.9
    assert s2[0, 0] == 0.0


def test_dhlif_branch_heterogeneity():
    """Branch currents integrate with distinct taus then sum into the soma."""
    n = DHLIF(n_branches=2, v_th=100.0)
    params = n.param_init(jax.random.PRNGKey(0), (3,))
    st = n.init_state((1, 3))
    cur = jnp.ones((1, 2, 3))
    st, _ = n.fire(st, cur, params)
    st, _ = n.fire(st, cur, params)
    tau_d = jax.nn.sigmoid(params["w_tau_d"])
    expected_d = tau_d + 1.0                        # after two unit inputs
    np.testing.assert_allclose(st["d"][0], expected_d, rtol=1e-5)
    assert not np.allclose(st["d"][0, 0], st["d"][0, 1])   # heterogeneous


def test_li_readout_never_fires():
    li = LI(tau=0.9)
    st = li.init_state((1, 2))
    st, out = li.fire(st, jnp.full((1, 2), 100.0))
    np.testing.assert_allclose(out, st["v"])         # membrane, not spikes


@pytest.mark.parametrize("name", surrogate_names())
def test_surrogates_forward_exact_backward_smooth(name):
    x = jnp.linspace(-2, 2, 41)
    y = spike(x, name, 1.0)
    np.testing.assert_array_equal(y, (x >= 0).astype(jnp.float32))
    g = jax.vmap(jax.grad(lambda z: spike(z, name, 1.0)))(x)
    assert np.all(np.asarray(g) >= 0)
    assert float(jnp.max(g)) > 0                     # non-degenerate


def test_neuron_registry_programmability():
    for name in ("lif", "plif", "alif", "dhlif", "li"):
        n = make_neuron(name)
        st = n.init_state((2, 4))
        cur = (jnp.ones((2, n.n_branches, 4)) if name == "dhlif"
               else jnp.ones((2, 4)))
        p = n.param_init(jax.random.PRNGKey(0), (4,)) or None
        st2, s = n.fire(st, cur, p)
        assert s.shape == (2, 4)


def test_locacc_is_matmul():
    s = jnp.array([[1.0, 0.0, 1.0]])
    w = jnp.arange(12.0).reshape(3, 4)
    np.testing.assert_allclose(locacc(s, w), (w[0] + w[2])[None])
