"""Neuron-DSL dynamics tests: closed-form checks + programmability, plus
parity between the generic NeuronProgram interpreter and the legacy
closed-form updates each built-in used to hard-code."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.neuron import (ALIF, DHLIF, LI, LIF, PLIF, Decay,
                               NeuronProgram, ProgramNeuron, StateVar,
                               Threshold, diff, locacc, make_neuron,
                               register_neuron, validate_program)
from repro.core.surrogate import spike, surrogate_names


def test_diff_closed_form():
    """v_T = tau^T v_0 for zero input (pure decay)."""
    v = jnp.full((3,), 2.0)
    for _ in range(10):
        v = diff(v, 0.9, 0.0)
    np.testing.assert_allclose(v, 2.0 * 0.9 ** 10, rtol=1e-6)


def test_lif_fires_at_threshold():
    lif = LIF(tau=0.0, v_th=1.0)
    st = lif.init_state((1, 4))
    st, s = lif.fire(st, jnp.array([[0.5, 0.99, 1.0, 3.0]]))
    np.testing.assert_array_equal(np.asarray(s[0]), [0.0, 0.0, 1.0, 1.0])
    # hard reset to zero where fired
    np.testing.assert_allclose(np.asarray(st["v"][0]), [0.5, 0.99, 0.0, 0.0],
                               rtol=1e-6)


def test_lif_subthreshold_integration():
    lif = LIF(tau=0.5, v_th=10.0)
    st = lif.init_state((1, 1))
    for _ in range(5):
        st, _ = lif.fire(st, jnp.ones((1, 1)))
    # v = sum_{i<5} 0.5^i = 1.9375
    np.testing.assert_allclose(st["v"][0, 0], 1.9375, rtol=1e-6)


def test_alif_threshold_adapts():
    """After a spike, ALIF's effective threshold rises (homeostasis)."""
    alif = ALIF(tau=0.9, rho=0.9, beta=2.0, v_th=1.0)
    st = alif.init_state((1, 1))
    st, s1 = alif.fire(st, jnp.full((1, 1), 1.5))     # fires
    assert s1[0, 0] == 1.0 and st["a"][0, 0] == 1.0
    st, s2 = alif.fire(st, jnp.full((1, 1), 1.5))     # th now 1 + 2*0.9
    assert s2[0, 0] == 0.0


def test_dhlif_branch_heterogeneity():
    """Branch currents integrate with distinct taus then sum into the soma."""
    n = DHLIF(n_branches=2, v_th=100.0)
    params = n.param_init(jax.random.PRNGKey(0), (3,))
    st = n.init_state((1, 3))
    cur = jnp.ones((1, 2, 3))
    st, _ = n.fire(st, cur, params)
    st, _ = n.fire(st, cur, params)
    tau_d = jax.nn.sigmoid(params["w_tau_d"])
    expected_d = tau_d + 1.0                        # after two unit inputs
    np.testing.assert_allclose(st["d"][0], expected_d, rtol=1e-5)
    assert not np.allclose(st["d"][0, 0], st["d"][0, 1])   # heterogeneous


def test_li_readout_never_fires():
    li = LI(tau=0.9)
    st = li.init_state((1, 2))
    st, out = li.fire(st, jnp.full((1, 2), 100.0))
    np.testing.assert_allclose(out, st["v"])         # membrane, not spikes


@pytest.mark.parametrize("name", surrogate_names())
def test_surrogates_forward_exact_backward_smooth(name):
    x = jnp.linspace(-2, 2, 41)
    y = spike(x, name, 1.0)
    np.testing.assert_array_equal(y, (x >= 0).astype(jnp.float32))
    g = jax.vmap(jax.grad(lambda z: spike(z, name, 1.0)))(x)
    assert np.all(np.asarray(g) >= 0)
    assert float(jnp.max(g)) > 0                     # non-degenerate


def test_neuron_registry_programmability():
    for name in ("lif", "plif", "alif", "dhlif", "li"):
        n = make_neuron(name)
        st = n.init_state((2, 4))
        cur = (jnp.ones((2, n.n_branches, 4)) if name == "dhlif"
               else jnp.ones((2, 4)))
        p = n.param_init(jax.random.PRNGKey(0), (4,)) or None
        st2, s = n.fire(st, cur, p)
        assert s.shape == (2, 4)


def test_locacc_is_matmul():
    s = jnp.array([[1.0, 0.0, 1.0]])
    w = jnp.arange(12.0).reshape(3, 4)
    np.testing.assert_allclose(locacc(s, w), (w[0] + w[2])[None])


# ---------------------------------------------------------------------------
# the neuron-program IR: interpreter parity vs the legacy closed forms
# ---------------------------------------------------------------------------


def _legacy_fire(neuron, state, current, params):
    """The closed-form updates each dataclass used to hard-code before the
    FIRE stage became a declarative NeuronProgram — kept here as the
    numerical oracle for the generic interpreter."""
    dt = current.dtype
    if isinstance(neuron, LIF):
        v = diff(state["v"], jnp.asarray(neuron.tau, dt), current)
        s = spike(v - neuron.v_th, neuron.surrogate, neuron.alpha)
        return {"v": v * (1.0 - s)}, s
    if isinstance(neuron, PLIF):
        tau = jax.nn.sigmoid(params["w_tau"]).astype(dt)
        v = diff(state["v"], tau, current)
        s = spike(v - neuron.v_th, neuron.surrogate, neuron.alpha)
        return {"v": v * (1.0 - s)}, s
    if isinstance(neuron, ALIF):
        if params:
            tau = jax.nn.sigmoid(params["w_tau"]).astype(dt)
            rho = jax.nn.sigmoid(params["w_rho"]).astype(dt)
        else:
            tau = jnp.asarray(neuron.tau, dt)
            rho = jnp.asarray(neuron.rho, dt)
        v = diff(state["v"], tau, current)
        th = neuron.v_th + neuron.beta * state["a"]
        s = spike(v - th, neuron.surrogate, neuron.alpha)
        return {"v": v * (1.0 - s), "a": diff(state["a"], rho, s)}, s
    if isinstance(neuron, DHLIF):
        tau_d = jax.nn.sigmoid(params["w_tau_d"]).astype(dt)
        tau_s = jax.nn.sigmoid(params["w_tau_s"]).astype(dt)
        d = diff(state["d"], tau_d, current)
        v = diff(state["v"], tau_s, jnp.sum(d, axis=-2))
        s = spike(v - neuron.v_th, neuron.surrogate, neuron.alpha)
        return {"v": v * (1.0 - s), "d": d}, s
    if isinstance(neuron, LI):
        v = diff(state["v"], jnp.asarray(neuron.tau, dt), current)
        return {"v": v}, v
    raise TypeError(neuron)


_BUILTINS = ["lif", "plif", "alif", "alif_plain", "dhlif", "li"]


def _builtin_case(name, key):
    n = 6
    if name == "lif":
        neuron, params = LIF(tau=0.8, v_th=0.6), None
    elif name == "plif":
        neuron = PLIF(v_th=0.7)
        params = neuron.param_init(key, (n,))
    elif name == "alif":
        neuron = ALIF(surrogate="sigmoid", alpha=4.0, beta=0.5, v_th=0.8)
        params = neuron.param_init(key, (n,))
    elif name == "alif_plain":
        neuron, params = ALIF(beta=0.5, v_th=0.8), None
    elif name == "dhlif":
        neuron = DHLIF(n_branches=3, v_th=0.9)
        params = neuron.param_init(key, (n,))
    else:
        neuron, params = LI(tau=0.9), None
    cur_shape = (2, 3, n) if name == "dhlif" else (2, n)
    return neuron, params, cur_shape


@pytest.mark.parametrize("name", _BUILTINS)
def test_program_fire_matches_legacy_closed_form(name):
    """Forward AND gradients of the generic program interpreter equal the
    hand-written updates, for several steps of held state."""
    key = jax.random.PRNGKey(3)
    neuron, params, cur_shape = _builtin_case(name, key)
    currents = 0.9 * jax.random.normal(jax.random.fold_in(key, 1),
                                       (4,) + cur_shape)

    def rollout(fire_fn, params, currents):
        st = neuron.init_state((2, cur_shape[-1]))
        outs = []
        for t in range(currents.shape[0]):
            st, o = fire_fn(neuron, st, currents[t], params) \
                if fire_fn is _legacy_fire else fire_fn(st, currents[t],
                                                        params)
            outs.append(o)
        return st, jnp.stack(outs)

    st1, o1 = rollout(_legacy_fire, params, currents)
    st2, o2 = rollout(neuron.fire, params, currents)
    assert set(st1) == set(st2)
    np.testing.assert_allclose(o1, o2, atol=1e-6, rtol=1e-6)
    for k in st1:
        np.testing.assert_allclose(st1[k], st2[k], atol=1e-6, rtol=1e-6)

    def make_loss(fire_fn):
        def loss(args):
            p, c = args
            _, o = rollout(fire_fn, p, c)
            return jnp.sum(jnp.sin(o * 1.3))
        return loss

    g1 = jax.grad(make_loss(_legacy_fire))((params, currents))
    g2 = jax.grad(make_loss(neuron.fire))((params, currents))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5,
                                                         rtol=1e-5), g1, g2)


def test_builtin_programs_validate():
    for name in ("lif", "plif", "alif", "dhlif", "li"):
        validate_program(make_neuron(name).program)


def test_program_validation_rejects_malformed():
    v = StateVar("v", Decay("const", 0.9))
    bad = [
        NeuronProgram(states=(), threshold=Threshold()),
        NeuronProgram(states=(v, v), threshold=Threshold()),
        NeuronProgram(states=(v,), threshold=Threshold(on="ghost")),
        NeuronProgram(states=(v,), threshold=Threshold(adapt="ghost")),
        NeuronProgram(states=(v,), threshold=Threshold(), reset="bogus"),
        NeuronProgram(states=(v,), threshold=Threshold(), output="ghost"),
        NeuronProgram(states=(v,), threshold=None),   # spikes w/o threshold
        NeuronProgram(states=(StateVar("a", Decay("const", 0.9),
                                       drive="spikes"),), threshold=None,
                      output="a"),
        NeuronProgram(states=(StateVar("v", Decay("learned", 0.9)),),
                      threshold=Threshold()),         # learned w/o param
        NeuronProgram(states=(StateVar("v", Decay("per_branch", 0.9,
                                                  "w_k")),),
                      threshold=Threshold()),         # per_branch, no branch
        NeuronProgram(states=(StateVar("v", Decay("const", 0.9),
                                       drive="sum:v"),),
                      threshold=Threshold()),         # sums non-branch
        NeuronProgram(states=(StateVar("d", Decay("const", 0.9),
                                       branch=True),
                              StateVar("v", Decay("const", 0.9),
                                       drive="sum:d")),
                      threshold=Threshold(on="v", adapt="d", scale=0.3),
                      n_branches=2),                  # adapts on branch state
        NeuronProgram(states=(StateVar("d", Decay("const", 0.9),
                                       branch=True),
                              StateVar("v", Decay("const", 0.9),
                                       drive="sum:d")),
                      threshold=Threshold(on="v"), output="d",
                      n_branches=2),                  # branch-state output
    ]
    for prog in bad:
        with pytest.raises(ValueError):
            ProgramNeuron(prog=prog)


def test_register_neuron_opens_registry_and_rejects_duplicates():
    def izh_like(**kw):
        return ProgramNeuron(prog=NeuronProgram(
            states=(StateVar("v", Decay("const", 0.8)),
                    StateVar("u", Decay("const", 0.95), drive="spikes")),
            threshold=Threshold(base=1.0, on="v", adapt="u", scale=0.3)),
            **kw)

    name = "custom_adaptive_test"
    register_neuron(name, izh_like)
    try:
        n = make_neuron(name, alpha=2.0)
        assert n.alpha == 2.0
        st = n.init_state((2, 4))
        st, s = n.fire(st, jnp.ones((2, 4)))
        assert s.shape == (2, 4) and set(st) == {"v", "u"}
        with pytest.raises(ValueError):
            register_neuron(name, izh_like)
        register_neuron(name, izh_like, override=True)   # explicit wins
        with pytest.raises(ValueError):
            register_neuron("lif", izh_like)             # builtins guarded
    finally:
        from repro.core.neuron import NEURON_REGISTRY
        NEURON_REGISTRY.pop(name, None)
    with pytest.raises(KeyError):
        make_neuron("no_such_neuron")
