"""Test fixtures and harness policy.

NOTE: no XLA_FLAGS here — tests run on the single real CPU device (the
512-device override is dryrun.py-only, per the assignment).

Tier policy (mirrored in .github/workflows/ci.yml):
  fast tier    pytest -m "not slow"   — kernels, registry parity, topology,
               routing, plasticity; target well under 2 minutes
  full tier    pytest                 — adds model smoke / sharding /
               training-learns tests (the `slow` marker)
  tpu tier     pytest -m tpu          — real-Mosaic runs; auto-skipped off-TPU

If `hypothesis` is not installed (the baked container has no dev extras),
a minimal deterministic stub (tests/_hypothesis_stub.py) is registered
BEFORE collection so the property-test modules import and run; CI installs
the real engine via requirements-dev.txt.
"""

import importlib.util
import os
import sys

import numpy as np
import pytest

if importlib.util.find_spec("hypothesis") is None:
    _stub_path = os.path.join(os.path.dirname(__file__),
                              "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _stub = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _stub
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _clear_incidents():
    """Isolate the per-process incident log (repro.kernels.incidents)
    between tests, so one test's recorded degradations cannot satisfy or
    pollute another's assertions."""
    from repro.kernels.incidents import clear
    clear()
    yield
    clear()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration/smoke test; excluded "
                   "from the fast CI tier")
    config.addinivalue_line(
        "markers", "tpu: requires a real TPU backend; auto-skipped elsewhere")


def pytest_collection_modifyitems(config, items):
    tpu_items = [it for it in items if "tpu" in it.keywords]
    if not tpu_items:
        return
    import jax  # deferred: keep collection cheap for -m deselections

    if jax.default_backend() != "tpu":
        skip = pytest.mark.skip(reason="requires TPU backend "
                                       f"(running on {jax.default_backend()})")
        for it in tpu_items:
            it.add_marker(skip)
