"""Test fixtures. NOTE: no XLA_FLAGS here — tests run on the single real CPU
device (the 512-device override is dryrun.py-only, per the assignment)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
