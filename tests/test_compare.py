"""Perf-gate unit tests: benchmarks/compare.py must catch an injected
synthetic regression (the acceptance criterion is proven HERE, not by
breaking live CI), tolerate single-repeat noise via min-of-k, and support
the --update-baselines refresh flow."""

import copy
import json
import os

import pytest

from benchmarks import compare


def _doc(**rows):
    """A minimal BENCH_<suite>.json-shaped doc with a result payload."""
    return {"schema": 1, "suite": "kernels", "ok": True,
            "result": {"spikemm_sparsity": {"rows": {
                k: {"speedup_x": v} for k, v in rows.items()}}}}


TRACKED = [
    {"suite": "kernels",
     "path": "result/spikemm_sparsity/rows/0.01/speedup_x",
     "direction": "higher"},
    {"suite": "kernels",
     "path": "result/spikemm_sparsity/rows/0.05/speedup_x",
     "direction": "higher"},
]


def test_path_walk_handles_dotted_keys():
    doc = _doc(**{"0.01": 4.0})
    assert compare.get_path(
        doc, "result/spikemm_sparsity/rows/0.01/speedup_x") == 4.0
    assert compare.get_path(doc, "result/missing/x") is None
    assert compare.set_path(
        doc, "result/spikemm_sparsity/rows/0.01/speedup_x", 5.0)
    assert doc["result"]["spikemm_sparsity"]["rows"]["0.01"]["speedup_x"] == 5


def test_gate_fails_on_injected_regression():
    """Acceptance: a synthetic 50% drop on a tracked row is flagged."""
    base = _doc(**{"0.01": 4.0, "0.05": 2.4})
    fresh = _doc(**{"0.01": 2.0, "0.05": 2.3})     # 0.01 halved
    report = compare.compare({"kernels": [fresh]}, {"kernels": base},
                             TRACKED, tolerance=0.20)
    assert len(report["regressions"]) == 1
    reg = report["regressions"][0]
    assert reg["path"].endswith("0.01/speedup_x")
    assert reg["ratio"] == pytest.approx(0.5)
    ok = [r for r in report["rows"] if not r["regressed"]]
    assert len(ok) == 1                            # 0.05 within tolerance


def test_min_of_k_guard_forgives_one_noisy_repeat():
    """One throttled repeat must NOT fake a regression: the gate takes the
    best value across repeats."""
    base = _doc(**{"0.01": 4.0, "0.05": 2.4})
    noisy = _doc(**{"0.01": 1.1, "0.05": 0.9})     # contention burst
    good = _doc(**{"0.01": 3.9, "0.05": 2.5})
    report = compare.compare({"kernels": [noisy, good]}, {"kernels": base},
                             TRACKED, tolerance=0.20)
    assert report["regressions"] == []
    row = report["rows"][0]
    assert row["best"] == pytest.approx(3.9)
    assert row["n_repeats"] == 2


def test_direction_lower_gates_on_increase():
    base = {"result": {"lat_ms": 10.0}}
    fresh = {"result": {"lat_ms": 15.0}}
    tracked = [{"suite": "kernels", "path": "result/lat_ms",
                "direction": "lower"}]
    report = compare.compare({"kernels": [fresh]}, {"kernels": base},
                             tracked, tolerance=0.20)
    assert len(report["regressions"]) == 1
    assert report["rows"][0]["ratio"] == pytest.approx(10.0 / 15.0)


def test_improvements_and_missing_rows_do_not_gate():
    base = _doc(**{"0.01": 4.0})                   # no 0.05 row in baseline
    fresh = _doc(**{"0.01": 9.0, "0.05": 2.0})
    report = compare.compare({"kernels": [fresh]}, {"kernels": base},
                             TRACKED, tolerance=0.20)
    assert report["regressions"] == []
    assert len(report["missing"]) == 1


def test_per_row_tolerance_override():
    base = _doc(**{"0.01": 4.0, "0.05": 2.4})
    fresh = _doc(**{"0.01": 3.5, "0.05": 2.4})     # 12.5% drop
    tight = copy.deepcopy(TRACKED)
    tight[0]["tolerance"] = 0.05
    report = compare.compare({"kernels": [fresh]}, {"kernels": base},
                             tight, tolerance=0.20)
    assert len(report["regressions"]) == 1


def _write_run(dirpath, doc):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, "BENCH_kernels.json"), "w") as f:
        json.dump(doc, f)


def test_cli_gate_exit_codes(tmp_path):
    """End-to-end through main(): clean run exits 0, regressed run exits 1
    with --gate (0 without), and the JSON report is written."""
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    with open(baselines / "tracked.json", "w") as f:
        json.dump({"tracked": TRACKED}, f)
    _write_run(baselines, _doc(**{"0.01": 4.0, "0.05": 2.4}))

    fresh = tmp_path / "fresh"
    _write_run(fresh / "r0", _doc(**{"0.01": 4.1, "0.05": 2.3}))
    _write_run(fresh / "r1", _doc(**{"0.01": 3.8, "0.05": 2.5}))
    argv = [str(fresh), "--baselines", str(baselines)]
    assert compare.main(argv + ["--gate"]) == 0

    _write_run(fresh / "r0", _doc(**{"0.01": 1.0, "0.05": 2.4}))
    _write_run(fresh / "r1", _doc(**{"0.01": 1.2, "0.05": 2.4}))
    report_path = tmp_path / "diff.json"
    assert compare.main(argv) == 0                 # report-only: no gate
    assert compare.main(argv + ["--gate", "--json",
                                str(report_path)]) == 1
    report = json.loads(report_path.read_text())
    assert len(report["regressions"]) == 1


def test_cli_update_baselines_takes_best_across_repeats(tmp_path):
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    with open(baselines / "tracked.json", "w") as f:
        json.dump({"tracked": TRACKED}, f)
    fresh = tmp_path / "fresh"
    _write_run(fresh / "r0", _doc(**{"0.01": 3.0, "0.05": 2.0}))
    _write_run(fresh / "r1", _doc(**{"0.01": 4.5, "0.05": 1.8}))
    assert compare.main([str(fresh), "--baselines", str(baselines),
                         "--update-baselines"]) == 0
    doc = json.loads((baselines / "BENCH_kernels.json").read_text())
    rows = doc["result"]["spikemm_sparsity"]["rows"]
    assert rows["0.01"]["speedup_x"] == 4.5        # best, not r0's value
    assert rows["0.05"]["speedup_x"] == 2.0
    # the refreshed baseline now gates cleanly against the same run
    assert compare.main([str(fresh), "--baselines", str(baselines),
                         "--gate"]) == 0
