"""Static-analysis subsystem tests (repro.analysis, TB1xx-TB4xx).

Two directions:
  * the shipped registry / builtin models / mappings check CLEAN at
    warning severity (the CI gate `python -m repro.analysis --all
    --fail-on warning` must stay green);
  * injected defects produce exactly the documented TB codes — one test
    per defect class, plus hypothesis property tests that mutate valid
    random programs/graphs per defect family.
"""

import contextlib
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import analysis
from repro.core import mapping as mp
from repro.core import plan as plan_mod
from repro.core.events import Connection, LayerNode
from repro.core.neuron import (LI, LIF, Decay, NeuronProgram, NeuronSpec,
                               StateVar, Threshold)
from repro.core.plasticity import (SynapseProgram, TraceVar, UpdateTerm,
                                   pair_stdp)
from repro.core.snn_layers import (branch_integrate, ff_integrate,
                                   make_dhsnn_shd, make_plastic_ff,
                                   make_srnn_ecg)
from repro.kernels import registry
from repro.kernels.incidents import clear as clear_incidents
from repro.kernels.incidents import incidents as incident_log

KEY = jax.random.PRNGKey(0)


def codes_of(diags):
    return {d.code for d in diags}


def _lif(name, srcs, out_dim=8, neuron=None):
    return LayerNode(name, neuron or LIF(), ff_integrate,
                     inputs=tuple(srcs), out_dim=out_dim)


def _chain(depth, width=8):
    nodes = [_lif("n0", (Connection("input"),), width)]
    for i in range(1, depth):
        nodes.append(_lif(f"n{i}", (Connection(f"n{i - 1}"),), width))
    return nodes


# ---------------------------------------------------------------------------
# diagnostics plumbing
# ---------------------------------------------------------------------------


def test_make_rejects_unknown_code():
    with pytest.raises(KeyError):
        analysis.make("TB999", "x", "nope")


def test_severity_ordering_and_worst():
    ds = [analysis.make("TB201", "a", "info thing"),
          analysis.make("TB105", "b", "warn thing"),
          analysis.make("TB110", "c", "err thing")]
    assert analysis.worst(ds) == "error"
    ranked = analysis.at_least(ds, "warning")
    assert [d.code for d in ranked] == ["TB110", "TB105"]
    assert analysis.at_least(ds, "info") and not analysis.at_least([], "info")


def test_raise_if_carries_diagnostics():
    d = analysis.make("TB110", "site", "boom")
    with pytest.raises(analysis.DiagnosticError) as ei:
        analysis.raise_if([d])
    assert ei.value.diagnostics == (d,)
    analysis.raise_if([analysis.make("TB105", "s", "warn")])  # below floor


def test_render_mentions_code_site_and_hint():
    txt = analysis.render([analysis.make("TB103", "hid", "cycle",
                                         hint="add delay=1")])
    assert "TB103" in txt and "hid" in txt and "add delay=1" in txt


def test_every_code_has_a_titled_severity():
    for code, (sev, title) in analysis.CODES.items():
        assert sev in analysis.SEVERITIES and title, code


def test_polymorphic_check_dispatch():
    assert analysis.check("lif") == analysis.check_kernel("lif")
    prog = LIF().program
    assert analysis.check(prog) == analysis.check_program(prog)
    nodes = _chain(2)
    assert codes_of(analysis.check(nodes)) == codes_of(
        analysis.check_nodes(nodes))
    with pytest.raises(TypeError):
        analysis.check(42)


# ---------------------------------------------------------------------------
# the shipped registry / models / mappings check clean (the CI gate)
# ---------------------------------------------------------------------------


def test_registry_checks_clean():
    diags = analysis.check_kernels()
    assert not analysis.at_least(diags, "warning"), analysis.render(diags)


def test_builtin_models_check_clean():
    factories = {
        "srnn_ecg": make_srnn_ecg,
        "dhsnn_shd": lambda k: make_dhsnn_shd(k, n_in=32, n_hidden=24,
                                              n_out=8),
        "plastic_ff": make_plastic_ff,
    }
    for name, factory in factories.items():
        nodes, params = factory(KEY)
        diags = analysis.check_nodes(nodes, params=params, T=64, B=4)
        assert not analysis.at_least(diags, "warning"), \
            f"{name}:\n{analysis.render(diags)}"


def test_builtin_mapping_checks_clean():
    from repro.configs.snn_models import MODELS, to_ops
    specs, _ = MODELS["plif_net"]()
    ops = to_ops(specs)
    ir = mp.fuse_ops([dataclasses.replace(o) for o in ops])
    cores = mp.partition(ir)
    bad = analysis.at_least(analysis.check_cores(cores, ir), "error")
    assert not bad, analysis.render(bad)


def test_cli_kernels_json(capsys):
    from repro.analysis.__main__ import main
    assert main(["--kernels", "--fail-on", "warning", "--json"]) == 0
    assert json.loads(capsys.readouterr().out) == []


@pytest.mark.slow
def test_cli_all_gate_is_green():
    from repro.analysis.__main__ import main
    assert main(["--all", "--fail-on", "warning"]) == 0


# ---------------------------------------------------------------------------
# TB1xx: injected program / graph defects
# ---------------------------------------------------------------------------


def test_tb100_invalid_program_is_one_finding():
    prog = NeuronProgram(states=(StateVar("v", Decay("const", 0.9)),),
                         threshold=Threshold(on="ghost"))
    diags = analysis.check_program(prog)
    assert codes_of(diags) == {"TB100"}


def test_tb102_duplicate_decay_params():
    prog = NeuronProgram(
        states=(StateVar("v", Decay("learned", 0.9, param="tau")),
                StateVar("u", Decay("learned", 0.8, param="tau"),
                         drive="spikes")),
        threshold=Threshold(on="v", adapt="u", scale=0.5))
    assert "TB102" in codes_of(analysis.check_program(prog))


def test_tb105_unread_state():
    prog = NeuronProgram(
        states=(StateVar("v", Decay("const", 0.9)),
                StateVar("shadow", Decay("const", 0.5))),
        threshold=Threshold())
    diags = [d for d in analysis.check_program(prog) if d.code == "TB105"]
    assert len(diags) == 1 and "shadow" in diags[0].site


def test_tb108_decay_out_of_range():
    prog = NeuronProgram(states=(StateVar("v", Decay("const", 1.5)),),
                         threshold=Threshold())
    assert "TB108" in codes_of(analysis.check_program(prog))


def test_tb109_degenerate_thresholds():
    flat = NeuronProgram(states=(StateVar("v", Decay("const", 0.9)),),
                         threshold=Threshold(base=-1.0))
    assert "TB109" in codes_of(analysis.check_program(flat))
    noop_adapt = NeuronProgram(
        states=(StateVar("v", Decay("const", 0.9)),
                StateVar("a", Decay("const", 0.7), drive="spikes")),
        threshold=Threshold(base=1.0, adapt="a", scale=0.0))
    assert "TB109" in codes_of(analysis.check_program(noop_adapt))


def test_tb106_unused_trace_and_tb108_trace_decay():
    sp = SynapseProgram(
        traces=(TraceVar("x", "pre", Decay("const", 1.5)),),
        terms=(UpdateTerm(0.01),))
    got = codes_of(analysis.check_synapse(sp))
    assert {"TB106", "TB108"} <= got


def test_tb101_unknown_source():
    nodes = _chain(2)[:-1] + [_lif("n1", (Connection("hiden"),), 8)]
    diags = analysis.check_nodes(nodes)
    hits = [d for d in diags if d.code == "TB101"]
    assert hits and "hiden" in hits[0].message


def test_tb103_zero_delay_cycle_names_the_loop():
    nodes = [_lif("a", (Connection("input"), Connection("b")), 8),
             _lif("b", (Connection("a"),), 8)]
    hits = [d for d in analysis.check_nodes(nodes) if d.code == "TB103"]
    assert hits and "a -> b -> a" in hits[0].message
    assert "delay=1" in hits[0].hint


def test_tb104_unreachable_and_dead_nodes():
    orphan = _lif("orphan", (Connection("self"),), 8)
    diags = analysis.check_nodes(_chain(2) + [orphan])
    assert any(d.code == "TB104" and d.site == "orphan" for d in diags)
    # dead output: feeds nothing, not the terminal node
    nodes = [_lif("n0", (Connection("input"),), 8),
             _lif("stub", (Connection("n0"),), 8),
             _lif("n1", (Connection("n0"),), 8)]
    diags = analysis.check_nodes(nodes)
    assert any(d.code == "TB104" and d.site == "stub" for d in diags)


def test_tb107_plastic_edge_missing_weight():
    nodes, params = make_plastic_ff(KEY, n_in=8, n_hidden=8, n_out=4)
    del params["hidden"]["w_input"]
    diags = analysis.check_nodes(nodes, params=params)
    assert any(d.code == "TB107" and d.site == "hidden.input" for d in diags)


def test_tb110_weight_shape_mismatches():
    nodes = [_lif("h", (Connection("input"),), 8),
             LayerNode("o", LI(), ff_integrate,
                       inputs=(Connection("h"),), out_dim=4)]
    params = {"h": {"w_input": jnp.zeros((16, 8))},
              "o": {"w_h": jnp.zeros((8, 5))}}       # expected (8, 4)
    hits = [d for d in analysis.check_nodes(nodes, params=params)
            if d.code == "TB110"]
    assert [d.site for d in hits] == ["o.h"]
    params["o"]["w_h"] = jnp.zeros((8, 4))
    clean = analysis.check_nodes(nodes, params=params)
    assert "TB110" not in codes_of(clean)


def test_tb111_missing_out_dim():
    nodes = [LayerNode("z", LIF(), ff_integrate,
                       inputs=(Connection("input"),))]
    assert "TB111" in codes_of(analysis.check_nodes(nodes))


def test_tb231_tb232_weight_key_hazards():
    rule = pair_stdp()
    pre = _lif("pre", (Connection("input"),), 8)
    h = LayerNode("h", LIF(), ff_integrate,
                  inputs=(Connection("input", plastic=rule,
                                     weight="w_shared"),
                          Connection("pre", plastic=rule,
                                     weight="w_shared")),
                  out_dim=8)
    assert "TB231" in codes_of(analysis.check_nodes([pre, h]))
    h2 = LayerNode("h", LIF(), ff_integrate,
                   inputs=(Connection("input", plastic=rule,
                                      weight="w_shared"),
                           Connection("pre", weight="w_shared")),
                   out_dim=8)
    assert "TB232" in codes_of(analysis.check_nodes([pre, h2]))


# ---------------------------------------------------------------------------
# TB2xx: fusion explainability + VMEM prediction
# ---------------------------------------------------------------------------


def test_tb201_back_reference_is_whole_program_fallback():
    nodes = [_lif("a", (Connection("input"), Connection("b")), 8),
             _lif("b", (Connection("input"),), 8)]
    compiled = analysis.compile_quiet(nodes)
    seg = compiled.segments[0]
    assert seg.kind == plan_mod.FALLBACK and seg.codes == ("TB201",)
    assert len(compiled.segments) == 1 and set(seg.names) == {"a", "b"}
    assert "TB201" in codes_of(analysis.check_plan(nodes, plan=compiled))


def test_tb202_unhoistable_integrate():
    def opaque(params, feeds):
        return sum(feeds.values())
    nodes = [LayerNode("a", LIF(), opaque,
                       inputs=(Connection("input"),), out_dim=8)]
    diags = analysis.check_plan(nodes)
    hits = [d for d in diags if d.code == "TB202"]
    assert hits and hits[0].site == "a"


def test_tb203_delayed_self():
    nodes = [_lif("a", (Connection("input"),
                        Connection("self", delay=1)), 8)]
    assert "TB203" in codes_of(analysis.check_plan(nodes))


def test_tb206_unmatched_fire_pattern():
    nodes = [_lif("a", (Connection("input"),), 8,
                  neuron=LIF(reset="none"))]
    hits = [d for d in analysis.check_plan(nodes) if d.code == "TB206"]
    assert hits and "reset" in hits[0].message


def test_tb207_integrate_program_mismatch():
    nodes = [LayerNode("a", LIF(), branch_integrate,
                       inputs=(Connection("input"),), out_dim=8)]
    assert "TB207" in codes_of(analysis.check_plan(nodes))


def test_tb205_neuron_without_program():
    nodes = [LayerNode("a", NeuronSpec(), ff_integrate,
                       inputs=(Connection("input"),), out_dim=8)]
    assert "TB205" in codes_of(analysis.check_plan(nodes))


def test_tb210_plastic_step_fallback_sites_the_edge():
    rule = pair_stdp()
    big = dataclasses.replace(rule, terms=rule.terms + tuple(
        UpdateTerm(0.001) for _ in range(3)))
    nodes, _ = make_plastic_ff(KEY, n_in=8, n_hidden=8, rule=big)
    compiled = analysis.compile_quiet(nodes)
    assert compiled.plastic[0].code == "TB210"
    hits = [d for d in analysis.check_plan(nodes, plan=compiled)
            if d.code == "TB210"]
    assert hits and hits[0].site == "hidden.input"


def test_fallback_segments_all_carry_codes():
    """ISSUE acceptance: every fallback segment is machine-explained."""
    def opaque(params, feeds):
        return sum(feeds.values())
    nodes = [LayerNode("a", LIF(), opaque,
                       inputs=(Connection("input"),), out_dim=8),
             _lif("b", (Connection("a"), Connection("self", delay=2)), 8),
             _lif("c", (Connection("b"),), 4)]
    compiled = analysis.compile_quiet(nodes)
    for seg in compiled.segments:
        if seg.kind == plan_mod.FALLBACK:
            assert seg.codes and len(seg.codes) == len(seg.names)
            assert all(code in analysis.CODES for code in seg.codes)
            assert all(code in seg.reason for code in seg.codes)
    desc = compiled.describe()
    assert "TB202" in desc and "TB203" in desc


def test_tb230_predicted_vmem_over_budget(monkeypatch):
    nodes, params = make_srnn_ecg(KEY)
    monkeypatch.setenv("REPRO_VMEM_LIMIT_MB", "0.05")
    diags = analysis.check_plan(nodes, T=256, B=8, params=params)
    hits = [d for d in diags if d.code == "TB230"]
    assert hits and "MiB" in hits[0].message
    monkeypatch.delenv("REPRO_VMEM_LIMIT_MB")
    assert "TB230" not in codes_of(
        analysis.check_plan(nodes, T=256, B=8, params=params))


# ---------------------------------------------------------------------------
# the REPRO_CHECK compile hook
# ---------------------------------------------------------------------------


def test_check_mode_rejects_unknown_value(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK", "bogus")
    with pytest.raises(ValueError):
        plan_mod.check_mode()


def test_repro_check_warn_records_incident(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK", "warn")
    clear_incidents()
    nodes = [_lif("a", (Connection("input"), Connection("b")), 8),
             _lif("b", (Connection("a"),), 8)]
    try:
        compiled = plan_mod.compile_program(nodes)   # warn: still compiles
        assert compiled.segments
        checks = [e for e in incident_log() if e.kind == "check"]
        assert any(e.stage == "TB103" for e in checks), checks
    finally:
        clear_incidents()


def test_repro_check_raise_rejects_weight_collision(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK", "raise")
    rule = pair_stdp()
    pre = _lif("pre", (Connection("input"),), 8)
    h = LayerNode("h", LIF(), ff_integrate,
                  inputs=(Connection("input", plastic=rule,
                                     weight="w_shared"),
                          Connection("pre", plastic=rule,
                                     weight="w_shared")),
                  out_dim=8)
    with pytest.raises(analysis.DiagnosticError) as ei:
        plan_mod.compile_program([pre, h])
    assert any(d.code == "TB231" for d in ei.value.diagnostics)
    monkeypatch.setenv("REPRO_CHECK", "off")
    assert plan_mod.compile_program([pre, h]).segments  # off: compiles


# ---------------------------------------------------------------------------
# TB3xx: kernel-spec defects via a throwaway registered spec
# ---------------------------------------------------------------------------


def _noop(*args, **kw):
    return None


@contextlib.contextmanager
def fake_spec(name="_tb_test", preferred=8, align=4, coverage=None,
              vmem=None, candidates=(), tile_model="default"):
    if tile_model == "default":
        tile_model = registry.TileModel(
            out=(("M", "bm"),),
            tiles=lambda dims, blocks: {"x": (blocks["bm"],)},
            coverage=coverage)
    spec = registry.KernelSpec(
        name=name, ref=_noop, pallas=_noop, apply=_noop,
        block_axes=(registry.BlockAxis("bm", "M", preferred, align),),
        dims_of=lambda: {"M": 32},
        make_inputs=lambda key: (),
        candidates=tuple(candidates),
        vmem_bytes=vmem,
        tile_model=tile_model)
    registry.register(spec)
    try:
        yield spec
    finally:
        registry._REGISTRY.pop(name, None)


def test_tb301_coverage_gap():
    with fake_spec(coverage=lambda dims, blocks: [((0, 16),)]) as spec:
        hits = [d for d in analysis.check_kernel(spec.name)
                if d.code == "TB301"]
    assert hits and "never written" in hits[0].message


def test_tb302_coverage_overlap():
    with fake_spec(coverage=lambda dims, blocks:
                   [((0, 32),), ((8, 16),)]) as spec:
        hits = [d for d in analysis.check_kernel(spec.name)
                if d.code == "TB302"]
    assert hits and "more than once" in hits[0].message


def test_tb303_misaligned_preferred_block():
    with fake_spec(preferred=6, align=4) as spec:
        assert "TB303" in codes_of(analysis.check_kernel(spec.name))


def test_tb304_vmem_model_underestimates():
    # declared tile: 8 floats = 32 B; the model claims 8 B
    with fake_spec(vmem=lambda dims, blocks: 8) as spec:
        assert "TB304" in codes_of(analysis.check_kernel(spec.name))


def test_tb305_tb306_vmem_model_too_loose_and_over_budget():
    with fake_spec(vmem=lambda dims, blocks: 64 * 2 ** 20) as spec:
        got = codes_of(analysis.check_kernel(spec.name))
    assert {"TB305", "TB306"} <= got


def test_tb308_candidate_names_unknown_axis():
    with fake_spec(candidates=({"bogus": 8},)) as spec:
        hits = [d for d in analysis.check_kernel(spec.name)
                if d.code == "TB308"]
    assert hits and "bogus" in hits[0].message


def test_tb309_spec_without_tile_model():
    with fake_spec(tile_model=None) as spec:
        assert "TB309" in codes_of(analysis.check_kernel(spec.name))


def test_honest_fake_spec_checks_clean():
    with fake_spec(vmem=lambda dims, blocks: 4 * blocks["bm"]) as spec:
        diags = analysis.check_kernel(spec.name)
    assert not diags, analysis.render(diags)


def test_tb307_block_table_violations():
    flags = np.array([[1, 1], [1, 0]], np.int32)
    ok = analysis.check_block_table(
        flags, ii=[0, 0, 1], kk=[0, 1, 0], active=[1, 1, 1])
    assert ok == []
    dup = analysis.check_block_table(
        flags, ii=[0, 0, 0, 1], kk=[0, 1, 1, 0], active=[1, 1, 1, 1])
    assert any("twice" in p for p in dup)
    missed = analysis.check_block_table(
        flags, ii=[0, 0], kk=[0, 1], active=[1, 1])
    assert any("never visited" in p for p in missed)
    assert any("absent" in p for p in missed)        # row 1 unrepresented
    ghost = analysis.check_block_table(
        flags, ii=[0, 0, 1, 1], kk=[0, 1, 0, 1], active=[1, 1, 1, 1])
    assert any("silent block" in p for p in ghost)
    unsorted_rows = analysis.check_block_table(
        flags, ii=[1, 0, 0], kk=[0, 0, 1], active=[1, 1, 1])
    assert any("non-decreasing" in p for p in unsorted_rows)


def test_coverage_problems_ragged_tail_is_exact():
    tm = registry.TileModel(out=(("M", "bm"),),
                            tiles=lambda dims, blocks: {})
    assert analysis.coverage_problems(tm, {"M": 10}, {"bm": 4}) == []


# ---------------------------------------------------------------------------
# TB4xx: mapping defects
# ---------------------------------------------------------------------------


def test_tb401_core_over_budget():
    ops = [mp.Op("a", "fc", n_neurons=40, fan_in=16, inputs=("input",))]
    cores = [mp.CoreAssignment("a", 0, 40)]
    diags = analysis.check_cores(cores, ops, core_neurons=32)
    assert any(d.code == "TB401" for d in diags)
    assert "TB401" in codes_of(analysis.check_cores(
        [mp.CoreAssignment("a", 8, 4)], ops, core_neurons=64))


def test_tb402_uncovered_op_and_range_hole():
    ops = [mp.Op("a", "fc", 8, 4, inputs=("input",)),
           mp.Op("b", "fc", 12, 4, inputs=("a",))]
    diags = analysis.check_cores([mp.CoreAssignment("a", 0, 8)], ops)
    assert any(d.code == "TB402" and d.site == "b" for d in diags)
    holey = [mp.CoreAssignment("a", 0, 8),
             mp.CoreAssignment("b", 0, 4), mp.CoreAssignment("b", 8, 12)]
    diags = analysis.check_cores(holey, ops)
    assert any(d.code == "TB402" and "holes" in d.message for d in diags)


def test_tb403_off_grid_placement():
    ops = [mp.Op("a", "fc", 4, 4, inputs=("input",))]
    mapping = mp.Mapping(cores=[mp.CoreAssignment("a", 0, 4)],
                         positions=np.array([[99, 0]]), cost=0.0)
    diags = analysis.check_mapping(mapping, ops)
    assert any(d.code == "TB403" for d in diags)
    short = mp.Mapping(cores=[mp.CoreAssignment("a", 0, 4)],
                       positions=np.zeros((0, 2), int), cost=0.0)
    assert "TB403" in codes_of(analysis.check_mapping(short, ops))


def test_tb404_fanin_beyond_physical_core():
    ops = [mp.Op("wide", "fc", 4,
                 fan_in=mp.CORE_FANIN * (mp.CORE_NEURONS + 1),
                 inputs=("input",))]
    diags = analysis.check_cores([mp.CoreAssignment("wide", 0, 4)], ops)
    assert any(d.code == "TB404" for d in diags)


def test_tb405_link_fanout_budget():
    ops = [mp.Op("s", "fc", 2, 0),
           mp.Op("c", "fc", 10, 4, inputs=("s",))]
    mapping = mp.Mapping(cores=[mp.CoreAssignment("s", 0, 2),
                                mp.CoreAssignment("c", 0, 10)],
                         positions=np.array([[0, 0], [0, 1]]), cost=0.0)
    diags = analysis.check_mapping(mapping, ops, link_fanout=10)
    assert any(d.code == "TB405" and d.site == "s" for d in diags)
    assert "TB405" not in codes_of(
        analysis.check_mapping(mapping, ops, link_fanout=100))


# ---------------------------------------------------------------------------
# property tests: mutate valid random artifacts per defect class
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.sampled_from(["TB102", "TB105", "TB108"]))
def test_property_injected_program_defects(n, code):
    base = NeuronProgram(states=(StateVar("v", Decay("const", 0.9)),),
                         threshold=Threshold())
    assert analysis.check_program(base) == []
    if code == "TB102":
        extra = tuple(StateVar(f"s{i}", Decay("learned", 0.9, param="tau"))
                      for i in range(n + 1))
        prog = dataclasses.replace(base, states=base.states + extra)
    elif code == "TB105":
        extra = tuple(StateVar(f"s{i}", Decay("const", 0.5))
                      for i in range(n))
        prog = dataclasses.replace(base, states=base.states + extra)
    else:
        prog = dataclasses.replace(
            base, states=(StateVar("v", Decay("const", 1.0 + n)),))
    assert code in codes_of(analysis.check_program(prog))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=2, max_value=5),
       st.sampled_from(["TB101", "TB103", "TB104", "TB111"]))
def test_property_injected_graph_defects(depth, code):
    nodes = _chain(depth)
    assert not analysis.at_least(analysis.check_nodes(nodes), "warning")
    last = f"n{depth - 1}"
    if code == "TB101":
        bad = nodes[:-1] + [_lif(last, (Connection("nope"),), 8)]
    elif code == "TB103":
        bad = [_lif("n0", (Connection("input"), Connection(last)), 8)]
        bad += nodes[1:]
    elif code == "TB104":
        bad = nodes + [_lif("orphan", (Connection("self"),), 8)]
    else:
        bad = nodes[:-1] + [LayerNode(last, LIF(), ff_integrate,
                                      inputs=(Connection(f"n{depth - 2}"),))]
    assert code in codes_of(analysis.check_nodes(bad))
