"""Streaming serve engine: session isolation (property-tested), the LRU
state cache, ragged scheduling/backpressure, fault degradation without
cross-session contamination, long-prompt admission, and TB5xx checks.

The load-bearing invariant: a session's output trajectory and final state
are bit-identical whether it runs alone, interleaved with strangers, or
is evicted to host and restored mid-stream — because the batched engine
always executes the SAME fixed-shape resident step (free slots
zero-padded) and spill/restore is a pure device<->host copy.
"""

import functools
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import analysis
from repro.core import faults
from repro.core.snn_layers import make_dhsnn_shd, make_plastic_ff
from repro.kernels.incidents import clear, incidents
from repro.serve import (EngineConfig, Histogram, Scheduler, ServeMetrics,
                         Session, StateCache, make_engine)
from repro.serve.loop import Request, ServeConfig, _admit, generate
from tests._faults import env, forced_pallas

W, C = 8, 4        # one cohort shape for the whole module: jit once


@functools.lru_cache(maxsize=None)
def _model():
    return make_dhsnn_shd(jax.random.PRNGKey(0), n_in=12, n_hidden=16,
                          n_out=5, dendritic=False)


@functools.lru_cache(maxsize=None)
def _plastic_model():
    return make_plastic_ff(jax.random.PRNGKey(1), n_in=10, n_hidden=12,
                           n_out=3)


def _streams(n, T, seed, n_in=12):
    rng = np.random.default_rng(seed)
    return {f"s{i}": (rng.random((T, n_in)) < 0.25).astype(np.float32)
            for i in range(n)}


def _run(kind, data, cache_bytes=None, learn=False, model=None, drip=0,
         window=W):
    nodes, params = model if model is not None else _model()
    eng = make_engine(nodes, params,
                      EngineConfig(window=window, capacity=C,
                                   cache_bytes=cache_bytes, learn=learn),
                      kind=kind)
    for sid in data:
        eng.open(sid)
    if drip:     # ragged arrival: submit in drip-sized chunks, stepping
        offs = {sid: 0 for sid in data}
        while any(offs[s] < len(data[s]) for s in data):
            for sid, x in data.items():
                if offs[sid] < len(x):
                    eng.submit(sid, x[offs[sid]:offs[sid] + drip])
                    offs[sid] += drip
                    if offs[sid] >= len(x):
                        eng.close(sid)
            eng.step()
    else:
        for sid, x in data.items():
            assert eng.submit(sid, x)
            eng.close(sid)
    eng.drain()
    return eng


def _leaves(state):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(state)]


# ---------------------------------------------------------------------------
# isolation property
# ---------------------------------------------------------------------------


@settings(max_examples=6)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=3, max_value=40),
       st.integers(min_value=0, max_value=10_000))
def test_isolation_solo_interleaved_evict_restore(n_extra, T, seed):
    """Session s0's outputs and final state: solo == interleaved with
    strangers == interleaved under a 1-byte cache (every window evicts
    and restores) — exact equality, both engines."""
    data = _streams(1 + n_extra, T, seed)
    for kind in ("batched", "naive"):
        solo = _run(kind, {"s0": data["s0"]})
        inter = _run(kind, data)
        evict = _run(kind, data, cache_bytes=1)
        if len(data) > 1:
            assert evict.metrics.cache_evictions > 0
        np.testing.assert_array_equal(solo.outputs("s0"),
                                      inter.outputs("s0"))
        np.testing.assert_array_equal(solo.outputs("s0"),
                                      evict.outputs("s0"))
        for a, b in zip(_leaves(solo.state_of("s0")),
                        _leaves(inter.state_of("s0"))):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(_leaves(solo.state_of("s0")),
                        _leaves(evict.state_of("s0"))):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kind", ["batched", "naive"])
def test_ragged_arrival_matches_bulk(kind):
    """Dripping uneven chunks through interleaved steps produces exactly
    the bulk-submitted trajectory (scheduling never changes numerics)."""
    data = _streams(5, 37, seed=7)
    bulk = _run(kind, data)
    drip = _run(kind, data, drip=5)
    for sid in data:
        np.testing.assert_array_equal(bulk.outputs(sid), drip.outputs(sid))
        assert bulk.outputs(sid).shape == (37, 5)
        assert bulk.finished(sid)


def test_learned_weights_stay_per_session():
    """With learn=True each session owns its synapse weights: s0's learned
    tensors are bit-identical solo vs interleaved, and differ from a
    stranger fed different spikes (no batch-summed contamination)."""
    model = _plastic_model()
    data = _streams(3, 20, seed=3, n_in=10)
    solo = _run("batched", {"s0": data["s0"]}, learn=True, model=model)
    inter = _run("batched", data, learn=True, model=model)

    def syn_w(eng, sid):
        st_ = eng.state_of(sid)
        return {(n, k): np.asarray(v["w"]) for n, d in st_.items()
                for k, v in d.items() if k.startswith("syn:")}

    ws, wi = syn_w(solo, "s0"), syn_w(inter, "s0")
    assert ws, "plastic model produced no syn entries"
    for k in ws:
        np.testing.assert_array_equal(ws[k], wi[k])
    k = next(iter(ws))
    assert not np.array_equal(syn_w(inter, "s1")[k], wi[k])
    np.testing.assert_array_equal(solo.outputs("s0"), inter.outputs("s0"))


def test_compile_fail_degrades_without_contamination():
    """Under a forced-pallas compile_fail world the engine serves through
    the dispatch fallback chain (incidents recorded, nothing raises) and
    the isolation invariant still holds inside that world."""
    data = _streams(3, 19, seed=11)
    clear()
    with forced_pallas(), faults.inject("compile_fail:kernels=*"):
        solo = _run("batched", {"s0": data["s0"]})
        inter = _run("batched", data)
    assert incidents(kind="dispatch"), "fallback chain never engaged"
    np.testing.assert_array_equal(solo.outputs("s0"), inter.outputs("s0"))
    assert inter.outputs("s1").shape == (19, 5)


def test_fault_world_retraces_resident_step():
    """The resident-step cache keys on the ambient fault spec: a clean
    run, then the same shapes inside faults.inject, must not replay the
    clean executable (the fault world traces fresh and records dispatch
    incidents). window=5 is unique to this test so neither world's step
    was traced by an earlier test."""
    data = _streams(2, 16, seed=5)
    _run("batched", data, window=5)             # populate clean-world cache
    clear()
    with forced_pallas(), faults.inject("compile_fail:kernels=*"):
        _run("batched", data, window=5)
    assert incidents(kind="dispatch")


# ---------------------------------------------------------------------------
# state cache
# ---------------------------------------------------------------------------


def _toy_state(v, n=4):
    return {"node": {"mem": jnp.full((1, n), float(v), jnp.float32),
                     "out": jnp.zeros((1, n), jnp.float32)}}


def test_cache_lru_spills_and_restores_bit_identical():
    m = ServeMetrics()
    nbytes = 2 * 4 * 4                          # two (1,4) float32 leaves
    cache = StateCache(budget_bytes=2 * nbytes, metrics=m)
    for i in range(3):
        cache.put(f"s{i}", _toy_state(i))
    assert cache.hot_bytes <= 2 * nbytes
    assert cache.spilled == ("s0",)             # LRU spilled first
    assert m.cache_evictions == 1
    got = cache.get("s0")                       # restore refreshes recency
    np.testing.assert_array_equal(np.asarray(got["node"]["mem"]),
                                  np.full((1, 4), 0.0))
    assert isinstance(got["node"]["mem"], jax.Array)
    assert not cache.is_spilled("s0")
    assert m.cache_misses == 1 and m.cache_restores == 1
    assert "s0" not in cache.spilled and len(cache.spilled) == 1


def test_cache_unbounded_never_spills():
    cache = StateCache(budget_bytes=None)
    for i in range(20):
        cache.put(f"s{i}", _toy_state(i))
    assert cache.spilled == ()


def test_cache_budget_smaller_than_one_session_still_serves():
    m = ServeMetrics()
    cache = StateCache(budget_bytes=1, metrics=m)
    cache.put("a", _toy_state(1))
    cache.put("b", _toy_state(2))
    got = cache.get("a")                        # the active session stays hot
    assert not cache.is_spilled("a") and cache.is_spilled("b")
    np.testing.assert_array_equal(np.asarray(got["node"]["mem"]),
                                  np.full((1, 4), 1.0))


def test_cache_rejects_bad_budget():
    with pytest.raises(ValueError, match="budget_bytes"):
        StateCache(budget_bytes=0)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def test_scheduler_round_robin_fairness():
    """A firehose session cannot starve a trickle session: with one slot
    per cohort, service alternates between two ready sessions."""
    sch = Scheduler(window=4, n_in=2)
    for sid in ("hog", "meek"):
        sch.open(sid)
        sch.submit(sid, np.ones((40, 2), np.float32))
    order = [sch.next_cohort(1)[0][0].sid for _ in range(6)]
    assert order == ["hog", "meek", "hog", "meek", "hog", "meek"]


def test_scheduler_backpressure_rejects_and_records():
    clear()
    m = ServeMetrics()
    sch = Scheduler(window=4, n_in=2, queue_limit=2, metrics=m)
    sch.open("a")
    assert sch.submit("a", np.ones((8, 2), np.float32))      # 2 windows
    assert not sch.submit("a", np.ones((8, 2), np.float32))  # would be 4
    assert m.chunks_rejected == 1 and m.chunks_admitted == 1
    evs = incidents(kind="serve")
    assert evs and evs[-1].stage == "admission"
    # draining frees budget (one window per session per cohort — fair
    # round-robin — so two cohorts empty the queue); the submit now fits
    sch.next_cohort(4)
    sch.next_cohort(4)
    assert sch.pending_windows == 0
    assert sch.submit("a", np.ones((8, 2), np.float32))


def test_scheduler_partial_tail_only_after_close():
    sch = Scheduler(window=8, n_in=3)
    s = sch.open("a")
    sch.submit("a", np.ones((5, 3), np.float32))
    assert not s.ready(8)                       # open partial: not runnable
    assert sch.next_cohort(4) == []
    sch.close("a")
    cohort = sch.next_cohort(4)
    assert len(cohort) == 1
    _, x, valid = cohort[0]
    assert x.shape == (8, 3) and valid == 5
    np.testing.assert_array_equal(x[5:], np.zeros((3, 3)))
    assert s.finished


def test_session_rejects_bad_chunks():
    s = Session(sid="x", n_in=4)
    with pytest.raises(ValueError, match="chunk shape"):
        s.push(np.ones((3, 5), np.float32))
    s.closed = True
    with pytest.raises(ValueError, match="closed"):
        s.push(np.ones((3, 4), np.float32))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_exact_quantiles():
    h = Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    assert h.quantile(0.50) == 50.0
    assert h.quantile(0.99) == 99.0
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["max"] == 100.0
    assert snap["mean"] == pytest.approx(50.5)


def test_metrics_publish_records_not_raises_under_strict():
    clear()
    data = _streams(2, 16, seed=2)
    eng = _run("batched", data)
    with env(REPRO_STRICT="1"):
        eng.publish_metrics()                   # record(), never degrade()
    evs = incidents(kind="serve")
    assert any(e.stage == "metrics" for e in evs)
    snap = eng.stats()
    assert snap["steps_run"] == 32 and snap["sessions_finished"] == 2
    assert 0.0 < snap["occupancy"]["mean"] <= 1.0
    assert snap["cache_hit_rate"] == 1.0        # unbounded cache: all hot


# ---------------------------------------------------------------------------
# long-prompt admission (loop.py)
# ---------------------------------------------------------------------------


def test_admit_truncates_to_most_recent_tokens():
    scfg = ServeConfig(max_seq=16)
    r = Request(np.arange(30, dtype=np.int32), max_new=4)
    clear()
    admitted, notes = _admit([r], scfg)
    assert len(admitted[0].prompt) == 12        # max_seq - max_new
    np.testing.assert_array_equal(admitted[0].prompt,
                                  np.arange(18, 30, dtype=np.int32))
    assert "truncated" in notes[0]
    assert any(e.stage == "admission" for e in incidents(kind="serve"))


def test_admit_reject_policy_refuses():
    scfg = ServeConfig(max_seq=16, long_prompt="reject")
    r = Request(np.arange(30, dtype=np.int32), max_new=4)
    admitted, notes = _admit([r], scfg)
    assert admitted == [None] and "rejected" in notes[0]
    short = Request(np.arange(5, dtype=np.int32), max_new=4)
    admitted, notes = _admit([short], scfg)
    assert admitted[0] is short and notes[0] is None


def test_generate_raises_on_rejected_prompt_before_model_runs():
    scfg = ServeConfig(max_seq=8, long_prompt="reject")
    reqs = [Request(np.arange(30, dtype=np.int32), max_new=4)]
    with pytest.raises(ValueError, match="refused at admission"):
        generate(None, SimpleNamespace(family="dense"), reqs, scfg)


def test_admit_unknown_policy_raises():
    scfg = ServeConfig(max_seq=8, long_prompt="shrug")
    with pytest.raises(ValueError, match="long_prompt"):
        _admit([Request(np.arange(30, dtype=np.int32))], scfg)


# ---------------------------------------------------------------------------
# TB5xx serve checks
# ---------------------------------------------------------------------------


def test_check_serve_clean_for_sane_deployment():
    nodes, params = _model()
    fp = analysis.session_footprint(nodes, params)
    cfg = EngineConfig(window=W, capacity=C, queue_limit=32,
                       cache_bytes=C * fp)
    assert analysis.check_serve(nodes, params, cfg) == []


def test_check_serve_flags_budget_and_queue():
    nodes, params = _model()
    fp = analysis.session_footprint(nodes, params)
    cfg = SimpleNamespace(window=W, capacity=C, queue_limit=C - 1,
                          cache_bytes=fp - 1)
    codes = {d.code for d in analysis.check_serve(nodes, params, cfg)}
    assert {"TB501", "TB504"} <= codes
    cfg = SimpleNamespace(window=W, capacity=C, queue_limit=None,
                          cache_bytes=C * fp - 1)
    codes = {d.code for d in analysis.check_serve(nodes, params, cfg)}
    assert "TB502" in codes and "TB501" not in codes


def test_check_serve_flags_invalid_config():
    nodes, params = _model()
    cfg = SimpleNamespace(window=0, capacity=-1, queue_limit=0,
                          cache_bytes=0)
    diags = analysis.check_serve(nodes, params, cfg)
    assert {d.code for d in diags} == {"TB505"}
    assert len(diags) == 4 and all(d.severity == "error" for d in diags)
