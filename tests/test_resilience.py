"""Resilient-execution tests: the dispatch fallback chain, strict mode,
numerical guardrails, autotune degradation, serve retry/deadline handling,
and the perf gate's corrupt-artifact tolerance."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import compare as cmp
from repro.core import faults, guards, plan, plasticity
from repro.kernels import registry, tuning
from repro.kernels.incidents import (FallbackError, clear, incidents,
                                     strict_mode)
from tests._faults import dh_net, env, forced_pallas, plastic_net, spikes


# ---------------------------------------------------------------------------
# dispatch fallback chain
# ---------------------------------------------------------------------------


def _linrec_args(key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    k1, k2 = jax.random.split(key)
    a = jnp.full((16, 4, 32), 0.9, jnp.float32)
    x = jax.random.normal(k1, (16, 4, 32))
    h0 = jax.random.normal(k2, (4, 32))
    return a, x, h0


def test_forced_pallas_failure_degrades_bit_identical_to_ref():
    args = _linrec_args()
    with env(REPRO_KERNEL_IMPL="ref"), faults.inject(""):
        ref = registry.dispatch("linrec", args)
    clear()
    with forced_pallas(), faults.inject("compile_fail:kernels=linrec"):
        out = registry.dispatch("linrec", args)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))
    evs = incidents(family="linrec", kind="dispatch")
    assert len(evs) == 1                       # exactly one degradation
    assert evs[0].stage == "pallas"
    assert "FaultInjectedError" in evs[0].error
    assert evs[0].dims and evs[0].blocks       # structured context rode along


def test_untargeted_kernels_do_not_degrade():
    clear()
    with forced_pallas(), faults.inject("compile_fail:kernels=attention"):
        registry.dispatch("linrec", _linrec_args())
    assert incidents(kind="dispatch") == ()


def test_strict_mode_turns_degradation_into_raise():
    # strict is set AFTER forced_pallas (which clears ambient strict)
    with forced_pallas(), env(REPRO_STRICT="1"), \
            faults.inject("compile_fail:kernels=linrec"):
        assert strict_mode()
        with pytest.raises(FallbackError, match="linrec"):
            registry.dispatch("linrec", _linrec_args())


def test_vmem_pressure_rejects_pallas_and_runs_ref():
    args = _linrec_args()
    with env(REPRO_KERNEL_IMPL="ref"), faults.inject(""):
        ref = registry.dispatch("linrec", args)
    clear()
    with forced_pallas(), faults.inject("vmem_limit:mb=0.0001"):
        out = registry.dispatch("linrec", args)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))
    evs = incidents(family="linrec", kind="vmem")
    assert len(evs) == 1 and evs[0].stage == "vmem-model"


def test_plan_run_completes_under_total_kernel_failure():
    """The acceptance scenario: every Pallas kernel failing to compile
    must leave plan.run bit-identical to the pure-ref path, with the
    degradations on the incident log; REPRO_STRICT=1 makes it raise."""
    nodes, params = dh_net()
    x = spikes(jax.random.PRNGKey(1))
    with env(REPRO_KERNEL_IMPL="ref"), faults.inject(""):
        _, ref_out, _ = plan.run(nodes, params, x)
    clear()
    with forced_pallas(), faults.inject("compile_fail:kernels=*"):
        _, out, _ = plan.run(nodes, params, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    families = {e.family for e in incidents(kind="dispatch")}
    assert {"linrec", "lif", "spikemm"} <= families
    with forced_pallas(), env(REPRO_STRICT="1"), \
            faults.inject("compile_fail:kernels=*"):
        with pytest.raises(FallbackError):
            plan.run(nodes, params, x)


# ---------------------------------------------------------------------------
# numerical guardrails
# ---------------------------------------------------------------------------


def test_guard_off_by_default_and_env_resolution(monkeypatch):
    assert not guards.config(None).active
    monkeypatch.setenv("REPRO_GUARD", "warn")
    assert guards.config(None).policy == "warn"
    with pytest.raises(ValueError, match="REPRO_GUARD"):
        guards.config("shrug")


def test_guard_sanitize_repairs_nonfinite_input():
    nodes, params = dh_net()
    x = spikes(jax.random.PRNGKey(1)).at[0, 0, 0].set(jnp.nan)
    with faults.inject(""):
        _, out, _ = plan.run(nodes, params, x, guard="sanitize")
    assert bool(jnp.isfinite(out).all())


def test_guard_warn_records_incident_and_raise_raises():
    nodes, params = dh_net()
    x = spikes(jax.random.PRNGKey(1))
    bad = {k: dict(v) for k, v in params.items()}
    bad["hidden"]["w_input"] = bad["hidden"]["w_input"].at[0, 0, 0].set(
        jnp.nan)
    clear()
    with faults.inject(""), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        plan.run(nodes, bad, x, guard="warn")
    assert incidents(kind="guard")
    with faults.inject(""):
        with pytest.raises(guards.GuardViolation, match="nonfinite"):
            plan.run(nodes, bad, x, guard="raise")


def test_guard_flags_silent_population():
    nodes, params = dh_net()
    x = jnp.zeros((12, 4, 32))                  # no input -> no spikes
    clear()
    with faults.inject(""), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        plan.run(nodes, params, x, guard="warn")
    assert any(e.error.startswith("population silent")
               for e in incidents(kind="guard"))


def test_guard_learned_rolls_back_diverged_window():
    w0 = jnp.ones((8, 8))
    cfg = guards.GuardConfig(policy="sanitize")
    # nonfinite entries fall back elementwise
    w1 = w0.at[0, 0].set(jnp.nan)
    fixed = guards.guard_learned("t", w0, w1, cfg)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(w0))
    # a norm explosion rolls the whole window back
    blown = 1e6 * w0
    np.testing.assert_array_equal(
        np.asarray(guards.guard_learned("t", w0, blown, cfg)),
        np.asarray(w0))
    # a sane update passes through untouched
    ok = 1.5 * w0
    np.testing.assert_array_equal(
        np.asarray(guards.guard_learned("t", w0, ok, cfg)), np.asarray(ok))


def test_guard_learned_in_plan_run():
    """A plasticity rule driven into NaN territory publishes the entry
    weights under sanitize instead of a poisoned window."""
    nodes, params = plastic_net()
    params = {k: dict(v) for k, v in params.items()}
    params["hidden"]["w_input"] = params["hidden"]["w_input"].at[0, 0].set(
        jnp.nan)
    x = spikes(jax.random.PRNGKey(2), n=24)
    with faults.inject(""):
        state, _, _ = plan.run(nodes, params, x,
                               guard=guards.GuardConfig(policy="sanitize"))
    w1 = state["hidden"]["syn:input"]["w"]
    assert bool(jnp.isfinite(w1).all())


# ---------------------------------------------------------------------------
# autotuner degradation
# ---------------------------------------------------------------------------


def test_autotune_records_infeasible_candidates_and_continues(tmp_path):
    cache = tuning.TuningCache(str(tmp_path / "cache.json"))
    clear()
    with env(REPRO_STRICT=None), \
            faults.inject("compile_fail:kernels=linrec,autotune=1"):
        blocks, report = tuning.autotune("linrec", cache=cache, repeats=1,
                                         save=False)
    assert blocks                                # spec defaults came back
    assert report["winner"]["degraded"] is True
    assert all(t.get("infeasible") for t in report["timings"])
    assert incidents(family="linrec", kind="autotune")


def test_autotune_without_autotune_flag_is_unaffected(tmp_path):
    cache = tuning.TuningCache(str(tmp_path / "cache.json"))
    with faults.inject("compile_fail:kernels=linrec"):   # dispatch-only fault
        blocks, report = tuning.autotune("linrec", cache=cache, repeats=1,
                                         save=False)
    assert report["winner"].get("degraded") is None
    assert report["winner"]["best_s"] is not None


def test_autotune_strict_raises_on_total_failure(tmp_path):
    cache = tuning.TuningCache(str(tmp_path / "cache.json"))
    with env(REPRO_STRICT="1"), \
            faults.inject("compile_fail:kernels=linrec,autotune=1"):
        with pytest.raises(FallbackError):
            tuning.autotune("linrec", cache=cache, repeats=1, save=False)


# ---------------------------------------------------------------------------
# serve: retries, degradation flags, deadlines
# ---------------------------------------------------------------------------


def _serve_fixture():
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve.loop import Request, ServeConfig

    cfg = get_smoke_config("llama3.2-3b").replace(dtype="float32")
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(1, 200, size=n).astype(np.int32), max_new=4)
            for n in (5, 3, 7)]
    return cfg, params, reqs, ServeConfig


@pytest.mark.slow
def test_generate_resilient_healthy_matches_generate():
    from repro.serve.loop import generate, generate_resilient

    cfg, params, reqs, ServeConfig = _serve_fixture()
    scfg = ServeConfig(batch=2, max_seq=32)
    plain = generate(params, cfg, reqs, scfg)
    res = generate_resilient(params, cfg, reqs, scfg)
    assert len(res) == len(plain)
    for p, r in zip(plain, res):
        np.testing.assert_array_equal(p, r.tokens)
        assert not r.degraded and r.retries == 0 and r.error is None


@pytest.mark.slow
def test_generate_resilient_exhausted_retries_degrade(monkeypatch):
    from repro.serve import loop as serve_loop

    cfg, params, reqs, ServeConfig = _serve_fixture()
    calls = {"n": 0}

    def boom(*a, **kw):
        calls["n"] += 1
        raise RuntimeError("injected serve failure")

    monkeypatch.setattr(serve_loop, "_generate_cohort", boom)
    monkeypatch.delenv("REPRO_STRICT", raising=False)
    clear()
    scfg = ServeConfig(batch=2, max_seq=32, max_retries=2,
                       retry_base_s=0.0)
    res = serve_loop.generate_resilient(params, cfg, reqs, scfg)
    assert len(res) == len(reqs)
    assert all(r.degraded and r.tokens.size == 0 for r in res)
    assert all("injected serve failure" in r.error for r in res)
    assert calls["n"] == 2 * 3                  # 2 cohorts x (1 + 2 retries)
    assert len(incidents(kind="serve")) == 6


@pytest.mark.slow
def test_generate_resilient_strict_propagates(monkeypatch):
    from repro.serve import loop as serve_loop

    cfg, params, reqs, ServeConfig = _serve_fixture()
    monkeypatch.setattr(serve_loop, "_generate_cohort",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    with env(REPRO_STRICT="1"):
        with pytest.raises(RuntimeError, match="boom"):
            serve_loop.generate_resilient(
                params, cfg, reqs, ServeConfig(batch=2, max_seq=32))


@pytest.mark.slow
def test_generate_resilient_deadline_flags_late_responses():
    from repro.serve.loop import generate_resilient

    cfg, params, reqs, ServeConfig = _serve_fixture()
    scfg = ServeConfig(batch=8, max_seq=32, deadline_s=0.0)
    clear()
    res = generate_resilient(params, cfg, reqs, scfg)
    assert all(r.degraded for r in res)         # everything misses 0s
    assert all(r.tokens.size > 0 for r in res)  # but the answers are intact
    assert any(e.stage == "deadline" for e in incidents(kind="serve"))


# ---------------------------------------------------------------------------
# perf gate tolerance
# ---------------------------------------------------------------------------


def test_compare_tolerates_corrupt_bench_file(tmp_path, capsys):
    (tmp_path / "BENCH_kernels.json").write_text("{not json")
    assert cmp.load_suite(str(tmp_path), "kernels") is None
    assert "unreadable bench file" in capsys.readouterr().out


def test_compare_missing_rows_warn_with_update_hint(tmp_path, capsys):
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    (baselines / "tracked.json").write_text(json.dumps({"tracked": [
        {"suite": "kernels", "path": "a/b", "direction": "higher"}]}))
    fresh = tmp_path / "fresh"
    fresh.mkdir()
    rc = cmp.main([str(fresh), "--baselines", str(baselines), "--gate"])
    out = capsys.readouterr().out
    assert rc == 0                              # missing rows never gate
    assert "missing" in out
    assert "--update-baselines" in out


def test_chunked_online_survives_guarded_faults():
    """End-to-end graceful degradation: a plastic stream under packet loss
    + dead rows + guards keeps producing finite weights every window."""
    nodes, params = plastic_net()
    key = jax.random.PRNGKey(0)
    with faults.inject("drop_blocks:p=0.2,seed=1;dead_rows:frac=0.1,seed=2"):
        for w in range(3):
            x = spikes(jax.random.fold_in(key, w), n=24)
            state, _, _ = plan.run(nodes, params, x, guard="sanitize")
            params = plasticity.apply_learned(nodes, params, state)
            assert bool(jnp.isfinite(params["hidden"]["w_input"]).all())
