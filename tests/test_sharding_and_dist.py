"""Distribution-layer tests: sharding rules, HLO collective parser,
multi-device lowering in a subprocess (8 fake devices), gradient
compression, serving loop, behavioural simulator."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.roofline.hlo import collective_bytes
from repro.sharding import rules


def test_param_specs_match_rules():
    params = {
        "embed": {"tok": jnp.zeros((128, 32)), "head": jnp.zeros((32, 128))},
        "layers": {"attn": {"wq": jnp.zeros((4, 32, 64)),
                            "wo": jnp.zeros((4, 64, 32))},
                   "mlp": {"w_gate": jnp.zeros((4, 32, 96)),
                           "w_down": jnp.zeros((4, 96, 32))},
                   "norm1": jnp.zeros((4, 32))},
    }
    specs = rules.param_specs(params)
    assert specs["embed"]["tok"] == PartitionSpec("model", None)
    assert specs["layers"]["attn"]["wq"] == PartitionSpec(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == PartitionSpec(None, "model", None)
    assert specs["layers"]["mlp"]["w_down"] == PartitionSpec(None, "model", None)
    assert specs["layers"]["norm1"] == PartitionSpec(None, None)


def test_fsdp_adds_data_axis():
    params = {"layers": {"mlp": {"w_gate": jnp.zeros((4, 32, 96))}}}
    specs = rules.param_specs(params, fsdp=True)
    assert specs["layers"]["mlp"]["w_gate"] == \
        PartitionSpec(None, "data", "model")


def test_state_specs_share_param_rules():
    state = {"params": {"embed": {"tok": jnp.zeros((128, 32))}},
             "mu": {"embed": {"tok": jnp.zeros((128, 32))}},
             "nu": {"embed": {"tok": jnp.zeros((128, 32))}},
             "step": jnp.int32(0)}
    specs = rules.state_specs(state)
    assert specs["params"]["embed"]["tok"] == specs["mu"]["embed"]["tok"] \
        == PartitionSpec("model", None)
    assert specs["step"] == PartitionSpec()


def test_collective_parser_counts_known_hlo():
    hlo = textwrap.dedent("""
    HloModule test
    ENTRY %main (p0: f32[256,128]) -> f32[256,128] {
      %p0 = f32[256,128]{1,0} parameter(0)
      %ar = f32[256,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
      %ag = f32[512,128]{1,0} all-gather(%ar), dimensions={0}
      ROOT %cp = f32[256,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
    }
    """)
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 128 * 4
    assert out["all-gather"] == 512 * 128 * 4
    assert out["collective-permute"] == 256 * 128 * 4
    assert out["total_bytes"] == (256 + 512 + 256) * 128 * 4


def test_collective_parser_scales_by_trip_count():
    hlo = textwrap.dedent("""
    HloModule test
    %body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
      %p = (s32[], f32[64]) parameter(0)
      %x = f32[64]{0} get-tuple-element(%p), index=1
      %ar = f32[64]{0} all-reduce(%x), to_apply=%add
      ROOT %t = (s32[], f32[64]) tuple(%i, %ar)
    }
    ENTRY %main (p0: f32[64]) -> f32[64] {
      %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
      ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
    }
    """)
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 64 * 4 * 12


DRYRUN_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_mesh
from repro.sharding import rules

mesh = make_mesh((2, 4), ("data", "model"))
rules.set_mesh(mesh)
cfg = get_smoke_config("{arch}").replace(
    d_model=128, d_ff=256, n_heads=8, n_kv_heads=8 if "{arch}" != "qwen2-1.5b" else 2,
    vocab_size=512)
mode, inputs, shardings = specs_mod.cell_inputs(cfg, "{shape}", mesh)
step = specs_mod.step_fn_for(cfg, mode)
compiled = jax.jit(step, in_shardings=shardings).lower(*inputs).compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # jax<0.5 returns a per-device list
    cost = cost[0] if cost else {{}}
print(json.dumps({{"flops": cost.get("flops", 0.0), "ok": True}}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("olmoe-1b-7b", "train_4k"),
    ("rwkv6-3b", "train_4k"),      # pure_dp (ZeRO-3) lowering
    ("rwkv6-3b", "decode_32k"),    # decode keeps TP under pure_dp
])
def test_dryrun_tiny_mesh_subprocess(arch, shape):
    """The dry-run machinery on an 8-device fake mesh (subprocess so the
    device-count override can't leak into other tests)."""
    code = DRYRUN_SUBPROCESS.format(arch=arch, shape=shape)
    # shrink the shapes via SHAPES override? cells use full shapes; instead
    # patch SHAPES in-process to tiny values:
    code = code.replace(
        'mode, inputs, shardings',
        'from repro.models.config import SHAPES, ShapeConfig\n'
        'import repro.models.config as mc\n'
        'mc.SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 8, "train")\n'
        'mc.SHAPES["decode_32k"] = ShapeConfig("decode_32k", 64, 8, "decode")\n'
        'mode, inputs, shardings')
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0


def test_grad_compression_unbiased():
    from repro.optim.compression import compress_grads
    g = {"w": jnp.linspace(-1, 1, 1024).reshape(32, 32)}
    keys = [jax.random.PRNGKey(i) for i in range(32)]
    outs = jnp.stack([compress_grads(g, k)["w"] for k in keys])
    err = jnp.mean(outs, 0) - g["w"]
    assert float(jnp.max(jnp.abs(err))) < 4e-3     # unbiased estimator
    # and each sample is within one quantization step
    step = 2.0 / 254
    assert float(jnp.max(jnp.abs(outs[0] - g["w"]))) <= step * 1.05


def test_serving_loop_greedy_consistent():
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve.loop import Request, ServeConfig, generate
    cfg = get_smoke_config("qwen2-1.5b").replace(dtype="float32")
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(p, max_new=4) for p in prompts]
    outs = generate(params, cfg, reqs, ServeConfig(batch=2, max_seq=32))
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)
    # same request twice -> same greedy tokens
    outs2 = generate(params, cfg, [Request(prompts[0], max_new=4)],
                     ServeConfig(batch=1, max_seq=32))
    np.testing.assert_array_equal(outs[0], outs2[0])


def test_simulator_energy_and_gpu_comparison():
    from repro.core.simulator import LayerStats, energy_per_sop, simulate
    layers = [LayerStats("h", 4096, 1024, 0.02, 2 * 4096 * 1024)]
    rep = simulate(layers, timesteps=100)
    assert rep.power_w < 2.5                     # chip-class power
    assert rep.efficiency_x > 10                 # beats dense GPU on sparse
    assert 0.1 < energy_per_sop(rep) < 100
    # higher spike rate -> more energy, lower efficiency (paper §V-C1)
    rep_hot = simulate([LayerStats("h", 4096, 1024, 0.33,
                                   2 * 4096 * 1024)], timesteps=100)
    assert rep_hot.energy_j > rep.energy_j
    assert rep_hot.efficiency_x < rep.efficiency_x
