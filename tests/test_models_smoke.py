"""Per-assigned-architecture smoke tests (reduced same-family configs):
one forward + one train step + one decode step on CPU, asserting shapes and
finiteness; decode-vs-forward consistency for each family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import lm
from repro.optim.adamw import AdamWConfig

KEY = jax.random.PRNGKey(0)
B, T = 2, 16


def _batch(cfg):
    b = {"tokens": jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(KEY, (B, cfg.encoder_len, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (B, cfg.n_patches, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    params = lm.model_init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = lm.model_forward(params, batch, cfg)
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.family == "moe":
        assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # >= perfect balance


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    state = lm.init_train_state(KEY, cfg)
    step = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state, m = step(state, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    leaves = jax.tree.leaves(state["params"])
    assert all(bool(jnp.all(jnp.isfinite(leaf))) for leaf in leaves)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-3b", "zamba2-1.2b",
                                  "olmoe-1b-7b", "whisper-small"])
def test_decode_consistent_with_forward(arch):
    """Teacher-forced decode (token by token through the cache path) must
    reproduce the full-sequence forward logits for every family."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    if cfg.family == "moe":
        # decode-vs-forward consistency holds when no token is capacity-
        # dropped; give the router ample slots for the comparison
        cfg = cfg.replace(capacity_factor=8.0)
    params = lm.model_init(KEY, cfg)
    batch = _batch(cfg)
    toks = batch["tokens"][:, :-1]

    if cfg.family == "encdec":
        from repro.models import encdec
        memory = encdec.encode(params, batch["frames"].astype(cfg.dtype), cfg)
        full = encdec.decode_forward(params, toks, memory, cfg)
    else:
        full, _ = lm.model_forward(params, batch, cfg)
        if cfg.family == "vlm":
            pass  # patch prefix already stripped by model_forward

    cache = lm.init_cache(cfg, B, T + 8)
    if cfg.family == "encdec":
        cache = encdec.prefill_cross(params, memory, cache, cfg)
    from repro.models import transformer as tf_mod
    from repro.models import encdec as encdec_mod
    step_logits = []
    for t in range(T):
        if cfg.family == "encdec":
            lg, cache = encdec_mod.decode_step(params, toks[:, t:t+1], cache,
                                               jnp.asarray(t), cfg)
        else:
            lg, cache = tf_mod.decode_step(params, toks[:, t:t+1], cache,
                                           jnp.asarray(t), cfg)
        step_logits.append(lg[:, 0])
    dec = jnp.stack(step_logits, axis=1)

    if cfg.family == "vlm":
        # forward path prepends patches; compare text-only stream decoded
        # without patches against a text-only forward
        full, _ = lm.model_forward(params, {"tokens": batch["tokens"]}, cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_minicpm_residual_scaling_applied():
    cfg = get_smoke_config("minicpm-2b")
    assert 0 < cfg.residual_scale < 1


def test_qwen2_has_qkv_bias():
    cfg = get_smoke_config("qwen2-1.5b").replace(dtype="float32")
    params = lm.model_init(KEY, cfg)
    assert "bq" in jax.tree_util.tree_leaves_with_path(params)[0][0][0].key \
        or any("bq" in str(p) for p, _ in
               jax.tree_util.tree_leaves_with_path(params))


def test_spiking_ffn_variant_trains():
    """The paper's technique composed onto an LM: binarized (spiking) FFN
    activations with surrogate grads still train."""
    cfg = get_smoke_config("qwen2-1.5b").replace(dtype="float32",
                                                 spiking_ffn=True)
    state = lm.init_train_state(KEY, cfg)
    step = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=1e-3)))
    batch = _batch(cfg)
    l0 = None
    for i in range(8):
        state, m = step(state, batch)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0
