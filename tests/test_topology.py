"""Topology-table tests: every encoder's event-driven propagate() must equal
the dense linear map it encodes, and the storage accounting must show the
paper's compression ordering (Fig. 14)."""

import numpy as np

from repro.core import topology as topo


def test_fc_propagate_matches_dense(rng):
    w = rng.standard_normal((40, 30)).astype(np.float32)
    enc = topo.encode_fc(w, n_cores=4)
    spikes = (rng.random(40) < 0.3).astype(np.float32)
    np.testing.assert_allclose(enc.propagate(spikes), spikes @ w,
                               rtol=1e-5, atol=1e-5)


def test_fc_storage_is_four_fields_per_core():
    w = np.zeros((1000, 4096), np.float32)
    enc = topo.encode_fc(w, n_cores=8)
    # type-2 IE: 4 fields regardless of destination count (paper Fig. 6)
    per_ie = (topo.BITS["coding_mask"] + topo.BITS["margin"]
              + topo.BITS["count"] + topo.BITS["neuron_id"])
    assert enc.fan_in_bits() <= 8 * per_ie + 200     # + one DE header
    assert enc.baseline_bits() > enc.fan_in_bits() * 1000


def test_conv_propagate_matches_im2col(rng):
    c_in, c_out, k, h, w = 2, 3, 3, 6, 5
    filt = rng.standard_normal((c_out, c_in, k, k)).astype(np.float32)
    enc = topo.encode_conv(filt, h, w, stride=1, pad=1)
    spikes = (rng.random(c_in * h * w) < 0.4).astype(np.float32)
    out = enc.propagate(spikes)
    # dense reference via explicit convolution of the spike image
    img = spikes.reshape(c_in, h, w)
    ref = np.zeros((c_out, h, w), np.float32)
    for co in range(c_out):
        for ci in range(c_in):
            for y in range(h):
                for x in range(w):
                    for ky in range(k):
                        for kx in range(k):
                            yy, xx = y + ky - 1, x + kx - 1
                            if 0 <= yy < h and 0 <= xx < w:
                                ref[co, y, x] += img[ci, yy, xx] * filt[co, ci, ky, kx]
    np.testing.assert_allclose(out, ref.reshape(-1), rtol=1e-4, atol=1e-4)


def test_conv_storage_independent_of_channels():
    """Type-3 decoupled addressing: IE count ∝ single-channel positions,
    NOT channels (the mechanism behind the 286-947x reduction)."""
    f_small = np.zeros((4, 2, 3, 3), np.float32)
    f_big = np.zeros((256, 128, 3, 3), np.float32)
    e_small = topo.encode_conv(f_small, 8, 8, 1, 1)
    e_big = topo.encode_conv(f_big, 8, 8, 1, 1)
    assert e_small.fan_in_bits() == e_big.fan_in_bits()
    # the baseline (unrolled) grows with c_in*c_out
    assert e_big.baseline_bits() > 1000 * e_small.baseline_bits()


def test_conv_weight_address_polynomial(rng):
    """paper eq. (4): w_addr = axon_global * k^2 + axon_local."""
    filt = rng.standard_normal((3, 2, 3, 3)).astype(np.float32)
    enc = topo.encode_conv(filt, 5, 5, 1, 1)
    k = 3
    for pos in range(25):
        de = enc.fan_in[pos]
        for ie in de.ies:
            for ax in ie.local_axons:
                for ch in range(2):
                    w_addr = ch * k * k + ax
                    ky, kx = divmod(int(ax), k)
                    assert filt.reshape(3, 2 * k * k)[0, w_addr] == \
                        filt[0, ch, ky, kx]


def test_sparse_propagate_both_types(rng):
    dense = rng.standard_normal((50, 60)).astype(np.float32)
    dense[rng.random((50, 60)) > 0.1] = 0.0      # 10% density
    spikes = (rng.random(50) < 0.3).astype(np.float32)
    for ie_type in (0, 1):
        enc = topo.encode_sparse(dense, ie_type=ie_type)
        np.testing.assert_allclose(enc.propagate(spikes), spikes @ dense,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(enc.dense_equivalent(), dense)


def test_sparse_type0_smaller_type1_faster(rng):
    dense = rng.standard_normal((100, 100)).astype(np.float32)
    dense[rng.random((100, 100)) > 0.05] = 0.0
    t0 = topo.encode_sparse(dense, ie_type=0)
    t1 = topo.encode_sparse(dense, ie_type=1)
    # type 0 stores only neuron IDs -> smaller; type 1 adds local axon IDs
    assert t0.fan_in_bits() < t1.fan_in_bits()


def test_pool_propagate(rng):
    enc = topo.encode_pool(h=6, w=6, c=2, k=2)
    spikes = (rng.random(2 * 36) < 0.5).astype(np.float32)
    out = enc.propagate(spikes)
    img = spikes.reshape(2, 6, 6)
    ref = img.reshape(2, 3, 2, 3, 2).mean((2, 4)).reshape(-1)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_skip_reuses_fanout_no_relay(rng):
    filt = rng.standard_normal((4, 4, 3, 3)).astype(np.float32)
    conv = topo.encode_conv(filt, 8, 8, 1, 1)
    skip = topo.encode_skip(conv, delay=2)
    # delayed-fire adds only the delay bits per fan-out entry (Fig. 8c)
    extra = skip.fan_out_bits() - conv.fan_out_bits()
    assert extra == conv.n_pre * topo.BITS["delay"]
    # relay-neuron alternative costs orders of magnitude more
    assert topo.relay_baseline_bits(conv, 2) > 10 * extra


def test_storage_reduction_reaches_paper_range():
    """Fig. 14: full method vs unrolled baseline = 286-947x on conv nets."""
    from repro.configs.snn_models import MODELS, topology_layers
    specs, name = MODELS["vgg16"]()
    layers = topology_layers(specs)
    ours = sum(t.storage_bits() + t.meta.get("extra_bits", 0) for t in layers)
    base = sum(t.baseline_bits() for t in layers)
    assert base / ours > 100, (name, base / ours)
