"""Topology-table tests: every encoder's event-driven propagate() must equal
the dense linear map it encodes, and the storage accounting must show the
paper's compression ordering (Fig. 14)."""

import numpy as np

from repro.core import topology as topo


def test_fc_propagate_matches_dense(rng):
    w = rng.standard_normal((40, 30)).astype(np.float32)
    enc = topo.encode_fc(w, n_cores=4)
    spikes = (rng.random(40) < 0.3).astype(np.float32)
    np.testing.assert_allclose(enc.propagate(spikes), spikes @ w,
                               rtol=1e-5, atol=1e-5)


def test_fc_storage_is_four_fields_per_core():
    w = np.zeros((1000, 4096), np.float32)
    enc = topo.encode_fc(w, n_cores=8)
    # type-2 IE: 4 fields regardless of destination count (paper Fig. 6)
    per_ie = (topo.BITS["coding_mask"] + topo.BITS["margin"]
              + topo.BITS["count"] + topo.BITS["neuron_id"])
    assert enc.fan_in_bits() <= 8 * per_ie + 200     # + one DE header
    assert enc.baseline_bits() > enc.fan_in_bits() * 1000


def test_conv_propagate_matches_im2col(rng):
    c_in, c_out, k, h, w = 2, 3, 3, 6, 5
    filt = rng.standard_normal((c_out, c_in, k, k)).astype(np.float32)
    enc = topo.encode_conv(filt, h, w, stride=1, pad=1)
    spikes = (rng.random(c_in * h * w) < 0.4).astype(np.float32)
    out = enc.propagate(spikes)
    # dense reference via explicit convolution of the spike image
    img = spikes.reshape(c_in, h, w)
    ref = np.zeros((c_out, h, w), np.float32)
    for co in range(c_out):
        for ci in range(c_in):
            for y in range(h):
                for x in range(w):
                    for ky in range(k):
                        for kx in range(k):
                            yy, xx = y + ky - 1, x + kx - 1
                            if 0 <= yy < h and 0 <= xx < w:
                                ref[co, y, x] += img[ci, yy, xx] * filt[co, ci, ky, kx]
    np.testing.assert_allclose(out, ref.reshape(-1), rtol=1e-4, atol=1e-4)


def test_conv_storage_independent_of_channels():
    """Type-3 decoupled addressing: IE count ∝ single-channel positions,
    NOT channels (the mechanism behind the 286-947x reduction)."""
    f_small = np.zeros((4, 2, 3, 3), np.float32)
    f_big = np.zeros((256, 128, 3, 3), np.float32)
    e_small = topo.encode_conv(f_small, 8, 8, 1, 1)
    e_big = topo.encode_conv(f_big, 8, 8, 1, 1)
    assert e_small.fan_in_bits() == e_big.fan_in_bits()
    # the baseline (unrolled) grows with c_in*c_out
    assert e_big.baseline_bits() > 1000 * e_small.baseline_bits()


def test_conv_weight_address_polynomial(rng):
    """paper eq. (4): w_addr = axon_global * k^2 + axon_local."""
    filt = rng.standard_normal((3, 2, 3, 3)).astype(np.float32)
    enc = topo.encode_conv(filt, 5, 5, 1, 1)
    k = 3
    for pos in range(25):
        de = enc.fan_in[pos]
        for ie in de.ies:
            for ax in ie.local_axons:
                for ch in range(2):
                    w_addr = ch * k * k + ax
                    ky, kx = divmod(int(ax), k)
                    assert filt.reshape(3, 2 * k * k)[0, w_addr] == \
                        filt[0, ch, ky, kx]


def test_sparse_propagate_both_types(rng):
    dense = rng.standard_normal((50, 60)).astype(np.float32)
    dense[rng.random((50, 60)) > 0.1] = 0.0      # 10% density
    spikes = (rng.random(50) < 0.3).astype(np.float32)
    for ie_type in (0, 1):
        enc = topo.encode_sparse(dense, ie_type=ie_type)
        np.testing.assert_allclose(enc.propagate(spikes), spikes @ dense,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(enc.dense_equivalent(), dense)


def test_sparse_type0_smaller_type1_faster(rng):
    dense = rng.standard_normal((100, 100)).astype(np.float32)
    dense[rng.random((100, 100)) > 0.05] = 0.0
    t0 = topo.encode_sparse(dense, ie_type=0)
    t1 = topo.encode_sparse(dense, ie_type=1)
    # type 0 stores only neuron IDs -> smaller; type 1 adds local axon IDs
    assert t0.fan_in_bits() < t1.fan_in_bits()


def test_pool_propagate(rng):
    enc = topo.encode_pool(h=6, w=6, c=2, k=2)
    spikes = (rng.random(2 * 36) < 0.5).astype(np.float32)
    out = enc.propagate(spikes)
    img = spikes.reshape(2, 6, 6)
    ref = img.reshape(2, 3, 2, 3, 2).mean((2, 4)).reshape(-1)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_skip_reuses_fanout_no_relay(rng):
    filt = rng.standard_normal((4, 4, 3, 3)).astype(np.float32)
    conv = topo.encode_conv(filt, 8, 8, 1, 1)
    skip = topo.encode_skip(conv, delay=2)
    # delayed-fire adds only the delay bits per fan-out entry (Fig. 8c)
    extra = skip.fan_out_bits() - conv.fan_out_bits()
    assert extra == conv.n_pre * topo.BITS["delay"]
    # relay-neuron alternative costs orders of magnitude more
    assert topo.relay_baseline_bits(conv, 2) > 10 * extra


def test_storage_reduction_reaches_paper_range():
    """Fig. 14: full method vs unrolled baseline = 286-947x on conv nets."""
    from repro.configs.snn_models import MODELS, topology_layers
    specs, name = MODELS["vgg16"]()
    layers = topology_layers(specs)
    ours = sum(t.storage_bits() + t.meta.get("extra_bits", 0) for t in layers)
    base = sum(t.baseline_bits() for t in layers)
    assert base / ours > 100, (name, base / ours)


# ---------------------------------------------------------------------------
# polymorphic encode() + registry
# ---------------------------------------------------------------------------


def test_encode_dispatches_by_kind_and_inference(rng):
    dense = rng.standard_normal((20, 15)).astype(np.float32)
    sparse = dense * (rng.random((20, 15)) < 0.1)
    filt = rng.standard_normal((3, 2, 3, 3)).astype(np.float32)
    assert topo.encode(dense, kind="fc").kind == "fc"
    assert topo.encode(sparse, kind="sparse").kind == "sparse"
    assert topo.encode(filt, kind="conv", h=6, w=6).kind == "conv"
    assert topo.encode(None, kind="pool", h=6, w=6, c=2, k=2).kind == "pool"
    # kind inference: 4-d -> conv needs h/w so stays explicit; 2-d arrays
    # pick fc vs sparse by zero fraction; EncodedTopology -> skip
    assert topo.encode(dense).kind == "fc"
    assert topo.encode(sparse).kind == "sparse"
    sk = topo.encode(topo.encode(dense), delay=1)
    assert sk.kind == "skip" and sk.meta["delay"] == 1


def test_encode_wrappers_equal_registry_path(rng):
    w = rng.standard_normal((10, 8)).astype(np.float32)
    a, b = topo.encode_fc(w, n_cores=2), topo.encode(w, kind="fc", n_cores=2)
    np.testing.assert_array_equal(a.dense_equivalent(), b.dense_equivalent())
    assert a.storage_bits() == b.storage_bits()


def test_register_encoding_duplicate_raises():
    import pytest

    with pytest.raises(ValueError, match="override=True"):
        topo.register_encoding("fc", lambda obj, **kw: None)
    # unknown kind names the registry contents
    with pytest.raises(KeyError, match="fc"):
        topo.encode(None, kind="no_such_kind")
    # override + custom kind round-trips through encode()
    marker = object()
    topo.register_encoding("test_kind", lambda obj, **kw: marker)
    try:
        assert topo.encode(None, kind="test_kind") is marker
        topo.register_encoding("test_kind", lambda obj, **kw: obj,
                               override=True)
        assert topo.encode("x", kind="test_kind") == "x"
    finally:
        topo.ENCODING_REGISTRY.pop("test_kind", None)


# ---------------------------------------------------------------------------
# hypothesis round-trips: propagate() == dense map on dense_equivalent()
# ---------------------------------------------------------------------------


from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro import analysis  # noqa: E402


def _rt(enc, n_pre, seed=0):
    rng = np.random.default_rng(seed)
    spikes = (rng.random(n_pre) < 0.4).astype(np.float32)
    np.testing.assert_allclose(enc.propagate(spikes),
                               spikes @ enc.dense_equivalent(),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=37),
       st.integers(min_value=1, max_value=23),
       st.integers(min_value=1, max_value=5))
def test_fc_roundtrip_property(n_pre, n_post, n_cores):
    rng = np.random.default_rng(n_pre * 100 + n_post)
    w = rng.standard_normal((n_pre, n_post)).astype(np.float32)
    enc = topo.encode(w, kind="fc", n_cores=n_cores)
    _rt(enc, n_pre)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=30),
       st.integers(min_value=1, max_value=25),
       st.sampled_from([0.0, 0.02, 0.3, 1.0]),
       st.sampled_from([0, 1]))
def test_sparse_roundtrip_property(n_pre, n_post, density, ie_type):
    rng = np.random.default_rng(n_pre + 31 * n_post)
    dense = rng.standard_normal((n_pre, n_post)).astype(np.float32)
    dense[rng.random((n_pre, n_post)) >= density] = 0.0
    enc = topo.encode(dense, kind="sparse", ie_type=ie_type)
    _rt(enc, n_pre)
    assert not analysis.check_topology(enc)
    # sparse_coo builds the same map from explicit triples
    pre, post = np.nonzero(dense)
    coo = topo.encode((pre, post, dense[pre, post]), kind="sparse_coo",
                      n_pre=n_pre, n_post=n_post)
    np.testing.assert_allclose(coo.dense_equivalent(), dense,
                               rtol=1e-5, atol=1e-5)
    _rt(coo, n_pre)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=3, max_value=9),
       st.integers(min_value=3, max_value=8),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=1, max_value=3),
       st.sampled_from([(1, 0), (1, 1), (2, 0), (2, 1)]))
def test_conv_roundtrip_property(h, w, c_in, c_out, stride_pad):
    stride, pad = stride_pad
    k = 3
    if (h + 2 * pad - k) < 0 or (w + 2 * pad - k) < 0:
        return  # kernel larger than padded input: not a valid conv
    rng = np.random.default_rng(h * 10 + w)
    filt = rng.standard_normal((c_out, c_in, k, k)).astype(np.float32)
    enc = topo.encode(filt, kind="conv", h=h, w=w, stride=stride, pad=pad)
    _rt(enc, enc.n_pre, seed=h)
    assert not analysis.check_topology(enc)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=2, max_value=9),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=2, max_value=3))
def test_pool_roundtrip_property(h, w, c, k):
    """Includes non-divisible shapes: edge positions in partial windows
    must contribute nothing (empty IEs), not corrupt neighbours."""
    if h < k or w < k:
        return
    enc = topo.encode(None, kind="pool", h=h, w=w, c=c, k=k)
    _rt(enc, enc.n_pre, seed=w)
    assert not analysis.check_topology(enc)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=15), st.integers(min_value=0,
                                                           max_value=15))
def test_skip_roundtrip_property(n_pre, delay):
    rng = np.random.default_rng(n_pre)
    dense = rng.standard_normal((n_pre, 7)).astype(np.float32)
    dense[rng.random((n_pre, 7)) >= 0.3] = 0.0
    enc = topo.encode(topo.encode(dense, kind="sparse"), kind="skip",
                      delay=delay)
    _rt(enc, n_pre, seed=delay)
    assert enc.meta["delay"] == delay and enc.kind == "skip"


def test_storage_beats_baseline_at_scale(rng):
    """The compression claims hold where they are made — real layer
    sizes, where per-row DE headers amortize (tiny property-test shapes
    legitimately do not beat the unrolled baseline)."""
    dense = rng.standard_normal((256, 256)).astype(np.float32)
    dense[rng.random((256, 256)) > 0.3] = 0.0
    sp = topo.encode(dense, kind="sparse", ie_type=0)
    assert sp.storage_bits() + sp.meta["extra_bits"] < sp.baseline_bits()
    conv = topo.encode(rng.standard_normal((64, 32, 3, 3)).astype(
        np.float32), kind="conv", h=16, w=16, pad=1)
    assert conv.storage_bits() < conv.baseline_bits()
    # pool: the IT compression is the claim — fan-in IEs are per
    # single-channel position; the per-neuron fan-out DT exists in every
    # scheme and is not what the unrolled baseline prices
    pool = topo.encode(None, kind="pool", h=16, w=16, c=32, k=2)
    assert pool.fan_in_bits() < pool.baseline_bits()
