"""Grouped-MoE routing invariants (hypothesis) — the dispatch tensor is a
materialized fan-out table (DESIGN.md §6), so table semantics must hold:
every surviving token lands in exactly one slot of each chosen expert, and
combine weights are the renormalized router gates."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.moe import moe_init, route


def _cfg(E, K, cap=8.0, group=64):
    return get_smoke_config("olmoe-1b-7b").replace(
        n_experts=E, top_k=K, capacity_factor=cap, moe_group=group,
        dtype="float32")


@given(st.integers(2, 8), st.integers(1, 3), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_route_dispatch_is_permutation_like(E, K, seed):
    K = min(K, E)
    cfg = _cfg(E, K)
    key = jax.random.PRNGKey(seed)
    params = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    dispatch, combine, aux = route(params, x, cfg)
    # ample capacity: every token occupies exactly K (expert, slot) cells
    per_token = jnp.sum(dispatch, axis=(2, 3))
    np.testing.assert_allclose(np.asarray(per_token), K, atol=1e-5)
    # each (expert, slot) holds at most one token
    per_slot = jnp.sum(dispatch, axis=1)
    assert float(jnp.max(per_slot)) <= 1.0 + 1e-5
    # combine weights sum to ~1 per token (renormalized top-k gates)
    gates = jnp.sum(combine, axis=(2, 3))
    np.testing.assert_allclose(np.asarray(gates), 1.0, atol=1e-4)


def test_route_respects_capacity():
    cfg = _cfg(E=4, K=2, cap=0.25, group=64)   # tiny capacity -> drops
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg)
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    dispatch, _, _ = route(params, x, cfg)
    C = dispatch.shape[-1]
    per_slot = jnp.sum(dispatch, axis=1)
    assert float(jnp.max(per_slot)) <= 1.0 + 1e-5
    assert float(jnp.sum(dispatch)) <= 4 * C + 1e-5   # bounded by capacity


def test_grouped_equals_ungrouped_when_one_group():
    """moe_group >= tokens reduces to a single group — same routing."""
    cfg1 = _cfg(E=4, K=2, group=64)
    cfg2 = _cfg(E=4, K=2, group=1 << 20)
    key = jax.random.PRNGKey(3)
    params = moe_init(key, cfg1)
    from repro.models.moe import moe_layer
    x = jax.random.normal(key, (2, 32, cfg1.d_model))
    y1, _ = moe_layer(params, x, cfg1)
    y2, _ = moe_layer(params, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
