"""Minimal, deterministic stand-in for `hypothesis`, used ONLY when the
real package is not installed (this container bakes the JAX toolchain but
not dev extras; CI installs real hypothesis from requirements-dev.txt).

`tests/conftest.py` installs this module into `sys.modules["hypothesis"]`
before collection, so `from hypothesis import given, settings, strategies`
works unchanged. Coverage semantics: each `@given` test runs
`max_examples` times with draws that visit the strategy's boundary values
first (min, max, midpoint / min_size, max_size) and then deterministic
pseudo-random interiors seeded by the test's qualified name — no shrinking,
no database, but reproducible across runs and processes.

Only the strategy surface this repo uses is implemented: `integers`,
`floats(allow_nan=)`, `lists(min_size=, max_size=)`, `booleans`,
`sampled_from`.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib


class _Strategy:
    """A strategy draws example #i deterministically from an rng."""

    def __init__(self, draw):
        self._draw = draw

    def example_at(self, rng: random.Random, i: int):
        return self._draw(rng, i)

    def map(self, fn):
        return _Strategy(lambda rng, i: fn(self._draw(rng, i)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng, i):
            for _ in range(_tries):
                v = self._draw(rng, i)
                if pred(v):
                    return v
                i = None  # fall back to random draws after the edge miss
            raise ValueError("filter predicate rejected every draw")
        return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    edges = (min_value, max_value, (min_value + max_value) // 2)

    def draw(rng, i):
        if i is not None and i < len(edges):
            return edges[i]
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite here
    edges = (min_value, max_value, 0.5 * (min_value + max_value))

    def draw(rng, i):
        if i is not None and i < len(edges):
            return edges[i]
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def booleans() -> _Strategy:
    return _Strategy(lambda rng, i: (i % 2 == 0) if i is not None and i < 2
                     else rng.random() < 0.5)


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng, i: options[i % len(options)]
                     if i is not None and i < len(options)
                     else rng.choice(options))


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            size = min_size
        elif i == 1:
            size = max_size
        else:
            size = rng.randint(min_size, max_size)
        # element edge-draws only for the first couple of examples; interiors
        # otherwise, so lists are not all-constant
        return [elements.example_at(rng, i if i is not None and i < 2 and
                                    j == 0 else None)
                for j in range(size)]
    return _Strategy(draw)


class settings:
    """Decorator recording run parameters; `deadline`/database are ignored."""

    def __init__(self, max_examples: int = 10, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*strategies, **kw_strategies):
    if kw_strategies:
        raise NotImplementedError("stub @given supports positional "
                                  "strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            cfg = getattr(wrapper, "_stub_settings", None)
            n = cfg.max_examples if cfg else 10
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                vals = [s.example_at(rng, i) for s in strategies]
                try:
                    fn(*fixture_args, *vals, **fixture_kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__}: falsified on example #{i} "
                        f"args={vals!r}") from e
        # hide the strategy-filled params from pytest's fixture resolution
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(params[:-len(strategies)]
                                                  if strategies else params)
        del wrapper.__wrapped__
        return wrapper
    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.lists = lists

HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                    filter_too_much="filter_too_much",
                                    data_too_large="data_too_large")

__all__ = ["given", "settings", "strategies", "HealthCheck"]
__version__ = "0.0.0-repro-stub"
