"""Fault-injection harness tests: spec parsing, determinism, jit-safety,
and the behavioral signature of every fault kind (repro.core.faults)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults, plan
from tests._faults import dh_net, spikes


def run_net(x=None, **kw):
    nodes, params = dh_net()
    if x is None:
        x = spikes(jax.random.PRNGKey(1))
    return plan.run(nodes, params, x, **kw)


# ---------------------------------------------------------------------------
# spec grammar + resolution
# ---------------------------------------------------------------------------


def test_parse_spec():
    fs = faults.parse("drop_blocks:p=0.1,seed=3; dead_rows:frac=0.2,mode=stuck")
    assert [f.kind for f in fs] == ["drop_blocks", "dead_rows"]
    assert fs[0].getf("p", 0.0) == pytest.approx(0.1)
    assert fs[0].geti("seed", 0) == 3
    assert fs[1].get("mode") == "stuck"


def test_parse_rejects_unknown_kind_and_bad_param():
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.parse("cosmic_ray:p=1")
    with pytest.raises(ValueError, match="not key=value"):
        faults.parse("drop_blocks:p")


def test_env_spec_activates(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "bitflip:frac=0.5,seed=1")
    assert [f.kind for f in faults.active()] == ["bitflip"]
    monkeypatch.setenv("REPRO_FAULTS", "")
    assert faults.active() == ()


def test_inject_overrides_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "bitflip:frac=0.5")
    with faults.inject("dead_rows:frac=0.1"):
        assert [f.kind for f in faults.active()] == ["dead_rows"]
        with faults.inject(""):        # chaos-CI escape hatch: clean world
            assert faults.active() == ()
    assert [f.kind for f in faults.active()] == ["bitflip"]


# ---------------------------------------------------------------------------
# data faults: determinism + signatures
# ---------------------------------------------------------------------------


def test_drop_blocks_zeroes_tiles_deterministically():
    x = jnp.ones((16, 2, 64))
    with faults.inject("drop_blocks:p=0.5,bt=4,bn=16,seed=7"):
        a = faults.perturb_input(x)
        b = faults.perturb_input(x)
    np.testing.assert_array_equal(a, b)
    assert float(a.sum()) < float(x.sum())          # something was dropped
    # drops are whole (bt x bn) tiles: each tile is all-kept or all-zero
    tiles = np.asarray(a).reshape(4, 4, 2, 4, 16).transpose(0, 3, 2, 1, 4)
    per_tile = tiles.reshape(16, -1).sum(axis=1)
    assert set(np.unique(per_tile)).issubset({0.0, 2 * 4 * 16})


def test_dead_rows_masks_only_named_node():
    out = jnp.ones((5, 3, 40))
    with faults.inject("dead_rows:frac=0.4,node=hidden,seed=2"):
        hit = faults.perturb_output("hidden", out)
        other = faults.perturb_output("readout", out)
    assert float(hit.sum()) < float(out.sum())
    np.testing.assert_array_equal(other, out)
    # the mask is per-neuron and time-independent: dead columns are dead
    # at every timestep (the property that makes engines bit-identical)
    col_sums = np.asarray(hit).sum(axis=(0, 1))
    assert set(np.unique(col_sums)).issubset({0.0, 15.0})


def test_stuck_rows_force_ones():
    out = jnp.zeros((5, 3, 40))
    with faults.inject("dead_rows:frac=0.4,mode=stuck,seed=2"):
        hit = faults.perturb_output("hidden", out)
    col = np.asarray(hit).sum(axis=(0, 1))
    assert set(np.unique(col)).issubset({0.0, 15.0})
    assert float(hit.sum()) > 0


def test_weight_poisoning_targets_w_planes_only():
    params = {"hidden": {"w_input": jnp.ones((8, 8)),
                         "neuron": jnp.ones((8,)),
                         "bias": jnp.ones((8,))},
              "readout": {"w_hidden": jnp.ones((8, 4))}}
    with faults.inject("nan_weights:frac=0.3,seed=5"):
        p = faults.perturb_params(params)
    assert bool(jnp.isnan(p["hidden"]["w_input"]).any())
    assert bool(jnp.isnan(p["readout"]["w_hidden"]).any())
    assert not bool(jnp.isnan(p["hidden"]["neuron"]).any())
    assert not bool(jnp.isnan(p["hidden"]["bias"]).any())
    with faults.inject("bitflip:frac=0.3,seed=5"):
        q = faults.perturb_params(params)
    flipped = np.asarray(q["hidden"]["w_input"])
    assert set(np.unique(flipped)) == {-1.0, 1.0}    # sign flips only


def test_identity_when_inactive():
    x = jnp.ones((4, 2, 8))
    with faults.inject(""):
        assert faults.perturb_input(x) is x
        assert faults.perturb_output("n", x) is x
        p = {"n": {"w_x": x}}
        assert faults.perturb_params(p) is p


# ---------------------------------------------------------------------------
# through the engines: determinism, jit == eager, engine equivalence
# ---------------------------------------------------------------------------

SPEC = "drop_blocks:p=0.3,seed=3;dead_rows:frac=0.2,seed=5;bitflip:frac=0.01,seed=7"


def test_faults_change_the_run_and_are_deterministic():
    _, clean, _ = run_net()
    with faults.inject(SPEC):
        _, a, _ = run_net()
        _, b, _ = run_net()
    assert not np.array_equal(np.asarray(a), np.asarray(clean))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_faults_jit_matches_eager():
    nodes, params = dh_net()
    x = spikes(jax.random.PRNGKey(1))
    with faults.inject(SPEC):
        _, eager, _ = plan.run(nodes, params, x)
        jitted = jax.jit(lambda p, xx: plan.run(nodes, p, xx)[1])(params, x)
    # same masks, same math; tolerance covers associative-scan vs
    # sequential-fold fp32 reordering (see plan.CROSS_ENGINE_ATOL)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               atol=plan.CROSS_ENGINE_ATOL)


def test_faults_identical_across_engines(monkeypatch):
    """The fused plan and the per-step stepper must see the SAME injected
    world: masks depend only on (seed, site), never on engine internals."""
    nodes, params = dh_net()
    x = spikes(jax.random.PRNGKey(1))
    with faults.inject(SPEC):
        _, fused, _ = plan.run(nodes, params, x)
        monkeypatch.setenv("REPRO_SNN_ENGINE", "stepper")
        _, stepped, _ = plan.run(nodes, params, x)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(stepped),
                               atol=plan.CROSS_ENGINE_ATOL)


def test_compile_fail_is_deterministic_per_kernel():
    f = faults.parse("compile_fail:kernels=*,p=0.5,seed=1")[0]
    names = ("linrec", "lif", "spikemm", "attention", "stdp_seq")
    picks = {k: faults._fails(f, k) for k in names}
    assert picks == {k: faults._fails(f, k) for k in names}   # stable
    sure = faults.parse("compile_fail:kernels=*,p=1")[0]
    never = faults.parse("compile_fail:kernels=*,p=0")[0]
    assert all(faults._fails(sure, k) for k in names)
    assert not any(faults._fails(never, k) for k in names)


def test_compile_fail_targets_named_kernels():
    with faults.inject("compile_fail:kernels=lif|linrec"):
        with pytest.raises(faults.FaultInjectedError):
            faults.maybe_fail_compile("lif")
        faults.maybe_fail_compile("spikemm")      # untargeted: no raise


def test_vmem_limit_override_takes_min():
    with faults.inject("vmem_limit:mb=2;vmem_limit:mb=1"):
        assert faults.vmem_limit_override_bytes() == 1 * 2 ** 20
    with faults.inject(""):
        assert faults.vmem_limit_override_bytes() is None
