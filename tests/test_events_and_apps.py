"""INTEG/FIRE engine + the paper's three application models (§V-B3)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events
from repro.core.neuron import LIF
from repro.core.snn_layers import (BCIConfig, bci_finetune_fc, bci_forward,
                                   bci_init, ff_integrate, make_dhsnn_shd,
                                   make_srnn_ecg)


def test_engine_feedforward_equals_manual():
    """One hidden LIF layer driven by input spikes must equal a hand-rolled
    loop (INTEG = locacc, FIRE = lif)."""
    key = jax.random.PRNGKey(0)
    T, B, n_in, n_h = 6, 2, 5, 4
    w = jax.random.normal(key, (n_in, n_h)) * 0.8
    x = (jax.random.uniform(jax.random.fold_in(key, 1), (T, B, n_in)) < 0.4
         ).astype(jnp.float32)
    nodes = [events.LayerNode("h", LIF(tau=0.9), ff_integrate,
                              inputs=("input",), out_dim=n_h)]
    params = {"h": {"w_input": w}}
    _, outs, _ = events.run(nodes, params, x)

    v = jnp.zeros((B, n_h))
    for t in range(T):
        v = 0.9 * v + x[t] @ w
        s = (v >= 1.0).astype(jnp.float32)
        v = v * (1 - s)
        np.testing.assert_allclose(outs[t], s)


def test_engine_recurrent_uses_previous_timestep():
    """'self' input must deliver t-1 spikes (not same-step)."""
    n_h = 3
    w_in = jnp.eye(n_h) * 2.0          # input always fires the neuron
    w_self = jnp.full((n_h, n_h), -5.0)
    nodes = [events.LayerNode("h", LIF(tau=0.0), ff_integrate,
                              inputs=("input", "self"), out_dim=n_h)]
    params = {"h": {"w_input": w_in, "w_self": w_self}}
    x = jnp.ones((3, 1, n_h))
    _, outs, _ = events.run(nodes, params, x)
    # t=0: fires (no recurrence yet); t=1: inhibited by t=0 spikes
    np.testing.assert_allclose(outs[0], 1.0)
    np.testing.assert_allclose(outs[1], 0.0)
    np.testing.assert_allclose(outs[2], 1.0)


def test_engine_skip_connection_delay():
    """'src@d' must deliver spikes delayed by d steps (delayed-fire, Fig 8c)."""
    nodes = [
        events.LayerNode("a", LIF(tau=0.0, v_th=0.5), ff_integrate,
                         inputs=("input",), out_dim=1),
        events.LayerNode("b", LIF(tau=0.0, v_th=0.5), ff_integrate,
                         inputs=("a@2",), out_dim=1),
    ]
    params = {"a": {"w_input": jnp.ones((1, 1))},
              "b": {"w_a": jnp.ones((1, 1))}}
    x = jnp.zeros((6, 1, 1)).at[0].set(1.0)       # single event at t=0
    _, outs, recs = events.run(nodes, params, x, record=("a", "b"))
    a_spikes = np.asarray(recs["a"][:, 0, 0])
    b_spikes = np.asarray(recs["b"][:, 0, 0])
    assert a_spikes[0] == 1.0
    assert b_spikes[2] == 1.0 and b_spikes[:2].sum() == 0   # delayed 2 steps


# ---------------------------------------------------------------------------
# applications
# ---------------------------------------------------------------------------


def _train_a_bit(loss_fn, params, steps=30, lr=0.5):
    losses = []
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for i in range(steps):
        loss, g = grad_fn(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(gg))
                          for gg in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 1.0 / (gn + 1e-9))      # clipped SGD
        params = jax.tree.map(
            lambda p, gg: p - lr * sc * gg if gg is not None else p,
            params, g)
        losses.append(float(loss))
    return params, losses


def test_srnn_ecg_learns_both_variants():
    """Both the heterogeneous (ALIF) model and its homogeneous ablation must
    train to materially lower loss. NOTE: the paper's het>hom accuracy
    ordering (Fig. 15a) is a claim about real QTDB recordings; on the
    synthetic generator the ordering is seed-dependent, so the benchmark
    (bench_applications) reports both numbers and this test asserts
    learnability only."""
    from repro.data.spikes import gen_ecg_qtdb
    spikes, labels = gen_ecg_qtdb(8, T=160)
    x = jnp.asarray(spikes.transpose(1, 0, 2))     # (T, B, 4)
    y = jnp.asarray(labels.T)                      # (T, B)

    def make_loss(nodes, params0):
        def loss(params):
            _, outs, _ = events.run(nodes, params, x)   # (T, B, 6)
            logp = jax.nn.log_softmax(outs, -1)
            return -jnp.mean(jnp.take_along_axis(logp, y[..., None], -1))
        return loss

    for het in (True, False):
        nodes, params = make_srnn_ecg(jax.random.PRNGKey(0),
                                      heterogeneous=het, n_hidden=32)
        loss = make_loss(nodes, params)
        _, losses = _train_a_bit(loss, params, steps=60, lr=0.1)
        assert losses[-1] < 0.7 * losses[0], \
            f"no learning (het={het}): {losses[0]} -> {losses[-1]}"


def test_dhsnn_shd_learns():
    from repro.data.spikes import gen_shd_spikes
    spikes, labels = gen_shd_spikes(16, T=40)
    x = jnp.asarray(spikes.transpose(1, 0, 2))
    y = jnp.asarray(labels)
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(1), n_hidden=32)

    def loss(params):
        _, outs, _ = events.run(nodes, params, x)
        logits = jnp.mean(outs, axis=0)            # time-averaged membrane
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])

    _, losses = _train_a_bit(loss, params, steps=25, lr=0.3)
    assert losses[-1] < losses[0] * 0.9


def test_bci_cross_day_finetune_recovers_accuracy():
    """The paper's on-chip learning demo: train day 0, accuracy drops on a
    drifted day, 32-sample FC-only fine-tune recovers it."""
    from repro.data.spikes import gen_bci_trials
    cfg = BCIConfig(n_channels=32, n_steps=20, n_paths=4, d_path=8)
    params = bci_init(jax.random.PRNGKey(0), cfg)

    x0, y0 = gen_bci_trials(96, day=0, n_channels=32, n_bins=20)
    x0, y0 = jnp.asarray(x0), jnp.asarray(y0)

    def loss(params):
        logits, _ = bci_forward(params, x0, cfg)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y0)), y0])

    params, losses = _train_a_bit(loss, params, steps=60, lr=0.05)
    assert losses[-1] < losses[0] * 0.8

    def acc(params, x, y):
        logits, _ = bci_forward(params, jnp.asarray(x), cfg)
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))

    xt, yt = gen_bci_trials(64, day=3, n_channels=32, n_bins=20, seed=5)
    before = acc(params, xt, yt)
    xf, yf = gen_bci_trials(32, day=3, n_channels=32, n_bins=20, seed=9)
    tuned, _ = bci_finetune_fc(params, jnp.asarray(xf), jnp.asarray(yf),
                               cfg, lr=0.05, steps=25)
    after = acc(tuned, xt, yt)
    assert after >= before, (before, after)
