"""Plasticity tests: STDP causality properties (hypothesis) + the
accumulated-spike backprop identity (paper §IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.plasticity import (STDPConfig, accumulated_spike_fc,
                                   fuse_bn1d_fc, stdp_init, stdp_run,
                                   stdp_step)


def _pair_run(dt_pre: int, dt_post: int, T: int = 20):
    """One pre spike at dt_pre, one post spike at dt_post."""
    pre = np.zeros((T, 1, 1), np.float32)
    post = np.zeros((T, 1, 1), np.float32)
    pre[dt_pre, 0, 0] = 1.0
    post[dt_post, 0, 0] = 1.0
    w = jnp.zeros((1, 1))
    return float(stdp_run(STDPConfig(), w, jnp.asarray(pre),
                          jnp.asarray(post))[0, 0])


def test_stdp_causal_potentiates():
    assert _pair_run(3, 6) > 0          # pre before post: LTP


def test_stdp_acausal_depresses():
    assert _pair_run(6, 3) < 0          # post before pre: LTD


def test_stdp_window_decays():
    """|dw| shrinks as |dt| grows (exponential STDP window)."""
    close = abs(_pair_run(5, 7))
    far = abs(_pair_run(5, 15))
    assert close > far > 0


@given(st.integers(0, 9), st.integers(0, 9))
@settings(max_examples=20, deadline=None)
def test_stdp_sign_matches_timing(t_pre, t_post):
    if t_pre == t_post:
        return
    dw = _pair_run(t_pre, t_post, T=12)
    if t_pre < t_post:
        assert dw > 0
    else:
        assert dw < 0


def test_stdp_bounds_respected():
    cfg = STDPConfig(w_min=-0.5, w_max=0.5, a_plus=10.0, a_minus=10.0)
    rng = np.random.default_rng(0)
    pre = (rng.random((50, 2, 8)) < 0.5).astype(np.float32)
    post = (rng.random((50, 2, 4)) < 0.5).astype(np.float32)
    w = stdp_run(cfg, jnp.zeros((8, 4)), jnp.asarray(pre), jnp.asarray(post))
    assert float(jnp.max(w)) <= 0.5 and float(jnp.min(w)) >= -0.5


# ---------------------------------------------------------------------------
# accumulated-spike backprop
# ---------------------------------------------------------------------------


def test_accumulated_fc_forward_identity(rng):
    """Forward == sum_t (s_t @ W + b): lossless for time-summed readouts."""
    s = (rng.random((7, 3, 10)) < 0.3).astype(np.float32)
    w = rng.standard_normal((10, 4)).astype(np.float32)
    b = rng.standard_normal(4).astype(np.float32)
    out = accumulated_spike_fc(jnp.asarray(s), jnp.asarray(w), jnp.asarray(b))
    ref = sum(s[t] @ w + b for t in range(7))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_accumulated_fc_weight_grad_exact(rng):
    """dL/dW through the accumulated path == full BPTT dL/dW (paper's claim
    that the approximation is exact for the readout weights)."""
    s = (rng.random((7, 3, 10)) < 0.3).astype(np.float32)
    w = rng.standard_normal((10, 4)).astype(np.float32)
    b = np.zeros(4, np.float32)
    y = rng.integers(0, 4, 3)

    def loss_acc(w):
        logits = accumulated_spike_fc(jnp.asarray(s), w, jnp.asarray(b))
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(3), y])

    def loss_full(w):
        logits = sum(jnp.asarray(s[t]) @ w + jnp.asarray(b) for t in range(7))
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(3), y])

    g1 = jax.grad(loss_acc)(jnp.asarray(w))
    g2 = jax.grad(loss_full)(jnp.asarray(w))
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


def test_accumulated_fc_memory_saving():
    """The VJP residual stores (B, N), not (T, B, N)."""
    s = jnp.ones((100, 2, 16))
    w = jnp.ones((16, 4))
    b = jnp.zeros(4)
    _, vjp_fn = jax.vjp(accumulated_spike_fc, s, w, b)
    res_sizes = [x.size for x in jax.tree.leaves(vjp_fn)
                 if hasattr(x, "size")]
    assert max(res_sizes) <= 2 * 16 + 16 * 4   # acc + w, no (T,B,N) history


def test_bn1d_fc_fusion(rng):
    x = rng.standard_normal((5, 8)).astype(np.float32)
    gamma = rng.standard_normal(8).astype(np.float32)
    beta = rng.standard_normal(8).astype(np.float32)
    mean = rng.standard_normal(8).astype(np.float32)
    var = rng.random(8).astype(np.float32) + 0.5
    w = rng.standard_normal((8, 3)).astype(np.float32)
    b = rng.standard_normal(3).astype(np.float32)
    ref = ((x - mean) / np.sqrt(var + 1e-5) * gamma + beta) @ w + b
    wf, bf = fuse_bn1d_fc(*map(jnp.asarray, (gamma, beta, mean, var)),
                          1e-5, jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(x @ np.asarray(wf) + np.asarray(bf), ref,
                               rtol=1e-4, atol=1e-4)
