"""Plasticity tests: STDP causality properties (hypothesis), the
declarative SynapseProgram IR (rule factories vs hand references,
validation, registry), and the accumulated-spike backprop identity
(paper §IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.neuron import Decay
from repro.core.plasticity import (STDPConfig, SynapseProgram, TraceVar,
                                   UpdateTerm, accumulated_spike,
                                   accumulated_spike_fc, fuse_bn1d_fc,
                                   make_synapse, pair_stdp, register_synapse,
                                   reward_stdp, stdp_init, stdp_run,
                                   stdp_step, synapse_init, synapse_run,
                                   synapse_step, triplet_stdp,
                                   validate_synapse_program)


def _pair_run(dt_pre: int, dt_post: int, T: int = 20):
    """One pre spike at dt_pre, one post spike at dt_post."""
    pre = np.zeros((T, 1, 1), np.float32)
    post = np.zeros((T, 1, 1), np.float32)
    pre[dt_pre, 0, 0] = 1.0
    post[dt_post, 0, 0] = 1.0
    w = jnp.zeros((1, 1))
    return float(stdp_run(STDPConfig(), w, jnp.asarray(pre),
                          jnp.asarray(post))[0, 0])


def test_stdp_causal_potentiates():
    assert _pair_run(3, 6) > 0          # pre before post: LTP


def test_stdp_acausal_depresses():
    assert _pair_run(6, 3) < 0          # post before pre: LTD


def test_stdp_window_decays():
    """|dw| shrinks as |dt| grows (exponential STDP window)."""
    close = abs(_pair_run(5, 7))
    far = abs(_pair_run(5, 15))
    assert close > far > 0


@given(st.integers(0, 9), st.integers(0, 9))
@settings(max_examples=20, deadline=None)
def test_stdp_sign_matches_timing(t_pre, t_post):
    if t_pre == t_post:
        return
    dw = _pair_run(t_pre, t_post, T=12)
    if t_pre < t_post:
        assert dw > 0
    else:
        assert dw < 0


def test_stdp_bounds_respected():
    cfg = STDPConfig(w_min=-0.5, w_max=0.5, a_plus=10.0, a_minus=10.0)
    rng = np.random.default_rng(0)
    pre = (rng.random((50, 2, 8)) < 0.5).astype(np.float32)
    post = (rng.random((50, 2, 4)) < 0.5).astype(np.float32)
    w = stdp_run(cfg, jnp.zeros((8, 4)), jnp.asarray(pre), jnp.asarray(post))
    assert float(jnp.max(w)) <= 0.5 and float(jnp.min(w)) >= -0.5


def _trains(seed, T=12, B=3, M=8, N=5, rate=0.4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pre = (jax.random.uniform(ks[0], (T, B, M)) < rate).astype(jnp.float32)
    post = (jax.random.uniform(ks[1], (T, B, N)) < rate).astype(jnp.float32)
    w = 0.3 * jax.random.normal(ks[2], (M, N), jnp.float32)
    return pre, post, w


def test_stdp_run_use_kernel_matches_reference():
    """Regression: `use_kernel` used to be silently dropped by the scan
    body, so the fused Pallas kernel never ran. Now it must run — and agree
    with the einsum reference."""
    pre, post, w = _trains(0, T=6, B=2, M=8, N=6)
    cfg = STDPConfig()
    w_ref = stdp_run(cfg, w, pre, post, use_kernel=False)
    w_ker = stdp_run(cfg, w, pre, post, use_kernel=True)
    np.testing.assert_allclose(np.asarray(w_ker), np.asarray(w_ref),
                               atol=1e-5, rtol=1e-5)
    assert float(jnp.linalg.norm(w_ref - w)) > 0     # something was learned


# ---------------------------------------------------------------------------
# the SynapseProgram IR: factories vs hand references
# ---------------------------------------------------------------------------


def test_pair_stdp_program_matches_legacy_loop():
    """The pair_stdp factory's per-step interpretation must reproduce the
    hand-coded stdp_step/stdp_run trajectory exactly (weights AND traces)."""
    pre, post, w = _trains(1)
    cfg = STDPConfig()
    prog = cfg.program
    syn = synapse_run(prog, w, pre, post)
    w_legacy = stdp_run(cfg, w, pre, post)
    np.testing.assert_allclose(np.asarray(syn["w"]), np.asarray(w_legacy),
                               atol=1e-6)
    # traces too: replay the legacy loop and compare the finals
    traces = stdp_init(w.shape[0], w.shape[1], pre.shape[1])
    ww = w
    for t in range(pre.shape[0]):
        traces, ww = stdp_step(cfg, traces, ww, pre[t], post[t])
    np.testing.assert_allclose(np.asarray(syn["x_pre"]),
                               np.asarray(traces["x_pre"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(syn["x_post"]),
                               np.asarray(traces["x_post"]), atol=1e-6)


def test_triplet_stdp_slow_traces_read_previous_value():
    """Triplet terms gate on the slow traces' pre-update values
    (update="after"): a manual Pfister-Gerstner step must agree."""
    prog = triplet_stdp(w_min=-5.0, w_max=5.0)
    pre, post, w = _trains(2, T=10, B=2, M=6, N=4)
    syn = synapse_init(prog, w, pre.shape[1])
    tr = {k: syn[k] for k in ("r1", "r2", "o1", "o2")}
    ww = w
    taus = {t.name: t.decay.value for t in prog.traces}
    amps = [t.amp for t in prog.terms]
    for t in range(pre.shape[0]):
        r1 = taus["r1"] * tr["r1"] + pre[t]
        o1 = taus["o1"] * tr["o1"] + post[t]
        # slow traces are READ old, then updated
        dw = (amps[0] * jnp.einsum("bi,bj->ij", r1, post[t])
              + amps[1] * jnp.einsum("bi,bj->ij", r1, post[t] * tr["o2"])
              + amps[2] * jnp.einsum("bi,bj->ij", pre[t], o1)
              + amps[3] * jnp.einsum("bi,bj->ij", pre[t] * tr["r2"], o1))
        ww = jnp.clip(ww + dw, prog.w_min, prog.w_max)
        tr = {"r1": r1, "o1": o1,
              "r2": taus["r2"] * tr["r2"] + pre[t],
              "o2": taus["o2"] * tr["o2"] + post[t]}
    syn = synapse_run(prog, w, pre, post)
    np.testing.assert_allclose(np.asarray(syn["w"]), np.asarray(ww),
                               atol=1e-5)
    for k in tr:
        np.testing.assert_allclose(np.asarray(syn[k]), np.asarray(tr[k]),
                                   atol=1e-5)


def test_reward_stdp_gated_by_modulator():
    """No reward -> frozen weights; constant unit reward -> exactly pair
    STDP; reward scales the update linearly."""
    pre, post, w = _trains(3)
    T = pre.shape[0]
    prog = reward_stdp()
    frozen = synapse_run(prog, w, pre, post)            # mod=None
    np.testing.assert_allclose(np.asarray(frozen["w"]), np.asarray(w))
    ones = synapse_run(prog, w, pre, post, mod=jnp.ones((T,)))
    pair = synapse_run(pair_stdp(), w, pre, post)
    np.testing.assert_allclose(np.asarray(ones["w"]), np.asarray(pair["w"]),
                               atol=1e-6)
    half = synapse_run(prog, w, pre, post, mod=0.5 * jnp.ones((T,)))
    # wide bounds -> linear regime: half reward gives half the update
    np.testing.assert_allclose(np.asarray(half["w"] - w),
                               0.5 * np.asarray(ones["w"] - w), atol=1e-5)


def test_accumulated_spike_rule_matches_closed_form():
    """Teaching signal on the final step only: the learned update must be
    exactly lr * (sum_t s_pre) (x) delta — the paper's accumulated-spike
    FC update, as a synapse program."""
    pre, post, w = _trains(4, T=9, B=2, M=7, N=3)
    lr = 0.05
    delta = jax.random.normal(jax.random.PRNGKey(9), (2, 3), jnp.float32)
    T = pre.shape[0]
    mod = jnp.zeros((T, 2, 3)).at[-1].set(delta)
    syn = synapse_run(accumulated_spike(lr=lr), w, pre, post, mod=mod)
    expect = w + lr * jnp.einsum("bi,bj->ij", jnp.sum(pre, 0), delta)
    np.testing.assert_allclose(np.asarray(syn["w"]), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_synapse_program_validation():
    ok = pair_stdp()
    assert validate_synapse_program(ok) is ok
    with pytest.raises(ValueError, match="reserved"):
        validate_synapse_program(SynapseProgram(
            traces=(TraceVar("mod", "pre", Decay("const", 0.9)),),
            terms=(UpdateTerm(0.1),)))
    with pytest.raises(ValueError, match="bad source"):
        validate_synapse_program(SynapseProgram(
            traces=(TraceVar("x", "sideways", Decay("const", 0.9)),),
            terms=(UpdateTerm(0.1),)))
    with pytest.raises(ValueError, match="at least one update term"):
        validate_synapse_program(SynapseProgram(traces=(), terms=()))
    with pytest.raises(ValueError, match="unknown factor"):
        validate_synapse_program(SynapseProgram(
            traces=(), terms=(UpdateTerm(0.1, pre=("ghost",)),)))
    with pytest.raises(ValueError, match="post-side"):
        validate_synapse_program(SynapseProgram(
            traces=(), terms=(UpdateTerm(0.1, pre=("mod",)),)))
    with pytest.raises(ValueError, match="reads a pre trace"):
        validate_synapse_program(SynapseProgram(
            traces=(TraceVar("x", "pre", Decay("const", 0.9)),),
            terms=(UpdateTerm(0.1, post=("x",)),)))
    with pytest.raises(ValueError, match="w_min"):
        validate_synapse_program(SynapseProgram(
            traces=(), terms=(UpdateTerm(0.1),), w_min=1.0, w_max=-1.0))


def test_synapse_registry_roundtrip_and_duplicates():
    made = make_synapse("pair_stdp", a_plus=0.02)
    assert made.terms[0].amp == 0.02
    with pytest.raises(KeyError):
        make_synapse("no_such_rule")
    with pytest.raises(ValueError, match="already registered"):
        register_synapse("pair_stdp", pair_stdp)
    # override is explicit and reversible
    register_synapse("pair_stdp", pair_stdp, override=True)
    for name in ("pair_stdp", "triplet_stdp", "reward_stdp",
                 "accumulated_spike"):
        validate_synapse_program(make_synapse(name))


def test_synapse_step_is_jit_and_scan_safe():
    prog = pair_stdp()
    pre, post, w = _trains(5, T=4)
    syn = synapse_init(prog, w, pre.shape[1])
    stepped = jax.jit(lambda s, a, b: synapse_step(prog, s, a, b))(
        syn, pre[0], post[0])
    assert set(stepped) == set(syn)
    assert stepped["w"].shape == w.shape


# ---------------------------------------------------------------------------
# accumulated-spike backprop
# ---------------------------------------------------------------------------


def test_accumulated_fc_forward_identity(rng):
    """Forward == sum_t (s_t @ W + b): lossless for time-summed readouts."""
    s = (rng.random((7, 3, 10)) < 0.3).astype(np.float32)
    w = rng.standard_normal((10, 4)).astype(np.float32)
    b = rng.standard_normal(4).astype(np.float32)
    out = accumulated_spike_fc(jnp.asarray(s), jnp.asarray(w), jnp.asarray(b))
    ref = sum(s[t] @ w + b for t in range(7))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_accumulated_fc_weight_grad_exact(rng):
    """dL/dW through the accumulated path == full BPTT dL/dW (paper's claim
    that the approximation is exact for the readout weights)."""
    s = (rng.random((7, 3, 10)) < 0.3).astype(np.float32)
    w = rng.standard_normal((10, 4)).astype(np.float32)
    b = np.zeros(4, np.float32)
    y = rng.integers(0, 4, 3)

    def loss_acc(w):
        logits = accumulated_spike_fc(jnp.asarray(s), w, jnp.asarray(b))
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(3), y])

    def loss_full(w):
        logits = sum(jnp.asarray(s[t]) @ w + jnp.asarray(b) for t in range(7))
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(3), y])

    g1 = jax.grad(loss_acc)(jnp.asarray(w))
    g2 = jax.grad(loss_full)(jnp.asarray(w))
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


def test_accumulated_fc_memory_saving():
    """The VJP residual stores (B, N), not (T, B, N)."""
    s = jnp.ones((100, 2, 16))
    w = jnp.ones((16, 4))
    b = jnp.zeros(4)
    _, vjp_fn = jax.vjp(accumulated_spike_fc, s, w, b)
    res_sizes = [x.size for x in jax.tree.leaves(vjp_fn)
                 if hasattr(x, "size")]
    assert max(res_sizes) <= 2 * 16 + 16 * 4   # acc + w, no (T,B,N) history


def test_bn1d_fc_fusion(rng):
    x = rng.standard_normal((5, 8)).astype(np.float32)
    gamma = rng.standard_normal(8).astype(np.float32)
    beta = rng.standard_normal(8).astype(np.float32)
    mean = rng.standard_normal(8).astype(np.float32)
    var = rng.random(8).astype(np.float32) + 0.5
    w = rng.standard_normal((8, 3)).astype(np.float32)
    b = rng.standard_normal(3).astype(np.float32)
    ref = ((x - mean) / np.sqrt(var + 1e-5) * gamma + beta) @ w + b
    wf, bf = fuse_bn1d_fc(*map(jnp.asarray, (gamma, beta, mean, var)),
                          1e-5, jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(x @ np.asarray(wf) + np.asarray(bf), ref,
                               rtol=1e-4, atol=1e-4)
