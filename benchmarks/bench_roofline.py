"""§Roofline reporter: renders the per-cell three-term table from the
experiments/ JSON records (produced by repro.roofline.run + launch.dryrun).

This benchmark only READS records — compiling the 40-cell sweep is the
launchers' job — so `python -m benchmarks.run` stays fast."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict

ROOFLINE_DIR = os.environ.get("REPRO_ROOFLINE_DIR", "experiments/roofline")
DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(d: str) -> Dict[str, Dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[os.path.basename(path)[:-5]] = rec
    return out


def run() -> Dict:
    print("=== §Roofline: per-cell three-term analysis (16x16 pod) ===")
    recs = load_records(ROOFLINE_DIR)
    if not recs:
        print(f"(no records in {ROOFLINE_DIR} — run "
              f"`python -m repro.roofline.run --all` first)")
        return {}
    ok = {k: r for k, r in recs.items() if r.get("status") == "ok"}
    print(f"{'cell':42s} {'C(ms)':>9s} {'M(ms)':>9s} {'X(ms)':>9s} "
          f"{'dom':>6s} {'useful':>7s} {'roofl%':>7s}")
    for k, r in sorted(ok.items()):
        print(f"{k:42s} {r['compute_s']*1e3:9.1f} {r['memory_s']*1e3:9.1f} "
              f"{r['collective_s']*1e3:9.1f} {r['dominant'][:6]:>6s} "
              f"{r['useful_ratio']:7.2f} {100*r['roofline_fraction']:7.2f}")
    skipped = {k: r for k, r in recs.items() if r.get("status") == "skipped"}
    for k, r in sorted(skipped.items()):
        print(f"{k:42s} SKIPPED: {r['reason'][:60]}")

    dr = load_records(DRYRUN_DIR)
    n_ok = sum(1 for r in dr.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in dr.values() if r.get("status") == "skipped")
    n_err = len(dr) - n_ok - n_skip
    print(f"--- dry-run: {n_ok} compiled ok, {n_skip} skipped, "
          f"{n_err} errors over {len(dr)} (cell x mesh) records ---")
    return {"roofline": ok, "dryrun_ok": n_ok, "dryrun_err": n_err}


if __name__ == "__main__":
    run()
